"""MIND x EMVB — the paper's technique on the assigned recsys architecture
where it directly applies (DESIGN.md §5: a MIND user IS a multi-vector query
with n_q = 4 interest capsules; candidate scoring IS late interaction).

    PYTHONPATH=src python examples/mind_emvb_retrieval.py

Trains a smoke MIND model in-batch, then serves retrieval over a 20k-item
corpus two ways: exact brute-force MaxSim vs the EMVB engine (bit-vector
prefilter with 4-bit stacked words + PQ late interaction), and reports
recall overlap + speedup.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, build_index, engine
from repro.models.recsys import mind
from repro.train import optimizer as opt_lib
from repro.train.trainer import Trainer, TrainerConfig

N_ITEMS = 20_000


def main() -> None:
    cfg = mind.MINDConfig(name="mind-demo", vocab_items=N_ITEMS,
                          embed_dim=64, n_interests=4, capsule_iters=3,
                          seq_len=32)
    key = jax.random.PRNGKey(0)
    params = mind.init_params(key, cfg)

    def make_batch(step: int):
        k = jax.random.PRNGKey(step)
        k1, k2 = jax.random.split(k)
        # popularity-skewed histories: users cluster around item neighborhoods
        anchor = jax.random.randint(k1, (64, 1), 0, N_ITEMS - 64)
        hist = anchor + jax.random.randint(k2, (64, cfg.seq_len), 0, 64)
        return {"hist_items": hist,
                "hist_valid": jnp.ones((64, cfg.seq_len), bool),
                "target_item": (anchor[:, 0] + 32) % N_ITEMS}

    print("training MIND (in-batch sampled softmax) ...")
    tr = Trainer(lambda p, b: mind.loss_fn(p, b, cfg),
                 opt_lib.make("adamw", lr=1e-2), make_batch,
                 TrainerConfig(log_every=20), params)
    out = tr.run(60)
    print(f"  final loss {out['log'][-1]['loss']:.4f}")
    params = tr.state.params

    # ---- the item corpus as a multi-vector index (1 token per item) -------
    items = np.asarray(params["item_emb"], np.float32)
    items = items / np.maximum(np.linalg.norm(items, axis=-1, keepdims=True),
                               1e-9)
    print("indexing 20k items (EMVB: centroids + PQ m=16) ...")
    index, _ = build_index(jax.random.PRNGKey(1), items[:, None, :],
                           np.ones(N_ITEMS, np.int32), n_centroids=512,
                           m=16, nbits=8, kmeans_iters=4)

    # ---- user interests = the multi-vector queries -------------------------
    batch = make_batch(999)
    interests = mind.user_interests(params, batch["hist_items"],
                                    batch["hist_valid"], cfg)   # (B, 4, D)
    q = np.asarray(interests)

    # exact brute force MaxSim (the baseline every ANN system is judged by)
    score_fn = jax.jit(mind.score_candidates)
    _ = score_fn(interests, jnp.asarray(items))
    t0 = time.perf_counter()
    exact = jax.block_until_ready(score_fn(interests, jnp.asarray(items)))
    t_exact = time.perf_counter() - t0
    exact_top = np.asarray(jax.lax.top_k(exact, 10)[1])

    # EMVB engine with n_q = 4 (the interest capsules)
    ecfg = EngineConfig(n_q=4, k=10, nprobe=32, th=0.3, th_r=None,
                        n_filter=4096, n_docs=1024)
    _ = engine.retrieve(index, q, ecfg)
    t0 = time.perf_counter()
    res = jax.block_until_ready(engine.retrieve(index, q, ecfg))
    t_emvb = time.perf_counter() - t0
    emvb_top = np.asarray(res.doc_ids)

    overlap = np.mean([len(set(a) & set(b)) / 10.0
                       for a, b in zip(exact_top, emvb_top)])
    # near-duplicate items (co-trained neighborhoods) make strict top-10
    # overlap tie-dominated; score regret is the tie-robust quality metric
    best10 = -np.sort(-exact, axis=1)[:, :10]
    exact_np = np.asarray(exact)
    regret = np.mean([exact_np[b][emvb_top[b]].mean() / best10[b].mean()
                      for b in range(len(q))])
    print(f"\nexact MaxSim : {t_exact / 64 * 1e3:6.2f} ms/user "
          "(20k items fit one matmul — EMVB pays off at corpus scale;"
          " see the emvb-msmarco dry-run cells)")
    print(f"EMVB engine  : {t_emvb / 64 * 1e3:6.2f} ms/user")
    print(f"top-10 overlap vs exact : {overlap * 100:.0f}%")
    print(f"score quality (EMVB top-10 / exact top-10): {regret * 100:.1f}%")


if __name__ == "__main__":
    main()
