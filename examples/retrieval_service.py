"""Serving-subsystem walkthrough — per-generation result caching + request
micro-batching over a streaming ShardedTimeline.

    PYTHONPATH=src python examples/retrieval_service.py

The demo:
  1. streams a corpus into a 3-generation timeline and stands up a
     ``RetrievalService`` over it;
  2. shows the cold -> warm transition on repeated queries (bit-exact vs
     the uncached ``retrieve_timeline``, at a fraction of the cost);
  3. micro-batches heterogeneous-length queries through submit/flush
     (PR 3's pad+mask machinery keeps each result equal to the unpadded
     query's);
  4. mutates the timeline — ``add_passages`` on the open generation, then
     ``new_generation`` — and watches the cache invalidate by fingerprint
     (old generations keep hitting; changed ones recompute);
  5. prints the metrics snapshot: hit rate, warm share, p50/p99 latency,
     cache bytes, timeline footprint;
  6. turns on observability (docs/OBSERVABILITY.md): scoped span tracing
     over a served batch, the per-phase ``explain_timeline`` funnel for
     one query, and a Prometheus exposition excerpt.
"""
import time

import jax
import numpy as np

from repro import obs
from repro.core import (EngineConfig, ShardedTimeline, build_index,
                        new_generation, retrieve_timeline)
from repro.data.synthetic import make_corpus
from repro.serving import RetrievalService


def main(n_docs: int = 2048, n_centroids: int = 512,
         n_queries: int = 64) -> None:
    """Sizes are parameters so the tier-1 examples smoke test
    (tests/test_examples.py) can run the same code on a tiny corpus."""
    corpus = make_corpus(0, n_docs=n_docs, cap=48, n_queries=n_queries)
    per = n_docs // 4                     # generation size
    # selection budgets clamp to the generation size on tiny corpora
    cfg = EngineConfig(k=10, n_filter=min(256, per), n_docs=min(64, per),
                       th=0.2, th_r=0.3)

    print("1) stream 3 generations and stand up the service ...")
    gen0, meta0 = build_index(
        jax.random.PRNGKey(0), corpus.doc_embs[:per], corpus.doc_lens[:per],
        n_centroids=n_centroids, m=16, nbits=8, kmeans_iters=4)
    timeline = ShardedTimeline.of((gen0, meta0))
    for g in range(1, 3):
        lo = g * per
        timeline = timeline.append(*new_generation(
            gen0, meta0, corpus.doc_embs[lo:lo + per],
            corpus.doc_lens[lo:lo + per]))
    service = RetrievalService(timeline, cfg)
    nq = min(16, n_queries - 2)
    queries = corpus.queries[:nq]

    print("2) cold -> warm on repeated queries ...")
    ref = retrieve_timeline(timeline, corpus.queries[:nq], cfg)
    t0 = time.perf_counter()
    cold = service.query(queries)
    t_cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm = service.query(queries)
    t_warm = time.perf_counter() - t0
    exact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for r in (cold, warm) for a, b in ((r.doc_ids, ref.doc_ids),
                                           (r.scores, ref.scores)))
    print(f"   cold {t_cold * 1e3:.0f}ms -> warm {t_warm * 1e3:.0f}ms "
          f"(x{t_cold / t_warm:.1f}); bit-exact vs retrieve_timeline "
          f"(ids AND scores, both passes): {exact}")

    print("3) micro-batch heterogeneous queries via submit/flush ...")
    qa = min(20, n_queries - 2)           # two queries past the warm set
    short = service.submit(corpus.queries[qa][:12])     # 12-term query
    full = service.submit(corpus.queries[qa + 1])       # all 32 terms
    service.flush()
    ref12 = retrieve_timeline(timeline, corpus.queries[qa:qa + 1, :12], cfg)
    print(f"   12-term ticket == unpadded-prefix retrieval: "
          f"{np.array_equal(short.result()[1], np.asarray(ref12.doc_ids)[0])}"
          f"; full-length ticket done: {full.done}")

    print("4) mutate: add_passages on the open generation, then freeze ...")
    h0 = service.cache.hits
    grow = 3 * per + per // 2             # grow by half a slice, then freeze
    service.add_passages(corpus.doc_embs[3 * per:grow],
                         corpus.doc_lens[3 * per:grow])
    service.query(queries)      # old gens hit, grown gen recomputed
    print(f"   after add_passages: {service.cache.hits - h0} cache hits "
          "(old generations), grown generation recomputed fresh")
    service.new_generation(corpus.doc_embs[grow:], corpus.doc_lens[grow:])
    service.query(queries)      # previously-open gen now caching too
    service.query(queries)
    print(f"   after new_generation: {len(service.timeline)} generations, "
          f"{service.timeline.n_docs} docs; newly frozen generation now "
          "cacheable")

    print("5) metrics snapshot ...")
    s = service.stats()
    print(f"   hit_rate={s['cache']['hit_rate']:.2f} "
          f"warm_fraction={s['warm_fraction']:.2f} "
          f"p50={s['latency']['p50_ms']:.1f}ms "
          f"p99={s['latency']['p99_ms']:.1f}ms")
    print(f"   cache={s['cache']['bytes'] / 1024:.1f}KiB "
          f"({s['cache']['entries']} partials), "
          f"timeline={s['timeline']['total_bytes'] / 2**20:.1f}MiB "
          f"({s['timeline']['bytes_per_embedding_actual']:.1f} B/emb actual "
          f"vs {s['timeline']['bytes_per_embedding']:.1f} paper constant)")

    print("6) observability: spans, explain funnel, exposition ...")
    with obs.tracing() as tracer:          # scoped: no-op outside the with
        service.query(queries)
    names = sorted({sp["name"] for sp in tracer.finished()})
    print(f"   {len(tracer.finished())} spans from one served batch: "
          + ", ".join(names))

    funnel = obs.explain.explain_timeline(
        service.timeline, queries[0], cfg)
    g0 = funnel.generations[0]
    print(f"   explain: {funnel.n_generations} generations, contributions "
          f"{[g.contribution for g in funnel.generations]} (sum = k = "
          f"{funnel.k}); gen0 funnel: {g0.funnel.candidates} candidates -> "
          f"{g0.funnel.n_filter_survivors} prefiltered -> "
          f"{g0.funnel.phase4_docs_scored} scored "
          f"(term fraction {g0.funnel.scored_term_fraction:.2f})")

    text = service.exposition()
    lines = [ln for ln in text.splitlines()
             if ln.startswith(("emvb_queries_total", "emvb_cache_hits",
                               "emvb_batch_latency_seconds{"))]
    print("   exposition excerpt (full text is service.exposition()):")
    for ln in lines:
        print(f"     {ln}")


if __name__ == "__main__":
    main()
