"""Quickstart — build an EMVB index over a synthetic corpus and retrieve.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's full pipeline on CPU in under a minute: synthetic corpus
with planted relevance -> k-means centroids + PQ residuals -> bit-vector
pre-filter -> centroid interaction -> PQ late interaction -> top-k; then the
PLAID baseline on the same index for comparison.
"""
import time

import jax
import numpy as np

from repro.core import EngineConfig, PlaidConfig, build_index
from repro.core import engine, plaid
from repro.data.synthetic import make_corpus, mrr_at_k, recall_at_k


def main() -> None:
    print("1) synthetic corpus with planted ground truth ...")
    corpus = make_corpus(0, n_docs=2048, cap=48, n_queries=64)

    print("2) building index (k-means centroids, PQ m=16, PLAID 2-bit) ...")
    t0 = time.time()
    index, meta = build_index(
        jax.random.PRNGKey(0), corpus.doc_embs, corpus.doc_lens,
        n_centroids=1024, m=16, nbits=8, plaid_b=2, kmeans_iters=4)
    print(f"   {meta.n_docs} docs / {meta.n_centroids} centroids "
          f"in {time.time() - t0:.1f}s")

    queries = np.asarray(corpus.queries)
    # th calibrated to this corpus's score distribution (benchmarks/common.py)
    cfg = EngineConfig(k=10, n_filter=512, n_docs=64, th=0.2, th_r=0.3)

    print("3) EMVB retrieval (bit-vector prefilter + PQ late interaction) ...")
    res = engine.retrieve(index, queries, cfg)        # compile
    t0 = time.time()
    res = jax.block_until_ready(engine.retrieve(index, queries, cfg))
    t_emvb = time.time() - t0

    print("4) PLAID baseline (full centroid interaction + decompression) ...")
    pcfg = PlaidConfig(k=10, n_docs=64)
    pres = plaid.retrieve(index, queries, pcfg)       # compile
    t0 = time.time()
    pres = jax.block_until_ready(plaid.retrieve(index, queries, pcfg))
    t_plaid = time.time() - t0

    ids_e, ids_p = np.asarray(res.doc_ids), np.asarray(pres.doc_ids)
    print(f"\n   EMVB : mrr@10={mrr_at_k(ids_e, corpus.gt_doc):.3f} "
          f"r@10={recall_at_k(ids_e, corpus.gt_doc, 10):.3f} "
          f"({t_emvb / len(queries) * 1e3:.1f} ms/q)")
    print(f"   PLAID: mrr@10={mrr_at_k(ids_p, corpus.gt_doc):.3f} "
          f"r@10={recall_at_k(ids_p, corpus.gt_doc, 10):.3f} "
          f"({t_plaid / len(queries) * 1e3:.1f} ms/q)")
    print(f"   speedup x{t_plaid / t_emvb:.2f} "
          f"(paper Table 1: 2.1-2.8x at equal quality)")


if __name__ == "__main__":
    main()
