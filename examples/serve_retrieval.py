"""Distributed EMVB serving demo — the production execution plan on a local
8-device mesh (host platform devices; the same code runs on the 512-chip
mesh via launch/dryrun.py).

    PYTHONPATH=src python examples/serve_retrieval.py

Each device owns a doc shard with a local IVF, runs the full four-phase
pipeline for every request in the batch, and shards merge with a two-level
top-k (one small all-gather). Prints per-batch latency and verifies the
sharded result matches single-device retrieval exactly.
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import EngineConfig, build_index, engine  # noqa: E402
from repro.data.synthetic import make_corpus, mrr_at_k  # noqa: E402
from repro.launch.serve import make_shardmap_retriever, shard_index  # noqa: E402


def main(n_docs: int = 2048, n_centroids: int = 512,
         n_queries: int = 32) -> None:
    """Sizes are parameters so the tier-1 examples smoke test
    (tests/test_examples.py) can run the same code on a tiny corpus."""
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    corpus = make_corpus(3, n_docs=n_docs, cap=32, n_queries=n_queries)
    index, _ = build_index(jax.random.PRNGKey(0), corpus.doc_embs,
                           corpus.doc_lens, n_centroids=n_centroids, m=8,
                           kmeans_iters=4)

    mesh = Mesh(np.array(jax.devices()).reshape(n_dev), ("shard",))
    # selection budgets clamp to the per-device shard size on tiny corpora
    nf, nd = min(128, n_docs // n_dev), min(32, n_docs // n_dev)
    cfg = EngineConfig(k=10, n_filter=nf, n_docs=nd, th=0.2, th_r=0.3)

    print("sharding index across devices (local IVFs, two-level top-k) ...")
    stacked = shard_index(index, n_dev)
    retriever = make_shardmap_retriever(mesh, cfg)

    # device-resident queries ONCE, outside the loop: timing host numpy
    # arrays re-transfers them every iteration, so the loop would measure
    # H2D copies instead of the retrieval plan
    queries = jnp.asarray(corpus.queries)
    jax.block_until_ready(retriever(stacked, queries))    # compile
    lat = []
    for _ in range(5):
        t0 = time.perf_counter()
        res = jax.block_until_ready(retriever(stacked, queries))
        lat.append(time.perf_counter() - t0)
    ids_sharded = np.asarray(res.doc_ids)

    # single-device reference on the unsharded index
    ref = engine.retrieve(index, queries, EngineConfig(
        k=10, n_filter=nf * n_dev, n_docs=nd * n_dev, th=0.2, th_r=0.3))
    ids_ref = np.asarray(ref.doc_ids)

    mrr_s = mrr_at_k(ids_sharded, corpus.gt_doc)
    mrr_r = mrr_at_k(ids_ref, corpus.gt_doc)
    b = len(queries)
    print(f"\nsharded  mrr@10={mrr_s:.3f}   reference mrr@10={mrr_r:.3f}")
    print(f"top-1 agreement: "
          f"{(ids_sharded[:, 0] == ids_ref[:, 0]).mean() * 100:.0f}%")
    print(f"latency: {np.median(lat) / b * 1e3:.2f} ms/query "
          f"(batch={b}, {n_dev}-way doc sharding + two-level top-k)")


if __name__ == "__main__":
    main()
