"""Index lifecycle walkthrough — persistence, incremental growth, and
multi-generation (PLAID SHIRTTT-style) streaming retrieval.

    PYTHONPATH=src python examples/streaming_index.py

The corpus arrives in four slices. The demo:
  1. builds an index over slice 0 and saves/loads it (bit-exact round trip);
  2. grows it in place with ``add_passages`` (no k-means re-run) and reads
     the quantization-drift statistic that tells you when to re-train;
  3. serves slices 1..3 as immutable generations of a ``ShardedTimeline``,
     watching MRR@10 climb as the corpus streams in;
  4. persists and reloads the whole timeline.
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (EngineConfig, ShardedTimeline, add_passages,
                        build_index, engine, load_index, load_timeline,
                        new_generation, retrieve_timeline, save_index,
                        save_timeline)
from repro.data.synthetic import make_corpus, mrr_at_k


def main(n_docs: int = 2048, n_centroids: int = 512,
         n_queries: int = 64) -> None:
    """Sizes are parameters so the tier-1 examples smoke test
    (tests/test_examples.py) can run the same code on a tiny corpus."""
    corpus = make_corpus(0, n_docs=n_docs, cap=48, n_queries=n_queries)
    queries = jnp.asarray(corpus.queries)
    per = n_docs // 4                     # the corpus arrives in 4 slices
    # selection budgets clamp to the slice size on tiny corpora
    cfg = EngineConfig(k=10, n_filter=min(256, per), n_docs=min(64, per),
                       th=0.2, th_r=0.3)

    print("1) build generation 0 over the first slice ...")
    t0 = time.time()
    gen0, meta0 = build_index(
        jax.random.PRNGKey(0), corpus.doc_embs[:per], corpus.doc_lens[:per],
        n_centroids=n_centroids, m=16, nbits=8, kmeans_iters=4)
    print(f"   {meta0.n_docs} docs, {meta0.n_centroids} centroids "
          f"in {time.time() - t0:.1f}s "
          f"(train_quant_mse={meta0.train_quant_mse:.3f})")

    with tempfile.TemporaryDirectory() as tmp:
        print("2) save -> load round trip (bit-exact) ...")
        path = save_index(f"{tmp}/gen0", gen0, meta0)
        loaded, _ = load_index(path)
        a = engine.retrieve(gen0, queries, cfg)
        b = engine.retrieve(loaded, queries, cfg)
        exact = (np.array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
                 and np.array_equal(np.asarray(a.scores),
                                    np.asarray(b.scores)))
        print(f"   retrieval on loaded index bit-exact "
              f"(ids AND score bits): {exact}")

        print("3) grow the index in place with add_passages "
              "(frozen codebooks, no k-means) ...")
        grown, gmeta = add_passages(gen0, meta0, corpus.doc_embs[per:2 * per],
                                    corpus.doc_lens[per:2 * per])
        print(f"   {meta0.n_docs} -> {gmeta.n_docs} docs; "
              f"n_grown={gmeta.n_grown}, drift=x{gmeta.drift:.2f} "
              "(>> 1 would mean: re-train the codebooks)")

        print("4) stream the corpus as a ShardedTimeline of immutable "
              "generations ...")
        timeline = ShardedTimeline.of((gen0, meta0))
        for g in range(1, 4):
            lo = g * per
            timeline = timeline.append(*new_generation(
                gen0, meta0, corpus.doc_embs[lo:lo + per],
                corpus.doc_lens[lo:lo + per]))
            res = retrieve_timeline(timeline, queries, cfg)
            mrr = mrr_at_k(np.asarray(res.doc_ids), corpus.gt_doc)
            print(f"   gens={g + 1} docs={timeline.n_docs} "
                  f"mrr@10={mrr:.3f} "
                  f"drift=x{timeline.metas[-1].drift:.2f}")

        print("5) persist + reload the whole timeline ...")
        save_timeline(f"{tmp}/timeline", timeline)
        reloaded = load_timeline(f"{tmp}/timeline")
        res2 = retrieve_timeline(reloaded, queries, cfg)
        same = np.array_equal(np.asarray(res.doc_ids),
                              np.asarray(res2.doc_ids))
        print(f"   {len(reloaded)} generations reloaded; retrieval "
              f"identical: {same}")


if __name__ == "__main__":
    main()
