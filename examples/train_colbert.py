"""End-to-end driver — train a ColBERT-style multi-vector encoder with the
JMPQ option (STE product quantization *during* training, Fang et al. 2022),
then index its embeddings with EMVB and evaluate retrieval.

    PYTHONPATH=src python examples/train_colbert.py --steps 200 [--jmpq]

This is the paper's whole system in one script: encoder fine-tuning ->
PQ codebooks co-adapted with the model (--jmpq) -> index build -> EMVB
query processing, with checkpoint/resume via --ckpt-dir.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, build_index, engine
from repro.core.pq import train_pq
from repro.data.synthetic import mrr_at_k
from repro.models import colbert
from repro.train import optimizer as opt_lib
from repro.train.trainer import Trainer, TrainerConfig

VOCAB = 1000
N_TOPICS = 32


def make_batch_fn(batch: int = 16, seq: int = 24):
    """Paired (query, positive-doc) token batches: a query is a corrupted
    prefix of its positive document, so in-batch contrastive MaxSim learns
    topical token embeddings."""
    def make(step: int):
        k = jax.random.PRNGKey(1000 + step)
        k1, k2, k3 = jax.random.split(k, 3)
        topic = jax.random.randint(k1, (batch, 1), 0, N_TOPICS)
        # doc tokens concentrated in a per-topic 24-word slice of the vocab
        d_tokens = topic * 24 + jax.random.randint(k2, (batch, seq), 0, 24)
        corrupt = jax.random.bernoulli(k3, 0.15, (batch, seq))
        q_tokens = jnp.where(corrupt,
                             jax.random.randint(k3, (batch, seq), 0, VOCAB),
                             d_tokens)[:, :12]
        valid_d = jnp.ones((batch, seq), bool)
        return {"q_tokens": q_tokens, "q_valid": jnp.ones((batch, 12), bool),
                "d_tokens": d_tokens, "d_valid": valid_d}
    return make


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--jmpq", action="store_true",
                    help="STE-PQ during training (JMPQ reproduction)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = colbert.make_config(n_layers=2, d_model=128, n_heads=4, d_head=32,
                              d_ff=256, vocab=VOCAB, out_dim=64)
    key = jax.random.PRNGKey(0)
    params = colbert.init_params(key, cfg)

    pq_cb = None
    if args.jmpq:
        # seed codebooks from the *untrained* encoder's embeddings; the STE
        # loss then co-adapts encoder + quantizer (the JMPQ idea)
        probe = make_batch_fn()(0)
        de = colbert.encode(params, probe["d_tokens"], probe["d_valid"], cfg)
        pq_cb = train_pq(key, de.reshape(-1, de.shape[-1]), m=8, nbits=4)
        pq_cb = pq_cb.codebooks

    def loss(p, b):
        return colbert.contrastive_loss(p, b, cfg, pq_codebooks=pq_cb)

    trainer = Trainer(loss, opt_lib.make("adamw", lr=3e-3), make_batch_fn(),
                      TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100,
                                    log_every=25), params)
    print(f"training {args.steps} steps (jmpq={args.jmpq}) ...")
    t0 = time.time()
    out = trainer.run(args.steps)
    for m in out["log"]:
        print(f"  step {m['step']:4d}  loss {m['loss']:.4f}")
    print(f"trained in {time.time() - t0:.0f}s")

    # ---- index the corpus with the trained encoder and retrieve ----------
    print("encoding + indexing a 512-doc corpus ...")
    rng = np.random.default_rng(7)
    n_docs, seq = 512, 24
    topic = rng.integers(0, N_TOPICS, (n_docs, 1))
    d_tokens = jnp.asarray(topic * 24 + rng.integers(0, 24, (n_docs, seq)))
    d_valid = jnp.ones((n_docs, seq), bool)
    de = colbert.encode(trainer.state.params, d_tokens, d_valid, cfg)

    gt = rng.integers(0, n_docs, 32)
    q_tokens = np.asarray(d_tokens)[gt][:, :12].copy()
    corrupt = rng.random((32, 12)) < 0.15
    q_tokens[corrupt] = rng.integers(0, VOCAB, corrupt.sum())
    qe = colbert.encode(trainer.state.params, jnp.asarray(q_tokens),
                        jnp.ones((32, 12), bool), cfg)
    qe = np.asarray(qe)

    index, _ = build_index(
        jax.random.PRNGKey(1), np.asarray(de),
        np.full(n_docs, seq, np.int32), n_centroids=256, m=8, nbits=4,
        kmeans_iters=4)
    ecfg = EngineConfig(n_q=12, k=10, n_filter=128, n_docs=32, th=0.2,
                        th_r=0.3)
    ids = np.asarray(engine.retrieve(index, qe, ecfg).doc_ids)
    # exact MaxSim reference: isolates encoder quality from engine recall
    sim = np.einsum("qtd,nsd->qnts", qe, np.asarray(de))
    exact = sim.max(-1).sum(-1)
    ids_exact = np.argsort(-exact, axis=1)[:, :10]
    print(f"retrieval over trained embeddings: "
          f"mrr@10={mrr_at_k(ids, gt):.3f} (EMVB) vs "
          f"{mrr_at_k(ids_exact, gt):.3f} (exact MaxSim) — planted gt")


if __name__ == "__main__":
    main()
