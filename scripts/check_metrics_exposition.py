"""Prometheus text-exposition lint: validate the format of a LIVE
``RetrievalService.exposition()`` dump.

Run from the repo root (CI lint job; also wrapped by tests/test_obs.py):

    PYTHONPATH=src python scripts/check_metrics_exposition.py

The validator (``validate_exposition``) is a self-contained checker for
the Prometheus text exposition format (version 0.0.4) subset the
``repro.obs.registry`` emits:

  * structure — every sample belongs to a metric introduced by
    ``# HELP``/``# TYPE`` lines (in that order, each at most once);
  * naming — metric/label names match the Prometheus grammar, counters
    end in ``_total``;
  * samples — ``name{label="value",...} value`` with properly escaped
    label values and a parseable float (``+Inf``/``-Inf``/``NaN``
    allowed), no duplicate (name, labelset) pairs;
  * histograms — cumulative ``_bucket`` series with ``le`` labels ending
    in ``le="+Inf"``, whose count equals ``_count``;
  * summaries — ``quantile``-labeled series plus ``_sum``/``_count``;
  * the dump ends with a newline (scrape parsers require it).

Exit code 1 lists every violation. The live service is built tiny (the
same sizes the serving tests use), so the check runs in seconds on CPU.
"""
from __future__ import annotations

import math
import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
# one label pair: name="value" with \\, \" and \n escapes inside the value
_PAIR = r'[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\\\|\\"|\\n)*"'
SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>(?:' + _PAIR + r')(?:,(?:' + _PAIR + r'))*)?\})?'
    r' (?P<value>\S+)$')
PAIR_RE = re.compile(r'(' + _PAIR + r')')
KINDS = ("counter", "gauge", "histogram", "summary", "untyped")


def _parse_value(tok: str) -> float | None:
    """Prometheus sample value -> float, or None when unparseable."""
    if tok in ("+Inf", "Inf"):
        return math.inf
    if tok == "-Inf":
        return -math.inf
    if tok == "NaN":
        return math.nan
    try:
        return float(tok)
    except ValueError:
        return None


def _base_name(sample: str, kind: str) -> str:
    """Sample name -> the metric family it must belong to."""
    if kind == "histogram":
        for suf in ("_bucket", "_sum", "_count"):
            if sample.endswith(suf):
                return sample[:-len(suf)]
    if kind == "summary":
        for suf in ("_sum", "_count"):
            if sample.endswith(suf):
                return sample[:-len(suf)]
    return sample


def validate_exposition(text: str) -> list[str]:
    """-> list of format violations (empty = valid)."""
    errors: list[str] = []
    if not text:
        return ["exposition is empty"]
    if not text.endswith("\n"):
        errors.append("exposition does not end with a newline")

    kinds: dict[str, str] = {}       # metric family -> TYPE
    helped: set[str] = set()
    seen: set[tuple] = set()         # (sample name, labelset)
    buckets: dict[str, list[tuple[float, float]]] = {}  # family -> (le, v)
    counts: dict[str, float] = {}    # family -> _count value
    current: str | None = None       # family the HELP/TYPE header opened

    for lineno, line in enumerate(text.splitlines(), 1):
        loc = f"line {lineno}"
        if not line:
            errors.append(f"{loc}: blank line")
            continue
        if line.startswith("# HELP "):
            parts = line.split(" ", 3)
            if len(parts) < 4 or not NAME_RE.match(parts[2]):
                errors.append(f"{loc}: malformed HELP: {line!r}")
                continue
            if parts[2] in helped:
                errors.append(f"{loc}: duplicate HELP for {parts[2]}")
            helped.add(parts[2])
            current = parts[2]
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4 or not NAME_RE.match(parts[2]):
                errors.append(f"{loc}: malformed TYPE: {line!r}")
                continue
            name, kind = parts[2], parts[3]
            if kind not in KINDS:
                errors.append(f"{loc}: unknown TYPE {kind!r} for {name}")
            if name in kinds:
                errors.append(f"{loc}: duplicate TYPE for {name}")
            if name not in helped:
                errors.append(f"{loc}: TYPE for {name} precedes its HELP")
            if kind == "counter" and not name.endswith("_total"):
                errors.append(f"{loc}: counter {name} must end in _total")
            kinds[name] = kind
            current = name
            continue
        if line.startswith("#"):
            errors.append(f"{loc}: stray comment: {line!r}")
            continue

        m = SAMPLE_RE.match(line)
        if not m:
            errors.append(f"{loc}: unparseable sample: {line!r}")
            continue
        name, labels, vtok = m.group("name", "labels", "value")
        value = _parse_value(vtok)
        if value is None:
            errors.append(f"{loc}: bad value {vtok!r} for {name}")
        pairs = tuple(PAIR_RE.findall(labels)) if labels else ()
        for p in pairs:
            if not LABEL_RE.match(p.split("=", 1)[0]):
                errors.append(f"{loc}: bad label name in {p!r}")
        key = (name, pairs)
        if key in seen:
            errors.append(f"{loc}: duplicate sample {name}{{{pairs}}}")
        seen.add(key)

        # resolve the family: exact name, else a histogram/summary
        # suffix (_bucket/_sum/_count) of a declared family
        if name in kinds:
            family = name
        else:
            family = None
            for f, k in kinds.items():
                if k in ("histogram", "summary") and \
                        _base_name(name, k) == f and name != f:
                    family = f
                    break
            if family is None:
                errors.append(f"{loc}: sample {name} has no TYPE header")
                continue
        if family != current:
            errors.append(
                f"{loc}: sample {name} outside its {family} HELP/TYPE "
                "block (metrics must be contiguous)")
        kind = kinds[family]

        label_names = [p.split("=", 1)[0] for p in pairs]
        if kind == "histogram" and name.endswith("_bucket"):
            if "le" not in label_names:
                errors.append(f"{loc}: histogram bucket without le label")
            elif value is not None:
                le = next(p for p in pairs if p.startswith('le="'))
                bound = _parse_value(le[4:-1])
                if bound is None:
                    errors.append(f"{loc}: bad le bound in {le!r}")
                else:
                    buckets.setdefault(family, []).append((bound, value))
        if kind == "summary" and name == family and \
                "quantile" not in label_names:
            errors.append(f"{loc}: summary {name} sample without quantile")
        if name.endswith("_count") and kind in ("histogram", "summary") \
                and value is not None:
            counts[family] = value
        if kind == "counter" and value is not None and value < 0:
            errors.append(f"{loc}: counter {name} is negative")

    for family, bs in buckets.items():
        bounds = [b for b, _ in bs]
        vals = [v for _, v in bs]
        if not bounds or not math.isinf(bounds[-1]):
            errors.append(f"{family}: histogram buckets missing +Inf")
        if any(a > b for a, b in zip(vals, vals[1:])):
            errors.append(f"{family}: histogram buckets not cumulative")
        if family in counts and bounds and math.isinf(bounds[-1]) \
                and vals[-1] != counts[family]:
            errors.append(
                f"{family}: +Inf bucket {vals[-1]} != _count "
                f"{counts[family]}")
    return errors


def _live_exposition() -> str:
    """Stand up a tiny RetrievalService, serve a few queries (one of them
    filtered), run one maintenance pass, and return its exposition."""
    import jax
    import numpy as np

    from repro.core import (EngineConfig, ShardedTimeline, build_index,
                            new_generation)
    from repro.core.bitvector import Pred
    from repro.data.synthetic import make_corpus
    from repro.serving import RetrievalService

    corpus = make_corpus(0, n_docs=256, cap=32, n_queries=8)
    rng = np.random.default_rng(0)
    preds = {"lang_en": rng.random(256) < 0.7}
    per = 128
    cfg = EngineConfig(k=5, n_filter=64, n_docs=32, th=0.2, th_r=0.3)
    gen0, meta0 = build_index(
        jax.random.PRNGKey(0), corpus.doc_embs[:per], corpus.doc_lens[:per],
        n_centroids=32, m=16, nbits=4, kmeans_iters=2,
        predicates={n: v[:per] for n, v in preds.items()})
    timeline = ShardedTimeline.of((gen0, meta0)).append(*new_generation(
        gen0, meta0, corpus.doc_embs[per:], corpus.doc_lens[per:],
        predicates={n: v[per:] for n, v in preds.items()}))
    svc = RetrievalService(timeline, cfg)
    q = np.asarray(corpus.queries[:4])
    svc.query(q)
    svc.query(q)                          # warm pass: cache hits
    svc.query(q, doc_filter=Pred("lang_en"))
    return svc.exposition()


def main() -> int:
    """Lint the live exposition; print violations; return the exit code."""
    text = _live_exposition()
    errors = validate_exposition(text)
    if errors:
        print(f"{len(errors)} exposition violation(s):")
        print("\n".join(f"  {e}" for e in errors))
        return 1
    n_metrics = sum(1 for ln in text.splitlines()
                    if ln.startswith("# TYPE "))
    n_samples = sum(1 for ln in text.splitlines()
                    if ln and not ln.startswith("#"))
    print(f"exposition OK ({n_metrics} metrics, {n_samples} samples)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
