import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Diagnose one dry-run cell: top collectives (trip-count weighted), top HBM
contributors, and the raw HLO saved for inspection.

  PYTHONPATH=src python scripts/diag_cell.py <arch> <shape> [multi]
"""
import sys                                              # noqa: E402
from collections import defaultdict                     # noqa: E402

import jax                                              # noqa: E402

from repro.launch import hlo_stats                      # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.launch.steps import build_cell, donate_argnums  # noqa: E402

arch, shape = sys.argv[1], sys.argv[2]
multi = len(sys.argv) > 3 and sys.argv[3] == "multi"
mesh = make_production_mesh(multi_pod=multi)
fn, args = build_cell(arch, shape, mesh)
with mesh:
    lowered = jax.jit(fn, donate_argnums=donate_argnums(arch, shape)
                      ).lower(*args)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    mem = compiled.memory_analysis()
    print(f"peak={mem.peak_memory_in_bytes/2**30:.2f}GiB "
          f"args={mem.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB")

path = f"/tmp/hlo_{arch}_{shape}.txt"
open(path, "w").write(hlo)
print(f"HLO -> {path} ({len(hlo.splitlines())} lines)")

stats = hlo_stats.analyze(hlo)
print(f"flops/chip={stats['flops']:.3e} bytes/chip={stats['bytes']:.3e} "
      f"coll/chip={stats['collective_bytes']:.3e}")
print("\ntop collectives (link-bytes x trip count):")
for o in stats["top_collectives"]:
    print(f"  {o['kind']:20s} bytes={o['bytes']/2**20:10.1f}MiB "
          f"g={o['group']:4d} weight={o['weight']:6.0f} "
          f"link={o['link_bytes']/2**30:10.2f}GiB")

# top HBM ops: reuse the parser, accumulate per (kind, type)
comps, entry = hlo_stats.parse_module(hlo)
w = hlo_stats._weights(comps, entry)
fusion_bodies = set()
for ops in comps.values():
    for op in ops:
        if op.kind in ("fusion", "reduce", "scatter", "sort", "map",
                       "custom-call"):
            for cm in hlo_stats._CALLS_RE.finditer(op.rest):
                fusion_bodies.add(cm.group(1))
acc = defaultdict(float)
for name, ops in comps.items():
    weight = w.get(name, 0.0)
    if weight == 0.0 or name in fusion_bodies:
        continue
    for op in ops:
        if op.kind in ("tuple", "get-tuple-element", "constant", "while",
                       "bitcast"):
            continue
        acc[(op.kind, op.type_str[:64])] += weight * op.bytes
print("\ntop HBM contributors (result bytes x trips):")
for (kind, t), b in sorted(acc.items(), key=lambda kv: -kv[1])[:14]:
    print(f"  {b/2**40:8.2f}TiB  {kind:16s} {t}")
