"""Forbid new in-tree callers of the deprecated pre-batch phase signatures.

PR 7 unified the six phase entry points on one convention —
``phaseN(index, queries, cfg, *, q_mask=None, ...)`` over batched queries,
intermediates (``bits``/``bitmap``/``cs``/``sel1``/``sel2``) keyword-only.
The old single-query signatures (config trailing the positional
intermediates, loose positional ``q_mask``) survive as DeprecationWarning
shims for external callers, but nothing in this tree may use them.

The enforceable static rule: a call to any of the six entry points with
MORE THAN three positional arguments is legacy — every old form threads at
least one intermediate or the mask positionally past ``(index, queries,
cfg)``, and the new convention admits exactly those three positionals.
(The one legacy form this cannot see — three positionals with a 2-D query
— is covered dynamically: the test suite runs the engine paths with
DeprecationWarnings escalated.)

Usage: python scripts/check_legacy_signatures.py [root ...]
Exits 1 listing offending call sites, 0 when clean.
"""
from __future__ import annotations

import ast
import pathlib
import sys

ENTRY_POINTS = frozenset({
    "phase1_candidates", "phase2_prefilter", "phase12_prefilter",
    "phase3_centroid_interaction", "phase4_late_interaction",
    "phase34_late_interaction",
})
MAX_POSITIONAL = 3          # (index, queries, cfg)
DEFAULT_ROOTS = ("src", "tests", "benchmarks", "examples")
# the shims themselves and their direct tests legitimately exercise the
# legacy forms
ALLOWED = {"src/repro/core/engine.py", "tests/test_batched_kernels.py"}


def _called_name(call: ast.Call) -> str | None:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def check_file(path: pathlib.Path, repo: pathlib.Path) -> list[str]:
    rel = path.relative_to(repo).as_posix()
    if rel in ALLOWED:
        return []
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [f"{rel}:{e.lineno}: syntax error while scanning: {e.msg}"]
    bad = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _called_name(node)
        if name in ENTRY_POINTS and len(node.args) > MAX_POSITIONAL:
            bad.append(
                f"{rel}:{node.lineno}: {name} called with {len(node.args)} "
                f"positional args — the unified signature takes at most "
                f"{MAX_POSITIONAL} ((index, queries, cfg)); pass "
                "intermediates/q_mask as keywords")
    return bad


def main(argv: list[str]) -> int:
    repo = pathlib.Path(__file__).resolve().parents[1]
    roots = argv[1:] or [str(repo / r) for r in DEFAULT_ROOTS]
    offenders: list[str] = []
    for root in roots:
        for path in sorted(pathlib.Path(root).rglob("*.py")):
            offenders += check_file(path, repo)
    for line in offenders:
        print(line)
    if offenders:
        print(f"\n{len(offenders)} legacy phase-signature call site(s); "
              "see docs/ARCHITECTURE.md §entry points", file=sys.stderr)
    return 1 if offenders else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
