"""Docs link check: every file referenced from README.md and docs/*.md must
exist in the tree.

Run from the repo root (CI docs job; also wrapped by tests/test_docs_links.py):

    python scripts/check_doc_links.py

Two reference kinds are checked:
  * markdown links ``[text](target)`` with a relative target — resolved
    against the referencing file's directory (GitHub semantics); external
    (``http(s)://``, ``mailto:``) and pure-anchor targets are skipped;
  * backticked repo paths like ``src/repro/core/store.py`` — any
    `...`-quoted token that contains a ``/`` and a known source suffix and
    no glob/brace expansion characters, resolved against the repo root.

Exit code 1 lists every broken reference (file + the missing target).
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
BACKTICK = re.compile(r"`([^`\s]+)`")
PATHY = re.compile(r"^[A-Za-z0-9_./-]+\.(py|md|yml|yaml|toml|txt|json|cfg)$")


def references(text: str) -> list[tuple[str, str]]:
    """-> [(kind, target)] for every checkable reference in ``text``."""
    out = []
    for target in MD_LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        out.append(("link", target.split("#")[0]))
    for tok in BACKTICK.findall(text):
        if "/" in tok and PATHY.match(tok):
            out.append(("path", tok))
    return out


def main() -> int:
    """Check all doc files; print broken references; return the exit code."""
    broken = []
    n_checked = 0
    for doc in DOC_FILES:
        text = doc.read_text()
        for kind, target in references(text):
            if not target:
                continue
            base = doc.parent if kind == "link" else ROOT
            n_checked += 1
            if not (base / target).exists():
                broken.append(f"{doc.relative_to(ROOT)}: {kind} -> {target}")
    if broken:
        print(f"{len(broken)} broken doc reference(s):")
        print("\n".join(f"  {b}" for b in broken))
        return 1
    print(f"doc link check OK ({n_checked} references in "
          f"{len(DOC_FILES)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
