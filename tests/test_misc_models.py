"""ColBERT encoder, neighbor sampler, embedding bags, chunked attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import colbert, sampler
from repro.models.layers import chunked_causal_attention, gqa_attention
from repro.models.recsys.embedding_bag import embedding_bag, embedding_bag_pq


@pytest.mark.slow
def test_colbert_encode_and_train_step():
    cfg = colbert.make_config(n_layers=2, d_model=64, n_heads=4, d_head=16,
                              d_ff=128, vocab=300, out_dim=32)
    p = colbert.init_params(jax.random.PRNGKey(0), cfg)
    k = jax.random.PRNGKey(1)
    batch = {"q_tokens": jax.random.randint(k, (4, 8), 0, 300),
             "q_valid": jnp.ones((4, 8), bool),
             "d_tokens": jax.random.randint(k, (4, 16), 0, 300),
             "d_valid": jnp.arange(16)[None].repeat(4, 0) < 12}
    e = colbert.encode(p, batch["d_tokens"], batch["d_valid"], cfg)
    norms = np.linalg.norm(np.asarray(e), axis=-1)
    np.testing.assert_allclose(norms[:, :12], 1.0, rtol=1e-5)  # unit vectors
    np.testing.assert_allclose(norms[:, 12:], 0.0, atol=1e-6)  # padding zeroed
    colbert.contrastive_loss(p, batch, cfg)  # finite-loss smoke
    g = jax.grad(colbert.contrastive_loss)(p, batch, cfg)
    assert jax.tree_util.tree_all(
        jax.tree.map(lambda x: bool(jnp.isfinite(x).all()), g))
    # JMPQ path: STE through PQ codebooks
    cb = jax.random.normal(k, (4, 16, 8)) * 0.1
    loss_pq = colbert.contrastive_loss(p, batch, cfg, pq_codebooks=cb)
    assert np.isfinite(float(loss_pq))


@pytest.mark.slow
def test_sampler_respects_adjacency():
    import numpy as onp
    n = 30
    rng = onp.random.default_rng(0)
    deg = rng.integers(1, 5, size=n)
    row_ptr = onp.concatenate([[0], onp.cumsum(deg)])
    col_idx = rng.integers(0, n, size=row_ptr[-1])
    nbr, degrees = sampler.pad_adjacency(row_ptr, col_idx, n, 8, n)
    seeds = jnp.arange(6, dtype=jnp.int32)
    hop_nodes, blocks = sampler.sample_blocks(
        jax.random.PRNGKey(0), seeds, nbr, degrees, [4, 3])
    assert hop_nodes[1].shape == (24,) and hop_nodes[2].shape == (72,)
    # every sampled neighbor is a true neighbor of its seed
    h1 = np.asarray(hop_nodes[1]).reshape(6, 4)
    for si, s in enumerate(range(6)):
        nbrs = set(col_idx[row_ptr[s]:row_ptr[s + 1]].tolist())
        for x in h1[si]:
            assert int(x) in nbrs


def test_embedding_bag_modes():
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(5, 4))
    idx = jnp.asarray([[0, 1, 2], [3, 3, 0]])
    valid = jnp.asarray([[True, True, False], [True, False, False]])
    s = np.asarray(embedding_bag(table, idx, valid, "sum"))
    np.testing.assert_allclose(s[0], np.asarray(table[0] + table[1]))
    np.testing.assert_allclose(s[1], np.asarray(table[3]))
    m = np.asarray(embedding_bag(table, idx, valid, "mean"))
    np.testing.assert_allclose(m[0], np.asarray((table[0] + table[1]) / 2))


def test_embedding_bag_pq_equals_decoded_dense():
    rng = np.random.default_rng(0)
    m, k, dsub, v = 4, 8, 2, 50
    cbs = jnp.asarray(rng.normal(size=(m, k, dsub)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, k, size=(v, m)).astype(np.uint8))
    # dense table = decoded rows
    s_idx = np.broadcast_to(np.arange(m), (v, m))
    dense = np.asarray(cbs)[s_idx, np.asarray(codes).astype(int)]
    dense = jnp.asarray(dense.reshape(v, m * dsub))
    idx = jnp.asarray(rng.integers(0, v, size=(6, 3)).astype(np.int32))
    valid = jnp.ones((6, 3), bool)
    out_pq = embedding_bag_pq(codes, cbs, idx, valid)
    out_dense = embedding_bag(dense, idx, valid)
    np.testing.assert_allclose(np.asarray(out_pq), np.asarray(out_dense),
                               rtol=1e-6)


@pytest.mark.slow
def test_chunked_attention_matches_dense():
    k = jax.random.PRNGKey(0)
    B, S, H, KV, Dh = 2, 64, 4, 2, 16
    q = jax.random.normal(k, (B, S, H, Dh))
    kk = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, Dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, Dh))
    ref = gqa_attention(q, kk, v, jnp.tril(jnp.ones((S, S), bool)))
    for qc, kc in [(16, 16), (32, 8)]:
        out = chunked_causal_attention(q, kk, v, qc, kc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_moe_capacity_dispatch_routes_tokens():
    """With E=4, top_k=1, capacity ample: output == chosen expert's FFN."""
    from repro.models.moe import moe_block
    from repro.models.layers import ModelConfig, init_layer_params
    cfg = ModelConfig(n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
                      d_head=8, d_ff=32, vocab=0, n_experts=4, top_k=1,
                      capacity_factor=4.0)
    p = init_layer_params(jax.random.PRNGKey(0), cfg)["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    out, aux = moe_block(p, x, cfg)
    assert out.shape == x.shape and np.isfinite(float(aux))
    # manual per-token check
    xf = np.asarray(x).reshape(-1, 16)
    logits = xf @ np.asarray(p["router"])
    choice = logits.argmax(-1)
    outf = np.asarray(out).reshape(-1, 16)
    import jax.nn as jnn
    for t in range(xf.shape[0]):
        e = choice[t]
        h = np.asarray(jnn.silu(xf[t] @ np.asarray(p["wi_gate"][e]))) * \
            (xf[t] @ np.asarray(p["wi_up"][e]))
        y = h @ np.asarray(p["wo"][e])
        np.testing.assert_allclose(outf[t], y, rtol=2e-3, atol=2e-3)
