"""Tests for the §Perf features: context-parallel attention specs,
Megatron-SP residuals, distributed Muon, grouped MoE dispatch, per-token
compaction in the engine, and reduced-precision centroid scores."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import EngineConfig, engine
from repro.models import transformer as T
from repro.models.layers import ModelConfig
from repro.train import optimizer as opt_lib

CFG = ModelConfig(name="cp-test", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_head=16, d_ff=128, vocab=128,
                  dtype=jnp.float32, attn_q_chunk=8, attn_kv_chunk=8,
                  attn_chunk_min_seq=16)


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.slow
def test_context_parallel_specs_preserve_forward():
    """attn_act_specs + residual_spec are pure layout constraints: on a 1x1
    mesh the constrained forward must equal the unconstrained one exactly."""
    mesh = _mesh11()
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, CFG.vocab)
    ref_logits, ref_cache = jax.jit(
        lambda p, t: T.prefill(p, t, CFG))(params, tokens)
    cfg_cp = dataclasses.replace(
        CFG,
        attn_act_specs=(P("data", None, "model", None, None, None),
                        P("data", None, None, None, None)),
        residual_spec=P("data", "model", None))
    with mesh:
        out_logits, out_cache = jax.jit(
            lambda p, t: T.prefill(p, t, cfg_cp))(params, tokens)
    np.testing.assert_allclose(np.asarray(ref_logits),
                               np.asarray(out_logits), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(ref_cache.k),
                               np.asarray(out_cache.k), rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_distributed_muon_matches_plain_muon():
    """mats_spec + nested-vmap fold is numerics-equivalent to plain Muon
    (same ns_dtype) on a 1x1 mesh."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (3, 8, 16)),
              "b": jnp.ones((8,))}
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(1), p.shape), params)
    plain = opt_lib.make("muon", ns_dtype=jnp.float32)
    dist = opt_lib.make("muon", ns_dtype=jnp.float32,
                        mats_spec=lambda shape: (P("data", None, None)
                                                 if len(shape) == 3 else None))
    s0p = plain.init(params)
    s0d = dist.init(params)
    new_p, _ = plain.update(grads, s0p, params)
    with _mesh11():
        new_d, _ = jax.jit(dist.update)(grads, s0d, params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), new_p, new_d)


@pytest.mark.slow
def test_moe_grouped_drops_over_capacity():
    """Tight per-group capacity drops tokens (outputs zero for dropped rows)
    but never produces NaN, and aux loss stays finite."""
    from repro.models import moe
    cfg = ModelConfig(name="m", n_experts=4, top_k=2, capacity_factor=1.0,
                      d_model=8, d_ff=16, dtype=jnp.float32, moe_groups=2)
    key = jax.random.PRNGKey(0)
    p = {"router": jax.random.normal(key, (8, 4)),
         "wi_gate": jax.random.normal(key, (4, 8, 16)) * 0.1,
         "wi_up": jax.random.normal(key, (4, 8, 16)) * 0.1,
         "wo": jax.random.normal(key, (4, 16, 8)) * 0.1}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8))
    out, aux = moe.moe_block(p, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(float(aux))


def test_engine_compact_cap_full_buffer_is_exact(small_corpus, small_index):
    """compact_cap == doc cap must reproduce the full Eq.6 retrieval
    exactly (ids and scores)."""
    idx, meta = small_index
    q = jnp.asarray(small_corpus.queries[:8])
    base = EngineConfig(k=10, n_filter=64, n_docs=16, th=0.3, th_r=0.4)
    comp = dataclasses.replace(base, compact_cap=meta.cap)
    r0 = engine.retrieve(idx, q, base)
    r1 = engine.retrieve(idx, q, comp)
    np.testing.assert_array_equal(np.asarray(r0.doc_ids),
                                  np.asarray(r1.doc_ids))
    np.testing.assert_allclose(np.asarray(r0.scores), np.asarray(r1.scores),
                               rtol=1e-5, atol=1e-5)


def test_engine_compact_cap_half_buffer_keeps_quality(small_corpus,
                                                      small_index):
    """Half-cap compaction: same top-1 for the planted ground truth."""
    from repro.data.synthetic import mrr_at_k
    idx, meta = small_index
    q = jnp.asarray(small_corpus.queries)
    base = EngineConfig(k=10, n_filter=64, n_docs=16, th=0.3, th_r=0.4)
    comp = dataclasses.replace(base, compact_cap=meta.cap // 2)
    m0 = mrr_at_k(np.asarray(engine.retrieve(idx, q, base).doc_ids),
                  small_corpus.gt_doc)
    m1 = mrr_at_k(np.asarray(engine.retrieve(idx, q, comp).doc_ids),
                  small_corpus.gt_doc)
    assert m1 >= m0 - 0.02


def test_engine_bf16_centroid_scores_quality(small_corpus, small_index):
    """cs_dtype=bfloat16 (paper §6 reduced precision) keeps retrieval
    quality on the planted corpus."""
    from repro.data.synthetic import mrr_at_k
    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries)
    base = EngineConfig(k=10, n_filter=64, n_docs=16, th=0.2, th_r=0.4)
    bf = dataclasses.replace(base, cs_dtype="bfloat16")
    m0 = mrr_at_k(np.asarray(engine.retrieve(idx, q, base).doc_ids),
                  small_corpus.gt_doc)
    m1 = mrr_at_k(np.asarray(engine.retrieve(idx, q, bf).doc_ids),
                  small_corpus.gt_doc)
    assert m1 >= m0 - 0.02
