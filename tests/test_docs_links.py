"""Docs stay navigable: every file referenced from README.md / docs/*.md
exists (the same check the CI docs job runs via scripts/check_doc_links.py)."""
import pathlib
import sys


def test_doc_references_resolve(capsys):
    sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "scripts"))
    try:
        import check_doc_links
    finally:
        sys.path.pop(0)
    rc = check_doc_links.main()
    out = capsys.readouterr().out
    assert rc == 0, out
