"""End-to-end behaviour: the EMVB engine reproduces the paper's headline —
same retrieval quality as PLAID / exact MaxSim, smaller memory footprint —
on a planted-relevance corpus."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (EngineConfig, PlaidConfig, bytes_per_embedding,
                        engine, plaid)
from repro.core.interaction import maxsim
from repro.data.synthetic import mrr_at_k, recall_at_k

# th=0.2 is the fixture corpus's no-loss operating point (the same
# calibration the paper does on its Fig. 2 curve; see benchmarks/common.py).
# Above it the bit-vector filter drops true candidates; well below it
# F(P,q) saturates at n_q and phase-2 tie-breaking loses docs — the
# non-monotonicity the paper's Fig. 2-left shows.
CFG = EngineConfig(nprobe=8, th=0.2, th_r=0.4, n_filter=128, n_docs=48, k=10)
PCFG = PlaidConfig(nprobe=8, n_docs=48, k=10)


def _exact_ids(corpus, index, k=10):
    q = jnp.asarray(corpus.queries)
    tm = index.token_mask()
    sc = jax.vmap(lambda qq: maxsim(qq, jnp.asarray(corpus.doc_embs), tm))(q)
    return np.asarray(jnp.argsort(-sc, axis=-1)[:, :k])


def test_emvb_matches_exact_quality(small_corpus, small_index):
    idx, meta = small_index
    res = engine.retrieve(idx, jnp.asarray(small_corpus.queries), CFG)
    ids = np.asarray(res.doc_ids)
    exact = _exact_ids(small_corpus, idx)
    m_emvb = mrr_at_k(ids, small_corpus.gt_doc)
    m_exact = mrr_at_k(exact, small_corpus.gt_doc)
    assert m_emvb >= m_exact - 0.1, (m_emvb, m_exact)
    assert recall_at_k(ids, small_corpus.gt_doc, 10) >= \
        recall_at_k(exact, small_corpus.gt_doc, 10) - 0.1


def test_emvb_matches_plaid_quality(small_corpus, small_index):
    idx, meta = small_index
    q = jnp.asarray(small_corpus.queries)
    e_ids = np.asarray(engine.retrieve(idx, q, CFG).doc_ids)
    p_ids = np.asarray(plaid.retrieve(idx, q, PCFG).doc_ids)
    m_e = mrr_at_k(e_ids, small_corpus.gt_doc)
    m_p = mrr_at_k(p_ids, small_corpus.gt_doc)
    assert m_e >= m_p - 0.1, (m_e, m_p)  # "no loss in retrieval accuracy"


def test_memory_footprint_reduction(small_index):
    """Paper Table 1: EMVB m=16 uses 20 bytes/embedding vs PLAID's 36."""
    _, meta = small_index
    import dataclasses
    paper_meta = dataclasses.replace(meta, n_centroids=1 << 18, m=16,
                                     nbits=8, plaid_b=2, d=128)
    e = bytes_per_embedding(paper_meta, "emvb")
    p = bytes_per_embedding(paper_meta, "plaid")
    assert e == 20 and p == 36 and p / e == 1.8


def test_results_sorted_and_valid(small_corpus, small_index):
    idx, _ = small_index
    res = engine.retrieve(idx, jnp.asarray(small_corpus.queries), CFG)
    scores = np.asarray(res.scores)
    ids = np.asarray(res.doc_ids)
    assert (np.diff(scores, axis=1) <= 1e-5).all()
    assert (ids >= 0).all() and (ids < idx.codes.shape[0]).all()


def test_engine_kernel_path_equivalence(small_corpus, small_index):
    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[:4])
    ref = engine.retrieve(idx, q, CFG)
    import dataclasses
    kcfg = dataclasses.replace(CFG, use_kernels=True)
    ker = engine.retrieve(idx, q, kcfg)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(ker.doc_ids))


def test_term_filter_no_quality_loss(small_corpus, small_index):
    """Paper Fig. 5: Eq. 6 with th_r=0.5-ish keeps MRR within noise."""
    import dataclasses
    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries)
    no_filter = dataclasses.replace(CFG, th_r=None)
    ids_f = np.asarray(engine.retrieve(idx, q, CFG).doc_ids)
    ids_n = np.asarray(engine.retrieve(idx, q, no_filter).doc_ids)
    m_f = mrr_at_k(ids_f, small_corpus.gt_doc)
    m_n = mrr_at_k(ids_n, small_corpus.gt_doc)
    assert m_f >= m_n - 0.05


def test_compact_candidate_mode(small_corpus, small_index):
    import dataclasses
    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[:8])
    ccfg = dataclasses.replace(CFG, candidate_mode="compact", cand_cap=600)
    ids_c = np.asarray(engine.retrieve(idx, q, ccfg).doc_ids)
    ids_s = np.asarray(engine.retrieve(idx, q, CFG).doc_ids)
    # with cand_cap >= n_docs the two modes agree exactly
    np.testing.assert_array_equal(ids_c, ids_s)
