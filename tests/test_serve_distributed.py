"""Distributed serving: the shard_map plan equals the single-device engine,
shard_index's local IVFs are consistent with the global one, and the
multi-generation timeline plan equals the single-device merge path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, engine
from repro.launch.serve import (make_shardmap_retriever,
                                make_timeline_retriever, shard_index)

CFG = EngineConfig(nprobe=8, th=0.3, th_r=0.4, n_filter=64, n_docs=16, k=10)


def test_shardmap_matches_global_single_device(small_corpus, small_index):
    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[:8])
    ref = engine.retrieve(idx, q, CFG)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step = make_shardmap_retriever(mesh, CFG)
    with mesh:
        out = step(shard_index(idx, 1), q)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(out.doc_ids))
    np.testing.assert_allclose(np.asarray(ref.scores),
                               np.asarray(out.scores), rtol=1e-5)


def test_shardmap_runs_fused_megakernels(small_corpus, small_index):
    """The fully fused kernel engine (prefilter + late-interaction
    megakernels) runs inside shard_map against the local shard and matches
    the jnp-reference shard_map plan bit-exactly."""
    import dataclasses

    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[:4])
    kcfg = dataclasses.replace(CFG, use_kernels=True, fused_prefilter=True,
                               fused_late_interaction=True)
    ref = engine.retrieve(idx, q, kcfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step = make_shardmap_retriever(mesh, kcfg)
    with mesh:
        out = step(shard_index(idx, 1), q)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(out.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(out.scores))


def test_shard_index_partitions_consistently(small_index):
    idx, meta = small_index
    n_shards = 4
    n_docs = idx.codes.shape[0]
    assert n_docs % n_shards == 0
    st = shard_index(idx, n_shards)
    per = n_docs // n_shards
    # codes block-partitioned
    np.testing.assert_array_equal(
        np.asarray(st.codes).reshape(n_docs, -1), np.asarray(idx.codes))
    # every global IVF entry appears in exactly one local IVF (unless the
    # local list overflowed list_cap)
    g_ivf, g_lens = np.asarray(idx.ivf), np.asarray(idx.ivf_lens)
    l_ivf, l_lens = np.asarray(st.ivf), np.asarray(st.ivf_lens)
    for c in range(meta.n_centroids):
        global_docs = set(g_ivf[c, :g_lens[c]].tolist())
        local_docs = set()
        for s in range(n_shards):
            local_docs |= {int(x) + s * per
                           for x in l_ivf[s, c, :l_lens[s, c]]}
        assert local_docs <= global_docs
        if sum(l_lens[s, c] for s in range(n_shards)) == len(global_docs):
            assert local_docs == global_docs


def test_timeline_retriever_matches_single_device(small_corpus, small_index):
    """The sharded multi-generation plan (shard_map per generation + merge
    by score with doc-id offsets) returns the same ids as the single-device
    ``engine.retrieve_timeline`` over the same ShardedTimeline."""
    from repro.core import ShardedTimeline, new_generation, retrieve_timeline

    idx, meta = small_index
    gen1 = new_generation(idx, meta, np.asarray(small_corpus.doc_embs[:300]),
                          np.asarray(small_corpus.doc_lens[:300]))
    tl = ShardedTimeline.of((idx, meta), gen1)
    q = jnp.asarray(small_corpus.queries[:8])
    ref = retrieve_timeline(tl, q, CFG)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    run = make_timeline_retriever(mesh, CFG, tl)
    with mesh:
        out = run(q)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(out.doc_ids))
    np.testing.assert_allclose(np.asarray(ref.scores),
                               np.asarray(out.scores), rtol=1e-5)


def test_make_service_shardmap_miss_lane(small_corpus, small_index):
    """launch.serve.make_service: a RetrievalService whose miss lane runs
    the per-generation shard_map plans. Cold and warm results equal the
    sharded uncached retriever (the caching layer is plan-agnostic: it
    stores whatever partials the plan produced)."""
    from repro.core import ShardedTimeline, new_generation
    from repro.launch.serve import make_service

    idx, meta = small_index
    gen1 = new_generation(idx, meta, np.asarray(small_corpus.doc_embs[:300]),
                          np.asarray(small_corpus.doc_lens[:300]))
    tl = ShardedTimeline.of((idx, meta), gen1)
    q = jnp.asarray(small_corpus.queries[:8])
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ref = make_timeline_retriever(mesh, CFG, tl)(q)
    svc = make_service(mesh, CFG, tl)
    cold = svc.query(np.asarray(q))
    warm = svc.query(np.asarray(q))
    for out in (cold, warm):
        np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                      np.asarray(out.doc_ids))
        np.testing.assert_array_equal(np.asarray(ref.scores),
                                      np.asarray(out.scores))
    assert svc.cache.hits == 8          # warm pass, 1 immutable generation


def test_per_shard_topk_merge_recovers_global(small_corpus, small_index):
    """Two-level top-k invariant: with EXHAUSTIVE per-shard budgets (every
    local doc late-interacted), the merged union must equal the brute-force
    Eq. 5/6 top-k over the whole corpus exactly — this isolates the merge
    logic + shard score equivalence from filter-recall effects (with probe-
    limited budgets, global and sharded candidate sets legitimately differ;
    quality parity for that regime is covered by the serving example)."""
    import dataclasses

    from repro.core.interaction import late_interaction_pq
    from repro.core.pq import build_lut

    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[:4])
    n_shards = 4
    n_docs = idx.codes.shape[0]
    per = n_docs // n_shards
    ecfg = dataclasses.replace(CFG, n_filter=per, n_docs=per, th=-1.0)
    st = shard_index(idx, n_shards)
    merged_scores, merged_ids = [], []
    for s in range(n_shards):
        local = jax.tree.map(lambda x: x[s], st)
        res = engine.retrieve(local, q, ecfg)
        merged_scores.append(np.asarray(res.scores))
        merged_ids.append(np.asarray(res.doc_ids) + s * per)
    sc = np.concatenate(merged_scores, axis=1)
    ids = np.concatenate(merged_ids, axis=1)
    order = np.argsort(-sc, axis=1)[:, :CFG.k]
    top_ids = np.take_along_axis(ids, order, axis=1)
    top_sc = np.take_along_axis(sc, order, axis=1)

    token_mask = idx.token_mask()
    for b in range(q.shape[0]):
        lut = build_lut(jnp.asarray(q[b]) @ idx.opq_rotation, idx.pq)
        cs_t = (jnp.asarray(q[b]) @ idx.centroids.T).T
        exact = np.asarray(late_interaction_pq(
            cs_t, lut, idx.codes, idx.res_codes, token_mask, CFG.th_r))
        want = np.argsort(-exact)[:CFG.k]
        assert set(top_ids[b].tolist()) == set(want.tolist())
        np.testing.assert_allclose(np.sort(top_sc[b]),
                                   np.sort(exact[want]), rtol=1e-4)
