"""End-to-end query-term masking: the invariant that makes the mask
tractable is

    retrieve(zero-padded query, q_mask)  ==  retrieve(unpadded prefix)

bit-exactly — ids AND score bits — for the jnp reference, the unfused
kernels, both fused megakernels, both candidate modes, and under shard_map;
and an all-True mask (or no mask) reproduces the unmasked pipeline bit for
bit. Plus the bf16 probe-selection regression for ``masked_topk_centroids``
and the ``prune_queries`` helper contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, engine, prune_queries
from repro.core.bitvector import masked_topk_centroids

CFG = EngineConfig(nprobe=8, th=0.2, th_r=0.4, n_filter=128, n_docs=48, k=10)
N_PREFIX = 20          # live terms; terms 20..31 are zero padding


def _padded_queries(small_corpus, n=3):
    """(B, 32, d) queries with a zeroed tail + the matching (B, 32) mask."""
    q = np.asarray(small_corpus.queries[:n]).copy()
    q[:, N_PREFIX:, :] = 0.0
    mask = np.zeros(q.shape[:2], bool)
    mask[:, :N_PREFIX] = True
    return jnp.asarray(q), jnp.asarray(mask)


def _assert_results_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


# ---------------------------------------------------------------------------
# padded + mask == unpadded prefix (the tentpole invariant)
# ---------------------------------------------------------------------------

# (use_kernels, fused): jnp reference, unfused Pallas kernels, and the two
# megakernels. The unfused-kernel x compact combination shares all masked
# code paths with the cases below, so it is left out to save two compiles.
@pytest.mark.parametrize("mode,use_kernels,fused", [
    ("score_all", False, False),
    ("compact", False, False),
    ("score_all", True, False),
    ("score_all", True, True),
    ("compact", True, True),
])
def test_padded_query_equals_unpadded_prefix(small_corpus, small_index, mode,
                                             use_kernels, fused):
    idx, _ = small_index
    cfg = dataclasses.replace(CFG, candidate_mode=mode, cand_cap=600,
                              use_kernels=use_kernels, fused_prefilter=fused,
                              fused_late_interaction=fused)
    qp, mask = _padded_queries(small_corpus)
    padded = engine.retrieve(idx, qp, cfg, mask)
    prefix = engine.retrieve(idx, qp[:, :N_PREFIX], cfg)
    _assert_results_equal(padded, prefix)


@pytest.mark.parametrize("th_r", [None, 0.4])
def test_padded_equals_prefix_th_r_modes(small_corpus, small_index, th_r):
    """Eq. 5 (no term filter) and Eq. 6 both honour the mask."""
    idx, _ = small_index
    cfg = dataclasses.replace(CFG, th_r=th_r)
    qp, mask = _padded_queries(small_corpus, n=2)
    padded = engine.retrieve(idx, qp, cfg, mask)
    prefix = engine.retrieve(idx, qp[:, :N_PREFIX], cfg)
    _assert_results_equal(padded, prefix)


def test_padded_equals_prefix_compact_cap(small_corpus, small_index):
    """Per-token compaction path: masked terms must not keep tokens alive
    through the keymax criterion."""
    idx, meta = small_index
    cfg = dataclasses.replace(CFG, compact_cap=meta.cap)
    qp, mask = _padded_queries(small_corpus, n=2)
    padded = engine.retrieve(idx, qp, cfg, mask)
    prefix = engine.retrieve(idx, qp[:, :N_PREFIX], cfg)
    _assert_results_equal(padded, prefix)


def test_padded_equals_prefix_under_shard_map(small_corpus, small_index):
    """The shard_map plan replicates the mask like the queries; the merged
    two-level top-k must equal the prefix retrieval bit-exactly, and the
    masked sharded result must equal the masked single-device one."""
    from repro.launch.serve import make_shardmap_retriever, shard_index

    idx, _ = small_index
    kcfg = dataclasses.replace(CFG, use_kernels=True)
    qp, mask = _padded_queries(small_corpus, n=2)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    retr = make_shardmap_retriever(mesh, kcfg)
    stacked = shard_index(idx, 1)
    with mesh:
        sharded = retr(stacked, qp, mask)
        sharded_prefix = retr(stacked, qp[:, :N_PREFIX])
    _assert_results_equal(sharded, sharded_prefix)
    single = engine.retrieve(idx, qp, kcfg, mask)
    _assert_results_equal(sharded, single)


# ---------------------------------------------------------------------------
# all-True mask == no mask, bit for bit (property test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernels", [False, True])
def test_all_true_mask_is_identity(small_corpus, small_index, use_kernels):
    idx, _ = small_index
    cfg = dataclasses.replace(CFG, use_kernels=use_kernels)
    q = jnp.asarray(small_corpus.queries[:3])
    unmasked = engine.retrieve(idx, q, cfg)
    masked = engine.retrieve(idx, q, cfg, jnp.ones(q.shape[:2], jnp.bool_))
    _assert_results_equal(unmasked, masked)


def test_all_true_mask_is_identity_phase_split(small_corpus, small_index):
    """The phase-split entry points honour the mask the same way."""
    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[:1])
    ones = jnp.ones(q.shape[:2], jnp.bool_)
    cs0, bits0, bm0 = engine.phase1_candidates(idx, q, CFG)
    cs1, bits1, bm1 = engine.phase1_candidates(idx, q, CFG, q_mask=ones)
    np.testing.assert_array_equal(np.asarray(bits0), np.asarray(bits1))
    np.testing.assert_array_equal(np.asarray(bm0), np.asarray(bm1))
    sel1 = jnp.arange(CFG.n_filter, dtype=jnp.int32)[None]
    sel2 = engine.phase3_centroid_interaction(idx, q, CFG, q_mask=ones,
                                              cs=cs0, sel1=sel1)
    sel2_ref = engine.phase3_centroid_interaction(idx, q, CFG, cs=cs0,
                                                  sel1=sel1)
    np.testing.assert_array_equal(np.asarray(sel2), np.asarray(sel2_ref))


# ---------------------------------------------------------------------------
# masked_topk_centroids: dtype-safe probe masking (bf16 regression) + the
# masked-terms-probe-nothing contract
# ---------------------------------------------------------------------------

def test_masked_topk_bf16_matches_f32_selection():
    """Regression: the old ``cs - 1e6`` sentinel, computed in the CS dtype,
    collapsed all non-survivor scores onto one bf16 value (ulp at 1e6 is
    2048), so the bf16 selection silently diverged from the f32 one. With
    the ranking done in f32 the selection is identical for scores exactly
    representable in bf16 — and the best-non-survivor fallback order is
    preserved (slots beyond the survivors rank by score, not index)."""
    # bf16-exact values, one survivor (> th), non-survivors NOT in index
    # order of merit — the old f32 path ranked them by score, the old bf16
    # path by index, so old code fails this equality.
    vals = np.array([[0.5, 0.125, 0.21875, 0.3125, 0.40625,
                      0.25, 0.375, 0.34375]], np.float32)
    cs32 = jnp.asarray(vals)
    cs16 = cs32.astype(jnp.bfloat16)
    th, nprobe = 0.45, 4
    idx32 = np.asarray(masked_topk_centroids(cs32, th, nprobe))
    idx16 = np.asarray(masked_topk_centroids(cs16, th, nprobe))
    np.testing.assert_array_equal(idx32, idx16)
    # survivor first, then the BEST non-survivors by score (not by index)
    np.testing.assert_array_equal(idx32[0], [0, 4, 6, 7])


def test_masked_topk_survivors_lead():
    """Every threshold survivor must outrank every non-survivor."""
    rng = np.random.default_rng(0)
    cs = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    th, nprobe = 0.8, 8
    idx = np.asarray(masked_topk_centroids(cs, th, nprobe))
    cs_np = np.asarray(cs)
    for t in range(4):
        n_surv = int((cs_np[t] > th).sum())
        lead = idx[t, :min(n_surv, nprobe)]
        assert (cs_np[t, lead] > th).all()


def test_masked_terms_probe_nothing():
    rng = np.random.default_rng(1)
    cs = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    q_mask = jnp.asarray([True, False, True, False])
    idx = np.asarray(masked_topk_centroids(cs, 0.2, 4, q_mask))
    assert (idx[1] == 64).all() and (idx[3] == 64).all()  # sentinel == n_c
    ref = np.asarray(masked_topk_centroids(cs, 0.2, 4))
    np.testing.assert_array_equal(idx[0], ref[0])
    np.testing.assert_array_equal(idx[2], ref[2])


def test_sentinel_probes_add_no_candidates():
    """candidate_bitmap must treat sentinel probe ids as empty lists."""
    ivf = jnp.asarray(np.arange(12, dtype=np.int32).reshape(4, 3))
    ivf_lens = jnp.asarray([3, 3, 3, 3], np.int32)
    probes = jnp.asarray([[0], [4]], np.int32)     # term 1 masked -> n_c=4
    bm = np.asarray(engine.candidate_bitmap(ivf, ivf_lens, probes, 16))
    assert set(np.nonzero(bm)[0].tolist()) == {0, 1, 2}


# ---------------------------------------------------------------------------
# prune_queries
# ---------------------------------------------------------------------------

def test_prune_queries_identity_at_full_keep(small_corpus):
    q = jnp.asarray(small_corpus.queries[:2])
    qp, qm = prune_queries(q, q.shape[1])
    np.testing.assert_array_equal(np.asarray(qp), np.asarray(q))
    assert np.asarray(qm).all()


def test_prune_queries_strips_padding_first(small_corpus):
    """Zero-padded terms rank last under the default (norm) importance, so
    pruning down to the live count recovers exactly the prefix."""
    qp_full, _ = _padded_queries(small_corpus, n=2)
    qp, qm = prune_queries(qp_full, N_PREFIX)
    np.testing.assert_array_equal(np.asarray(qp),
                                  np.asarray(qp_full[:, :N_PREFIX]))
    assert np.asarray(qm).all()


def test_prune_queries_masks_kept_padding(small_corpus):
    """keep > live count: the kept zero slots come back mask=False, so
    retrieval with the pruned pair equals the true prefix."""
    idx_keep = N_PREFIX + 4
    qp_full, _ = _padded_queries(small_corpus, n=2)
    qp, qm = prune_queries(qp_full, idx_keep)
    assert np.asarray(qm)[:, :N_PREFIX].all()
    assert not np.asarray(qm)[:, N_PREFIX:].any()


def test_pruned_retrieval_quality(small_corpus, small_index):
    """Dropping a quarter of the terms keeps MRR within a small delta on the
    planted corpus — the latency/quality trade-off the benchmark tracks."""
    from repro.data.synthetic import mrr_at_k

    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries)
    full = mrr_at_k(np.asarray(engine.retrieve(idx, q, CFG).doc_ids),
                    small_corpus.gt_doc)
    qp, qm = prune_queries(q, 24)
    pruned = mrr_at_k(np.asarray(engine.retrieve(idx, qp, CFG, qm).doc_ids),
                      small_corpus.gt_doc)
    assert pruned >= full - 0.15, (pruned, full)
