"""Observability contract (repro.obs — docs/OBSERVABILITY.md):

* tracing disabled is a TRUE no-op: ``trace.span(...)`` returns the shared
  ``NOOP_SPAN`` singleton (identity pinned — no allocation on the hot
  path) and serving results are bit-exact with instrumentation compiled
  in;
* tracing enabled: still bit-exact (spans observe, never mutate), the
  expected span vocabulary shows up, hierarchy/ring/drop semantics hold;
* ``explain``'s reported top-k IS ``retrieve``'s (ids AND score bits)
  across both candidate modes and both megakernels, masked and filtered,
  and its funnel counts are consistent with the retrieval outputs;
* ``explain_timeline``: per-generation contributions sum to k and the
  merged top-k equals ``retrieve_timeline``;
* the registry renders valid Prometheus text exposition — including from
  a live RetrievalService — per scripts/check_metrics_exposition.py.
"""
import dataclasses
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import (EngineConfig, ShardedTimeline, build_index, engine,
                        new_generation, retrieve_timeline)
from repro.core.bitvector import Pred, compile_filter
from repro.data.synthetic import make_corpus
from repro.obs import trace
from repro.obs.registry import MetricsRegistry
from repro.serving import RetrievalService

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "scripts"))
try:
    from check_metrics_exposition import validate_exposition
finally:
    sys.path.pop(0)

CFG = EngineConfig(nprobe=8, th=0.2, th_r=0.4, n_filter=128, n_docs=48, k=10)

RETRIEVAL_CFGS = {
    "ref-score_all": CFG,
    "ref-compact": dataclasses.replace(CFG, candidate_mode="compact",
                                       cand_cap=600),
    "prefilter-megakernel": dataclasses.replace(
        CFG, use_kernels=True, fused_late_interaction=False),
    "pqinter-megakernel": dataclasses.replace(
        CFG, use_kernels=True, fused_prefilter=False),
    "fused-score_all": dataclasses.replace(CFG, use_kernels=True),
    "fused-compact": dataclasses.replace(CFG, use_kernels=True,
                                         candidate_mode="compact",
                                         cand_cap=600),
}


@pytest.fixture(scope="module")
def obs_corpus():
    return make_corpus(5, n_docs=400, cap=24, min_len=8, n_queries=16,
                       n_topics=32)


@pytest.fixture(scope="module")
def obs_preds(obs_corpus):
    rng = np.random.default_rng(7)
    n = obs_corpus.doc_embs.shape[0]
    return {"lang_en": rng.random(n) < 0.7, "recent": rng.random(n) < 0.4}


@pytest.fixture(scope="module")
def obs_index(obs_corpus, obs_preds):
    c = obs_corpus
    return build_index(jax.random.PRNGKey(0), c.doc_embs, c.doc_lens,
                       n_centroids=128, m=8, nbits=4, kmeans_iters=3,
                       predicates=obs_preds)


@pytest.fixture(scope="module")
def obs_timeline(obs_corpus, obs_preds):
    c = obs_corpus
    idx0, m0 = build_index(
        jax.random.PRNGKey(0), c.doc_embs[:200], c.doc_lens[:200],
        n_centroids=128, m=8, nbits=4, kmeans_iters=3,
        predicates={k: v[:200] for k, v in obs_preds.items()})
    tl = ShardedTimeline.of((idx0, m0))
    return tl.append(*new_generation(
        idx0, m0, c.doc_embs[200:], c.doc_lens[200:],
        predicates={k: v[200:] for k, v in obs_preds.items()}))


# ---------------------------------------------------------------------------
# Tracer: no-op contract, hierarchy, ring, export
# ---------------------------------------------------------------------------

def test_disabled_tracer_is_noop_singleton():
    """The overhead contract: with tracing disabled (the default), every
    instrumented call site gets the SAME shared no-op span — no
    allocation, no clock read. Identity, not just equality."""
    assert trace.get_tracer() is trace.NOOP_TRACER
    assert trace.span("anything", attr=1) is trace.NOOP_SPAN
    assert trace.span("else") is trace.NOOP_SPAN
    # the no-op span is inert through the full protocol
    with trace.span("x") as sp:
        assert sp is trace.NOOP_SPAN
        assert sp.set(foo=1) is trace.NOOP_SPAN
    assert trace.record("x", 0.1) is None


def test_noop_span_propagates_exceptions():
    with pytest.raises(RuntimeError, match="boom"):
        with trace.span("x"):
            raise RuntimeError("boom")


def test_tracing_scope_installs_and_restores():
    assert trace.get_tracer() is trace.NOOP_TRACER
    with obs.tracing() as t:
        assert trace.get_tracer() is t
        assert t.enabled
        with trace.span("inside"):
            pass
    assert trace.get_tracer() is trace.NOOP_TRACER
    assert [s["name"] for s in t.finished()] == ["inside"]


def test_span_hierarchy_ids():
    with obs.tracing() as t:
        with trace.span("root", a=1):
            with trace.span("child"):
                with trace.span("grandchild"):
                    pass
            trace.record("sibling", 0.005, b=2)
        with trace.span("root2"):
            pass
    by_name = {s["name"]: s for s in t.finished()}
    root, child, gc = (by_name[n] for n in ("root", "child", "grandchild"))
    assert root["parent_id"] is None
    assert root["trace_id"] == root["span_id"]
    assert child["parent_id"] == root["span_id"]
    assert gc["parent_id"] == child["span_id"]
    assert gc["trace_id"] == root["trace_id"]
    # record() parents under the innermost OPEN span at call time
    sib = by_name["sibling"]
    assert sib["parent_id"] == root["span_id"]
    assert sib["attrs"] == {"b": 2} and sib["duration_s"] == 0.005
    # a second root starts a new trace
    assert by_name["root2"]["trace_id"] != root["trace_id"]
    # children finish (emit) before parents
    names = [s["name"] for s in t.finished()]
    assert names.index("grandchild") < names.index("child") \
        < names.index("root")
    assert root["attrs"] == {"a": 1}


def test_span_set_and_error_flag():
    with obs.tracing() as t:
        with trace.span("work", planned=3) as sp:
            sp.set(done=2)
        try:
            with trace.span("fails"):
                raise ValueError("x")
        except ValueError:
            pass
    by_name = {s["name"]: s for s in t.finished()}
    assert by_name["work"]["attrs"] == {"planned": 3, "done": 2}
    assert by_name["fails"]["error"] is True
    assert "error" not in by_name["work"]


def test_span_durations_from_injected_clock():
    now = [0.0]

    def clk():
        return now[0]

    with obs.tracing(clock=clk) as t:
        with trace.span("outer"):
            now[0] += 0.5
            with trace.span("inner"):
                now[0] += 0.25
    by_name = {s["name"]: s for s in t.finished()}
    assert by_name["inner"]["duration_s"] == pytest.approx(0.25)
    assert by_name["outer"]["duration_s"] == pytest.approx(0.75)
    assert by_name["inner"]["start"] == pytest.approx(0.5)


def test_ring_capacity_drops_oldest():
    with obs.tracing(capacity=3) as t:
        for i in range(5):
            with trace.span(f"s{i}"):
                pass
    assert [s["name"] for s in t.finished()] == ["s2", "s3", "s4"]
    assert t.dropped == 2


def test_drain_and_export_jsonl(tmp_path):
    with obs.tracing() as t:
        with trace.span("a", arr=np.int32(3)):   # non-JSON attr -> str()
            pass
        with trace.span("b"):
            pass
    path = tmp_path / "spans.jsonl"
    assert t.export_jsonl(path) == 2
    lines = path.read_text().splitlines()
    assert [json.loads(ln)["name"] for ln in lines] == ["a", "b"]
    # export leaves the ring intact; drain empties it
    assert len(t.finished()) == 2
    assert [s["name"] for s in t.drain()] == ["a", "b"]
    assert t.finished() == []


def test_tracer_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        trace.Tracer(capacity=0)


# ---------------------------------------------------------------------------
# Serving under tracing: bit-exact, expected span vocabulary
# ---------------------------------------------------------------------------

def test_service_traced_is_bit_exact_with_expected_spans(obs_corpus,
                                                         obs_timeline):
    """Tracing on changes no result bit, and the serving hot path emits
    the documented span vocabulary (queue wait, flush, per-generation
    lookup/miss, merge, swap)."""
    c = obs_corpus
    q = np.asarray(c.queries[:4])
    ref_svc = RetrievalService(obs_timeline, CFG)
    ref_cold = ref_svc.query(q)
    ref_warm = ref_svc.query(q)

    svc = RetrievalService(obs_timeline, CFG)
    with obs.tracing() as t:
        cold = svc.query(q)
        warm = svc.query(q)
        # drive the batcher path too, so queue_wait/flush spans appear
        ticket = svc.submit(c.queries[4])
        svc.flush()
        # and a timeline swap (prepare + install spans)
        svc.update_timeline(obs_timeline)
    for got, want in ((cold, ref_cold), (warm, ref_warm)):
        np.testing.assert_array_equal(np.asarray(got.doc_ids),
                                      np.asarray(want.doc_ids))
        np.testing.assert_array_equal(np.asarray(got.scores),
                                      np.asarray(want.scores))
    assert ticket.done
    names = {s["name"] for s in t.finished()}
    for expect in ("service.execute", "service.generation",
                   "service.cache_lookup", "service.miss_execute",
                   "service.merge", "batcher.queue_wait", "service.flush",
                   "service.swap.prepare", "service.swap.install",
                   "engine.retrieve.dispatch"):
        assert expect in names, (expect, sorted(names))
    # generation spans carry the hit/miss split as attrs
    gen_spans = [s for s in t.finished() if s["name"] == "service.generation"]
    assert all({"hits", "misses"} <= s["attrs"].keys() for s in gen_spans)
    # warm pass: the immutable generation's lookups all hit
    warm_gen = [s for s in gen_spans
                if s["attrs"].get("generation") == 0][-2]
    assert warm_gen["attrs"]["hits"] + warm_gen["attrs"]["misses"] == 4


# ---------------------------------------------------------------------------
# explain: funnel vs retrieve, all dispatch modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(RETRIEVAL_CFGS))
def test_explain_matches_retrieve_and_funnel_consistent(obs_corpus,
                                                        obs_index, name):
    """The explained top-k IS retrieve's (ids AND score bits) in every
    dispatch mode, and the funnel counts narrate a consistent pipeline."""
    idx, meta = obs_index
    # budgets are used as-is (like retrieve) — clamp to the corpus first,
    # exactly as the per-generation serving path does
    cfg = engine.adapt_config_to_corpus(RETRIEVAL_CFGS[name],
                                        meta.n_docs, meta.cap)
    q = obs_corpus.queries[0]
    rpt = obs.explain.explain(idx, q, cfg)
    ref = engine.retrieve(idx, jnp.asarray(q)[None], cfg)
    np.testing.assert_array_equal(rpt.topk_ids, np.asarray(ref.doc_ids)[0])
    np.testing.assert_array_equal(rpt.topk_scores,
                                  np.asarray(ref.scores)[0])
    # funnel consistency, top to bottom
    n_docs = idx.codes.shape[0]
    assert rpt.live_terms == cfg.n_q
    assert 0 < rpt.centroids_probed <= min(rpt.probe_budget,
                                           rpt.n_centroids)
    assert 0 < rpt.candidates <= n_docs
    assert rpt.n_filter_budget == cfg.n_filter
    assert 0 < rpt.n_filter_survivors <= rpt.n_filter_budget
    assert rpt.n_filter_survivors <= rpt.candidates
    assert rpt.phase3_docs_scored == cfg.n_filter
    assert rpt.phase4_docs_scored == cfg.n_docs
    assert 0.0 <= rpt.scored_term_fraction <= 1.0
    assert rpt.candidate_mode == cfg.candidate_mode
    if cfg.candidate_mode == "compact":
        assert rpt.candidate_cap == cfg.cand_cap
    else:
        assert rpt.candidate_cap is None
    assert rpt.k == cfg.k and len(rpt.topk_ids) == cfg.k
    assert set(rpt.phase_ms) == {"phase1", "phase2", "phase3", "phase4"}
    assert all(v >= 0 for v in rpt.phase_ms.values())
    # JSON-ready
    json.dumps(rpt.to_dict())


def test_explain_masked_query_matches_padded_retrieve(obs_corpus, obs_index):
    """A masked (pruned/padded) query explains bit-identically to its
    retrieval, and masking shrinks the probe budget."""
    idx, _ = obs_index
    q = obs_corpus.queries[1].copy()
    mask = np.ones(CFG.n_q, bool)
    mask[20:] = False
    q[20:] = 0.0
    rpt = obs.explain.explain(idx, q, CFG, q_mask=mask)
    ref = engine.retrieve(idx, jnp.asarray(q)[None], CFG,
                          jnp.asarray(mask)[None])
    np.testing.assert_array_equal(rpt.topk_ids, np.asarray(ref.doc_ids)[0])
    np.testing.assert_array_equal(rpt.topk_scores,
                                  np.asarray(ref.scores)[0])
    assert rpt.live_terms == 20
    assert rpt.probe_budget == 20 * CFG.nprobe
    assert rpt.centroids_probed <= rpt.probe_budget


def test_explain_filtered_query(obs_corpus, obs_index, obs_preds):
    """Filtered explain: selectivity equals the predicate plane's count,
    candidates come only from passing docs, and the top-k equals filtered
    retrieve bit for bit."""
    idx, meta = obs_index
    q = obs_corpus.queries[2]
    plan = compile_filter(Pred("lang_en") & ~Pred("recent"),
                          meta.pred_names)
    rpt = obs.explain.explain(idx, q, CFG, doc_filter=plan)
    ref = engine.retrieve(idx, jnp.asarray(q)[None], CFG, doc_filter=plan)
    np.testing.assert_array_equal(rpt.topk_ids, np.asarray(ref.doc_ids)[0])
    np.testing.assert_array_equal(rpt.topk_scores,
                                  np.asarray(ref.scores)[0])
    want_passing = int((obs_preds["lang_en"] & ~obs_preds["recent"]).sum())
    assert rpt.docs_passing_filter == want_passing
    assert rpt.filter_selectivity == pytest.approx(
        want_passing / idx.codes.shape[0])
    # the candidate bitmap is pre-ANDed with the filter
    assert rpt.candidates <= want_passing
    # unfiltered explain reports no selectivity
    assert obs.explain.explain(idx, q, CFG).docs_passing_filter is None


def test_explain_input_validation(obs_corpus, obs_index):
    idx, _ = obs_index
    with pytest.raises(ValueError, match="per-query"):
        obs.explain.explain(idx, obs_corpus.queries[:2], CFG)
    with pytest.raises(ValueError, match="expected"):
        obs.explain.explain(idx, obs_corpus.queries[0][:5], CFG)
    with pytest.raises(ValueError, match="compiled FilterPlan"):
        obs.explain.explain(idx, obs_corpus.queries[0], CFG,
                            doc_filter=Pred("lang_en"))


def test_explain_timeline_contributions_sum_to_k(obs_corpus, obs_timeline):
    """Timeline explain: the merged top-k equals retrieve_timeline and
    per-generation contributions (global-id range attribution) sum to k."""
    q = obs_corpus.queries[3]
    rpt = obs.explain.explain_timeline(obs_timeline, q, CFG)
    ref = retrieve_timeline(obs_timeline, jnp.asarray(q)[None], CFG)
    np.testing.assert_array_equal(rpt.topk_ids, np.asarray(ref.doc_ids)[0])
    np.testing.assert_array_equal(rpt.topk_scores,
                                  np.asarray(ref.scores)[0])
    assert rpt.n_generations == len(obs_timeline)
    assert sum(g.contribution for g in rpt.generations) == CFG.k
    offsets = [g.offset for g in rpt.generations]
    assert offsets == sorted(offsets)
    for g in rpt.generations:
        # every final id attributed to g really lies in its range
        in_range = ((rpt.topk_ids >= g.offset)
                    & (rpt.topk_ids < g.offset + g.n_docs)).sum()
        assert g.contribution == int(in_range)
        assert g.funnel.k == CFG.k
    json.dumps(rpt.to_dict())


def test_explain_timeline_filtered_expr(obs_corpus, obs_timeline,
                                        obs_preds):
    """explain_timeline accepts a raw FilterExpr (compiled per epoch like
    retrieve_timeline) and stays bit-exact + k-attributed."""
    q = obs_corpus.queries[4]
    expr = Pred("lang_en")
    rpt = obs.explain.explain_timeline(obs_timeline, q, CFG,
                                       doc_filter=expr)
    ref = retrieve_timeline(obs_timeline, jnp.asarray(q)[None], CFG,
                            doc_filter=expr)
    np.testing.assert_array_equal(rpt.topk_ids, np.asarray(ref.doc_ids)[0])
    assert sum(g.contribution for g in rpt.generations) == CFG.k
    # every returned doc passes the filter (ids are global)
    assert obs_preds["lang_en"][rpt.topk_ids].all()
    # per-generation funnels carry the per-generation selectivity
    for g in rpt.generations:
        lo, hi = g.offset, g.offset + g.n_docs
        assert g.funnel.docs_passing_filter == \
            int(obs_preds["lang_en"][lo:hi].sum())


# ---------------------------------------------------------------------------
# Registry: instruments + Prometheus exposition format
# ---------------------------------------------------------------------------

def test_registry_counter_semantics():
    r = MetricsRegistry()
    c = r.counter("reqs_total", "requests")
    c.inc()
    c.inc(2.5)
    assert c.value() == 3.5
    with pytest.raises(ValueError, match="_total"):
        r.counter("reqs", "bad name")
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)
    # get-or-create: same name -> same instrument; kind clash -> error
    assert r.counter("reqs_total", "requests") is c
    with pytest.raises(ValueError):
        r.gauge("reqs_total", "now a gauge?")


def test_registry_gauge_labels_and_escaping():
    r = MetricsRegistry()
    g = r.gauge("temp", "temperature", label_names=("site",))
    g.set(1.5, site='a"b\\c\nd')
    text = r.exposition()
    assert validate_exposition(text) == []
    assert 'site="a\\"b\\\\c\\nd"' in text
    assert g.value(site='a"b\\c\nd') == 1.5
    with pytest.raises(ValueError):
        g.set(1.0)                       # missing the declared label


def test_registry_histogram_buckets_cumulative():
    r = MetricsRegistry()
    h = r.histogram("sizes", "batch sizes", buckets=(1, 4, 16))
    for v in (1, 3, 5, 20):
        h.observe(v)
    text = r.exposition()
    assert validate_exposition(text) == []
    assert 'sizes_bucket{le="1"} 1' in text
    assert 'sizes_bucket{le="4"} 2' in text
    assert 'sizes_bucket{le="16"} 3' in text
    assert 'sizes_bucket{le="+Inf"} 4' in text
    assert "sizes_count 4" in text


def test_registry_summary_from_latency_stats():
    from repro.serving import LatencyStats
    r = MetricsRegistry()
    ls = LatencyStats(window=64)
    for v in range(1, 11):
        ls.record(v / 1000)
    r.summary("lat_seconds", "latency", stats=ls)
    text = r.exposition()
    assert validate_exposition(text) == []
    assert 'lat_seconds{quantile="0.5"}' in text
    assert "lat_seconds_count 10" in text
    snap = r.snapshot()
    assert snap["lat_seconds"]["count"] == 10


def test_live_service_exposition_passes_lint(obs_corpus, obs_timeline):
    """The acceptance gate: a live RetrievalService's exposition passes
    the same validator CI runs."""
    svc = RetrievalService(obs_timeline, CFG)
    q = np.asarray(obs_corpus.queries[:4])
    svc.query(q)
    svc.query(q)
    text = svc.exposition()
    errors = validate_exposition(text)
    assert errors == [], "\n".join(errors)
    assert "emvb_queries_total 8" in text
    assert "emvb_cache_hits_total" in text
    assert "emvb_timeline_docs" in text
    assert 'emvb_generation_cache_hit_ratio{generation=' in text
    # JSON snapshot and exposition agree on the headline counter
    assert svc.stats()["queries"] == 8
