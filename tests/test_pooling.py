"""Constant-space document budgets (PR 9 tentpole): pooling edge cases,
pooled persistence (round trip + corruption modes), growth/maintenance
budget carry, and the footprint counterfactual.

The property suite (tests/test_props.py) covers the randomized laws; this
file pins the deterministic corners: single-token docs, m=1, pass-through
identity, degenerate (all-identical-token) clusters, and every new schema-v4
validation failure.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EngineConfig, ShardedTimeline, add_passages,
                        build_index, engine, index_fingerprint, load_index,
                        merge_generations, new_generation, pool_documents,
                        retrieve_timeline, save_index)
from repro.core.store import generation_footprint, timeline_footprint
from repro.data.synthetic import make_corpus
from repro.serving import reepoch_tail

CFG = EngineConfig(n_q=8, nprobe=4, th=0.2, th_r=0.3, n_filter=64,
                   n_docs=32, k=8)


@pytest.fixture(scope="module")
def pcorpus():
    return make_corpus(7, n_docs=120, cap=12, min_len=2, d=32, n_topics=12,
                       n_queries=6, n_q=8)


@pytest.fixture(scope="module")
def pooled(pcorpus):
    return build_index(jax.random.PRNGKey(0), pcorpus.doc_embs,
                       pcorpus.doc_lens, n_centroids=32, m=8, nbits=4,
                       kmeans_iters=2, doc_budget=4)


# ---------------------------------------------------------------------------
# pool_documents edge cases
# ---------------------------------------------------------------------------

def test_pool_rejects_nonpositive_budget(pcorpus):
    for bad in (0, -3):
        with pytest.raises(ValueError, match="budget"):
            pool_documents(pcorpus.doc_embs, pcorpus.doc_lens, bad)


def test_single_token_docs_pass_through():
    rng = np.random.default_rng(0)
    embs = np.zeros((5, 6, 8), np.float32)
    embs[:, 0] = rng.normal(size=(5, 8)).astype(np.float32)
    lens = np.ones(5, np.int32)
    for budget in (1, 3):
        out, olens = pool_documents(embs, lens, budget)
        np.testing.assert_array_equal(olens, lens)
        np.testing.assert_array_equal(out[:, 0], embs[:, 0])
        assert (out[:, 1:] == 0.0).all()


def test_budget_one_pools_to_token_mean(pcorpus):
    """m=1 is one cluster holding every token: the pooled vector is the
    mean of the document's RAW token vectors."""
    out, olens = pool_documents(pcorpus.doc_embs, pcorpus.doc_lens, 1)
    assert out.shape[1] == 1
    assert (olens == 1).all()
    for i in (0, 17, 119):
        ln = int(pcorpus.doc_lens[i])
        np.testing.assert_allclose(out[i, 0],
                                   pcorpus.doc_embs[i, :ln].mean(0),
                                   rtol=1e-5, atol=1e-6)


def test_budget_covering_all_lens_is_identity(pcorpus):
    """m >= every doc len: pooling is byte-for-byte the identity."""
    out, olens = pool_documents(pcorpus.doc_embs, pcorpus.doc_lens,
                                int(pcorpus.doc_lens.max()))
    np.testing.assert_array_equal(olens, pcorpus.doc_lens)
    np.testing.assert_array_equal(out, pcorpus.doc_embs[:, :out.shape[1]])


def test_identical_tokens_collapse_to_one_cluster():
    """A doc of identical tokens degenerates every cluster onto the same
    centroid; empties are dropped, leaving ONE pooled vector == the token."""
    tok = np.full(8, 0.5, np.float32)
    embs = np.tile(tok, (1, 10, 1)).astype(np.float32)
    lens = np.asarray([10], np.int32)
    out, olens = pool_documents(embs, lens, 4)
    assert olens[0] == 1
    np.testing.assert_allclose(out[0, 0], tok, rtol=1e-6)
    assert (out[0, 1:] == 0.0).all()
    assert np.isfinite(out).all()


# ---------------------------------------------------------------------------
# Pooled build: meta, footprint counterfactual, budget-aware growth
# ---------------------------------------------------------------------------

def test_pooled_build_meta_and_footprint(pcorpus, pooled):
    idx, meta = pooled
    assert meta.doc_budget == 4
    assert meta.cap <= 4
    assert meta.n_raw_tokens == int(pcorpus.doc_lens.sum())
    assert (np.asarray(idx.doc_lens) <= 4).all()
    fp = generation_footprint(idx, meta)
    assert fp["doc_budget"] == 4
    assert fp["n_raw_tokens"] == meta.n_raw_tokens
    # the acceptance number: pooled bytes/doc strictly beat the per-token
    # counterfactual, by exactly the token-count ratio
    assert fp["bytes_per_doc"] < fp["unpooled_bytes_per_doc"]
    assert fp["pooling_savings"] == pytest.approx(
        1.0 - fp["n_tokens"] / fp["n_raw_tokens"])
    assert fp["pooling_savings"] > 0.3


def test_pooled_growth_matches_standalone_pooling(pcorpus, pooled):
    """add_passages / new_generation accept RAW docs on a budgeted index and
    pool them exactly as pool_documents would (same deterministic seeds)."""
    idx, meta = pooled
    new_embs, new_lens = pcorpus.doc_embs[:40], pcorpus.doc_lens[:40]
    want_lens = pool_documents(new_embs, new_lens, meta.doc_budget)[1]

    grown, gmeta = add_passages(idx, meta, new_embs, new_lens)
    assert gmeta.doc_budget == meta.doc_budget
    assert gmeta.n_raw_tokens == meta.n_raw_tokens + int(new_lens.sum())
    np.testing.assert_array_equal(
        np.asarray(grown.doc_lens)[meta.n_docs:], want_lens)

    gen, genmeta = new_generation(idx, meta, new_embs, new_lens)
    assert genmeta.doc_budget == meta.doc_budget
    assert genmeta.n_raw_tokens == int(new_lens.sum())
    np.testing.assert_array_equal(np.asarray(gen.doc_lens), want_lens)


def test_budgeted_growth_overflowing_base_cap_is_actionable():
    """A budgeted index whose base corpus never filled the budget has
    cap < budget; growing it with longer docs must fail with the rebuild
    hint, not corrupt the layout."""
    c = make_corpus(11, n_docs=40, cap=6, min_len=2, d=16, n_topics=8,
                    n_queries=4, n_q=4)
    short_lens = np.minimum(c.doc_lens, 4).astype(np.int32)
    idx, meta = build_index(jax.random.PRNGKey(0), c.doc_embs, short_lens,
                            n_centroids=16, m=4, nbits=4, kmeans_iters=2,
                            doc_budget=8)
    assert meta.cap == 6 < 8  # the budget was never filled (cap < budget)
    long_docs = make_corpus(12, n_docs=4, cap=8, min_len=8, d=16,
                            n_topics=8, n_queries=1, n_q=4)
    with pytest.raises(ValueError, match="larger cap"):
        add_passages(idx, meta, long_docs.doc_embs, long_docs.doc_lens)


# ---------------------------------------------------------------------------
# Persistence: round trip + every new schema-v4 corruption mode
# ---------------------------------------------------------------------------

def _resave(src, dst, mutate_manifest=None):
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(src, "arrays.npz")) as npz:
        arrays = {k: npz[k] for k in npz.files}
    if mutate_manifest:
        mutate_manifest(manifest)
    os.makedirs(dst, exist_ok=True)
    np.savez(os.path.join(dst, "arrays.npz"), **arrays)
    with open(os.path.join(dst, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return dst


@pytest.fixture()
def saved_pooled(tmp_path, pooled):
    idx, meta = pooled
    return save_index(str(tmp_path / "pooled"), idx, meta)


def test_pooled_save_load_round_trip(pcorpus, saved_pooled, pooled):
    idx, meta = pooled
    loaded, lmeta = load_index(saved_pooled)
    assert lmeta == meta
    assert index_fingerprint(loaded) == index_fingerprint(idx)
    q = jnp.asarray(pcorpus.queries[:4])
    a = engine.retrieve(idx, q, CFG)
    b = engine.retrieve(loaded, q, CFG)
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores),
                                  np.asarray(b.scores))


@pytest.mark.parametrize("bad", ["8", True, 0, -4, 2.5])
def test_load_rejects_bad_doc_budget(tmp_path, saved_pooled, bad):
    dst = _resave(saved_pooled, str(tmp_path / "bad"),
                  lambda m: m["meta"].update(doc_budget=bad))
    with pytest.raises(ValueError, match="doc_budget"):
        load_index(dst)


def test_load_rejects_cap_exceeding_budget(tmp_path, saved_pooled):
    dst = _resave(saved_pooled, str(tmp_path / "bad"),
                  lambda m: m["meta"].update(doc_budget=1))
    with pytest.raises(ValueError, match="doc_budget"):
        load_index(dst)


@pytest.mark.parametrize("bad", [-5, "many", 1.5])
def test_load_rejects_bad_n_raw_tokens(tmp_path, saved_pooled, bad):
    dst = _resave(saved_pooled, str(tmp_path / "bad"),
                  lambda m: m["meta"].update(n_raw_tokens=bad))
    with pytest.raises(ValueError, match="n_raw_tokens"):
        load_index(dst)


def test_load_rejects_n_raw_tokens_below_stored(tmp_path, saved_pooled):
    dst = _resave(saved_pooled, str(tmp_path / "bad"),
                  lambda m: m["meta"].update(n_raw_tokens=1))
    with pytest.raises(ValueError, match="n_raw_tokens"):
        load_index(dst)


def test_v3_manifest_loads_with_budget_defaults(tmp_path, pcorpus):
    """A pre-budget (schema v3) save loads as doc_budget=None /
    n_raw_tokens=0 — the per-token layout, footprints falling back to the
    stored token count."""
    idx, meta = build_index(jax.random.PRNGKey(1), pcorpus.doc_embs[:30],
                            pcorpus.doc_lens[:30], n_centroids=16, m=4,
                            nbits=4, kmeans_iters=2)
    src = save_index(str(tmp_path / "v4"), idx, meta)

    def downgrade(m):
        m["schema_version"] = 3
        m["meta"].pop("doc_budget")
        m["meta"].pop("n_raw_tokens")
    dst = _resave(src, str(tmp_path / "v3"), downgrade)
    loaded, lmeta = load_index(dst)
    assert lmeta.doc_budget is None
    assert lmeta.n_raw_tokens == 0
    assert index_fingerprint(loaded) == index_fingerprint(idx)
    fp = generation_footprint(loaded, lmeta)
    assert fp["n_raw_tokens"] == fp["n_tokens"]
    assert fp["pooling_savings"] == 0.0


# ---------------------------------------------------------------------------
# Maintenance: merge refuses mixed budgets; re-epoching carries the budget
# ---------------------------------------------------------------------------

def test_merge_refuses_mixed_budgets(pcorpus, pooled):
    idx, meta = pooled
    plain_gen = new_generation(
        idx, dataclasses.replace(meta, doc_budget=None),
        pool_documents(pcorpus.doc_embs[:20], pcorpus.doc_lens[:20], 4)[0],
        pool_documents(pcorpus.doc_embs[:20], pcorpus.doc_lens[:20], 4)[1])
    tl = ShardedTimeline.of((idx, meta)).append(*plain_gen)
    with pytest.raises(ValueError, match="mixes document budgets"):
        merge_generations(tl, 0, 2)


def test_merge_carries_budget_and_raw_tokens(pcorpus, pooled):
    idx, meta = pooled
    tl = ShardedTimeline.of((idx, meta)).append(
        *new_generation(idx, meta, pcorpus.doc_embs[:20],
                        pcorpus.doc_lens[:20]))
    merged = merge_generations(tl, 0, 2)
    mmeta = merged.metas[0]
    assert mmeta.doc_budget == 4
    assert mmeta.n_raw_tokens == sum(m.n_raw_tokens for m in tl.metas)
    tf = timeline_footprint(merged)
    assert tf["doc_budget"] == 4
    assert tf["bytes_per_doc"] < tf["unpooled_bytes_per_doc"]


def test_reepoch_carries_budget_and_accepts_raw_docs(pcorpus, pooled):
    """reepoch_tail on a budgeted timeline takes RAW embeddings, re-pools
    them under the inherited budget, and the fresh epoch keeps it."""
    idx, meta = pooled
    tl = ShardedTimeline.of((idx, meta)).append(
        *new_generation(idx, meta, pcorpus.doc_embs[:20],
                        pcorpus.doc_lens[:20]))
    et = reepoch_tail(tl, 1, pcorpus.doc_embs[:20], pcorpus.doc_lens[:20],
                      key=jax.random.PRNGKey(2), n_centroids=16,
                      kmeans_iters=2)
    new_meta = et.epochs[-1].metas[0]
    assert new_meta.doc_budget == 4
    assert new_meta.n_raw_tokens == int(pcorpus.doc_lens[:20].sum())


# ---------------------------------------------------------------------------
# Pooled timelines retrieve end to end (sanity on the whole thread-through)
# ---------------------------------------------------------------------------

def test_pooled_timeline_retrieves_and_reports(pcorpus, pooled):
    idx, meta = pooled
    tl = ShardedTimeline.of((idx, meta)).append(
        *new_generation(idx, meta, pcorpus.doc_embs[:20],
                        pcorpus.doc_lens[:20]))
    res = retrieve_timeline(tl, jnp.asarray(pcorpus.queries[:4]), CFG)
    assert res.doc_ids.shape == (4, CFG.k)
    assert (np.asarray(res.doc_ids) < tl.n_docs).all()
    tf = timeline_footprint(tl)
    assert tf["doc_budget"] == 4
    assert tf["n_raw_tokens"] == sum(m.n_raw_tokens for m in tl.metas)
    assert 0.0 < tf["pooling_savings"] < 1.0
