"""The tentpole contract of the batch-native megakernels: with
``cfg.batched_kernels`` the whole micro-batch runs as ONE launch per fused
phase pair, and the result equals the per-query vmap path bit-exactly —
ids AND score bits, including tie order — across both candidate modes,
masked/pruned queries, shard_map, the timeline, and ``RetrievalService``.

Plus the deprecation shims: every pre-batch single-query phase signature
still works, warns ``DeprecationWarning``, and returns exactly what the
unified batched signature returns for that query."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EngineConfig, QueryBatch, ShardedTimeline, engine,
                        new_generation, retrieve_timeline)
from repro.serving import RetrievalService

CFG = EngineConfig(nprobe=8, th=0.2, th_r=0.4, n_filter=128, n_docs=48, k=10,
                   use_kernels=True, fused_prefilter=True,
                   fused_late_interaction=True)
VMAP = dataclasses.replace(CFG, batched_kernels=False)


def _eq(a, b):
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


# ---------------------------------------------------------------------------
# batched == vmap, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["score_all", "compact"])
def test_batched_equals_vmap_both_modes(small_corpus, small_index, mode):
    idx, _ = small_index
    bcfg = dataclasses.replace(CFG, candidate_mode=mode, cand_cap=600)
    q = jnp.asarray(small_corpus.queries[:4])
    _eq(engine.retrieve(idx, q, bcfg),
        engine.retrieve(idx, q, dataclasses.replace(bcfg,
                                                    batched_kernels=False)))


def test_batched_equals_vmap_masked(small_corpus, small_index):
    """Heterogeneous zero-padded queries with per-term masks — the serving
    shape — take the same batched launch and stay bit-exact."""
    idx, _ = small_index
    q = np.asarray(small_corpus.queries[:3]).copy()
    mask = np.zeros(q.shape[:2], bool)
    for i, keep in enumerate((12, 20, q.shape[1])):
        q[i, keep:] = 0.0
        mask[i, :keep] = True
    qj, mj = jnp.asarray(q), jnp.asarray(mask)
    _eq(engine.retrieve(idx, qj, CFG, mj), engine.retrieve(idx, qj, VMAP, mj))
    # the mask travels identically inside a QueryBatch
    _eq(engine.retrieve(idx, QueryBatch(qj, mj), CFG),
        engine.retrieve(idx, qj, CFG, mj))


def test_query_batch_conflicting_mask_raises(small_corpus, small_index):
    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[:2])
    m = jnp.ones(q.shape[:2], jnp.bool_)
    with pytest.raises(ValueError, match="exactly one"):
        engine.retrieve(idx, QueryBatch(q, m), CFG, m)


def test_batched_equals_vmap_under_shard_map(small_corpus, small_index):
    """The shard_map plan routes its per-shard batch through the same
    batched dispatch; the merged two-level top-k must equal the
    single-device vmap result bit-exactly."""
    from repro.launch.serve import make_shardmap_retriever, shard_index

    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[:4])
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    stacked = shard_index(idx, 1)
    with mesh:
        sharded = make_shardmap_retriever(mesh, CFG)(stacked, q)
    _eq(sharded, engine.retrieve(idx, q, VMAP))


@pytest.fixture(scope="module")
def two_gen_timeline(small_corpus, small_index):
    idx, meta = small_index
    return ShardedTimeline.of((idx, meta)).append(*new_generation(
        idx, meta, small_corpus.doc_embs[:100], small_corpus.doc_lens[:100]))


def test_batched_equals_vmap_timeline(small_corpus, two_gen_timeline):
    """Per-generation retrieval + cross-generation merge ride the batched
    kernels; the merged top-k equals the vmap path's bit-exactly (the
    second generation is smaller than n_filter, so the clamped-budget
    branch is exercised too)."""
    q = jnp.asarray(small_corpus.queries[:3])
    _eq(retrieve_timeline(two_gen_timeline, q, CFG),
        retrieve_timeline(two_gen_timeline, q, VMAP))


def test_service_miss_lane_rides_batched_kernels(small_corpus,
                                                 two_gen_timeline):
    """submit/flush pads heterogeneous queries to one dense QueryBatch, so
    the miss lane is a batched launch — each ticket must still equal the
    vmap-path retrieval of ITS unpadded prefix."""
    svc = RetrievalService(two_gen_timeline, CFG, max_batch=4)
    prefixes = (14, 32, 25)
    tickets = [svc.submit(np.asarray(small_corpus.queries[i][:n]))
               for i, n in enumerate(prefixes)]
    svc.flush()
    for i, (t, n) in enumerate(zip(tickets, prefixes)):
        ref = retrieve_timeline(
            two_gen_timeline, jnp.asarray(small_corpus.queries[i:i + 1, :n]),
            VMAP)
        np.testing.assert_array_equal(t.result()[1],
                                      np.asarray(ref.doc_ids)[0])
        np.testing.assert_array_equal(t.result()[0],
                                      np.asarray(ref.scores)[0])


# ---------------------------------------------------------------------------
# deprecation shims: old signatures warn and match the unified convention
# ---------------------------------------------------------------------------

LCFG = EngineConfig(nprobe=8, th=0.2, th_r=0.4, n_filter=128, n_docs=48,
                    k=10)          # jnp path: shim equality, no kernel cost


def test_legacy_phase_signatures_warn_and_match(small_corpus, small_index):
    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[0])
    qb = q[None]
    cs_b, bits_b, bm_b = engine.phase1_candidates(idx, qb, LCFG)
    with pytest.warns(DeprecationWarning, match="phase1_candidates"):
        cs, bits, bm = engine.phase1_candidates(idx, q, LCFG)
    np.testing.assert_array_equal(np.asarray(bits), np.asarray(bits_b[0]))

    sel1_b = engine.phase2_prefilter(idx, qb, LCFG, bits=bits_b, bitmap=bm_b)
    with pytest.warns(DeprecationWarning, match="phase2_prefilter"):
        sel1 = engine.phase2_prefilter(idx, bits, bm, LCFG)
    np.testing.assert_array_equal(np.asarray(sel1), np.asarray(sel1_b[0]))

    sel2_b = engine.phase3_centroid_interaction(idx, qb, LCFG, cs=cs_b,
                                                sel1=sel1_b)
    with pytest.warns(DeprecationWarning, match="phase3_centroid"):
        sel2 = engine.phase3_centroid_interaction(idx, cs, sel1, LCFG)
    np.testing.assert_array_equal(np.asarray(sel2), np.asarray(sel2_b[0]))

    res_b = engine.phase4_late_interaction(idx, qb, LCFG, cs=cs_b,
                                           sel2=sel2_b)
    with pytest.warns(DeprecationWarning, match="phase4_late"):
        scores, ids = engine.phase4_late_interaction(idx, q, cs, sel2, LCFG)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(res_b.doc_ids[0]))
    np.testing.assert_array_equal(np.asarray(scores),
                                  np.asarray(res_b.scores[0]))

    with pytest.warns(DeprecationWarning, match="phase12_prefilter"):
        cs12, sel12 = engine.phase12_prefilter(idx, q, LCFG)
    np.testing.assert_array_equal(np.asarray(sel12), np.asarray(sel1_b[0]))

    res34_b = engine.phase34_late_interaction(idx, qb, LCFG, cs=cs_b,
                                              sel1=sel1_b)
    with pytest.warns(DeprecationWarning, match="phase34_late"):
        s34, i34 = engine.phase34_late_interaction(idx, q, cs, sel1, LCFG)
    np.testing.assert_array_equal(np.asarray(i34),
                                  np.asarray(res34_b.doc_ids[0]))


def test_new_signatures_do_not_warn(small_corpus, small_index, recwarn):
    idx, _ = small_index
    qb = jnp.asarray(small_corpus.queries[:2])
    cs, bits, bm = engine.phase1_candidates(idx, qb, LCFG)
    sel1 = engine.phase2_prefilter(idx, qb, LCFG, bits=bits, bitmap=bm)
    engine.phase34_late_interaction(idx, qb, LCFG, cs=cs, sel1=sel1)
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]
