"""Faceted/filtered retrieval over the predicate plane (docs/FILTERING.md).

The load-bearing contract: under lossless budgets, filtered retrieval is
BIT-EXACT to retrieve-then-post-filter — ids AND score bits — across the
jnp reference, the unfused kernels, both megakernels, batched and vmap
dispatch, both candidate modes, masked queries, reduced-precision CS, and
the timeline merge path (merged and unmerged). Plus: the FilterExpr →
FilterPlan compiler semantics, schema-v3 persistence with its corruption
modes, and the serving layer's filter-aware cache keys and micro-batching.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitvector as bv
from repro.core import engine, store
from repro.core.engine import EngineConfig
from repro.core.index import build_index

N_DOCS, CAP, D = 96, 12, 16
EXPR = bv.Pred("recent") & ~bv.Pred("lang_en")


@pytest.fixture(scope="module")
def fcorpus():
    key = jax.random.PRNGKey(0)
    embs = np.asarray(jax.random.normal(key, (N_DOCS, CAP, D)))
    lens = np.full((N_DOCS,), CAP, np.int32)
    rng = np.random.default_rng(0)
    preds = {"lang_en": rng.random(N_DOCS) < 0.7,
             "recent": rng.random(N_DOCS) < 0.5}
    queries = np.asarray(
        jax.random.normal(jax.random.PRNGKey(2), (3, 8, D)), np.float32)
    return embs, lens, preds, queries


@pytest.fixture(scope="module")
def findex(fcorpus):
    embs, lens, preds, _ = fcorpus
    return build_index(jax.random.PRNGKey(0), embs, lens, n_centroids=32,
                       predicates=preds)


# lossless budgets: every phase keeps the whole corpus, so the filtered and
# post-filtered rankings must agree bit for bit
BASE = dict(n_q=8, nprobe=4, th=0.2, th_r=0.3, n_filter=N_DOCS,
            n_docs=N_DOCS, k=8, cand_cap=N_DOCS, kernel_interpret=True)

MODES = {
    "ref-score_all": {},
    "ref-compact": dict(candidate_mode="compact"),
    "unfused-score_all": dict(use_kernels=True, fused_prefilter=False,
                              fused_late_interaction=False),
    "unfused-compact": dict(use_kernels=True, fused_prefilter=False,
                            fused_late_interaction=False,
                            candidate_mode="compact"),
    "fused-score_all": dict(use_kernels=True, batched_kernels=False),
    "fused-compact": dict(use_kernels=True, batched_kernels=False,
                          candidate_mode="compact"),
    "fused-batched-score_all": dict(use_kernels=True, batched_kernels=True),
    "fused-batched-compact": dict(use_kernels=True, batched_kernels=True,
                                  candidate_mode="compact"),
}


def post_filter(res, pass_np, k):
    """The oracle: cut a FULL unfiltered ranking down to its passing docs."""
    out_s, out_i = [], []
    for b in range(res.doc_ids.shape[0]):
        ids = np.asarray(res.doc_ids[b])
        sc = np.asarray(res.scores[b])
        keep = pass_np[ids]
        out_s.append(sc[keep][:k])
        out_i.append(ids[keep][:k])
    return np.stack(out_s), np.stack(out_i)


def assert_filtered_equals_postfilter(idx, meta, queries, cfg, q_masks=None):
    plan = bv.compile_filter(EXPR, meta.pred_names)
    pass_np = np.asarray(bv.apply_filter_plan(plan, idx.pred_words))
    assert cfg.k <= pass_np.sum(), "oracle needs >= k passing docs"
    full = dataclasses.replace(cfg, k=N_DOCS)
    want_s, want_i = post_filter(
        engine.retrieve(idx, queries, full, q_masks), pass_np, cfg.k)
    got = engine.retrieve(idx, queries, cfg, q_masks, doc_filter=plan)
    np.testing.assert_array_equal(np.asarray(got.doc_ids), want_i)
    np.testing.assert_array_equal(np.asarray(got.scores), want_s)


# ---------------------------------------------------------------------------
# PredicateSet packing + FilterExpr compilation
# ---------------------------------------------------------------------------

def test_predicateset_pack_roundtrip(fcorpus):
    _, _, preds, _ = fcorpus
    ps = bv.PredicateSet.pack(preds)
    assert ps.names == tuple(preds)
    for name, col in preds.items():
        np.testing.assert_array_equal(np.asarray(ps.mask(name)), col)
    with pytest.raises(ValueError, match="unknown predicate"):
        ps.mask("nope")


def test_predicateset_pack_errors():
    with pytest.raises(ValueError, match="empty mapping"):
        bv.PredicateSet.pack({})
    with pytest.raises(ValueError, match="> 32"):
        bv.PredicateSet.pack(
            {f"p{i}": np.ones(4, bool) for i in range(33)})
    with pytest.raises(ValueError, match="expected a 1-D"):
        bv.PredicateSet.pack({"p": np.ones((4, 2), bool)})
    with pytest.raises(ValueError, match="must cover the same corpus"):
        bv.PredicateSet.pack({"p": np.ones(4, bool), "q": np.ones(5, bool)})


def test_compile_unknown_name():
    with pytest.raises(ValueError, match="nope"):
        bv.compile_filter(bv.Pred("nope"), ("a", "b"))


def test_compile_demorgan():
    """~(a & b) and ~a | ~b compile to semantically equal plans."""
    names = ("a", "b")
    words = jnp.arange(4, dtype=jnp.uint32)   # 00, 01, 10, 11
    lhs = bv.compile_filter(~(bv.Pred("a") & bv.Pred("b")), names)
    rhs = bv.compile_filter(~bv.Pred("a") | ~bv.Pred("b"), names)
    np.testing.assert_array_equal(
        np.asarray(bv.apply_filter_plan(lhs, words)),
        np.asarray(bv.apply_filter_plan(rhs, words)))
    assert np.asarray(bv.apply_filter_plan(lhs, words)).tolist() == \
        [True, True, True, False]


def test_compile_contradiction_passes_nothing():
    plan = bv.compile_filter(bv.Pred("a") & ~bv.Pred("a"), ("a",))
    words = jnp.arange(2, dtype=jnp.uint32)
    assert not np.asarray(bv.apply_filter_plan(plan, words)).any()


def test_plan_matches_python_oracle(fcorpus, findex):
    _, _, preds, _ = fcorpus
    idx, meta = findex
    en, rec = preds["lang_en"], preds["recent"]
    cases = [
        (bv.Pred("lang_en"), en),
        (~bv.Pred("recent"), ~rec),
        (bv.Pred("lang_en") & bv.Pred("recent"), en & rec),
        (bv.Pred("lang_en") | ~bv.Pred("recent"), en | ~rec),
        (~(bv.Pred("lang_en") | bv.Pred("recent")), ~(en | rec)),
        (EXPR, rec & ~en),
    ]
    for expr, want in cases:
        plan = bv.compile_filter(expr, meta.pred_names)
        got = np.asarray(bv.apply_filter_plan(plan, idx.pred_words))
        np.testing.assert_array_equal(got, want, err_msg=repr(expr))


def test_engine_config_rejects_uncompiled_expr():
    with pytest.raises(ValueError, match="compile your FilterExpr"):
        EngineConfig(doc_filter=bv.Pred("a"))


def test_generation_rejects_mismatched_plan(findex, fcorpus):
    idx, meta = findex
    _, _, _, queries = fcorpus
    plan = bv.compile_filter(bv.Pred("x"), ("x",))
    cfg = EngineConfig(**BASE)
    with pytest.raises(ValueError, match="recompile the FilterExpr"):
        engine.retrieve_generation_topk(idx, meta, 0, jnp.asarray(queries),
                                        cfg, doc_filter=plan)


# ---------------------------------------------------------------------------
# The equivalence matrix: filtered == retrieve-then-post-filter, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", sorted(MODES))
def test_filtered_equals_postfilter(findex, fcorpus, mode):
    idx, meta = findex
    _, _, _, queries = fcorpus
    cfg = EngineConfig(**BASE, **MODES[mode])
    assert_filtered_equals_postfilter(idx, meta, jnp.asarray(queries), cfg)


def test_filtered_masked_queries(findex, fcorpus):
    """The filter composes with per-term query masks (the micro-batcher's
    padding contract) on the batched megakernel path."""
    idx, meta = findex
    _, _, _, queries = fcorpus
    cfg = EngineConfig(**BASE, **MODES["fused-batched-score_all"])
    masks = np.ones((queries.shape[0], BASE["n_q"]), bool)
    masks[:, 5:] = False
    q = np.array(queries)
    q[:, 5:] = 0.0
    assert_filtered_equals_postfilter(idx, meta, jnp.asarray(q), cfg,
                                      jnp.asarray(masks))


def test_filtered_bf16_cs(findex, fcorpus):
    idx, meta = findex
    _, _, _, queries = fcorpus
    cfg = EngineConfig(**BASE, **MODES["fused-batched-score_all"],
                       cs_dtype="bfloat16")
    assert_filtered_equals_postfilter(idx, meta, jnp.asarray(queries), cfg)


# ---------------------------------------------------------------------------
# Timeline: filtered retrieval across generations, merged and unmerged
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ftimeline(findex):
    idx, meta = findex
    rng = np.random.default_rng(5)
    embs = np.asarray(jax.random.normal(jax.random.PRNGKey(9),
                                        (64, CAP, D)))
    lens = np.full((64,), CAP, np.int32)
    preds = {"lang_en": rng.random(64) < 0.7, "recent": rng.random(64) < 0.5}
    gen, gmeta = store.new_generation(idx, meta, embs, lens,
                                      predicates=preds)
    return store.ShardedTimeline.of((idx, meta), (gen, gmeta))


def test_timeline_filtered_merged_equals_unmerged(ftimeline, fcorpus):
    _, _, _, queries = fcorpus
    q = jnp.asarray(queries)
    cfg = EngineConfig(**{**BASE, "n_filter": 160, "n_docs": 160,
                          "cand_cap": 160})
    merged = store.merge_generations(ftimeline, 0, 2)
    a = engine.retrieve_timeline(ftimeline, q, cfg, doc_filter=EXPR)
    b = engine.retrieve_timeline(merged, q, cfg, doc_filter=EXPR)
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores),
                                  np.asarray(b.scores))
    # the merged plane is the concatenation, docs keep their global ids
    np.testing.assert_array_equal(
        np.asarray(merged.generations[0].pred_words),
        np.concatenate([np.asarray(g.pred_words)
                        for g in ftimeline.generations]))


def test_timeline_filtered_equals_postfilter(ftimeline, fcorpus):
    _, _, _, queries = fcorpus
    q = jnp.asarray(queries)
    cfg = EngineConfig(**{**BASE, "n_filter": 160, "n_docs": 160,
                          "cand_cap": 160})
    plan = bv.compile_filter(EXPR, ftimeline.metas[0].pred_names)
    pass_np = np.concatenate(
        [np.asarray(bv.apply_filter_plan(plan, g.pred_words))
         for g in ftimeline.generations])
    # the full-depth (k = all docs) oracle run needs one generation holding
    # every doc — per-generation top-k caps k at the generation size — and
    # merge_generations preserves retrieval bit-exactly (tested above)
    full = dataclasses.replace(cfg, k=160)
    merged = store.merge_generations(ftimeline, 0, 2)
    want_s, want_i = post_filter(
        engine.retrieve_timeline(merged, q, full), pass_np, cfg.k)
    got = engine.retrieve_timeline(ftimeline, q, cfg, doc_filter=EXPR)
    np.testing.assert_array_equal(np.asarray(got.doc_ids), want_i)
    np.testing.assert_array_equal(np.asarray(got.scores), want_s)


def test_timeline_rejects_mismatched_plane(findex):
    idx, meta = findex
    other = dataclasses.replace(meta, pred_names=("a", "b"))
    with pytest.raises(ValueError, match="predicate plane"):
        store.ShardedTimeline.of((idx, meta), (idx, other))


# ---------------------------------------------------------------------------
# Schema v3 persistence: round trip + corruption modes
# ---------------------------------------------------------------------------

def _resave(src, dst, mutate_manifest=None, mutate_arrays=None):
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(src, "arrays.npz")) as npz:
        arrays = {k: npz[k] for k in npz.files}
    if mutate_manifest:
        mutate_manifest(manifest)
    if mutate_arrays:
        mutate_arrays(arrays)
    os.makedirs(dst, exist_ok=True)
    np.savez(os.path.join(dst, "arrays.npz"), **arrays)
    with open(os.path.join(dst, "manifest.json"), "w") as f:
        json.dump(manifest, f)


@pytest.fixture(scope="module")
def fsaved(findex, tmp_path_factory):
    idx, meta = findex
    path = str(tmp_path_factory.mktemp("filtering") / "idx")
    store.save_index(path, idx, meta)
    return path


def test_v3_round_trip_preserves_plane(findex, fcorpus, fsaved):
    idx, meta = findex
    _, _, _, queries = fcorpus
    loaded, lmeta = store.load_index(fsaved)
    assert lmeta.pred_names == meta.pred_names
    np.testing.assert_array_equal(np.asarray(loaded.pred_words),
                                  np.asarray(idx.pred_words))
    cfg = EngineConfig(**BASE, **MODES["fused-batched-score_all"])
    plan = bv.compile_filter(EXPR, lmeta.pred_names)
    q = jnp.asarray(queries)
    a = engine.retrieve(idx, q, cfg, doc_filter=plan)
    b = engine.retrieve(loaded, q, cfg, doc_filter=plan)
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores),
                                  np.asarray(b.scores))


def test_load_wrong_plane_word_count(tmp_path, fsaved):
    dst = str(tmp_path / "badcount")

    def shrink(arrays):
        arrays["pred_words"] = arrays["pred_words"][:-3]

    def fix_decl(m):
        m["arrays"]["pred_words"]["shape"] = [N_DOCS - 3]

    _resave(fsaved, dst, mutate_manifest=fix_decl, mutate_arrays=shrink)
    with pytest.raises(ValueError, match="one uint32 word per doc"):
        store.load_index(dst)


def test_load_plane_bits_beyond_names(tmp_path, fsaved):
    dst = str(tmp_path / "badbits")

    def set_high_bit(arrays):
        pw = arrays["pred_words"].copy()
        pw[0] |= np.uint32(1 << 7)       # the meta declares 2 names
        arrays["pred_words"] = pw

    def refinger(m):
        # keep the content fingerprint consistent so the NAMES check (not
        # the byte-level one) is what fires
        m["fingerprint"] = "recomputed-below"

    _resave(fsaved, dst, mutate_manifest=refinger,
            mutate_arrays=set_high_bit)
    with open(os.path.join(dst, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(dst, "arrays.npz")) as npz:
        from repro.core.index import PackedIndex
        idx = PackedIndex(**{k: jnp.asarray(npz[k]) for k in npz.files})
    manifest["fingerprint"] = store.index_fingerprint(idx)
    with open(os.path.join(dst, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="bits set beyond"):
        store.load_index(dst)


def test_load_v2_without_plane(tmp_path, findex, fsaved):
    """A v2 save (no pred_words array, no pred_names meta, fingerprint over
    the v2 field set) loads as an index with an empty plane."""
    idx, meta = findex
    dst = str(tmp_path / "v2")

    def downgrade(m):
        m["schema_version"] = 2
        del m["meta"]["pred_names"]
        del m["arrays"]["pred_words"]
        m["fingerprint"] = store.index_fingerprint(
            idx, fields=store._V2_FIELDS)

    def drop_plane(arrays):
        del arrays["pred_words"]

    _resave(fsaved, dst, mutate_manifest=downgrade,
            mutate_arrays=drop_plane)
    loaded, lmeta = store.load_index(dst)
    assert lmeta.pred_names == ()
    np.testing.assert_array_equal(np.asarray(loaded.pred_words),
                                  np.zeros(N_DOCS, np.uint32))
    # filtering such an index fails loudly at compile: no names exist
    with pytest.raises(ValueError, match="recent"):
        bv.compile_filter(EXPR, lmeta.pred_names)


# ---------------------------------------------------------------------------
# Serving: filter-aware cache keys, micro-batch homogeneity, metrics
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fservice_cfg():
    return EngineConfig(**{**BASE, "n_filter": 64, "n_docs": 64,
                           "cand_cap": 64})


def test_service_filtered_cold_warm_and_no_collision(ftimeline, fcorpus,
                                                     fservice_cfg):
    from repro.serving import RetrievalService

    _, _, _, queries = fcorpus
    q = jnp.asarray(queries)
    svc = RetrievalService(ftimeline, fservice_cfg)
    want_u = engine.retrieve_timeline(ftimeline, q, fservice_cfg)
    want_f = engine.retrieve_timeline(ftimeline, q, fservice_cfg,
                                      doc_filter=EXPR)
    # unfiltered first — its partials populate the cache under the base
    # config fingerprint; the filtered queries that follow must NOT hit them
    got_u = svc.query(q)
    got_f_cold = svc.query(q, doc_filter=EXPR)
    got_f_warm = svc.query(q, doc_filter=EXPR)
    for got, want in ((got_u, want_u), (got_f_cold, want_f),
                      (got_f_warm, want_f)):
        np.testing.assert_array_equal(np.asarray(got.doc_ids),
                                      np.asarray(want.doc_ids))
        np.testing.assert_array_equal(np.asarray(got.scores),
                                      np.asarray(want.scores))
    s = svc.stats()
    assert s["filtered_queries"] == 2 * q.shape[0]
    assert s["unfiltered_queries"] == q.shape[0]
    assert "predicate_bytes" in s["timeline"]
    # the warm filtered pass hit the cache (its partials were cached by the
    # cold filtered pass, NOT poisoned by the unfiltered ones)
    assert s["warm_queries"] >= q.shape[0]


def test_service_submit_groups_by_filter(ftimeline, fcorpus, fservice_cfg):
    from repro.serving import RetrievalService

    _, _, _, queries = fcorpus
    q = jnp.asarray(queries)
    svc = RetrievalService(ftimeline, fservice_cfg, max_batch=16)
    want_u = engine.retrieve_timeline(ftimeline, q, fservice_cfg)
    want_f = engine.retrieve_timeline(ftimeline, q, fservice_cfg,
                                      doc_filter=EXPR)
    t0 = svc.submit(queries[0], doc_filter=EXPR)
    t1 = svc.submit(queries[1])
    t2 = svc.submit(queries[2], doc_filter=EXPR)
    svc.flush()
    for t, want, b in ((t0, want_f, 0), (t1, want_u, 1), (t2, want_f, 2)):
        np.testing.assert_array_equal(t.result()[1],
                                      np.asarray(want.doc_ids)[b])
        np.testing.assert_array_equal(t.result()[0],
                                      np.asarray(want.scores)[b])


def test_batcher_drains_longest_same_filter_prefix():
    from repro.serving.batcher import MicroBatcher

    mb = MicroBatcher(n_q=4, max_batch=8)
    q = np.zeros((2, 3), np.float32)
    for f in ("A", "A", "B", "A"):          # batcher compares filters by ==
        mb.submit(q, doc_filter=f)
    qb, tickets, f = mb.drain()
    assert (qb.q.shape[0], f) == (2, "A")
    qb, tickets, f = mb.drain()
    assert (qb.q.shape[0], f) == (1, "B")
    qb, tickets, f = mb.drain()
    assert (qb.q.shape[0], f) == (1, "A")
    assert mb.drain() is None


def test_metrics_filtered_split():
    from repro.serving.metrics import ServiceMetrics

    m = ServiceMetrics()
    m.record_batch(4, 0, 0.01)
    m.record_batch(3, 3, 0.01, n_filtered=3)
    snap = m.snapshot()
    assert snap["filtered_queries"] == 3
    assert snap["unfiltered_queries"] == 4


# ---------------------------------------------------------------------------
# shard_map: the filter evaluates per shard against the local plane slice
# ---------------------------------------------------------------------------

def test_shardmap_filtered_matches_engine(findex, fcorpus):
    from repro.launch.serve import make_shardmap_retriever, shard_index

    idx, meta = findex
    _, _, _, queries = fcorpus
    q = jnp.asarray(queries)
    cfg = EngineConfig(**BASE, **MODES["fused-batched-score_all"])
    plan = bv.compile_filter(EXPR, meta.pred_names)
    ref = engine.retrieve(idx, q, cfg, doc_filter=plan)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    run = make_shardmap_retriever(mesh, cfg)
    with mesh:
        stacked = shard_index(idx, 1)
        out = run(stacked, q, doc_filter=plan)
        out_u = run(stacked, q)
    ref_u = engine.retrieve(idx, q, cfg)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(out.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(out.scores))
    # the same retriever still serves unfiltered traffic (separate trace)
    np.testing.assert_array_equal(np.asarray(ref_u.doc_ids),
                                  np.asarray(out_u.doc_ids))
