"""Per-assigned-architecture smoke tests: a REDUCED config of the same family
runs one forward/train step on CPU; output shapes asserted + no NaNs.
(Full configs are exercised via the dry-run only.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry

LM_ARCHS = ["qwen2.5-3b", "qwen2.5-32b", "internlm2-20b",
            "granite-moe-1b-a400m", "kimi-k2-1t-a32b"]
RECSYS_ARCHS = ["dlrm-mlperf", "dcn-v2", "dien", "mind"]


def test_registry_has_all_assigned():
    assert set(LM_ARCHS + RECSYS_ARCHS + ["gcn-cora", "emvb-msmarco"]) == \
        set(registry.names())


@pytest.mark.slow
@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    from repro.models import transformer as T
    spec = registry.get(arch)
    cfg = spec.make_smoke_config()
    p = T.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, aux = T.forward(p, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    loss = T.loss_fn(p, {"tokens": toks, "labels": toks}, cfg)
    assert np.isfinite(float(loss))
    # one train step
    from repro.train import optimizer as O
    from repro.train.trainer import TrainState, TrainerConfig, make_train_step
    opt = O.make(spec.optimizer)
    step = make_train_step(lambda pp, b: T.loss_fn(pp, b, cfg), opt,
                           TrainerConfig())
    st = TrainState(jnp.int32(0), p, opt.init(p))
    st2, metrics = step(st, {"tokens": toks, "labels": toks})
    assert np.isfinite(float(metrics["loss"]))
    assert int(st2.step) == 1
    # serving path: prefill + one decode step
    lg, cache = T.prefill(p, toks, cfg)
    assert lg.shape == (2, cfg.vocab)
    pad = T.KVCache(jnp.pad(cache.k, ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0))),
                    jnp.pad(cache.v, ((0, 0), (0, 0), (0, 4), (0, 0), (0, 0))))
    tok = jax.random.randint(jax.random.PRNGKey(2), (2,), 0, cfg.vocab)
    dl, _ = T.decode_step(p, pad, tok, jnp.int32(16), cfg)
    assert dl.shape == (2, cfg.vocab) and not bool(jnp.isnan(dl).any())


@pytest.mark.slow
def test_gcn_smoke():
    from repro.models import gcn
    spec = registry.get("gcn-cora")
    cfg = spec.make_smoke_config()
    p = gcn.init_params(jax.random.PRNGKey(0), cfg)
    n, e = 40, 160
    k = jax.random.PRNGKey(1)
    batch = {"feats": jax.random.normal(k, (n, cfg.d_feat)),
             "edges": jax.random.randint(k, (2, e), 0, n),
             "edge_mask": jnp.ones((e,), bool),
             "labels": jax.random.randint(k, (n,), 0, cfg.n_classes)}
    logits = gcn.forward(p, batch["feats"], batch["edges"],
                         batch["edge_mask"], cfg)
    assert logits.shape == (n, cfg.n_classes)
    assert not bool(jnp.isnan(logits).any())
    g = jax.grad(gcn.loss_fn)(p, batch, cfg)
    assert jax.tree_util.tree_all(
        jax.tree.map(lambda x: bool(jnp.isfinite(x).all()), g))


@pytest.mark.slow
@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    from repro.launch.steps import _recsys_model
    from repro.launch.train import recsys_batch_fn
    spec = registry.get(arch)
    cfg = spec.make_smoke_config()
    M = _recsys_model(arch)
    p = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = recsys_batch_fn(arch, cfg, batch=8)(0)
    out = M.forward(p, batch, cfg)
    assert out.shape == (8,)
    assert not bool(jnp.isnan(out).any())
    loss = M.loss_fn(p, batch, cfg)
    assert np.isfinite(float(loss))


def test_emvb_smoke(small_corpus, small_index):
    """The paper's own arch: smoke config retrieves plausibly."""
    from repro.core import engine
    spec = registry.get("emvb-msmarco")
    cfg = spec.make_smoke_config()
    idx, _ = small_index
    res = engine.retrieve(idx, jnp.asarray(small_corpus.queries[:4]),
                          cfg.engine)
    assert res.doc_ids.shape == (4, cfg.engine.k)
    assert not bool(jnp.isnan(res.scores).any())


@pytest.mark.parametrize("arch", LM_ARCHS + RECSYS_ARCHS + ["gcn-cora"])
def test_full_config_constructs(arch):
    """The FULL paper-exact configs must instantiate abstractly (no alloc)."""
    spec = registry.get(arch)
    if spec.family == "lm":
        from repro.models import transformer as T
        cfg = spec.make_config()
        avals = T.abstract_params(cfg)
        n_params = sum(np.prod(a.shape) for a in jax.tree.leaves(avals))
        expected = spec.model_flops_params["n_params"]
        assert abs(n_params - expected) / expected < 0.25, \
            (arch, n_params, expected)
    else:
        spec.make_config()
