"""Per-kernel allclose sweeps (shapes x dtypes) against the ref.py oracles,
in Pallas interpret mode (the CPU-validation target per the assignment)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    # (n_q, n_c, n_docs, cap, m, ksub)
    (32, 256, 64, 16, 8, 16),
    (32, 640, 100, 24, 16, 16),
    (16, 512, 130, 32, 8, 256),   # n_q < 32; non-multiple doc count
    (4, 1024, 33, 8, 4, 256),     # MIND-like n_q=4
]


def _inputs(n_q, n_c, n_docs, cap, m, ksub, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    cs = jnp.asarray(rng.normal(size=(n_q, n_c)).astype(dtype))
    codes = jnp.asarray(rng.integers(0, n_c + 1, size=(n_docs, cap)
                                     ).astype(np.int32))
    lens = rng.integers(1, cap + 1, size=n_docs)
    mask = jnp.asarray(np.arange(cap)[None, :] < lens[:, None])
    lut = jnp.asarray(rng.normal(size=(n_q, m, ksub)).astype(dtype))
    res = jnp.asarray(rng.integers(0, ksub, size=(n_docs, cap, m)
                                   ).astype(np.uint8))
    return cs, codes, mask, lut, res


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("th", [-0.5, 0.0, 0.5, 2.0])
def test_bitpack(shape, th):
    cs, *_ = _inputs(*shape)
    np.testing.assert_array_equal(np.asarray(ops.bitpack(cs, th)),
                                  np.asarray(ref.bitpack(cs, th)))


@pytest.mark.parametrize("shape", SHAPES)
def test_bitfilter(shape):
    cs, codes, mask, _, _ = _inputs(*shape)
    bits = ref.bitpack(cs, 0.3)
    np.testing.assert_array_equal(
        np.asarray(ops.bitfilter(bits, codes, mask)),
        np.asarray(ref.bitfilter(bits, codes, mask)))


@pytest.mark.parametrize("shape", SHAPES)
def test_cinter(shape):
    cs, codes, mask, _, _ = _inputs(*shape)
    out = ops.cinter(cs.T, codes, mask)
    exp = ref.cinter(cs.T, codes, mask)
    # fp32 sum-of-maxes: kernel accumulates per-block, ref in one reduce —
    # accumulation order differs, so allow normal fp32 slack.
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-4,
                               atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("th_r", [None, 0.3])
def test_pqscore(shape, th_r):
    cs, codes, mask, lut, res = _inputs(*shape)
    out = ops.pqscore(cs.T, lut, codes, res, mask, th_r)
    exp = ref.pqscore(cs.T, lut, codes, res, mask, th_r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


def test_bitpack_block_boundary():
    """n_c not a multiple of the block: padding must not flip bits."""
    cs, *_ = _inputs(32, 700, 8, 8, 4, 16)
    out = ops.bitpack(cs, 0.1)
    exp = ref.bitpack(cs, 0.1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_pqscore_bf16_tolerance():
    cs, codes, mask, lut, res = _inputs(32, 256, 32, 16, 8, 16)
    out = ops.pqscore(cs.T.astype(jnp.bfloat16).astype(jnp.float32), lut,
                      codes, res, mask, 0.3)
    exp = ref.pqscore(cs.T.astype(jnp.bfloat16).astype(jnp.float32), lut,
                      codes, res, mask, 0.3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


def test_empty_docs_masked_out():
    """A doc with zero valid tokens must score popcount 0 / NEG maxsim."""
    cs, codes, mask, lut, res = _inputs(32, 256, 16, 8, 4, 16)
    mask = mask.at[3].set(False)
    bits = ref.bitpack(cs, 0.0)
    f = np.asarray(ops.bitfilter(bits, codes, mask))
    assert f[3] == 0


# ---------------------------------------------------------------------------
# Query-term masking: every masked kernel against its masked oracle
# ---------------------------------------------------------------------------

def _q_mask(n_q, seed=0):
    """A random mask with at least one live and one dead term."""
    rng = np.random.default_rng(seed + 101)
    qm = rng.random(n_q) < 0.6
    qm[0], qm[-1] = True, False
    return jnp.asarray(qm)


def test_bitpack_q_mask_zeroes_masked_bits():
    cs, *_ = _inputs(*SHAPES[1])
    qm = _q_mask(cs.shape[0])
    out = np.asarray(ops.bitpack(cs, 0.2, qm))
    np.testing.assert_array_equal(out, np.asarray(ref.bitpack(cs, 0.2, qm)))
    dead_bits = np.uint32(0)
    for i, live in enumerate(np.asarray(qm)):
        if not live:
            dead_bits |= np.uint32(1) << np.uint32(i)
    assert (out & dead_bits == 0).all()


def test_cinter_q_mask_matches_ref():
    cs, codes, mask, _, _ = _inputs(*SHAPES[1])
    qm = _q_mask(cs.shape[0])
    out = ops.cinter(cs.T, codes, mask, qm)
    exp = ref.cinter(cs.T, codes, mask, qm)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("th_r", [None, 0.3])
def test_pqscore_q_mask_matches_ref(th_r):
    cs, codes, mask, lut, res = _inputs(*SHAPES[0])
    qm = _q_mask(cs.shape[0])
    out = ops.pqscore(cs.T, lut, codes, res, mask, th_r, qm)
    exp = ref.pqscore(cs.T, lut, codes, res, mask, th_r, qm)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_prefilter_fused_q_mask_matches_ref():
    cs, codes, mask, _, _ = _inputs(*SHAPES[1])
    qm = _q_mask(cs.shape[0])
    n_docs = codes.shape[0]
    s, i, bits = ops.prefilter(cs, 0.2, codes, mask, _bitmap(n_docs),
                               n_docs // 3, qm)
    rs, ri = ref.prefilter(cs, 0.2, codes, mask, _bitmap(n_docs),
                           n_docs // 3, qm)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(bits),
                                  np.asarray(ref.bitpack(cs, 0.2, qm)))


def test_pqinter_fused_q_mask_matches_ref():
    cs, codes, mask, lut, res = _inputs(*SHAPES[0])
    qm = _q_mask(cs.shape[0])
    out = ops.pqinter(cs.T, lut, codes, res, mask, 0.5, 20, 7, qm)
    exp = ref.pqinter(cs.T, lut, codes, res, mask, 0.5, 20, 7, qm)
    for got, want, name in zip(out, exp, ("scores", "pos", "sel2", "sbar")):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=name)


def test_all_terms_masked_scores_zero():
    """All-False mask: S̄ and Eq. 5/6 scores collapse to exactly 0.0 (the
    empty sum) for every doc, masked bit words are all-zero."""
    cs, codes, mask, lut, res = _inputs(*SHAPES[0])
    qm = jnp.zeros((cs.shape[0],), jnp.bool_)
    assert (np.asarray(ops.bitpack(cs, 0.2, qm)) == 0).all()
    assert (np.asarray(ops.cinter(cs.T, codes, mask, qm)) == 0.0).all()
    assert (np.asarray(ops.pqscore(cs.T, lut, codes, res, mask, 0.3,
                                   qm)) == 0.0).all()


# ---------------------------------------------------------------------------
# Fused prefilter megakernel (phases 1b-2 in one launch)
# ---------------------------------------------------------------------------

def _bitmap(n_docs, seed=0, density=0.4):
    rng = np.random.default_rng(seed + 1)
    return jnp.asarray(rng.random(n_docs) < density)


def _assert_prefilter_matches_ref(cs, codes, mask, bitmap, n_filter, th=0.2):
    s, i, bits = ops.prefilter(cs, th, codes, mask, bitmap, n_filter)
    rs, ri = ref.prefilter(cs, th, codes, mask, bitmap, n_filter)
    # selection parity is BIT-EXACT, including lax.top_k tie order
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(rs))
    # the byproduct bit table equals the standalone bitpack
    np.testing.assert_array_equal(np.asarray(bits),
                                  np.asarray(ref.bitpack(cs, th)))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("th", [-0.5, 0.5, 2.0])
def test_prefilter_fused(shape, th):
    cs, codes, mask, _, _ = _inputs(*shape)
    n_docs = codes.shape[0]
    _assert_prefilter_matches_ref(cs, codes, mask, _bitmap(n_docs),
                                  max(1, n_docs // 3), th)


@pytest.mark.parametrize("shape", [SHAPES[0], SHAPES[3]])
def test_prefilter_fused_full_and_tiny_nfilter(shape):
    """n_filter == n_docs (everything survives, order must still match) and
    n_filter == 1 (running merge degenerates to an argmax)."""
    cs, codes, mask, _, _ = _inputs(*shape, seed=3)
    n_docs = codes.shape[0]
    bm = _bitmap(n_docs, seed=3)
    _assert_prefilter_matches_ref(cs, codes, mask, bm, n_docs)
    _assert_prefilter_matches_ref(cs, codes, mask, bm, 1)


def test_prefilter_fused_block_boundary():
    """Doc counts straddling the block size: padded rows must never be
    selected ahead of real docs (even real docs with f == -1)."""
    for n_docs in (255, 257):
        cs, codes, mask, _, _ = _inputs(32, 256, n_docs, 16, 8, 16, seed=7)
        _assert_prefilter_matches_ref(cs, codes, mask,
                                      _bitmap(n_docs, seed=7), n_docs // 2)


def test_prefilter_fused_all_docs_masked():
    """bitmap all-False: ref top_k ranks a flat -1 array, i.e. doc ids in
    index order with score -1 — the fused tie-break must reproduce that."""
    cs, codes, mask, _, _ = _inputs(32, 256, 64, 16, 8, 16)
    s, i, _ = ops.prefilter(cs, 0.2, codes, mask, jnp.zeros(64, bool), 16)
    np.testing.assert_array_equal(np.asarray(i), np.arange(16))
    assert (np.asarray(s) == -1).all()


def test_prefilter_fused_zero_token_docs():
    """Docs whose every token is padding score popcount 0, not -1 (they are
    still candidates if the bitmap says so)."""
    cs, codes, mask, _, _ = _inputs(32, 256, 64, 16, 8, 16)
    mask = mask.at[5].set(False)
    bm = jnp.ones(64, bool)
    _assert_prefilter_matches_ref(cs, codes, mask, bm, 64)
    s, i, _ = ops.prefilter(cs, 0.2, codes, mask, bm, 64)
    assert np.asarray(s)[np.asarray(i) == 5] == 0


def test_prefilter_fused_bf16_cs():
    """bf16 centroid scores: threshold comparison happens in the CS dtype on
    both sides, so parity stays bit-exact."""
    cs, codes, mask, _, _ = _inputs(32, 640, 100, 24, 16, 16)
    _assert_prefilter_matches_ref(cs.astype(jnp.bfloat16), codes, mask,
                                  _bitmap(100), 40, th=0.1)


# ---------------------------------------------------------------------------
# Fused phase-3/4 megakernel (centroid interaction + selection + PQ late
# interaction + final top-k in one launch)
# ---------------------------------------------------------------------------

def _tie_heavy(n_q, n_c, n_docs, cap, m, ksub, seed=0, levels=2):
    """Inputs whose scores collide constantly: CS and LUT quantized to
    ``levels`` distinct values, so both the phase-3 S̄ selection and the
    final top-k are decided by tie-breaking almost everywhere."""
    cs, codes, mask, lut, res = _inputs(n_q, n_c, n_docs, cap, m, ksub, seed)
    cs = jnp.asarray(np.round(np.asarray(cs) * levels) / levels)
    lut = jnp.asarray(np.round(np.asarray(lut) * levels) / levels)
    return cs, codes, mask, lut, res


def _assert_pqinter_matches_ref(cs, codes, mask, lut, res, th_r, n_docs, k):
    out = ops.pqinter(cs.T, lut, codes, res, mask, th_r, n_docs, k)
    exp = ref.pqinter(cs.T, lut, codes, res, mask, th_r, n_docs, k)
    for got, want, name in zip(out, exp, ("scores", "pos", "sel2", "sbar")):
        # selection AND score parity are BIT-EXACT, incl. lax.top_k tie order
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=name)


# SHAPES[1] is exercised by test_pqinter_fused_all_terms_filtered below —
# keeping it out of the sweep saves two compiles of the unrolled megakernel.
@pytest.mark.parametrize("shape", [SHAPES[0], SHAPES[2], SHAPES[3]])
@pytest.mark.parametrize("th_r", [None, 0.5])
def test_pqinter_fused(shape, th_r):
    """Eq. 5 (th_r=None) and Eq. 6 (th_r=0.5) across the shape sweep."""
    cs, codes, mask, lut, res = _inputs(*shape)
    n_docs = max(1, codes.shape[0] // 3)
    _assert_pqinter_matches_ref(cs, codes, mask, lut, res, th_r, n_docs,
                                max(1, n_docs // 4))


@pytest.mark.parametrize("shape,th_r", [(SHAPES[0], 0.5), (SHAPES[3], None)])
def test_pqinter_fused_tie_heavy(shape, th_r):
    """Quantized score distributions: ranking is almost entirely tie-breaks,
    which must match lax.top_k's lowest-index order at BOTH selections."""
    cs, codes, mask, lut, res = _tie_heavy(*shape)
    n_docs = max(1, codes.shape[0] // 2)
    _assert_pqinter_matches_ref(cs, codes, mask, lut, res, th_r, n_docs,
                                max(1, n_docs // 3))


def test_pqinter_fused_selection_boundaries():
    """n_docs == n_filter (phase 3 selects everything — order must still
    match) with k == n_docs, and k == 1 (the final merge degenerates to an
    argmax)."""
    cs, codes, mask, lut, res = _inputs(*SHAPES[0], seed=5)
    n = codes.shape[0]
    _assert_pqinter_matches_ref(cs, codes, mask, lut, res, 0.5, n, n)
    _assert_pqinter_matches_ref(cs, codes, mask, lut, res, 0.5, n, 1)


def test_pqinter_fused_empty_survivors():
    """Every survivor slot is padding (all tokens masked): scores collapse
    to the n_q * NEG floor and the top-k must fall back to index order."""
    cs, codes, mask, lut, res = _inputs(32, 256, 64, 16, 8, 16)
    empty = jnp.zeros_like(mask)
    _assert_pqinter_matches_ref(cs, codes, empty, lut, res, 0.5, 32, 10)
    scores, pos, _, _ = ops.pqinter(cs.T, lut, codes, res, empty, 0.5, 32, 10)
    np.testing.assert_array_equal(np.asarray(pos), np.arange(10))


def test_pqinter_fused_all_terms_filtered():
    """th_r above every centroid score: every J̄_i is empty, so Eq. 6 must
    fall back to Eq. 5 for every term — and still match the ref bitwise."""
    cs, codes, mask, lut, res = _inputs(32, 640, 100, 24, 16, 16)
    _assert_pqinter_matches_ref(cs, codes, mask, lut, res, 1e9, 40, 10)
    s_eq6, p_eq6, _, _ = ops.pqinter(cs.T, lut, codes, res, mask, 1e9, 40, 10)
    s_eq5, p_eq5, _, _ = ops.pqinter(cs.T, lut, codes, res, mask, None, 40, 10)
    np.testing.assert_array_equal(np.asarray(p_eq6), np.asarray(p_eq5))
    np.testing.assert_array_equal(np.asarray(s_eq6), np.asarray(s_eq5))


def test_pqinter_fused_bf16_cs():
    """bf16 centroid scores: S̄ rides bf16 exactly like the reference (the
    f32 cast in the merge is lossless and order-preserving), and the Eq. 6
    threshold comparison happens in the CS dtype on both sides — parity
    stays bit-exact, selections and score bits included."""
    cs, codes, mask, lut, res = _inputs(32, 640, 100, 24, 16, 16)
    cs16 = cs.astype(jnp.bfloat16)
    _assert_pqinter_matches_ref(cs16, codes, mask, lut, res, 0.5, 40, 10)
    _assert_pqinter_matches_ref(cs16, codes, mask, lut, res, None, 40, 10)


def test_pqinter_fused_block_boundaries():
    """Survivor counts straddling the pass-1 block and n_docs straddling the
    pass-2 block (explicit small blocks so both loops run >1 iteration with
    a ragged tail): padded rows / dead buffer lanes must never be selected."""
    from repro.kernels.pqinter import pqinter

    for n_docs, nd in ((95, 33), (97, 31)):
        cs, codes, mask, lut, res = _inputs(32, 256, n_docs, 16, 8, 16,
                                            seed=7)
        out = pqinter(cs.T, lut, codes, res, mask, 0.3, nd, 9,
                      block_d1=32, block_d2=16)
        exp = ref.pqinter(cs.T, lut, codes, res, mask, 0.3, nd, 9)
        for got, want, name in zip(out, exp, ("scores", "pos", "sel2",
                                              "sbar")):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                          err_msg=name)
