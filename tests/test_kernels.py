"""Per-kernel allclose sweeps (shapes x dtypes) against the ref.py oracles,
in Pallas interpret mode (the CPU-validation target per the assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    # (n_q, n_c, n_docs, cap, m, ksub)
    (32, 256, 64, 16, 8, 16),
    (32, 640, 100, 24, 16, 16),
    (16, 512, 130, 32, 8, 256),   # n_q < 32; non-multiple doc count
    (4, 1024, 33, 8, 4, 256),     # MIND-like n_q=4
]


def _inputs(n_q, n_c, n_docs, cap, m, ksub, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    cs = jnp.asarray(rng.normal(size=(n_q, n_c)).astype(dtype))
    codes = jnp.asarray(rng.integers(0, n_c + 1, size=(n_docs, cap)
                                     ).astype(np.int32))
    lens = rng.integers(1, cap + 1, size=n_docs)
    mask = jnp.asarray(np.arange(cap)[None, :] < lens[:, None])
    lut = jnp.asarray(rng.normal(size=(n_q, m, ksub)).astype(dtype))
    res = jnp.asarray(rng.integers(0, ksub, size=(n_docs, cap, m)
                                   ).astype(np.uint8))
    return cs, codes, mask, lut, res


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("th", [-0.5, 0.0, 0.5, 2.0])
def test_bitpack(shape, th):
    cs, *_ = _inputs(*shape)
    np.testing.assert_array_equal(np.asarray(ops.bitpack(cs, th)),
                                  np.asarray(ref.bitpack(cs, th)))


@pytest.mark.parametrize("shape", SHAPES)
def test_bitfilter(shape):
    cs, codes, mask, _, _ = _inputs(*shape)
    bits = ref.bitpack(cs, 0.3)
    np.testing.assert_array_equal(
        np.asarray(ops.bitfilter(bits, codes, mask)),
        np.asarray(ref.bitfilter(bits, codes, mask)))


@pytest.mark.parametrize("shape", SHAPES)
def test_cinter(shape):
    cs, codes, mask, _, _ = _inputs(*shape)
    out = ops.cinter(cs.T, codes, mask)
    exp = ref.cinter(cs.T, codes, mask)
    # fp32 sum-of-maxes: kernel accumulates per-block, ref in one reduce —
    # accumulation order differs, so allow normal fp32 slack.
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-4,
                               atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("th_r", [None, 0.3])
def test_pqscore(shape, th_r):
    cs, codes, mask, lut, res = _inputs(*shape)
    out = ops.pqscore(cs.T, lut, codes, res, mask, th_r)
    exp = ref.pqscore(cs.T, lut, codes, res, mask, th_r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


def test_bitpack_block_boundary():
    """n_c not a multiple of the block: padding must not flip bits."""
    cs, *_ = _inputs(32, 700, 8, 8, 4, 16)
    out = ops.bitpack(cs, 0.1)
    exp = ref.bitpack(cs, 0.1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_pqscore_bf16_tolerance():
    cs, codes, mask, lut, res = _inputs(32, 256, 32, 16, 8, 16)
    out = ops.pqscore(cs.T.astype(jnp.bfloat16).astype(jnp.float32), lut,
                      codes, res, mask, 0.3)
    exp = ref.pqscore(cs.T.astype(jnp.bfloat16).astype(jnp.float32), lut,
                      codes, res, mask, 0.3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


def test_empty_docs_masked_out():
    """A doc with zero valid tokens must score popcount 0 / NEG maxsim."""
    cs, codes, mask, lut, res = _inputs(32, 256, 16, 8, 4, 16)
    mask = mask.at[3].set(False)
    bits = ref.bitpack(cs, 0.0)
    f = np.asarray(ops.bitfilter(bits, codes, mask))
    assert f[3] == 0
