"""Per-kernel allclose sweeps (shapes x dtypes) against the ref.py oracles,
in Pallas interpret mode (the CPU-validation target per the assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [
    # (n_q, n_c, n_docs, cap, m, ksub)
    (32, 256, 64, 16, 8, 16),
    (32, 640, 100, 24, 16, 16),
    (16, 512, 130, 32, 8, 256),   # n_q < 32; non-multiple doc count
    (4, 1024, 33, 8, 4, 256),     # MIND-like n_q=4
]


def _inputs(n_q, n_c, n_docs, cap, m, ksub, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    cs = jnp.asarray(rng.normal(size=(n_q, n_c)).astype(dtype))
    codes = jnp.asarray(rng.integers(0, n_c + 1, size=(n_docs, cap)
                                     ).astype(np.int32))
    lens = rng.integers(1, cap + 1, size=n_docs)
    mask = jnp.asarray(np.arange(cap)[None, :] < lens[:, None])
    lut = jnp.asarray(rng.normal(size=(n_q, m, ksub)).astype(dtype))
    res = jnp.asarray(rng.integers(0, ksub, size=(n_docs, cap, m)
                                   ).astype(np.uint8))
    return cs, codes, mask, lut, res


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("th", [-0.5, 0.0, 0.5, 2.0])
def test_bitpack(shape, th):
    cs, *_ = _inputs(*shape)
    np.testing.assert_array_equal(np.asarray(ops.bitpack(cs, th)),
                                  np.asarray(ref.bitpack(cs, th)))


@pytest.mark.parametrize("shape", SHAPES)
def test_bitfilter(shape):
    cs, codes, mask, _, _ = _inputs(*shape)
    bits = ref.bitpack(cs, 0.3)
    np.testing.assert_array_equal(
        np.asarray(ops.bitfilter(bits, codes, mask)),
        np.asarray(ref.bitfilter(bits, codes, mask)))


@pytest.mark.parametrize("shape", SHAPES)
def test_cinter(shape):
    cs, codes, mask, _, _ = _inputs(*shape)
    out = ops.cinter(cs.T, codes, mask)
    exp = ref.cinter(cs.T, codes, mask)
    # fp32 sum-of-maxes: kernel accumulates per-block, ref in one reduce —
    # accumulation order differs, so allow normal fp32 slack.
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-4,
                               atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("th_r", [None, 0.3])
def test_pqscore(shape, th_r):
    cs, codes, mask, lut, res = _inputs(*shape)
    out = ops.pqscore(cs.T, lut, codes, res, mask, th_r)
    exp = ref.pqscore(cs.T, lut, codes, res, mask, th_r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


def test_bitpack_block_boundary():
    """n_c not a multiple of the block: padding must not flip bits."""
    cs, *_ = _inputs(32, 700, 8, 8, 4, 16)
    out = ops.bitpack(cs, 0.1)
    exp = ref.bitpack(cs, 0.1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_pqscore_bf16_tolerance():
    cs, codes, mask, lut, res = _inputs(32, 256, 32, 16, 8, 16)
    out = ops.pqscore(cs.T.astype(jnp.bfloat16).astype(jnp.float32), lut,
                      codes, res, mask, 0.3)
    exp = ref.pqscore(cs.T.astype(jnp.bfloat16).astype(jnp.float32), lut,
                      codes, res, mask, 0.3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


def test_empty_docs_masked_out():
    """A doc with zero valid tokens must score popcount 0 / NEG maxsim."""
    cs, codes, mask, lut, res = _inputs(32, 256, 16, 8, 4, 16)
    mask = mask.at[3].set(False)
    bits = ref.bitpack(cs, 0.0)
    f = np.asarray(ops.bitfilter(bits, codes, mask))
    assert f[3] == 0


# ---------------------------------------------------------------------------
# Fused prefilter megakernel (phases 1b-2 in one launch)
# ---------------------------------------------------------------------------

def _bitmap(n_docs, seed=0, density=0.4):
    rng = np.random.default_rng(seed + 1)
    return jnp.asarray(rng.random(n_docs) < density)


def _assert_prefilter_matches_ref(cs, codes, mask, bitmap, n_filter, th=0.2):
    s, i, bits = ops.prefilter(cs, th, codes, mask, bitmap, n_filter)
    rs, ri = ref.prefilter(cs, th, codes, mask, bitmap, n_filter)
    # selection parity is BIT-EXACT, including lax.top_k tie order
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(rs))
    # the byproduct bit table equals the standalone bitpack
    np.testing.assert_array_equal(np.asarray(bits),
                                  np.asarray(ref.bitpack(cs, th)))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("th", [-0.5, 0.5, 2.0])
def test_prefilter_fused(shape, th):
    cs, codes, mask, _, _ = _inputs(*shape)
    n_docs = codes.shape[0]
    _assert_prefilter_matches_ref(cs, codes, mask, _bitmap(n_docs),
                                  max(1, n_docs // 3), th)


@pytest.mark.parametrize("shape", [SHAPES[0], SHAPES[3]])
def test_prefilter_fused_full_and_tiny_nfilter(shape):
    """n_filter == n_docs (everything survives, order must still match) and
    n_filter == 1 (running merge degenerates to an argmax)."""
    cs, codes, mask, _, _ = _inputs(*shape, seed=3)
    n_docs = codes.shape[0]
    bm = _bitmap(n_docs, seed=3)
    _assert_prefilter_matches_ref(cs, codes, mask, bm, n_docs)
    _assert_prefilter_matches_ref(cs, codes, mask, bm, 1)


def test_prefilter_fused_block_boundary():
    """Doc counts straddling the block size: padded rows must never be
    selected ahead of real docs (even real docs with f == -1)."""
    for n_docs in (255, 257):
        cs, codes, mask, _, _ = _inputs(32, 256, n_docs, 16, 8, 16, seed=7)
        _assert_prefilter_matches_ref(cs, codes, mask,
                                      _bitmap(n_docs, seed=7), n_docs // 2)


def test_prefilter_fused_all_docs_masked():
    """bitmap all-False: ref top_k ranks a flat -1 array, i.e. doc ids in
    index order with score -1 — the fused tie-break must reproduce that."""
    cs, codes, mask, _, _ = _inputs(32, 256, 64, 16, 8, 16)
    s, i, _ = ops.prefilter(cs, 0.2, codes, mask, jnp.zeros(64, bool), 16)
    np.testing.assert_array_equal(np.asarray(i), np.arange(16))
    assert (np.asarray(s) == -1).all()


def test_prefilter_fused_zero_token_docs():
    """Docs whose every token is padding score popcount 0, not -1 (they are
    still candidates if the bitmap says so)."""
    cs, codes, mask, _, _ = _inputs(32, 256, 64, 16, 8, 16)
    mask = mask.at[5].set(False)
    bm = jnp.ones(64, bool)
    _assert_prefilter_matches_ref(cs, codes, mask, bm, 64)
    s, i, _ = ops.prefilter(cs, 0.2, codes, mask, bm, 64)
    assert np.asarray(s)[np.asarray(i) == 5] == 0


def test_prefilter_fused_bf16_cs():
    """bf16 centroid scores: threshold comparison happens in the CS dtype on
    both sides, so parity stays bit-exact."""
    cs, codes, mask, _, _ = _inputs(32, 640, 100, 24, 16, 16)
    _assert_prefilter_matches_ref(cs.astype(jnp.bfloat16), codes, mask,
                                  _bitmap(100), 40, th=0.1)
