"""Index lifecycle contract (repro.core.store):

* save -> load -> retrieve is BIT-exact (ids AND score bits) to retrieval on
  the original index, across both candidate modes, both megakernels, and a
  masked/pruned query;
* corrupt / missing-field / future-schema-version files raise actionable
  ValueErrors;
* add_passages grows an index against frozen codebooks (IVF extended, drift
  stats surfaced) and a ShardedTimeline of grown generations matches one
  monolithic index built over the union corpus — exactly, under
  cut-lossless budgets (ties resolve toward the lower global doc id at
  every cut in both paths; under tight budgets phase 2/3 keep the top-n of
  the *visible pool*, so the timeline legitimately diverges in its favor —
  same relative-selection caveat as the shard_map plan).
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EngineConfig, ShardedTimeline, add_passages,
                        build_index, engine, index_fingerprint, load_index,
                        load_timeline, new_generation, prune_queries,
                        retrieve_timeline, save_index, save_timeline)
from repro.core.store import SCHEMA_VERSION
from repro.data.synthetic import make_corpus

# Same constants as tests/test_system.py so the jit cache is shared.
CFG = EngineConfig(nprobe=8, th=0.2, th_r=0.4, n_filter=128, n_docs=48, k=10)

RETRIEVAL_CFGS = {
    "ref-score_all": CFG,
    "ref-compact": dataclasses.replace(CFG, candidate_mode="compact",
                                       cand_cap=600),
    # each megakernel alone, then both (the default fused engine)
    "prefilter-megakernel": dataclasses.replace(
        CFG, use_kernels=True, fused_late_interaction=False),
    "pqinter-megakernel": dataclasses.replace(
        CFG, use_kernels=True, fused_prefilter=False),
    "fused-score_all": dataclasses.replace(CFG, use_kernels=True),
    "fused-compact": dataclasses.replace(CFG, use_kernels=True,
                                         candidate_mode="compact",
                                         cand_cap=600),
}


# ---------------------------------------------------------------------------
# Persistence: bit-exact round trip
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def saved(small_index, tmp_path_factory):
    idx, meta = small_index
    path = str(tmp_path_factory.mktemp("store") / "idx")
    save_index(path, idx, meta)
    return path


def test_round_trip_arrays_and_meta(small_index, saved):
    idx, meta = small_index
    loaded, lmeta = load_index(saved)
    assert lmeta == meta
    for f in idx._fields:
        a, b = np.asarray(getattr(idx, f)), np.asarray(getattr(loaded, f))
        assert a.dtype == b.dtype, f
        np.testing.assert_array_equal(a, b, err_msg=f)


@pytest.mark.parametrize("name", sorted(RETRIEVAL_CFGS))
def test_round_trip_retrieval_bit_exact(small_corpus, small_index, saved,
                                        name):
    """retrieve(load_index(save_index(p, idx)), q) == retrieve(idx, q),
    ids AND score bits, for both candidate modes and both megakernels."""
    idx, _ = small_index
    loaded, _ = load_index(saved)
    q = jnp.asarray(small_corpus.queries[:8])
    cfg = RETRIEVAL_CFGS[name]
    a = engine.retrieve(idx, q, cfg)
    b = engine.retrieve(loaded, q, cfg)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


def test_round_trip_retrieval_masked_pruned(small_corpus, small_index, saved):
    """The masking/pruning contract survives persistence: a pruned query +
    mask retrieves bit-identically on the loaded index."""
    idx, _ = small_index
    loaded, _ = load_index(saved)
    qp, qm = prune_queries(jnp.asarray(small_corpus.queries[:8]), keep=16)
    cfg = RETRIEVAL_CFGS["fused-score_all"]
    a = engine.retrieve(idx, qp, cfg, qm)
    b = engine.retrieve(loaded, qp, cfg, qm)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


# ---------------------------------------------------------------------------
# Persistence: every corruption raises an actionable ValueError
# ---------------------------------------------------------------------------

def _resave(src, dst, mutate_manifest=None, drop_array=None,
            mutate_arrays=None):
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(src, "arrays.npz")) as npz:
        arrays = {k: npz[k] for k in npz.files}
    if mutate_manifest:
        mutate_manifest(manifest)
    if drop_array:
        del arrays[drop_array]
    if mutate_arrays:
        mutate_arrays(arrays)
    os.makedirs(dst, exist_ok=True)
    np.savez(os.path.join(dst, "arrays.npz"), **arrays)
    with open(os.path.join(dst, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def test_load_missing_dir(tmp_path):
    with pytest.raises(ValueError, match="no manifest.json"):
        load_index(str(tmp_path / "nope"))


def test_load_corrupt_manifest(tmp_path, saved):
    dst = tmp_path / "bad"
    _resave(saved, str(dst))
    (dst / "manifest.json").write_text("{not json")
    with pytest.raises(ValueError, match="corrupt manifest.json"):
        load_index(str(dst))


def test_load_wrong_format(tmp_path, saved):
    dst = str(tmp_path / "bad")
    _resave(saved, dst, mutate_manifest=lambda m: m.update(format="tarball"))
    with pytest.raises(ValueError, match="format='tarball'"):
        load_index(dst)


def test_load_future_schema_version(tmp_path, saved):
    dst = str(tmp_path / "bad")
    _resave(saved, dst,
            mutate_manifest=lambda m: m.update(
                schema_version=SCHEMA_VERSION + 1))
    with pytest.raises(ValueError, match="newer than this build"):
        load_index(dst)


def test_load_missing_meta_field(tmp_path, saved):
    dst = str(tmp_path / "bad")
    _resave(saved, dst,
            mutate_manifest=lambda m: m["meta"].pop("n_centroids"))
    with pytest.raises(ValueError, match=r"missing field.*n_centroids"):
        load_index(dst)


def test_load_unknown_meta_field(tmp_path, saved):
    """Additive meta fields require a schema version bump — an unknown key
    at the current version means a mismatched writer, not silent luck."""
    dst = str(tmp_path / "bad")
    _resave(saved, dst,
            mutate_manifest=lambda m: m["meta"].update(frobnication=3))
    with pytest.raises(ValueError, match="unknown field.*frobnication"):
        load_index(dst)


def test_load_missing_array(tmp_path, saved):
    dst = str(tmp_path / "bad")
    _resave(saved, dst, drop_array="codes")
    with pytest.raises(ValueError, match="missing array 'codes'"):
        load_index(dst)


def test_load_dtype_mismatch(tmp_path, saved):
    dst = str(tmp_path / "bad")
    _resave(saved, dst,
            mutate_manifest=lambda m: m["arrays"]["codes"].update(
                dtype="float64"))
    with pytest.raises(ValueError, match="manifest declares float64"):
        load_index(dst)


def test_load_meta_array_disagreement(tmp_path, saved):
    dst = str(tmp_path / "bad")
    _resave(saved, dst,
            mutate_manifest=lambda m: m["meta"].update(n_docs=7))
    with pytest.raises(ValueError, match="disagrees with the arrays"):
        load_index(dst)


def test_load_missing_npz(tmp_path, saved):
    dst = tmp_path / "bad"
    _resave(saved, str(dst))
    (dst / "arrays.npz").unlink()
    with pytest.raises(ValueError, match="no arrays.npz"):
        load_index(str(dst))


def test_load_corrupt_npz(tmp_path, saved):
    dst = tmp_path / "bad"
    _resave(saved, str(dst))
    (dst / "arrays.npz").write_bytes(b"\x00" * 64)
    with pytest.raises(ValueError, match="corrupt arrays.npz"):
        load_index(str(dst))


# ---------------------------------------------------------------------------
# Content fingerprints (schema v2): the serving cache's generation ids
# ---------------------------------------------------------------------------

def test_manifest_fingerprint_matches_contents(small_index, saved):
    idx, _ = small_index
    with open(os.path.join(saved, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["schema_version"] == SCHEMA_VERSION
    assert manifest["fingerprint"] == index_fingerprint(idx)


def test_load_flipped_array_bytes(tmp_path, saved):
    """Same dtype, same shape, different BYTES: only the fingerprint can
    catch this corruption — the dtype/shape manifest checks cannot."""
    def flip(arrays):
        arrays["codes"] = arrays["codes"].copy()
        arrays["codes"][0, 0] += 1

    dst = str(tmp_path / "bad")
    _resave(saved, dst, mutate_arrays=flip)
    with pytest.raises(ValueError, match="disagrees with the array "
                                         "contents"):
        load_index(dst)


def test_load_missing_fingerprint_at_v2(tmp_path, saved):
    dst = str(tmp_path / "bad")
    _resave(saved, dst, mutate_manifest=lambda m: m.pop("fingerprint"))
    with pytest.raises(ValueError, match="no 'fingerprint'"):
        load_index(dst)


def test_load_v1_file_without_fingerprint(small_corpus, small_index, tmp_path,
                                          saved):
    """A schema-v1 save (pre-fingerprint, pre-predicate-plane) still loads,
    bit-exactly — the fingerprint is additive; only v2+ manifests are
    required to carry it, and the plane is synthesized all-zero."""
    def downgrade(m):
        m.pop("fingerprint")
        m["schema_version"] = 1
        del m["meta"]["pred_names"]
        del m["arrays"]["pred_words"]

    dst = str(tmp_path / "v1")
    _resave(saved, dst, mutate_manifest=downgrade, drop_array="pred_words")
    loaded, _ = load_index(dst)
    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[:4])
    a = engine.retrieve(idx, q, CFG)
    b = engine.retrieve(loaded, q, CFG)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


def test_fingerprint_tracks_mutation(stream_corpus, gen0):
    """add_passages changes the contents, so it must change the fingerprint
    (the serving cache's invalidation rule) — and with_newest swaps it into
    the timeline tail."""
    c = stream_corpus
    idx, meta = gen0
    fp0 = index_fingerprint(idx)
    grown, gmeta = add_passages(idx, meta, c.doc_embs[200:232],
                                c.doc_lens[200:232])
    assert index_fingerprint(grown) != fp0
    assert index_fingerprint(idx) == fp0          # input untouched
    tl = ShardedTimeline.of((idx, meta)).with_newest(grown, gmeta)
    assert tl.fingerprints == (index_fingerprint(grown),)


# ---------------------------------------------------------------------------
# Incremental growth + the timeline equivalence contract
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stream_corpus():
    # 3 slices of 200 docs; queries plant ground truth across all slices
    return make_corpus(0, n_docs=600, cap=24, min_len=8, n_queries=24,
                       n_topics=24)


@pytest.fixture(scope="module")
def gen0(stream_corpus):
    c = stream_corpus
    return build_index(jax.random.PRNGKey(0), c.doc_embs[:200],
                       c.doc_lens[:200], n_centroids=128, m=8, nbits=4,
                       kmeans_iters=3)


@pytest.fixture(scope="module")
def mono_grown(stream_corpus, gen0):
    """One monolithic index grown over the union corpus via add_passages."""
    c = stream_corpus
    idx, meta = gen0
    idx, meta = add_passages(idx, meta, c.doc_embs[200:400],
                             c.doc_lens[200:400])
    return add_passages(idx, meta, c.doc_embs[400:600], c.doc_lens[400:600])


@pytest.fixture(scope="module")
def timeline(stream_corpus, gen0):
    """The same union corpus as 3 immutable generations."""
    c = stream_corpus
    idx0, m0 = gen0
    tl = ShardedTimeline.of((idx0, m0))
    for lo in (200, 400):
        tl = tl.append(*new_generation(idx0, m0, c.doc_embs[lo:lo + 200],
                                       c.doc_lens[lo:lo + 200]))
    return tl


def test_add_passages_appends_consistently(stream_corpus, gen0, mono_grown):
    c = stream_corpus
    _, m0 = gen0
    idx, meta = mono_grown
    assert meta.n_docs == 600 and meta.n_grown == 400
    assert int(idx.codes.shape[0]) == 600
    # original docs untouched, appended docs' lengths preserved
    np.testing.assert_array_equal(np.asarray(idx.doc_lens),
                                  np.asarray(c.doc_lens[:600]))
    # every appended doc is reachable through each of its token centroids
    ivf = np.asarray(idx.ivf)
    lens = np.asarray(idx.ivf_lens)
    codes = np.asarray(idx.codes)
    for doc in (217, 599):
        for cid in np.unique(codes[doc][codes[doc] < meta.n_centroids]):
            assert doc in ivf[cid, :lens[cid]], (doc, cid)
    # drift stats: appended in-domain docs quantize a bit worse than the
    # training corpus, but in the same ballpark
    assert meta.train_quant_mse > 0
    assert meta.grown_quant_mse > 0
    assert 0.8 < meta.drift < 1.6, meta


def test_add_passages_validates_geometry(gen0):
    idx, meta = gen0
    bad = np.zeros((4, meta.cap + 3, meta.d), np.float32)
    with pytest.raises(ValueError, match="padded to"):
        add_passages(idx, meta, bad, np.full(4, 5, np.int32))
    with pytest.raises(ValueError, match="n_new=0"):
        add_passages(idx, meta, np.zeros((0, meta.cap, meta.d), np.float32),
                     np.zeros(0, np.int32))
    # degenerate but legal: an all-padding batch (zero real tokens) must not
    # blow up the drift accounting
    empty, emeta = add_passages(
        idx, meta, np.zeros((2, meta.cap, meta.d), np.float32),
        np.zeros(2, np.int32))
    assert emeta.n_docs == meta.n_docs + 2 and emeta.n_grown == 2
    assert np.isfinite(emeta.grown_quant_mse)


def test_add_passages_finds_new_docs(stream_corpus, mono_grown):
    """Queries whose planted doc lives in the APPENDED range retrieve it."""
    c = stream_corpus
    idx, _ = mono_grown
    grown_q = np.nonzero(c.gt_doc >= 200)[0][:8]
    assert grown_q.size >= 4
    res = engine.retrieve(idx, jnp.asarray(c.queries[grown_q]), CFG)
    ids = np.asarray(res.doc_ids)
    hits = [g in ids[i] for i, g in enumerate(c.gt_doc[grown_q])]
    assert np.mean(hits) >= 0.75, (hits, ids, c.gt_doc[grown_q])


def test_drift_ratio_flags_distribution_shift():
    """Out-of-distribution passages must quantize measurably worse against
    the frozen codebooks than in-domain passages — that gap is the re-train
    signal ``IndexMeta.drift`` exists to surface. Uses a low-token-noise
    corpus so the centroids genuinely fit the training distribution (on the
    noisy fixture corpus, quantization error is noise-dominated and drift
    ratios compress toward 1)."""
    c = make_corpus(5, n_docs=256, cap=16, min_len=8, n_queries=4,
                    n_topics=16, token_noise=0.05)
    idx0, m0 = build_index(jax.random.PRNGKey(0), c.doc_embs[:128],
                           c.doc_lens[:128], n_centroids=32, m=8, nbits=4,
                           kmeans_iters=3)
    _, in_meta = new_generation(idx0, m0, c.doc_embs[128:],
                                c.doc_lens[128:])
    # uniform random directions: no topic structure the centroids could fit
    rng = np.random.default_rng(99)
    ood_embs = rng.normal(size=(64, m0.cap, m0.d)).astype(np.float32)
    ood_embs /= np.linalg.norm(ood_embs, axis=-1, keepdims=True)
    _, ood_meta = new_generation(idx0, m0, ood_embs,
                                 np.full(64, m0.cap, np.int32))
    assert in_meta.drift < 1.5 < ood_meta.drift, (in_meta.drift,
                                                  ood_meta.drift)


@pytest.mark.parametrize("kernels", [False, True],
                         ids=["jnp-ref", "fused-megakernels"])
def test_timeline_matches_monolithic_exactly(stream_corpus, mono_grown,
                                             timeline, kernels):
    """The acceptance contract: a ShardedTimeline of G grown generations
    returns the SAME top-k ids (and score bits) as one monolithic index
    built over the union corpus, under cut-lossless budgets (every
    candidate late-interacted; see module docstring for the tie story and
    why tight budgets legitimately diverge in the timeline's favor)."""
    c = stream_corpus
    mono, _ = mono_grown
    cfg = EngineConfig(nprobe=8, th=0.2, th_r=0.4, n_filter=600, n_docs=600,
                       k=10, use_kernels=kernels)
    q = jnp.asarray(c.queries[:8] if kernels else c.queries)
    a = retrieve_timeline(timeline, q, cfg)
    b = engine.retrieve(mono, q, cfg)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


def test_timeline_masked_query_contract(stream_corpus, timeline):
    """Query masking threads through the merge path: a zero-padded query
    with its mask == the unpadded prefix, bit for bit, across generations."""
    c = stream_corpus
    keep = 20
    q = np.asarray(c.queries[:8]).copy()
    q[:, keep:] = 0.0
    qm = jnp.broadcast_to(jnp.arange(q.shape[1]) < keep, q.shape[:2])
    a = retrieve_timeline(timeline, jnp.asarray(q), CFG, qm)
    b = retrieve_timeline(timeline, jnp.asarray(q[:, :keep]), CFG)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


def test_timeline_small_final_generation(stream_corpus, gen0):
    """A freshly opened generation smaller than n_filter/cand_cap serves
    fine (budgets clamp per generation); one smaller than k raises."""
    c = stream_corpus
    idx0, m0 = gen0
    tiny = new_generation(idx0, m0, c.doc_embs[560:600], c.doc_lens[560:600])
    tl = ShardedTimeline.of((idx0, m0), tiny)
    res = retrieve_timeline(tl, jnp.asarray(c.queries[:8]), CFG)
    ids = np.asarray(res.doc_ids)
    assert ids.min() >= 0 and ids.max() < 240
    with pytest.raises(ValueError, match="must hold >= k docs"):
        engine.adapt_config_to_corpus(CFG, CFG.k - 1)


def test_timeline_compact_cap_clamped_to_generation_cap(stream_corpus,
                                                        timeline):
    """Regression: candidate_mode=compact with ``compact_cap`` above a
    generation's token cap used to die in ``lax.top_k`` over the token
    axis ("k argument to top_k must be no larger than minor dimension");
    ``adapt_config_to_corpus`` now clamps it per generation. The clamp is
    lossless — a buffer covering every token reproduces Eq. 6 exactly, so
    the result is bit-equal to ``compact_cap=None``."""
    base = dataclasses.replace(CFG, candidate_mode="compact", cand_cap=600)
    over = dataclasses.replace(base, compact_cap=40)      # > cap=24
    q = jnp.asarray(stream_corpus.queries[:8])
    a = retrieve_timeline(timeline, q, over)              # crashed pre-fix
    b = retrieve_timeline(timeline, q, base)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    # clamp semantics: shrinks to cap, preserves th_r, leaves None alone,
    # and without a cap (monolithic retrieve path) nothing changes
    g = engine.adapt_config_to_corpus(over, 200, 24)
    assert g.compact_cap == 24 and g.th_r == over.th_r
    assert engine.adapt_config_to_corpus(base, 200, 24).compact_cap is None
    assert engine.adapt_config_to_corpus(over, 200, None).compact_cap == 40


def test_timeline_rejects_mismatched_generations(stream_corpus, gen0):
    idx0, m0 = gen0
    bad_meta = dataclasses.replace(m0, n_centroids=m0.n_centroids * 2)
    with pytest.raises(ValueError, match="share the frozen codebooks"):
        ShardedTimeline.of((idx0, m0), (idx0, bad_meta))
    # same geometry, DIFFERENT codebooks (an independent build_index run):
    # scores are incomparable, so the merge must refuse
    c = stream_corpus
    other, om = build_index(jax.random.PRNGKey(7), c.doc_embs[200:400],
                            c.doc_lens[200:400], n_centroids=128, m=8,
                            nbits=4, kmeans_iters=3)
    with pytest.raises(ValueError, match="not comparable"):
        ShardedTimeline.of((idx0, m0), (other, om))


def test_timeline_save_load_round_trip(stream_corpus, timeline, tmp_path):
    path = str(tmp_path / "tl")
    save_timeline(path, timeline)
    loaded = load_timeline(path)
    assert len(loaded) == len(timeline)
    assert loaded.offsets == timeline.offsets
    # generation fingerprints round-trip (the serving cache's generation
    # ids survive persistence, so a reloaded timeline re-hits a warm cache)
    assert loaded.fingerprints == timeline.fingerprints
    q = jnp.asarray(stream_corpus.queries[:8])
    a = retrieve_timeline(timeline, q, CFG)
    b = retrieve_timeline(loaded, q, CFG)
    np.testing.assert_array_equal(np.asarray(a.doc_ids), np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


def test_load_timeline_errors(tmp_path):
    with pytest.raises(ValueError, match="no timeline.json"):
        load_timeline(str(tmp_path / "nope"))
    bad = tmp_path / "bad"
    bad.mkdir()
    (bad / "timeline.json").write_text(json.dumps(
        {"format": "emvb-sharded-timeline",
         "schema_version": SCHEMA_VERSION + 1, "generations": ["g"]}))
    with pytest.raises(ValueError, match="schema_version"):
        load_timeline(str(bad))


def test_load_timeline_swapped_generation(timeline, tmp_path):
    """A gen-NNNN directory replaced by a DIFFERENT (internally consistent)
    saved index must be refused: per-directory checks pass, only the
    timeline.json fingerprint list can see the swap."""
    import shutil

    path = str(tmp_path / "tl")
    save_timeline(path, timeline)
    shutil.rmtree(os.path.join(path, "gen-0002"))
    shutil.copytree(os.path.join(path, "gen-0001"),
                    os.path.join(path, "gen-0002"))
    with pytest.raises(ValueError, match="was replaced"):
        load_timeline(path)
    # a v1 timeline manifest (no fingerprints) skips the check and loads
    with open(os.path.join(path, "timeline.json")) as f:
        manifest = json.load(f)
    manifest.pop("fingerprints")
    manifest["schema_version"] = 1
    with open(os.path.join(path, "timeline.json"), "w") as f:
        json.dump(manifest, f)
    assert len(load_timeline(path)) == 3
