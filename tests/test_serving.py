"""Serving subsystem contract (repro.serving):

* ``RetrievalService(timeline, cfg).query(q)`` is BIT-exact (ids AND score
  bits) to the uncached ``retrieve_timeline(timeline, q, cfg)`` — cold and
  warm, across both candidate modes, both megakernels, masked/pruned
  queries, partial-warm (mixed hit/miss lane) batches, and across
  ``add_passages``/``new_generation`` mutations;
* cache correctness under mutation: a warm cache never serves stale results
  after ``add_passages`` on the newest generation, and ``new_generation``
  keeps old-generation entries live (hit/miss counters asserted);
* LRU eviction under the byte budget, fingerprint key semantics, batcher
  pad/deadline behavior, and the metrics/footprint accounting.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EngineConfig, ShardedTimeline, build_index,
                        bytes_per_embedding, generation_footprint,
                        new_generation, prune_queries, retrieve_timeline,
                        timeline_footprint)
from repro.data.synthetic import make_corpus
from repro.serving import (LatencyStats, MicroBatcher, ResultCache,
                           RetrievalService, ServiceMetrics,
                           config_fingerprint, pad_query, query_fingerprint)

# Same constants as tests/test_store.py so the jit cache is shared.
CFG = EngineConfig(nprobe=8, th=0.2, th_r=0.4, n_filter=128, n_docs=48, k=10)

RETRIEVAL_CFGS = {
    "ref-score_all": CFG,
    "ref-compact": dataclasses.replace(CFG, candidate_mode="compact",
                                       cand_cap=600),
    "prefilter-megakernel": dataclasses.replace(
        CFG, use_kernels=True, fused_late_interaction=False),
    "pqinter-megakernel": dataclasses.replace(
        CFG, use_kernels=True, fused_prefilter=False),
    "fused-score_all": dataclasses.replace(CFG, use_kernels=True),
    "fused-compact": dataclasses.replace(CFG, use_kernels=True,
                                         candidate_mode="compact",
                                         cand_cap=600),
}


@pytest.fixture(scope="module")
def serve_corpus():
    # 800 docs: 500 in the initial timeline, 100 for add_passages, 200 for
    # new_generation; queries plant ground truth across the whole range.
    return make_corpus(3, n_docs=800, cap=24, min_len=8, n_queries=32,
                       n_topics=32)


@pytest.fixture(scope="module")
def base_timeline(serve_corpus):
    """Generations of 200/200/100 docs (the last one deliberately small and
    still growing — the add_passages target)."""
    c = serve_corpus
    idx0, m0 = build_index(jax.random.PRNGKey(0), c.doc_embs[:200],
                           c.doc_lens[:200], n_centroids=128, m=8, nbits=4,
                           kmeans_iters=3)
    tl = ShardedTimeline.of((idx0, m0))
    tl = tl.append(*new_generation(idx0, m0, c.doc_embs[200:400],
                                   c.doc_lens[200:400]))
    return tl.append(*new_generation(idx0, m0, c.doc_embs[400:500],
                                     c.doc_lens[400:500]))


# ---------------------------------------------------------------------------
# The acceptance contract: service == uncached retrieve_timeline, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(RETRIEVAL_CFGS))
def test_service_matches_timeline_cold_and_warm(serve_corpus, base_timeline,
                                                name):
    """Cold (all-miss) AND warm (all immutable generations cached) service
    results equal the uncached merge path, ids AND score bits, for both
    candidate modes and both megakernels."""
    cfg = RETRIEVAL_CFGS[name]
    q = jnp.asarray(serve_corpus.queries[:8])
    ref = retrieve_timeline(base_timeline, q, cfg)
    svc = RetrievalService(base_timeline, cfg)
    cold = svc.query(np.asarray(q))
    warm = svc.query(np.asarray(q))
    for res in (cold, warm):
        np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                      np.asarray(res.doc_ids))
        np.testing.assert_array_equal(np.asarray(ref.scores),
                                      np.asarray(res.scores))
    # the warm pass hit every immutable generation for every query
    assert svc.cache.hits == (len(base_timeline) - 1) * 8
    assert svc.metrics.warm_queries == 8


def test_service_masked_pruned_queries(serve_corpus, base_timeline):
    """The PR 3 masking contract threads through the cache: pruned queries
    + masks retrieve bit-identically, cold and warm."""
    qp, qm = prune_queries(jnp.asarray(serve_corpus.queries[:8]), keep=16)
    ref = retrieve_timeline(base_timeline, qp, CFG, qm)
    svc = RetrievalService(base_timeline, CFG)
    for _ in range(2):  # cold, then warm
        res = svc.query(np.asarray(qp), np.asarray(qm))
        np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                      np.asarray(res.doc_ids))
        np.testing.assert_array_equal(np.asarray(ref.scores),
                                      np.asarray(res.scores))
    assert svc.cache.hits > 0


@pytest.mark.parametrize("pad_miss_lane", [True, False],
                         ids=["padded-miss-lane", "tight-miss-lane"])
def test_service_partial_warm_batch(serve_corpus, base_timeline,
                                    pad_miss_lane):
    """A batch mixing cached and novel queries (hit lane + miss lane inside
    ONE generation) still merges bit-exactly — the engine is bit-invariant
    to batch composition, padded or tight miss lane alike."""
    c = serve_corpus
    svc = RetrievalService(base_timeline, CFG, pad_miss_lane=pad_miss_lane)
    svc.query(np.asarray(c.queries[:8]))                      # cache 0..7
    mix = np.concatenate([c.queries[4:8], c.queries[8:12]])   # half warm
    ref = retrieve_timeline(base_timeline, jnp.asarray(mix), CFG)
    res = svc.query(mix)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(res.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(res.scores))
    # the warm half hit, the novel half missed (per immutable generation)
    assert svc.metrics.warm_queries == 4


# ---------------------------------------------------------------------------
# Cache correctness under mutation (the satellite the counters pin down)
# ---------------------------------------------------------------------------

def test_warm_cache_add_passages_not_stale(serve_corpus, base_timeline):
    """add_passages on the newest generation bumps its fingerprint: the
    very next query sees the new docs, while the old generations' cache
    entries keep serving (hit counters prove both)."""
    c = serve_corpus
    q = jnp.asarray(c.queries[:8])
    svc = RetrievalService(base_timeline, CFG)
    svc.query(np.asarray(q))                                  # cold fill
    svc.query(np.asarray(q))                                  # warm
    hits_before = svc.cache.hits
    assert hits_before == 16                                  # 2 gens x 8

    svc.add_passages(c.doc_embs[500:600], c.doc_lens[500:600])
    res = svc.query(np.asarray(q))
    # bit-exact vs the uncached path over the GROWN timeline
    ref = retrieve_timeline(svc.timeline, q, CFG)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(res.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(res.scores))
    # old generations still served from cache; only the grown one recomputed
    assert svc.cache.hits - hits_before == 16
    # not stale: queries planted in the appended range retrieve their doc
    new_q = np.nonzero((c.gt_doc >= 500) & (c.gt_doc < 600))[0][:4]
    assert new_q.size >= 2
    got = svc.query(np.asarray(c.queries[new_q]))
    ids = np.asarray(got.doc_ids)
    hits = [g in ids[i] for i, g in enumerate(c.gt_doc[new_q])]
    assert np.mean(hits) >= 0.5, (hits, ids, c.gt_doc[new_q])


def test_warm_cache_new_generation_reuses_old_entries(serve_corpus,
                                                      base_timeline):
    """new_generation freezes the previously-newest generation: old entries
    keep hitting, the frozen generation starts caching (miss once, then
    hit), and results stay bit-exact vs the uncached path."""
    c = serve_corpus
    q = jnp.asarray(c.queries[:8])
    svc = RetrievalService(base_timeline, CFG)
    svc.query(np.asarray(q))                                  # cold fill
    svc.new_generation(c.doc_embs[600:800], c.doc_lens[600:800])
    assert len(svc.timeline) == 4

    h0, m0 = svc.cache.hits, svc.cache.misses
    res = svc.query(np.asarray(q))
    # gens 0-1 hit from the pre-mutation fill; gen 2 (newly frozen) misses
    assert svc.cache.hits - h0 == 16
    assert svc.cache.misses - m0 == 8
    ref = retrieve_timeline(svc.timeline, q, CFG)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(res.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(res.scores))

    h1 = svc.cache.hits
    svc.query(np.asarray(q))
    # now all three immutable generations hit
    assert svc.cache.hits - h1 == 24


# ---------------------------------------------------------------------------
# Cache unit behavior: keys, LRU under the byte budget
# ---------------------------------------------------------------------------

def test_query_fingerprint_semantics():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(8, 16)).astype(np.float32)
    assert query_fingerprint(q) == query_fingerprint(q, np.ones(8, bool))
    mask = np.ones(8, bool)
    mask[3] = False
    assert query_fingerprint(q, mask) != query_fingerprint(q)
    q2 = q.copy()
    q2[0, 0] += 1e-7
    assert query_fingerprint(q2) != query_fingerprint(q)
    # a prefix and its zero-padded form are distinct keys
    padded = np.zeros((12, 16), np.float32)
    padded[:8] = q
    pm = np.arange(12) < 8
    assert query_fingerprint(padded, pm) != query_fingerprint(q)


def test_config_fingerprint_covers_every_field():
    base = config_fingerprint(CFG)
    for change in ({"k": 5}, {"th": 0.3}, {"use_kernels": True},
                   {"candidate_mode": "compact"}, {"cs_dtype": "bfloat16"}):
        assert config_fingerprint(dataclasses.replace(CFG, **change)) != base
    assert config_fingerprint(dataclasses.replace(CFG)) == base


def test_cache_lru_eviction_under_byte_budget():
    entry = (np.zeros(10, np.float32), np.zeros(10, np.int32))  # 80 B
    cache = ResultCache(max_bytes=3 * 80)
    for i in range(4):
        cache.put((f"q{i}", "g", "c"), *entry)
    assert len(cache) == 3 and cache.bytes == 3 * 80
    assert cache.evictions == 1
    assert cache.get(("q0", "g", "c")) is None          # LRU'd out
    assert cache.get(("q3", "g", "c")) is not None
    # recency refresh: touch q1, insert another -> q2 (now LRU) evicts
    assert cache.get(("q1", "g", "c")) is not None
    cache.put(("q4", "g", "c"), *entry)
    assert cache.get(("q2", "g", "c")) is None
    assert cache.get(("q1", "g", "c")) is not None
    # an entry larger than the whole budget is not cached at all
    big = (np.zeros(1000, np.float32), np.zeros(1000, np.int32))
    cache.put(("huge", "g", "c"), *big)
    assert cache.get(("huge", "g", "c")) is None
    assert cache.bytes <= cache.max_bytes


# ---------------------------------------------------------------------------
# Batcher: padding, tickets, size/deadline semantics
# ---------------------------------------------------------------------------

def test_pad_query_validation():
    q16 = np.ones((16, 8), np.float32)
    padded, mask = pad_query(q16, 32)
    assert padded.shape == (32, 8) and mask.sum() == 16
    np.testing.assert_array_equal(padded[16:], 0.0)
    with pytest.raises(ValueError, match="prune it first"):
        pad_query(np.ones((40, 8), np.float32), 32)
    with pytest.raises(ValueError, match="one bool per"):
        pad_query(q16, 32, np.ones(9, bool))
    # caller's mask survives under the padding mask
    m = np.ones(16, bool)
    m[2] = False
    _, full = pad_query(q16, 32, m)
    assert not full[2] and full[:16].sum() == 15


def test_submit_flush_tickets(serve_corpus, base_timeline):
    """Heterogeneous-length queries batch through submit/flush and each
    ticket equals the uncached retrieval of ITS unpadded prefix."""
    c = serve_corpus
    svc = RetrievalService(base_timeline, CFG, max_batch=4)
    t_short = svc.submit(c.queries[0][:16])                   # 16 terms
    t_full = svc.submit(c.queries[1])                         # all 32
    with pytest.raises(RuntimeError, match="still pending"):
        t_short.result()
    svc.flush()
    assert t_short.done and t_full.done
    ref_short = retrieve_timeline(base_timeline,
                                  jnp.asarray(c.queries[0:1, :16]), CFG)
    np.testing.assert_array_equal(t_short.result()[1],
                                  np.asarray(ref_short.doc_ids)[0])
    np.testing.assert_array_equal(t_short.result()[0],
                                  np.asarray(ref_short.scores)[0])
    ref_full = retrieve_timeline(base_timeline,
                                 jnp.asarray(c.queries[1:2]), CFG)
    np.testing.assert_array_equal(t_full.result()[1],
                                  np.asarray(ref_full.doc_ids)[0])


def test_batcher_size_and_deadline_triggers(serve_corpus, base_timeline):
    c = serve_corpus
    now = [0.0]
    svc = RetrievalService(base_timeline, CFG, max_batch=2,
                           max_delay_s=0.01, clock=lambda: now[0])
    # deadline: a lone query flushes only once max_delay_s has passed
    t1 = svc.submit(c.queries[0])
    svc.poll()
    assert not t1.done
    now[0] += 0.02
    svc.poll()
    assert t1.done
    # size: the max_batch-th submit flushes immediately, no poll needed
    t2 = svc.submit(c.queries[1])
    t3 = svc.submit(c.queries[2])
    assert t2.done and t3.done
    # the queue deadline re-anchors per batch
    mb = MicroBatcher(n_q=32, max_batch=2, max_delay_s=0.01,
                      clock=lambda: now[0])
    mb.submit(c.queries[0])
    assert not mb.due()
    now[0] += 0.02
    assert mb.due()


def test_batcher_overflow_keeps_original_deadline(serve_corpus):
    """A query left behind when a full max_batch drains keeps its ORIGINAL
    submit time: the deadline is a per-query promise, so it must come due
    max_delay_s after ITS submit — not max_delay_s after the drain (which
    would let an overflow query wait up to twice the promise)."""
    c = serve_corpus
    now = [0.0]
    mb = MicroBatcher(n_q=32, max_batch=2, max_delay_s=0.01,
                      clock=lambda: now[0])
    for i in range(3):                       # all submitted at t=0
        mb.submit(c.queries[i])
    now[0] = 0.008
    qb, _, _ = mb.drain()                    # full batch of 2 leaves at t=8ms
    assert qb.q.shape[0] == 2 and len(mb) == 1
    now[0] = 0.012                           # 12ms after the overflow submit
    assert mb.due()                          # NOT re-anchored to the drain
    # and the deadline was not due early either
    mb.drain()
    mb.submit(c.queries[0])
    now[0] = 0.0215
    assert not mb.due()
    now[0] = 0.023
    assert mb.due()


def test_query_empty_batch_raises_actionable(base_timeline):
    """A zero-length batch fails at the service entry point with an
    actionable message, not numpy's bare 'need at least one array to
    stack' from deep inside the pad loop."""
    svc = RetrievalService(base_timeline, CFG)
    with pytest.raises(ValueError, match="empty query batch"):
        svc.query(np.zeros((0, 32, 128), np.float32))
    with pytest.raises(ValueError, match="empty query batch"):
        svc._execute(np.zeros((0, 32, 128), np.float32),
                     np.zeros((0, 32), bool))
    with pytest.raises(ValueError, match="expected"):
        svc.query(np.zeros((32, 128), np.float32))   # missing batch dim


# ---------------------------------------------------------------------------
# Metrics + footprint accounting
# ---------------------------------------------------------------------------

def test_latency_stats_percentiles():
    ls = LatencyStats(window=100)
    for v in range(1, 101):                                   # 1..100 ms
        ls.record(v / 1e3)
    snap = ls.snapshot()
    assert snap["count"] == 100
    assert abs(snap["p50_ms"] - 50.5) < 1.0
    assert snap["p99_ms"] > 98.0
    # ring buffer: old samples age out of the window
    for _ in range(100):
        ls.record(0.2)
    assert abs(ls.snapshot()["p50_ms"] - 200.0) < 1e-6
    assert ls.count == 200


def test_service_metrics_warm_cold_split():
    m = ServiceMetrics()
    m.record_batch(8, 8, 0.001)                               # fully warm
    m.record_batch(8, 4, 0.010)                               # mixed = cold
    snap = m.snapshot()
    assert snap["queries"] == 16 and snap["warm_queries"] == 12
    assert snap["warm_latency"]["count"] == 1
    assert snap["cold_latency"]["count"] == 1
    assert snap["warm_fraction"] == 0.75


def test_footprint_accounting(base_timeline):
    tl = base_timeline
    fp = timeline_footprint(tl)
    gens = [generation_footprint(g, m) for g, m, _ in tl]
    assert fp["n_generations"] == len(tl) and fp["n_docs"] == tl.n_docs
    assert fp["index_bytes"] == sum(g["index_bytes"] for g in gens)
    assert fp["manifest_bytes"] > sum(g["manifest_bytes"] for g in gens)
    assert fp["total_bytes"] == fp["index_bytes"] + fp["manifest_bytes"]
    assert fp["n_tokens"] == int(sum(np.asarray(g.doc_lens).sum()
                                     for g in tl.generations))
    # paper-formula constant vs actual packed bytes: the fixed-shape layout
    # (padding, 4-byte ids, PLAID codes alongside PQ) costs strictly more
    assert fp["bytes_per_embedding"] == bytes_per_embedding(tl.metas[0],
                                                            "emvb")
    assert fp["bytes_per_embedding_actual"] > fp["bytes_per_embedding"]
    per_gen = gens[0]
    assert per_gen["index_bytes"] == sum(per_gen["array_bytes"].values())


def test_stats_snapshot_shape(serve_corpus, base_timeline):
    svc = RetrievalService(base_timeline, CFG)
    svc.query(np.asarray(serve_corpus.queries[:4]))
    snap = svc.stats()
    assert snap["cache"]["entries"] == 8                      # 2 gens x 4
    assert snap["timeline"]["n_generations"] == 3
    assert snap["timeline"]["total_bytes"] > 0
    assert snap["latency"]["count"] == 1
    assert snap["queries"] == 4


def test_latency_stats_ring_wrap_window():
    """Once count > window the quantiles and max see exactly the most
    recent `window` samples; count/mean stay cumulative over all."""
    ls = LatencyStats(window=8)
    for v in range(1, 21):                                    # 1..20 ms
        ls.record(v / 1e3)
    snap = ls.snapshot()
    assert snap["count"] == 20
    # window holds 13..20 ms only — the early cheap samples aged out
    assert snap["max_ms"] == pytest.approx(20.0)
    assert snap["p50_ms"] == pytest.approx(16.5)
    assert snap["p95_ms"] == pytest.approx(np.percentile(
        np.arange(13, 21), 95))
    assert ls.max() == pytest.approx(0.020)
    # mean is all-history: (1+..+20)/20 = 10.5 ms
    assert snap["mean_ms"] == pytest.approx(10.5)
    assert set(snap) == {"count", "mean_ms", "p50_ms", "p95_ms", "p99_ms",
                         "max_ms"}


def test_service_metrics_mixed_filtered_accounting():
    """n_filtered need not be 0 or n_queries: direct callers may report a
    mixed batch and the filtered/unfiltered split must still add up."""
    m = ServiceMetrics()
    m.record_batch(8, 8, 0.001, n_filtered=3)
    snap = m.snapshot()
    assert snap["filtered_queries"] == 3
    assert snap["unfiltered_queries"] == 5
    assert m.filtered_queries + m.unfiltered_queries == m.queries


def test_service_metrics_rejects_unknown_maintenance_kind():
    m = ServiceMetrics()
    m.record_maintenance("merge")
    m.record_maintenance("reepoch")
    with pytest.raises(ValueError, match="unknown maintenance action kind"):
        m.record_maintenance("compact")
    assert m.merges == 1 and m.reepochs == 1


def test_service_metrics_warm_reservoir_routing():
    """Only fully-warm batches land in the warm latency reservoir; any
    miss makes the batch's latency cold-path by accounting."""
    m = ServiceMetrics()
    m.record_batch(4, 4, 0.001)                               # fully warm
    m.record_batch(4, 3, 0.010)                               # one miss
    m.record_batch(4, 0, 0.020)                               # fully cold
    assert m.warm_latency.count == 1
    assert m.cold_latency.count == 2
    assert m.batch_latency.count == 3
    assert m.warm_latency.max() == pytest.approx(0.001)
    assert m.cold_latency.max() == pytest.approx(0.020)


def test_service_metrics_registry_equivalence():
    """The registry-backed snapshot keeps the historical dict shape: every
    counter field equals its property read, and the new batcher /
    generations sections ride along."""
    m = ServiceMetrics()
    m.record_batch(8, 8, 0.001)
    m.record_batch(8, 4, 0.010, n_filtered=8)
    m.record_swap()
    m.record_swap(deferred=True)
    m.record_maintenance("merge")
    m.record_deadline_misses(2)
    m.set_queue_depth(3)
    m.record_generation_lookups("abcdef0123456789", hits=6, misses=2)
    snap = m.snapshot()
    assert snap["batches"] == m.batches == 2
    assert snap["queries"] == m.queries == 16
    assert snap["warm_queries"] == m.warm_queries == 12
    assert snap["cold_queries"] == m.cold_queries == 4
    assert snap["warm_fraction"] == 0.75
    assert snap["filtered_queries"] == m.filtered_queries == 8
    assert snap["maintenance"] == {"swaps": 2, "deferred_swaps": 1,
                                   "merges": 1, "reepochs": 0}
    assert snap["batcher"] == {"queue_depth": 3, "deadline_misses": 2}
    assert snap["generations"] == {
        "abcdef012345": {"hits": 6, "misses": 2, "hit_ratio": 0.75}}
    assert snap["latency"]["count"] == 2
    # counters are registry instruments: mutation by assignment is gone
    with pytest.raises(AttributeError):
        m.queries = 99


def test_snapshot_rejects_partial_footprint(base_timeline):
    """A timeline_footprint dict missing required byte-accounting keys is
    a producer bug — KeyError naming the gaps, not silent omission."""
    m = ServiceMetrics()
    with pytest.raises(KeyError, match="predicate_bytes"):
        m.snapshot(timeline_footprint={"n_generations": 1, "n_docs": 10})
    full = timeline_footprint(base_timeline)
    snap = m.snapshot(timeline_footprint=full)
    assert snap["timeline"]["n_docs"] == base_timeline.n_docs
    # optional keys pass through when the producer supplies them...
    with_opt = dict(full, n_epochs=2)
    assert m.snapshot(timeline_footprint=with_opt)["timeline"][
        "n_epochs"] == 2
    # ...and are silently absent otherwise
    assert "n_epochs" not in snap["timeline"] or "n_epochs" in full
