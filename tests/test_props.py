"""Property tests on the system's invariants (docs/TESTING.md).

Runs under real Hypothesis when installed (CI's props lane) or under the
deterministic fallback runner in ``tests/strategies.py`` otherwise — the
suite always collects and runs; it never silently skips.

Two layers:

* component properties — bit vectors as set semantics, PQ LUT == decode,
  residual codec roundtrips, cache accounting vs an OrderedDict model;
* engine contracts under random inputs — the load-bearing bit-exact
  equivalences (padded==prefix, timeline==monolithic, cache==uncached,
  batched==vmap, filtered==post-filter, pooled pass-through==unpooled),
  each asserted on ids AND score bits over random query picks, mask
  prefixes, dispatch variants, filters, and document budgets.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from strategies import (HAVE_HYPOTHESIS, doc_budgets, engine_variants,
                        filter_exprs, given, make_cfg, predicate_plane,
                        prefix_lens, query_picks, settings, st, tiny_corpora)

from repro.core import (ShardedTimeline, add_passages, bitvector, engine,
                        build_index, new_generation, pool_documents,
                        residual, retrieve_timeline)
from repro.core.pq import PQCodebooks, build_lut, decode_pq, encode_pq, lut_score
from repro.train.compression import dequantize_int8, quantize_int8

SETTINGS = dict(max_examples=30, deadline=None)
# engine contracts retrieve through jit'd programs: few examples, drawn
# from small shape/variant pools so compiles amortize across examples
ENGINE_SETTINGS = dict(max_examples=5, deadline=None)


def _assert_bitexact(a, b):
    """ids AND score bits equal — every engine contract's acceptance bar."""
    np.testing.assert_array_equal(np.asarray(a.doc_ids),
                                  np.asarray(b.doc_ids))
    np.testing.assert_array_equal(np.asarray(a.scores),
                                  np.asarray(b.scores))


def test_props_backend_is_exercised():
    """Meta-test against the silent-skip hazard this suite used to have:
    whichever backend is active, @given must actually RUN the body."""
    ran = []

    @settings(max_examples=3, deadline=None)
    @given(st.integers(0, 10))
    def prop(n):
        ran.append(n)
        assert 0 <= n <= 10

    prop()
    assert ran, "property body never executed (backend=%s)" % (
        "hypothesis" if HAVE_HYPOTHESIS else "shim")


# ---------------------------------------------------------------------------
# C1: the stacked bit vector is EXACTLY the set-membership structure (Eq. 4)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.integers(1, 32), st.integers(2, 64),
       st.floats(-0.9, 0.9))
def test_bitvector_equals_set_semantics(seed, n_q, n_c, th):
    rng = np.random.default_rng(seed)
    cs = rng.uniform(-1, 1, size=(n_q, n_c)).astype(np.float32)
    bits = np.asarray(bitvector.build_bitvectors(jnp.asarray(cs), th))
    # brute-force close_i sets
    for c in range(n_c):
        for i in range(n_q):
            assert bool(bits[c] >> i & 1) == bool(cs[i, c] > th)


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.integers(1, 16), st.integers(4, 48),
       st.integers(1, 12))
def test_filter_score_counts_covered_terms(seed, n_q, n_c, cap):
    """F(P,q) == #{i : exists token whose centroid is in close_i} (Eq. 4)."""
    rng = np.random.default_rng(seed)
    cs = rng.uniform(-1, 1, size=(n_q, n_c)).astype(np.float32)
    th = 0.2
    codes = rng.integers(0, n_c, size=(5, cap)).astype(np.int32)
    lens = rng.integers(1, cap + 1, size=5)
    mask = np.arange(cap)[None] < lens[:, None]
    bits = bitvector.build_bitvectors(jnp.asarray(cs), th)
    f = np.asarray(bitvector.filter_score(bits, jnp.asarray(codes),
                                          jnp.asarray(mask)))
    for p in range(5):
        close = {(i, c) for i in range(n_q) for c in range(n_c)
                 if cs[i, c] > th}
        toks = set(codes[p, :lens[p]].tolist())
        expected = sum(1 for i in range(n_q)
                       if any((i, c) in close for c in toks))
        assert f[p] == expected


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1))
def test_filter_monotone_in_threshold(seed):
    """Raising th can only shrink close_i sets -> F non-increasing."""
    rng = np.random.default_rng(seed)
    cs = jnp.asarray(rng.uniform(-1, 1, size=(8, 32)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, 32, size=(7, 9)).astype(np.int32))
    mask = jnp.ones((7, 9), bool)
    f_lo = np.asarray(bitvector.filter_score(
        bitvector.build_bitvectors(cs, 0.1), codes, mask))
    f_hi = np.asarray(bitvector.filter_score(
        bitvector.build_bitvectors(cs, 0.5), codes, mask))
    assert (f_hi <= f_lo).all()
    assert (f_lo <= 8).all() and (f_lo >= 0).all()


# ---------------------------------------------------------------------------
# C3: PQ invariants
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]),
       st.sampled_from([4, 16]))
def test_pq_lut_score_equals_decode_dot(seed, m, ksub):
    """LUT scoring == dot with decoded vectors (the no-decompression claim)."""
    rng = np.random.default_rng(seed)
    d = m * 4
    cb = PQCodebooks(jnp.asarray(
        rng.normal(size=(m, ksub, 4)).astype(np.float32)))
    x = jnp.asarray(rng.normal(size=(20, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    codes = encode_pq(x, cb)
    via_lut = np.asarray(lut_score(build_lut(q, cb), codes))
    via_decode = np.asarray(decode_pq(codes, cb) @ q)
    np.testing.assert_allclose(via_lut, via_decode, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1))
def test_pq_encode_is_nearest_codeword(seed):
    rng = np.random.default_rng(seed)
    m, ksub, dsub = 4, 8, 3
    cb = PQCodebooks(jnp.asarray(
        rng.normal(size=(m, ksub, dsub)).astype(np.float32)))
    x = rng.normal(size=(10, m * dsub)).astype(np.float32)
    codes = np.asarray(encode_pq(jnp.asarray(x), cb))
    for n in range(10):
        for s in range(m):
            sub = x[n, s * dsub:(s + 1) * dsub]
            d2 = ((np.asarray(cb.codebooks)[s] - sub) ** 2).sum(-1)
            assert d2[codes[n, s]] <= d2.min() + 1e-5


# ---------------------------------------------------------------------------
# PLAID b-bit codec: pack/unpack roundtrip
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]),
       st.integers(1, 8))
def test_residual_pack_roundtrip(seed, b, groups):
    rng = np.random.default_rng(seed)
    d = groups * (8 // b)
    codes = rng.integers(0, 1 << b, size=(6, d)).astype(np.uint8)
    packed = residual.pack_codes(jnp.asarray(codes), b)
    assert packed.shape == (6, d * b // 8)
    out = np.asarray(residual.unpack_codes(packed, b, d))
    np.testing.assert_array_equal(out, codes)


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2]))
def test_residual_codec_error_bounded_by_buckets(seed, b):
    rng = np.random.default_rng(seed)
    r = rng.normal(scale=0.2, size=(512, 16)).astype(np.float32)
    codec = residual.train_residual_codec(jnp.asarray(r), b)
    dec = np.asarray(residual.decode_residual(
        residual.encode_residual(jnp.asarray(r), codec), codec, 16))
    # reconstruction is within the spread of adjacent bucket weights
    max_gap = np.max(np.abs(r - dec))
    assert max_gap <= np.abs(r).max() + 1e-6
    # quantizing the decoded values again is a fixed point
    dec2 = np.asarray(residual.decode_residual(
        residual.encode_residual(jnp.asarray(dec), codec), codec, 16))
    np.testing.assert_allclose(dec, dec2, atol=1e-6)


# ---------------------------------------------------------------------------
# int8 gradient compression
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.floats(1e-3, 1e3))
def test_int8_compression_relative_error(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray((rng.normal(size=(64,)) * scale).astype(np.float32))
    q, s = quantize_int8(g)
    out = dequantize_int8(q, s)
    err = np.abs(np.asarray(out) - np.asarray(g)).max()
    assert err <= float(s) * 0.5 + 1e-9  # half-ULP of the int8 grid


# ---------------------------------------------------------------------------
# Constant-space pooling (PR 9 tentpole): budget and determinism laws
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.data())
def test_pool_documents_budget_laws(data):
    """For ANY corpus and budget m: pooled lens are in [1, min(len, m)],
    padding past each pooled len is exactly zero, docs already under the
    budget pass through VERBATIM, and pooling is deterministic."""
    c = data.draw(tiny_corpora(), label="corpus")
    cap = c.doc_embs.shape[1]
    budget = data.draw(doc_budgets(cap, with_none=False), label="budget")
    pooled, plens = pool_documents(c.doc_embs, c.doc_lens, budget)
    new_cap = pooled.shape[1]
    assert new_cap == min(cap, budget)
    assert (plens >= 1).all()
    assert (plens <= np.minimum(c.doc_lens, budget)).all()
    pad = np.arange(new_cap)[None, :] >= plens[:, None]
    assert (pooled[pad] == 0.0).all()
    passthrough = c.doc_lens <= budget
    if passthrough.any():
        np.testing.assert_array_equal(plens[passthrough],
                                      c.doc_lens[passthrough])
        np.testing.assert_array_equal(pooled[passthrough],
                                      c.doc_embs[passthrough, :new_cap])
    pooled2, plens2 = pool_documents(c.doc_embs, c.doc_lens, budget)
    np.testing.assert_array_equal(pooled, pooled2)
    np.testing.assert_array_equal(plens, plens2)


# ---------------------------------------------------------------------------
# C4 (TPU-adapted): per-token compaction of the PQ late interaction
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.integers(4, 32), st.integers(4, 24),
       st.sampled_from([4, 8, 16]))
def test_compact_equals_full_when_buffer_covers_cap(seed, n_q, cap, m):
    """cap_c == cap must reproduce Eq. 6 EXACTLY (no approximation)."""
    from repro.core import interaction as I
    rng = np.random.default_rng(seed)
    n_c, docs, ksub = 128, 12, 256
    cs_t = jnp.asarray(rng.normal(size=(n_c, n_q)).astype(np.float32)) * 0.4
    codes = jnp.asarray(rng.integers(0, n_c + 1, (docs, cap)).astype(np.int32))
    lens = rng.integers(1, cap + 1, docs)
    mask = jnp.asarray(np.arange(cap)[None, :] < lens[:, None])
    lut = jnp.asarray(rng.normal(size=(n_q, m, ksub)).astype(np.float32)) * .1
    # uint8 res codes: regression for the flat-LUT uint8 index-offset wrap
    res = jnp.asarray(rng.integers(0, ksub, (docs, cap, m)).astype(np.uint8))
    full = I.late_interaction_pq(cs_t, lut, codes, res, mask, 0.3)
    comp = I.late_interaction_pq_compact(cs_t, lut, codes, res, mask, 0.3, cap)
    np.testing.assert_allclose(np.asarray(comp), np.asarray(full), rtol=1e-5,
                               atol=1e-5)


@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1))
def test_compact_masked_terms_exact_when_kept_fit(seed):
    """If every kept token fits the buffer, terms with J̄_i nonempty score
    EXACTLY as Eq. 6; only empty-J̄ fallback terms may be approximated."""
    from repro.core import interaction as I
    rng = np.random.default_rng(seed)
    n_q, n_c, docs, cap, m, ksub = 8, 64, 8, 16, 4, 16
    th_r = 0.5
    cs_t = jnp.asarray(rng.normal(size=(n_c, n_q)).astype(np.float32)) * 0.4
    codes = jnp.asarray(rng.integers(0, n_c, (docs, cap)).astype(np.int32))
    mask = jnp.ones((docs, cap), bool)
    lut = jnp.asarray(rng.normal(size=(n_q, m, ksub)).astype(np.float32)) * .1
    res = jnp.asarray(rng.integers(0, ksub, (docs, cap, m)).astype(np.uint8))
    row_max = np.asarray(cs_t).max(1)
    kept = (row_max[np.asarray(codes)] > th_r)
    cap_c = max(int(kept.sum(1).max()), 1)
    if cap_c >= cap:
        return  # nothing compacted, covered by the exactness test above
    centroid = np.asarray(I.gather_centroid_scores(cs_t, codes))
    keep_t = centroid > th_r                      # (docs, cap, n_q)
    full = np.asarray(I.late_interaction_pq(cs_t, lut, codes, res, mask, th_r))
    comp = np.asarray(I.late_interaction_pq_compact(
        cs_t, lut, codes, res, mask, th_r, cap_c))
    # docs where EVERY term has a kept token -> fully exact
    all_masked = keep_t.any(axis=1).all(axis=-1)
    if all_masked.any():
        np.testing.assert_allclose(comp[all_masked], full[all_masked],
                                   rtol=1e-5, atol=1e-5)
    # fallback terms can only lower the score (max over a token subset)
    assert (comp <= full + 1e-4).all()


# ---------------------------------------------------------------------------
# Serving ResultCache: byte accounting + LRU order under arbitrary churn
# ---------------------------------------------------------------------------

_CACHE_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("put"), st.integers(0, 5),
                  st.sampled_from([2, 8, 16, 40])),
        st.tuples(st.just("get"), st.integers(0, 5)),
        st.tuples(st.just("clear")),
    ), min_size=1, max_size=60)


@settings(**SETTINGS)
@given(_CACHE_OPS)
def test_result_cache_accounting_matches_model(ops):
    """Under ANY interleaving of put / re-put-same-key / get / clear,
    ``cache.bytes`` equals the sum of resident entry nbytes, the entry
    order is true LRU (gets refresh recency, re-puts move to MRU), an
    oversized put is rejected WITHOUT disturbing the existing entry at
    that key, and hits return exactly the latest payload stored."""
    from collections import OrderedDict

    from repro.serving.cache import ResultCache

    budget = 256                     # a size-40 entry (320 B) is oversized
    cache = ResultCache(max_bytes=budget)
    model: "OrderedDict[tuple, tuple[int, int]]" = OrderedDict()
    stamp = 0
    for op in ops:
        if op[0] == "put":
            _, ki, n = op
            stamp += 1
            key = (f"q{ki}", "g", "c")
            scores = np.full(n, float(stamp), np.float32)
            ids = np.arange(n, dtype=np.int32) + stamp
            cache.put(key, scores, ids)
            nbytes = scores.nbytes + ids.nbytes
            if nbytes <= budget:     # oversized: no change, old key survives
                model.pop(key, None)
                model[key] = (nbytes, stamp)
                while sum(v[0] for v in model.values()) > budget:
                    model.popitem(last=False)
        elif op[0] == "get":
            key = (f"q{op[1]}", "g", "c")
            got = cache.get(key)
            if key in model:
                model.move_to_end(key)
                nb, s = model[key]
                n = nb // 8
                np.testing.assert_array_equal(
                    got[0], np.full(n, float(s), np.float32))
                np.testing.assert_array_equal(
                    got[1], np.arange(n, dtype=np.int32) + s)
            else:
                assert got is None
        else:
            cache.clear()
            model.clear()
        assert cache.bytes == sum(v[0] for v in model.values())
        assert cache.bytes == sum(e.nbytes
                                  for e in cache._entries.values())
        assert list(cache._entries.keys()) == list(model.keys())
        assert cache.bytes <= cache.max_bytes


# ---------------------------------------------------------------------------
# Engine contracts under random inputs — the bit-exact equivalence suite.
#
# Shared module fixtures: a 300-doc base generation (prop_base), a grown
# monolith + 2-generation timeline over the same codebooks (prop_timeline),
# a predicate-plane twin (prop_findex), and pooled builds (pass-through and
# tight). All draw queries from the session small_corpus.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def prop_base(small_corpus):
    c = small_corpus
    return build_index(jax.random.PRNGKey(3), c.doc_embs[:300],
                       c.doc_lens[:300], n_centroids=64, m=8, nbits=4,
                       kmeans_iters=2)


@pytest.fixture(scope="module")
def prop_timeline(small_corpus, prop_base):
    idx0, m0 = prop_base
    c = small_corpus
    mono = add_passages(idx0, m0, c.doc_embs[300:450], c.doc_lens[300:450])
    tl = ShardedTimeline.of((idx0, m0)).append(
        *new_generation(idx0, m0, c.doc_embs[300:450], c.doc_lens[300:450]))
    return mono, tl


@pytest.fixture(scope="module")
def prop_findex(small_corpus):
    c = small_corpus
    return build_index(jax.random.PRNGKey(3), c.doc_embs[:300],
                       c.doc_lens[:300], n_centroids=64, m=8, nbits=4,
                       kmeans_iters=2, predicates=predicate_plane(300))


@pytest.fixture(scope="module")
def pooled_passthrough(small_corpus):
    """The session small_index rebuilt with doc_budget == max doc len: every
    doc passes through pooling verbatim, so arrays must be IDENTICAL."""
    c = small_corpus
    return build_index(jax.random.PRNGKey(0), c.doc_embs, c.doc_lens,
                       n_centroids=128, m=8, nbits=4, plaid_b=2,
                       kmeans_iters=3, doc_budget=int(c.doc_lens.max()))


@pytest.fixture(scope="module")
def pooled_tight(small_corpus):
    """A genuinely pooled build (budget 8 < most doc lens)."""
    c = small_corpus
    return build_index(jax.random.PRNGKey(3), c.doc_embs[:300],
                       c.doc_lens[:300], n_centroids=64, m=8, nbits=4,
                       kmeans_iters=2, doc_budget=8)


# lossless budgets over the 450-doc grown corpus / 300-doc filtered corpus:
# every phase keeps everything, so cut-order effects cannot perturb results
LOSSLESS_450 = dict(n_filter=450, n_docs=450, cand_cap=450, k=10)
LOSSLESS_300 = dict(n_filter=300, n_docs=300, cand_cap=300, k=8)


@settings(**ENGINE_SETTINGS)
@given(st.data())
def test_prop_padded_equals_prefix(small_corpus, small_index, data):
    """PR 3 contract: a zero-padded masked query retrieves bit-exactly as
    its unpadded prefix — for random variants, prefixes, and query picks."""
    idx, _ = small_index
    cfg = make_cfg(data.draw(engine_variants, label="variant"))
    keep = data.draw(prefix_lens, label="prefix")
    picks = data.draw(query_picks(24, 2, 2), label="picks")
    q = np.asarray(small_corpus.queries)[picks].copy()
    q[:, keep:] = 0.0
    mask = np.broadcast_to(np.arange(q.shape[1]) < keep, q.shape[:2])
    padded = engine.retrieve(idx, jnp.asarray(q), cfg, jnp.asarray(mask))
    prefix = engine.retrieve(idx, jnp.asarray(q[:, :keep]), cfg)
    _assert_bitexact(padded, prefix)


@settings(**ENGINE_SETTINGS)
@given(st.data())
def test_prop_timeline_equals_monolithic(small_corpus, prop_timeline, data):
    """PR 5 contract: under lossless budgets a sharded timeline's merged
    retrieval equals one monolithic index grown over the union corpus."""
    (mono_idx, _), tl = prop_timeline
    cfg = make_cfg(data.draw(engine_variants, label="variant"),
                   **LOSSLESS_450)
    picks = data.draw(query_picks(24, 2, 2), label="picks")
    q = jnp.asarray(np.asarray(small_corpus.queries)[picks])
    _assert_bitexact(engine.retrieve(mono_idx, q, cfg),
                     retrieve_timeline(tl, q, cfg))


@settings(max_examples=4, deadline=None)
@given(st.data())
def test_prop_cache_equals_uncached(small_corpus, prop_timeline, data):
    """PR 6 contract: a caching RetrievalService is bit-exact to the
    uncached merge path at EVERY point of a random (repeating) query
    stream — warm hits included."""
    from repro.serving import RetrievalService
    _, tl = prop_timeline
    cfg = make_cfg("ref")
    svc = RetrievalService(tl, cfg)
    qs = np.asarray(small_corpus.queries)
    stream = data.draw(st.lists(query_picks(24, 2, 2),
                                min_size=2, max_size=4), label="stream")
    stream.append(stream[0])     # force at least one fully warm revisit
    for picks in stream:
        got = svc.query(qs[picks])
        want = retrieve_timeline(tl, jnp.asarray(qs[picks]), cfg)
        _assert_bitexact(got, want)
    assert svc.cache.hits > 0    # the revisit was served from cache


@settings(**ENGINE_SETTINGS)
@given(st.data())
def test_prop_batched_equals_vmap(small_corpus, small_index, data):
    """PR 7 contract: the batch-native megakernels equal the vmap dispatch
    bit for bit for random batch sizes, picks, and mask prefixes."""
    idx, _ = small_index
    b = data.draw(st.sampled_from([2, 3]), label="batch")
    picks = data.draw(query_picks(24, b, b), label="picks")
    lens = data.draw(st.lists(st.integers(4, 32), min_size=b, max_size=b),
                     label="prefix_lens")
    q = np.asarray(small_corpus.queries)[picks].copy()
    mask = np.zeros(q.shape[:2], bool)
    for i, n in enumerate(lens):
        q[i, n:] = 0.0
        mask[i, :n] = True
    batched = engine.retrieve(idx, jnp.asarray(q), make_cfg("fused-batched"),
                              jnp.asarray(mask))
    vmapped = engine.retrieve(idx, jnp.asarray(q), make_cfg("fused"),
                              jnp.asarray(mask))
    _assert_bitexact(batched, vmapped)


@settings(**ENGINE_SETTINGS)
@given(st.data())
def test_prop_filtered_equals_postfilter(small_corpus, prop_findex, data):
    """PR 8 contract: filtered retrieval under lossless budgets equals the
    retrieve-then-post-filter oracle for random filter exprs and picks."""
    idx, meta = prop_findex
    variant = data.draw(st.sampled_from(["ref", "fused-batched"]),
                        label="variant")
    expr = data.draw(filter_exprs(), label="expr")
    picks = data.draw(query_picks(24, 2, 2), label="picks")
    cfg = make_cfg(variant, **LOSSLESS_300)
    plan = bitvector.compile_filter(expr, meta.pred_names)
    pass_np = np.asarray(bitvector.apply_filter_plan(plan, idx.pred_words))
    assert pass_np.sum() >= cfg.k, "oracle needs >= k passing docs"
    q = jnp.asarray(np.asarray(small_corpus.queries)[picks])
    full = engine.retrieve(idx, q, dataclasses.replace(cfg, k=300))
    want_s, want_i = [], []
    for bi in range(len(picks)):
        ids = np.asarray(full.doc_ids[bi])
        sc = np.asarray(full.scores[bi])
        keepm = pass_np[ids]
        want_i.append(ids[keepm][:cfg.k])
        want_s.append(sc[keepm][:cfg.k])
    got = engine.retrieve(idx, q, cfg, doc_filter=plan)
    np.testing.assert_array_equal(np.asarray(got.doc_ids), np.stack(want_i))
    np.testing.assert_array_equal(np.asarray(got.scores), np.stack(want_s))


def test_pooled_passthrough_index_is_bit_identical(small_index,
                                                   pooled_passthrough):
    """PR 9 tentpole identity: doc_budget >= max doc len stores the SAME
    bytes as an unpooled build — content fingerprints equal."""
    from repro.core.store import index_fingerprint
    uidx, _ = small_index
    pidx, pmeta = pooled_passthrough
    assert pmeta.doc_budget == int(np.asarray(uidx.doc_lens).max())
    assert pmeta.n_raw_tokens == int(np.asarray(uidx.doc_lens).sum())
    assert index_fingerprint(pidx) == index_fingerprint(uidx)


@settings(**ENGINE_SETTINGS)
@given(st.data())
def test_prop_pooled_passthrough_retrieves_identically(
        small_corpus, small_index, pooled_passthrough, data):
    """PR 9 contract: a pass-through-pooled index retrieves bit-exactly as
    the unpooled build across random variants and query picks."""
    uidx, _ = small_index
    pidx, _ = pooled_passthrough
    cfg = make_cfg(data.draw(engine_variants, label="variant"))
    picks = data.draw(query_picks(24, 2, 2), label="picks")
    q = jnp.asarray(np.asarray(small_corpus.queries)[picks])
    _assert_bitexact(engine.retrieve(pidx, q, cfg),
                     engine.retrieve(uidx, q, cfg))


@settings(max_examples=4, deadline=None)
@given(st.data())
def test_prop_pooled_index_honors_query_masking(small_corpus, pooled_tight,
                                                data):
    """Engine contracts survive pooling: on a genuinely pooled index
    (budget 8), padded==prefix still holds bit for bit."""
    idx, meta = pooled_tight
    assert meta.doc_budget == 8 and meta.cap == 8
    cfg = make_cfg(data.draw(st.sampled_from(["ref", "fused-batched"]),
                             label="variant"))
    picks = data.draw(query_picks(24, 2, 2), label="picks")
    keep = 20
    q = np.asarray(small_corpus.queries)[picks].copy()
    q[:, keep:] = 0.0
    mask = np.broadcast_to(np.arange(q.shape[1]) < keep, q.shape[:2])
    padded = engine.retrieve(idx, jnp.asarray(q), cfg, jnp.asarray(mask))
    prefix = engine.retrieve(idx, jnp.asarray(q[:, :keep]), cfg)
    _assert_bitexact(padded, prefix)


# ---------------------------------------------------------------------------
# Batch-composition invariance of the batched megakernels (PR 7 tentpole)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.data())
def test_batched_retrieve_is_batch_composition_invariant(small_corpus,
                                                         small_index, data):
    """A query's result must not depend on its batch-mates: retrieve of any
    (zero-padded, masked) query inside a random batch through the
    batch-native megakernels equals its single-query retrieve — which rides
    the vmap fallback at B=1 — bit for bit, for random batch sizes, query
    picks, and mask prefix lengths."""
    idx, _ = small_index
    cfg = make_cfg("fused-batched")
    assert cfg.batched_kernels
    qs = np.asarray(small_corpus.queries)
    b = data.draw(st.integers(2, 4), label="batch")
    picks = data.draw(st.lists(st.integers(0, len(qs) - 1), min_size=b,
                               max_size=b), label="picks")
    lens = data.draw(st.lists(st.integers(4, qs.shape[1]), min_size=b,
                              max_size=b), label="prefix_lens")
    q = qs[picks].copy()
    mask = np.zeros(q.shape[:2], bool)
    for i, n in enumerate(lens):
        q[i, n:] = 0.0
        mask[i, :n] = True
    batched = engine.retrieve(idx, jnp.asarray(q), cfg, jnp.asarray(mask))
    for i in range(b):
        single = engine.retrieve(idx, jnp.asarray(q[i:i + 1]), cfg,
                                 jnp.asarray(mask[i:i + 1]))
        np.testing.assert_array_equal(np.asarray(batched.doc_ids[i]),
                                      np.asarray(single.doc_ids[0]))
        np.testing.assert_array_equal(np.asarray(batched.scores[i]),
                                      np.asarray(single.scores[0]))


# ---------------------------------------------------------------------------
# MoE dispatch modes: grouped (GShard) == capacity-gather at ample capacity
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 2**31 - 1), st.sampled_from([2, 4, 8]),
       st.sampled_from([1, 2]), st.sampled_from([1, 2, 4]))
def test_moe_grouped_matches_gather_at_ample_capacity(seed, e, k, groups):
    """With capacity >= tokens-per-group, no tokens drop in either mode and
    the two dispatch strategies compute the SAME function."""
    from repro.models import moe
    from repro.models.layers import ModelConfig
    rng = np.random.default_rng(seed)
    d, f, b, s = 8, 16, 2, 8
    cfg = ModelConfig(name="m", n_experts=e, top_k=min(k, e),
                      capacity_factor=100.0, d_model=d, d_ff=f,
                      dtype=jnp.float32)
    p = {"router": jnp.asarray(rng.normal(size=(d, e)).astype(np.float32)),
         "wi_gate": jnp.asarray(rng.normal(size=(e, d, f)).astype(np.float32)) * .1,
         "wi_up": jnp.asarray(rng.normal(size=(e, d, f)).astype(np.float32)) * .1,
         "wo": jnp.asarray(rng.normal(size=(e, f, d)).astype(np.float32)) * .1}
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    out_g, aux_g = moe.moe_block(p, x, cfg)
    cfg2 = dataclasses.replace(cfg, moe_groups=groups)
    out_h, aux_h = moe.moe_block(p, x, cfg2)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_h),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(aux_g), float(aux_h), rtol=1e-5)
