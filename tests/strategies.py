"""Shared property-test strategies + a deterministic fallback runner.

One import site for every property test (docs/TESTING.md):

    from strategies import given, settings, st, HAVE_HYPOTHESIS

When the real ``hypothesis`` package is installed (CI's props lane installs
``requirements-dev.txt``), these re-export it unchanged and register a
bounded ``ci`` settings profile (derandomized, no deadline) selected with
``--hypothesis-profile=ci``.

When it is NOT installed (the tier-1 container has no dev deps), a small
deterministic shim stands in: ``@given`` runs the test body
``max_examples`` times with values drawn from a seeded ``numpy`` RNG
(seed = crc32 of the test name, overridable with ``PROPS_SEED``), so the
property suite ALWAYS collects and runs — the silent-skip hazard of the
old ``pytest.importorskip`` guard is gone. The shim implements only the
strategy surface this repo uses (integers, floats, booleans, sampled_from,
just, none, one_of, tuples, lists, data) and reports the falsifying draw
on failure. It does NOT shrink; reproduce CI failures under real
hypothesis.

Below the runner live the repo-specific strategies: tiny corpora with
heterogeneous doc/query lengths, EngineConfig variants (one small pool so
jit compiles amortize across properties), document budgets, predicate
planes, and query-pick helpers.
"""
from __future__ import annotations

import functools
import inspect
import os
import zlib

import numpy as np

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
    settings.register_profile("ci", deadline=None, derandomize=True,
                              max_examples=25)
except ImportError:                                         # tier-1 container
    HAVE_HYPOTHESIS = False

    _SEED = int(os.environ.get("PROPS_SEED", "0"))

    class _Strategy:
        """A draw function ``rng -> value`` with a description for errors."""

        def __init__(self, draw, desc="strategy"):
            self._draw = draw
            self.desc = desc

        def __repr__(self):
            return self.desc

        def map(self, f):
            return _Strategy(lambda rng: f(self._draw(rng)),
                             f"{self.desc}.map(...)")

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(None, "data()")

    class _DataObject:
        """Interactive draws inside a test body (``data.draw(strat)``)."""

        def __init__(self, rng):
            self._rng = rng
            self.drawn = []

        def draw(self, strat, label=None):
            v = strat._draw(self._rng)
            self.drawn.append((label or strat.desc, v))
            return v

    def _integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            f"integers({min_value}, {max_value})")

    def _floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            f"floats({min_value}, {max_value})")

    def _sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))],
                         f"sampled_from(<{len(seq)} options>)")

    def _booleans():
        return _sampled_from([False, True])

    def _just(value):
        return _Strategy(lambda rng: value, f"just({value!r})")

    def _none():
        return _just(None)

    def _one_of(*strats):
        if len(strats) == 1 and isinstance(strats[0], (list, tuple)):
            strats = tuple(strats[0])
        return _Strategy(
            lambda rng: strats[int(rng.integers(len(strats)))]._draw(rng),
            f"one_of(<{len(strats)}>)")

    def _tuples(*strats):
        return _Strategy(lambda rng: tuple(s._draw(rng) for s in strats),
                         "tuples(...)")

    def _lists(strat, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [strat._draw(rng) for _ in range(n)]
        return _Strategy(draw, f"lists({strat.desc}, {min_size}..{max_size})")

    class _St:
        integers = staticmethod(_integers)
        floats = staticmethod(_floats)
        booleans = staticmethod(_booleans)
        sampled_from = staticmethod(_sampled_from)
        just = staticmethod(_just)
        none = staticmethod(_none)
        one_of = staticmethod(_one_of)
        tuples = staticmethod(_tuples)
        lists = staticmethod(_lists)
        data = staticmethod(_DataStrategy)

    st = _St()

    def settings(**kw):
        def deco(f):
            f._shim_settings = kw
            return f
        return deco

    def given(*strats):
        def deco(f):
            sig = inspect.signature(f)
            params = list(sig.parameters.values())
            # the strategies bind the TRAILING params (hypothesis' rightmost
            # mapping); pytest passes fixtures by keyword, so drawn values
            # must be passed by name too
            draw_names = [p.name for p in params[len(params) - len(strats):]]

            @functools.wraps(f)
            def wrapper(*fixture_args, **fixture_kwargs):
                cfg = getattr(wrapper, "_shim_settings", {})
                n_ex = int(cfg.get("max_examples", 20))
                base = zlib.crc32(f.__qualname__.encode()) ^ _SEED
                for ex in range(n_ex):
                    rng = np.random.default_rng((base, ex))
                    drawn_kw, data_obj = {}, None
                    for name, s in zip(draw_names, strats):
                        if isinstance(s, _DataStrategy):
                            data_obj = _DataObject(rng)
                            drawn_kw[name] = data_obj
                        else:
                            drawn_kw[name] = s._draw(rng)
                    try:
                        f(*fixture_args, **fixture_kwargs, **drawn_kw)
                    except Exception as e:
                        shown = {k: v for k, v in drawn_kw.items()
                                 if v is not data_obj}
                        drawn = data_obj.drawn if data_obj else []
                        raise AssertionError(
                            f"property falsified on example {ex}/{n_ex} "
                            f"(PROPS_SEED={_SEED}): args={shown} "
                            f"drawn={drawn}") from e
            # hide the strategy-bound trailing params from pytest's
            # fixture resolution (real hypothesis does the same)
            wrapper.__signature__ = sig.replace(
                parameters=params[:len(params) - len(strats)])
            return wrapper
        return deco


# ---------------------------------------------------------------------------
# Repo-specific strategies (both backends)
# ---------------------------------------------------------------------------

seeds = st.integers(0, 2**31 - 1)


@functools.lru_cache(maxsize=None)
def tiny_corpus(seed=0, n_docs=64, cap=12, min_len=1, n_queries=6,
                n_topics=8, d=16, n_q=8):
    """A cached tiny corpus: heterogeneous doc lengths (``min_len``..``cap``
    real tokens, zero-padded), planted-topic queries. Cached so a strategy
    can draw from a small pool of geometries without rebuilding."""
    from repro.data.synthetic import make_corpus
    return make_corpus(seed, n_docs=n_docs, cap=cap, min_len=min_len,
                       n_queries=n_queries, n_topics=n_topics, d=d, n_q=n_q)


def tiny_corpora():
    """Strategy over a pool of cached tiny corpora (varied seed/lengths) —
    for properties that act on raw embeddings (e.g. pooling), where no
    index build is needed per example."""
    return st.tuples(st.sampled_from([0, 1, 2, 3]),
                     st.sampled_from([(12, 1), (12, 6), (8, 8), (16, 2)])
                     ).map(lambda t: tiny_corpus(seed=t[0], cap=t[1][0],
                                                 min_len=t[1][1]))


def doc_budgets(cap, with_none=True):
    """Document-budget strategy for an index of the given ``cap``: from the
    degenerate ``m=1`` through pass-through (``>= cap``) to ``None`` (the
    per-token layout; excluded with ``with_none=False`` for callers that
    need an actual pooling pass)."""
    pool = [1, 2, max(cap // 2, 1), cap, cap + 8]
    return st.sampled_from(([None] + pool) if with_none else pool)


# One shared EngineConfig variant pool: every property draws from THESE so
# each (variant, query shape) pair jit-compiles at most once per session.
BASE_CFG = dict(nprobe=8, th=0.2, th_r=0.4, n_filter=128, n_docs=48, k=10)

CFG_VARIANTS = {
    "ref": {},
    "ref-compact": dict(candidate_mode="compact", cand_cap=600),
    "fused": dict(use_kernels=True, fused_prefilter=True,
                  fused_late_interaction=True, batched_kernels=False),
    "fused-batched": dict(use_kernels=True, fused_prefilter=True,
                          fused_late_interaction=True, batched_kernels=True),
}

engine_variants = st.sampled_from(sorted(CFG_VARIANTS))


def make_cfg(variant, **overrides):
    """EngineConfig for a named variant from :data:`CFG_VARIANTS`."""
    from repro.core import EngineConfig
    return EngineConfig(**{**BASE_CFG, **CFG_VARIANTS[variant], **overrides})


# bounded prefix lengths for padded==prefix properties: each distinct
# length is a distinct compiled query shape, so the pool stays small
prefix_lens = st.sampled_from([16, 20, 26])


def query_picks(n_queries, min_size=1, max_size=3):
    """Random query-row picks (with repetition) from a corpus' query set."""
    return st.lists(st.integers(0, n_queries - 1), min_size=min_size,
                    max_size=max_size)


def predicate_plane(n_docs, seed=0):
    """A deterministic 3-name predicate plane for ``n_docs`` docs, dense
    enough that every expr in :func:`filter_exprs` passes >= k docs."""
    rng = np.random.default_rng(seed)
    return {
        "recent": rng.random(n_docs) < 0.7,
        "public": rng.random(n_docs) < 0.6,
        "gold": rng.random(n_docs) < 0.5,
    }


def filter_exprs():
    """Strategy over a pool of FilterExprs against ``predicate_plane``'s
    names, from a single predicate to nested and/or/not."""
    from repro.core import bitvector as bv
    return st.sampled_from([
        bv.Pred("recent"),
        bv.Or(bv.Pred("recent"), bv.Pred("gold")),
        bv.And(bv.Pred("recent"), bv.Pred("public")),
        bv.Or(bv.And(bv.Pred("recent"), bv.Pred("public")),
              bv.Pred("gold")),
        bv.And(bv.Pred("public"), bv.Not(bv.Pred("gold"))),
    ])
