"""Online maintenance contract (repro.serving.maintenance + the store/engine
primitives it drives):

* ``merge_generations`` compaction is BIT-exact: retrieval over the
  compacted timeline equals retrieval over the original (ids AND score
  bits) under cut-lossless budgets, jnp reference and both megakernels;
* ``MaintenancePolicy`` decides drift-retrain over merge, hierarchical
  same-tier merges, and the frozen-generation size bound — in that order;
* ``reepoch_tail`` opens a fresh codebook epoch over the drifted tail while
  preserving every surviving doc's GLOBAL id (what keeps caches valid);
* cross-epoch results merge by RANK, newest epoch first
  (``merge_partial_topk_by_rank``);
* end to end: a drift-crossing growth stream through ``RetrievalService``
  fires the policy, re-epochs OFF the serving path, hot-swaps at a flush
  boundary (deferred behind a pending ticket), and keeps untouched
  generations' cache entries warm across the swap.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EngineConfig, EpochedTimeline, ShardedTimeline,
                        build_index, merge_generations, new_generation,
                        retrieve_timeline, timeline_footprint)
from repro.core.engine import RetrievalResult, merge_partial_topk_by_rank
from repro.data.synthetic import make_corpus
from repro.serving import (MaintenancePolicy, MaintenanceRunner,
                           RetrievalService, reepoch_tail)

# Tight serving config (same constants as tests/test_serving.py) and the
# cut-lossless config the bit-exact merge contract needs (every candidate
# late-interacted; same as tests/test_store.py's equivalence tests).
CFG = EngineConfig(nprobe=8, th=0.2, th_r=0.4, n_filter=128, n_docs=48, k=10)
LOSSLESS = EngineConfig(nprobe=8, th=0.2, th_r=0.4, n_filter=600, n_docs=600,
                        k=10)

MERGE_CFGS = {
    "jnp-ref": LOSSLESS,
    "prefilter-megakernel": dataclasses.replace(
        LOSSLESS, use_kernels=True, fused_late_interaction=False),
    "pqinter-megakernel": dataclasses.replace(
        LOSSLESS, use_kernels=True, fused_prefilter=False),
}


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(7, n_docs=600, cap=24, min_len=8, n_queries=16,
                       n_topics=24)


@pytest.fixture(scope="module")
def timeline(corpus):
    """Three generations of 200 docs sharing gen 0's frozen codebooks."""
    c = corpus
    idx0, m0 = build_index(jax.random.PRNGKey(0), c.doc_embs[:200],
                           c.doc_lens[:200], n_centroids=128, m=8, nbits=4,
                           kmeans_iters=3)
    tl = ShardedTimeline.of((idx0, m0))
    tl = tl.append(*new_generation(idx0, m0, c.doc_embs[200:400],
                                   c.doc_lens[200:400]))
    return tl.append(*new_generation(idx0, m0, c.doc_embs[400:600],
                                     c.doc_lens[400:600]))


# ---------------------------------------------------------------------------
# Compaction: merge_generations is bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(MERGE_CFGS))
def test_merge_generations_bit_exact(corpus, timeline, name):
    """retrieve_timeline(merge_generations(tl, 0, 3)) equals
    retrieve_timeline(tl) — ids AND score bits — under cut-lossless
    budgets, for the jnp reference and both megakernels."""
    cfg = MERGE_CFGS[name]
    q = jnp.asarray(corpus.queries[:8])
    ref = retrieve_timeline(timeline, q, cfg)
    merged = merge_generations(timeline, 0, len(timeline))
    assert len(merged) == 1
    got = retrieve_timeline(merged, q, cfg)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(got.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(got.scores))


def test_merge_generations_partial_ranges(corpus, timeline):
    """Interior and prefix ranges compact bit-exactly too, and the
    untouched generations keep their identity (fingerprints unchanged)."""
    q = jnp.asarray(corpus.queries[:8])
    ref = retrieve_timeline(timeline, q, LOSSLESS)
    for lo, hi in ((0, 2), (1, 3)):
        merged = merge_generations(timeline, lo, hi)
        assert len(merged) == 2
        got = retrieve_timeline(merged, q, LOSSLESS)
        np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                      np.asarray(got.doc_ids))
        np.testing.assert_array_equal(np.asarray(ref.scores),
                                      np.asarray(got.scores))
    untouched = merge_generations(timeline, 0, 2)
    assert untouched.fingerprints[-1] == timeline.fingerprints[-1]
    assert untouched.fingerprints[0] not in timeline.fingerprints


def test_merge_generations_meta_accounting(timeline):
    """The merged IndexMeta sums docs and keeps the drift statistic
    consistent: the merged generation's grown tail is the union of the
    merged generations' grown tails (gen 0 was TRAINED, not grown)."""
    m = merge_generations(timeline, 1, 3)
    assert m.metas[1].n_docs == 400
    assert m.n_docs == timeline.n_docs
    assert m.offsets == (0, 200)
    # gens 1 and 2 were fully grown against gen 0's codebooks
    assert m.metas[1].n_grown == 400
    assert m.metas[1].train_quant_mse == timeline.metas[1].train_quant_mse
    full = merge_generations(timeline, 0, 3)
    # the walk stops at gen 0 (n_grown=0): only gens 1+2 count as grown
    assert full.metas[0].n_grown == 400
    assert full.metas[0].n_docs == 600


def test_merge_generations_validation(timeline):
    with pytest.raises(ValueError, match="single generation"):
        merge_generations(timeline, 0, 1)
    with pytest.raises(ValueError, match="not a valid"):
        merge_generations(timeline, 2, 1)
    with pytest.raises(ValueError, match="not a valid"):
        merge_generations(timeline, 0, 5)
    with pytest.raises(ValueError, match="not a valid"):
        merge_generations(timeline, 0.0, 2)


# ---------------------------------------------------------------------------
# Policy: drift > merge > size bound
# ---------------------------------------------------------------------------

def _with_drift(tl: ShardedTimeline, gen: int,
                ratio: float) -> ShardedTimeline:
    """A copy of ``tl`` whose ``gen``-th meta reports the given drift."""
    metas = list(tl.metas)
    metas[gen] = dataclasses.replace(
        metas[gen], n_grown=max(metas[gen].n_grown, 1),
        train_quant_mse=1.0, grown_quant_mse=float(ratio))
    return ShardedTimeline(tl.generations, tuple(metas))


def test_policy_validation():
    with pytest.raises(ValueError, match="merge_factor"):
        MaintenancePolicy(merge_factor=1)
    with pytest.raises(ValueError, match="max_frozen_generations"):
        MaintenancePolicy(max_frozen_generations=0)
    with pytest.raises(ValueError, match="drift_threshold"):
        MaintenancePolicy(drift_threshold=1.0)


def test_policy_tiers():
    p = MaintenancePolicy(merge_factor=4)
    assert p.tier(1) == 0 and p.tier(3) == 0
    assert p.tier(4) == 1 and p.tier(15) == 1
    assert p.tier(16) == 2 and p.tier(200) == 3


def test_policy_drift_outranks_merge(timeline):
    """A drifted generation triggers a tail re-epoch even when a merge run
    is also available — compacting stale quantization helps nothing."""
    p = MaintenancePolicy(merge_factor=2, drift_threshold=1.5)
    drifted = _with_drift(timeline, 1, 2.0)
    a = p.decide(drifted)
    assert a.kind == "reepoch" and (a.lo, a.hi) == (1, 3)
    assert "drift" in a.reason
    # the same timeline without drift falls through to the merge rule
    a2 = p.decide(timeline)
    assert a2.kind == "merge" and (a2.lo, a2.hi) == (0, 2)


def test_policy_hierarchical_and_size_bound(timeline):
    """Same-tier runs merge hierarchically; otherwise the frozen-count
    bound compacts the oldest generations; a timeline in shape yields
    None."""
    # 2 frozen gens of 200 docs: same tier, but no run of 4 -> the size
    # bound (max 1 frozen) fires instead, compacting the oldest two
    p = MaintenancePolicy(merge_factor=4, max_frozen_generations=1)
    a = p.decide(timeline)
    assert a.kind == "merge" and (a.lo, a.hi) == (0, 2)
    assert "frozen" in a.reason
    # relaxed bound: nothing to do
    assert MaintenancePolicy(merge_factor=4,
                             max_frozen_generations=8).decide(timeline) \
        is None
    # merge_factor=2: the two tier-3 frozen gens form a run -> hierarchical
    a3 = MaintenancePolicy(merge_factor=2).decide(timeline)
    assert a3.kind == "merge" and (a3.lo, a3.hi) == (0, 2)
    assert "tier" in a3.reason


def test_policy_accepts_epoched(timeline):
    """decide() sees through an EpochedTimeline to its newest epoch."""
    et = EpochedTimeline.of(timeline)
    a = MaintenancePolicy(merge_factor=2).decide(et)
    assert a.kind == "merge" and (a.lo, a.hi) == (0, 2)


# ---------------------------------------------------------------------------
# Cross-epoch rank merge
# ---------------------------------------------------------------------------

def test_merge_by_rank_interleaves_newest_first():
    old = RetrievalResult(jnp.asarray([[9.0, 8.0, 7.0]]),
                          jnp.asarray([[0, 1, 2]], dtype=jnp.int32))
    new = RetrievalResult(jnp.asarray([[5.0, 4.0, 3.0]]),
                          jnp.asarray([[100, 101, 102]], dtype=jnp.int32))
    # parts are oldest-first; the merge must put the NEWEST epoch's rank-r
    # doc before the older epoch's at every rank, despite its lower scores
    m = merge_partial_topk_by_rank([old, new], 4)
    np.testing.assert_array_equal(np.asarray(m.doc_ids),
                                  [[100, 0, 101, 1]])
    np.testing.assert_array_equal(np.asarray(m.scores),
                                  [[5.0, 9.0, 4.0, 8.0]])
    # a single part passes through bit-identically (the common case)
    solo = merge_partial_topk_by_rank([old], 3)
    assert solo is old


# ---------------------------------------------------------------------------
# Re-epoching: fresh codebooks, stable global ids
# ---------------------------------------------------------------------------

def test_reepoch_tail_structure(corpus, timeline):
    """Rebuilding the tail [1:] opens a second epoch holding those docs
    under fresh codebooks; the truncated epoch keeps its generation
    (fingerprint unchanged) and every global id is preserved."""
    et = reepoch_tail(timeline, 1, corpus.doc_embs[200:600],
                      corpus.doc_lens[200:600], key=jax.random.PRNGKey(1),
                      n_centroids=64, kmeans_iters=2)
    assert isinstance(et, EpochedTimeline) and len(et) == 2
    assert et.epoch_offsets == (0, 200)
    assert et.n_docs == 600 and et.n_generations == 2
    assert et.epochs[0].fingerprints == timeline.fingerprints[:1]
    new_meta = et.epochs[1].metas[0]
    assert new_meta.n_docs == 400 and new_meta.drift == 1.0
    assert new_meta.n_centroids == 64
    fp = timeline_footprint(et)
    assert fp["n_epochs"] == 2 and fp["n_docs"] == 600

    # retrieval over the epoched timeline: rank-level merge puts the new
    # epoch's rank-0 docs (global ids >= 200) first
    q = jnp.asarray(corpus.queries[:8])
    res = retrieve_timeline(et, q, CFG)
    ids = np.asarray(res.doc_ids)
    assert ids.shape == (8, CFG.k)
    assert np.all((ids >= 0) & (ids < 600))
    assert np.all(ids[:, 0] >= 200)
    new_only = retrieve_timeline(et.epochs[1], q, CFG)
    np.testing.assert_array_equal(ids[:, 0],
                                  np.asarray(new_only.doc_ids)[:, 0] + 200)


def test_reepoch_tail_full_rebuild(corpus, timeline):
    """lo=0 replaces the whole epoch: one fresh-codebook epoch, no stub."""
    et = reepoch_tail(timeline, 0, corpus.doc_embs[:600],
                      corpus.doc_lens[:600], key=jax.random.PRNGKey(2),
                      n_centroids=64, kmeans_iters=2)
    assert len(et) == 1 and et.n_docs == 600
    assert len(et.epochs[0]) == 1


def test_reepoch_tail_validation(corpus, timeline):
    key = jax.random.PRNGKey(3)
    with pytest.raises(ValueError, match="out of range"):
        reepoch_tail(timeline, 3, corpus.doc_embs[:0], corpus.doc_lens[:0],
                     key=key)
    with pytest.raises(ValueError, match="EXACTLY the tail"):
        reepoch_tail(timeline, 1, corpus.doc_embs[200:500],
                     corpus.doc_lens[200:500], key=key)
    with pytest.raises(ValueError, match="do not match"):
        reepoch_tail(timeline, 1, corpus.doc_embs[100:500],
                     corpus.doc_lens[100:500], key=key)
    with pytest.raises(ValueError, match="expected"):
        reepoch_tail(timeline, 1, corpus.doc_embs[200:600, :, :64],
                     corpus.doc_lens[200:600], key=key)


# ---------------------------------------------------------------------------
# The maintenance loop against a live service
# ---------------------------------------------------------------------------

def test_runner_merges_through_hot_swap(corpus, timeline):
    """run_once applies the policy's merge via update_timeline: the swap
    is immediate (no pending queries), results stay bit-exact vs the
    uncached path, and the maintenance counters record it."""
    svc = RetrievalService(timeline, CFG)
    q = np.asarray(corpus.queries[:8])
    svc.query(q)
    runner = MaintenanceRunner(svc, MaintenancePolicy(merge_factor=2))
    applied = runner.run_once()
    assert [a.kind for a in applied] == ["merge"]
    assert len(svc.timeline) == 2 and svc.timeline.n_docs == 600
    assert svc.metrics.merges == 1 and svc.metrics.swaps == 1
    assert svc.metrics.deferred_swaps == 0
    res = svc.query(q)
    ref = retrieve_timeline(svc.timeline, jnp.asarray(q), CFG)
    np.testing.assert_array_equal(np.asarray(ref.doc_ids),
                                  np.asarray(res.doc_ids))
    np.testing.assert_array_equal(np.asarray(ref.scores),
                                  np.asarray(res.scores))
    # nothing left to do
    assert runner.run_once() == []


def test_runner_requires_fetcher_for_reepoch(timeline):
    svc = RetrievalService(_with_drift(timeline, 0, 9.0), CFG)
    runner = MaintenanceRunner(
        svc, MaintenancePolicy(merge_factor=4, max_frozen_generations=8))
    with pytest.raises(RuntimeError, match="fetch_embeddings"):
        runner.run_once()


def test_drift_stream_end_to_end():
    """The whole loop: an in-domain service grows an out-of-distribution
    generation, the drift statistic crosses the threshold, the runner
    re-epochs OFF the serving path, the swap defers behind a pending
    ticket and installs at the flush boundary — and the untouched
    generation's cache entries stay warm across it all."""
    c = make_corpus(5, n_docs=256, cap=16, min_len=8, n_queries=4,
                    n_topics=16, token_noise=0.05)
    idx0, m0 = build_index(jax.random.PRNGKey(0), c.doc_embs[:128],
                           c.doc_lens[:128], n_centroids=32, m=8, nbits=4,
                           kmeans_iters=3)
    # uniform random directions: nothing gen 0's centroids could fit
    rng = np.random.default_rng(99)
    ood_embs = rng.normal(size=(64, m0.cap, m0.d)).astype(np.float32)
    ood_embs /= np.linalg.norm(ood_embs, axis=-1, keepdims=True)
    ood_lens = np.full(64, m0.cap, np.int32)
    all_embs = np.concatenate([c.doc_embs[:128], ood_embs])
    all_lens = np.concatenate([c.doc_lens[:128], ood_lens])

    svc = RetrievalService(ShardedTimeline.of((idx0, m0)), CFG)
    q = np.asarray(c.queries)
    before = svc.query(q)
    assert np.asarray(before.doc_ids).max() < 128

    svc.new_generation(ood_embs, ood_lens)
    assert svc.timeline.metas[-1].drift > 1.5
    svc.query(q)                              # cold fill for frozen gen 0
    svc.query(q)                              # warm: gen 0 hits
    hits0 = svc.cache.hits
    assert hits0 >= 4

    runner = MaintenanceRunner(
        svc, MaintenancePolicy(),
        fetch_embeddings=lambda a, b: (all_embs[a:b], all_lens[a:b]),
        build_key=jax.random.PRNGKey(3),
        build_kwargs=dict(n_centroids=32, kmeans_iters=3))

    # a pending ticket forces the swap to stage rather than install
    ticket = svc.submit(c.queries[0])
    applied = runner.run_once()
    assert [a.kind for a in applied] == ["reepoch"]
    assert svc.metrics.reepochs == 1
    assert len(svc.epoched) == 1              # still serving the old snap
    assert len(svc.latest_timeline) == 2      # the re-epoched one is staged
    assert not ticket.done

    svc.flush()                               # serve the ticket, then swap
    assert ticket.done
    assert len(svc.epoched) == 2
    assert svc.metrics.swaps >= 1 and svc.metrics.deferred_swaps == 1
    new_epoch = svc.epoched.epochs[-1]
    assert new_epoch.metas[0].drift == 1.0 and new_epoch.n_docs == 64
    # drift cured: the policy is satisfied
    assert runner.run_once() == []

    after = svc.query(q)
    ids = np.asarray(after.doc_ids)
    assert ids.shape == (4, CFG.k) and np.all((ids >= 0) & (ids < 192))
    # gen 0's fingerprint never changed: its entries survived the swap
    assert svc.cache.hits >= hits0 + 4
