"""Examples can't silently rot: import each demo module and run its
``main()`` end to end on a tiny corpus (the mains take size parameters for
exactly this). Any use of a removed API or a deprecated entry-point
signature fails here — the run is strict about DeprecationWarnings from
our own engine shims."""
import importlib.util
import pathlib
import warnings

import pytest

EXAMPLES = pathlib.Path(__file__).resolve().parents[1] / "examples"
TINY = dict(n_docs=256, n_centroids=32, n_queries=8)


def _load(name: str):
    spec = importlib.util.spec_from_file_location(name,
                                                  EXAMPLES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.parametrize("name", ["serve_retrieval", "streaming_index",
                                  "retrieval_service"])
def test_example_main_runs_on_tiny_corpus(name, capsys):
    mod = _load(name)
    with warnings.catch_warnings():
        # strict only about OUR engine shims (matched by message — the
        # shims attribute the warning to the calling frame, so a module
        # filter can't target them); third-party deprecations stay soft
        warnings.filterwarnings(
            "error", message=".*pre-batch single-query signature.*",
            category=DeprecationWarning)
        mod.main(**TINY)
    out = capsys.readouterr().out
    assert out.strip()                      # the demo narrated something
    assert ": False" not in out             # no failed bit-exactness check
