"""Training substrate: optimizers descend, fault tolerance (checkpoint +
resume == continuous), grad-accum equivalence, compression round-trip."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.layers import ModelConfig
from repro.train import checkpoint as C
from repro.train import optimizer as O
from repro.train.trainer import (TrainState, Trainer, TrainerConfig,
                                 make_train_step)

pytestmark = pytest.mark.slow  # transformer train steps: the multi-minute lane

CFG = ModelConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                  d_ff=64, vocab=64)


def _loss(params, batch):
    return T.loss_fn(params, batch, CFG)


def _make_batch(step):
    k = jax.random.PRNGKey(step)
    toks = jax.random.randint(k, (4, 16), 0, 64)
    return {"tokens": toks, "labels": toks}


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


@pytest.mark.parametrize("name", ["adamw", "adagrad", "adafactor", "muon"])
def test_optimizer_descends(name, params):
    opt = O.make(name)
    tr = Trainer(_loss, opt, _make_batch, TrainerConfig(log_every=1), params)
    out = tr.run(8)
    losses = [m["loss"] for m in out["log"]]
    assert losses[-1] < losses[0], (name, losses)
    assert all(np.isfinite(losses))


def test_checkpoint_resume_equals_continuous(params):
    with tempfile.TemporaryDirectory() as d:
        opt = O.make("adamw")
        cfg = TrainerConfig(ckpt_dir=d, ckpt_every=4, ckpt_chunks=3,
                            log_every=1)
        Trainer(_loss, opt, _make_batch, cfg, params).run(4)
        tr2 = Trainer(_loss, opt, _make_batch, cfg, params)
        out2 = tr2.run(9)
        assert out2["log"][0]["step"] == 5  # resumed, skipped 4 steps
        tr3 = Trainer(_loss, opt, _make_batch, TrainerConfig(log_every=1),
                      params)
        out3 = tr3.run(9)
        np.testing.assert_allclose(out2["log"][-1]["loss"],
                                   out3["log"][-1]["loss"], rtol=1e-4)


def test_checkpoint_atomic_and_latest(params):
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": np.arange(10, dtype=np.float32),
                "b": {"c": np.ones((3, 4), np.int32)}}
        C.save(d, tree, 7, n_chunks=2)
        C.save(d, tree, 13, n_chunks=2)
        assert C.latest_step(d) == 13
        out, step = C.restore(d, tree)
        assert step == 13
        np.testing.assert_array_equal(out["a"], tree["a"])
        np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])


def test_checkpoint_elastic_chunking(params):
    """A checkpoint written with n_chunks=4 restores into any layout."""
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": np.random.default_rng(0).normal(size=(16, 8)
                                                     ).astype(np.float32)}
        C.save(d, tree, 1, n_chunks=4)
        out, _ = C.restore(d, tree)
        np.testing.assert_array_equal(out["w"], tree["w"])


def test_grad_accum_equivalence(params):
    opt = O.make("adamw")
    s1 = make_train_step(_loss, opt, TrainerConfig(grad_accum=1))
    s2 = make_train_step(_loss, opt, TrainerConfig(grad_accum=2))
    st = TrainState(jnp.int32(0), params, opt.init(params))
    b = _make_batch(0)
    st1, m1 = s1(st, b)
    st2, m2 = s2(st, jax.tree.map(lambda x: jnp.stack([x, x]), b))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    l1 = jax.tree.leaves(st1.params)
    l2 = jax.tree.leaves(st2.params)
    for a, b_ in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-5)


def test_compressed_grads_still_descend(params):
    opt = O.make("adamw", lr=5e-3)
    tr = Trainer(_loss, opt, _make_batch,
                 TrainerConfig(compress_grads=True, log_every=1), params)
    out = tr.run(8)
    losses = [m["loss"] for m in out["log"]]
    assert losses[-1] < losses[0]


def test_sigterm_saves_and_stops(params):
    with tempfile.TemporaryDirectory() as d:
        opt = O.make("adamw")
        cfg = TrainerConfig(ckpt_dir=d, ckpt_every=1000, log_every=1)
        tr = Trainer(_loss, opt, _make_batch, cfg, params)
        orig_make = tr.make_batch

        def make_and_interrupt(step):
            if step == 3:
                tr._stop = True  # what the SIGTERM handler sets
            return orig_make(step)
        tr.make_batch = make_and_interrupt
        out = tr.run(10)
        assert out["interrupted"]
        assert C.latest_step(d) is not None  # emergency checkpoint written
