"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the 1 real CPU
device (the 512-device override belongs to launch/dryrun.py ONLY)."""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
# The tier-1 lane is compile-bound (dozens of tiny jits on 1 CPU core);
# backend optimization buys nothing at these shapes but ~2x wall time.
# setdefault: an explicit XLA_FLAGS from the caller wins.
os.environ.setdefault("XLA_FLAGS", "--xla_backend_optimization_level=0")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

# The property lane's bounded profile must be registered before pytest
# resolves --hypothesis-profile (i.e. before test modules import), so it
# lives here and not only in tests/strategies.py. Absent hypothesis the
# strategies shim takes over and this is a no-op.
try:  # noqa: E402
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", deadline=None, derandomize=True,
                                   max_examples=25)
except ImportError:
    pass

from repro.core import build_index  # noqa: E402
from repro.data.synthetic import make_corpus  # noqa: E402


@pytest.fixture(scope="session")
def small_corpus():
    return make_corpus(0, n_docs=600, cap=24, min_len=8, n_queries=24,
                       n_topics=24)


@pytest.fixture(scope="session")
def small_index(small_corpus):
    idx, meta = build_index(
        jax.random.PRNGKey(0), small_corpus.doc_embs, small_corpus.doc_lens,
        n_centroids=128, m=8, nbits=4, plaid_b=2, kmeans_iters=3)
    return idx, meta


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
