"""EngineConfig.__post_init__ validation: the configs that used to crash
deep inside ``top_k``/the bit pack (or run silently wrong) now raise
ValueError with actionable messages at construction time. Plus the IVF
truncation warning from ``build_index``."""
import dataclasses
import re
import warnings

import jax
import pytest

from repro.core import EngineConfig, build_index
from repro.data.synthetic import make_corpus


@pytest.mark.parametrize("kwargs,fragment", [
    (dict(n_q=33), "n_q=33 > 32"),
    (dict(k=100, n_docs=50), "k=100 > n_docs=50"),
    (dict(n_docs=600, n_filter=500), "n_docs=600 > n_filter=500"),
    (dict(cand_cap=100, n_filter=512, candidate_mode="compact"),
     "cand_cap=100 < n_filter=512"),
    (dict(compact_cap=16, th_r=None), "compact_cap=16 requires th_r"),
    (dict(candidate_mode="bogus"), "unknown candidate_mode='bogus'"),
    (dict(cs_dtype="fp8"), "unknown cs_dtype='fp8'"),
])
def test_engine_config_rejects_silent_crash_configs(kwargs, fragment):
    with pytest.raises(ValueError, match=re.escape(fragment)):
        EngineConfig(**kwargs)


def test_engine_config_default_is_valid():
    cfg = EngineConfig()
    assert cfg.n_q == 32


def test_engine_config_replace_revalidates():
    """dataclasses.replace re-runs __post_init__, so a valid base cannot be
    mutated into a silent-crash config."""
    cfg = EngineConfig()
    with pytest.raises(ValueError, match="n_docs"):
        dataclasses.replace(cfg, n_docs=cfg.n_filter + 1)


def test_engine_config_boundaries_allowed():
    """Equality at every boundary is legal (k == n_docs == n_filter ==
    cand_cap)."""
    EngineConfig(k=64, n_docs=64, n_filter=64, cand_cap=64,
                 candidate_mode="compact")


def test_engine_config_cand_cap_ignored_in_score_all():
    """cand_cap only bounds the compact-mode buffer; a score_all config
    with n_filter above the (unused) cand_cap default must construct."""
    EngineConfig(candidate_mode="score_all", n_filter=8192, n_docs=64)


def test_build_index_warns_on_ivf_truncation():
    """A too-small list_cap drops doc ids; the builder must say so and
    surface the count instead of truncating silently."""
    corpus = make_corpus(3, n_docs=64, cap=8, min_len=4, n_queries=2,
                         n_topics=2)
    with pytest.warns(UserWarning, match=r"doc-id entries dropped"):
        _, meta = build_index(jax.random.PRNGKey(0), corpus.doc_embs,
                              corpus.doc_lens, n_centroids=4, m=8, nbits=4,
                              list_cap=2, kmeans_iters=2)
    assert meta.n_dropped > 0


def test_build_index_auto_list_cap_never_drops():
    corpus = make_corpus(3, n_docs=64, cap=8, min_len=4, n_queries=2,
                         n_topics=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _, meta = build_index(jax.random.PRNGKey(0), corpus.doc_embs,
                              corpus.doc_lens, n_centroids=4, m=8, nbits=4,
                              kmeans_iters=2)
    assert meta.n_dropped == 0
