"""Engine invariant: the phase-split entry points compose to EXACTLY the
same top-k as the fused ``retrieve`` — for both candidate modes, with and
without Pallas kernels, and with the fused prefilter megakernel.

``retrieve`` and the phase entry points share the same ``_phaseN`` internals,
so this guards against the two paths drifting apart (the seed had three
divergences: phase1 ignored cs_dtype, phase2 ignored candidate_mode, phase4
ignored the compact/bf16 branches)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, engine

CFG = EngineConfig(nprobe=8, th=0.2, th_r=0.4, n_filter=128, n_docs=48, k=10)


def _compose(idx, queries, cfg):
    """Run the four phases through the public split entry points (the
    unified ``(index, queries, cfg, *, q_mask=None, ...)`` convention on
    batched queries)."""
    if cfg.use_kernels and cfg.fused_prefilter:
        cs, sel1 = engine.phase12_prefilter(idx, queries, cfg)
    else:
        cs, bits, bitmap = engine.phase1_candidates(idx, queries, cfg)
        sel1 = engine.phase2_prefilter(idx, queries, cfg, bits=bits,
                                       bitmap=bitmap)
    if cfg.use_kernels and cfg.fused_late_interaction:
        return engine.phase34_late_interaction(idx, queries, cfg, cs=cs,
                                               sel1=sel1)
    sel2 = engine.phase3_centroid_interaction(idx, queries, cfg, cs=cs,
                                              sel1=sel1)
    return engine.phase4_late_interaction(idx, queries, cfg, cs=cs,
                                          sel2=sel2)


# (use_kernels=True, fused=False) composition is covered more cheaply by
# test_fused_prefilter_matches_unfused_selection below — phases 3-4 are the
# same helpers either way.
@pytest.mark.parametrize("mode", ["score_all", "compact"])
@pytest.mark.parametrize("use_kernels,fused", [(False, False),
                                               (True, True)])
def test_phases_compose_to_retrieve(small_corpus, small_index, mode,
                                    use_kernels, fused):
    idx, _ = small_index
    cfg = dataclasses.replace(CFG, candidate_mode=mode, cand_cap=600,
                              use_kernels=use_kernels, fused_prefilter=fused)
    queries = jnp.asarray(small_corpus.queries[:2])
    full = engine.retrieve(idx, queries, cfg)
    scores, ids = _compose(idx, queries, cfg)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(full.doc_ids))
    np.testing.assert_allclose(np.asarray(scores), np.asarray(full.scores),
                               rtol=1e-6)


def test_phases_compose_with_th_r_none(small_corpus, small_index):
    """Eq. 5 fallback (no term filter) through the split path."""
    idx, _ = small_index
    cfg = dataclasses.replace(CFG, th_r=None)
    q = jnp.asarray(small_corpus.queries[0])
    full = engine.retrieve(idx, q[None], cfg)
    scores, ids = _compose(idx, q[None], cfg)
    np.testing.assert_array_equal(np.asarray(ids[0]),
                                  np.asarray(full.doc_ids[0]))


def test_phases_compose_bf16_cs(small_corpus, small_index):
    """phase1 must honour cs_dtype (the seed hardcoded f32 there, silently
    diverging from retrieve under reduced-precision CS)."""
    idx, _ = small_index
    cfg = dataclasses.replace(CFG, cs_dtype="bfloat16")
    q = jnp.asarray(small_corpus.queries[0])
    full = engine.retrieve(idx, q[None], cfg)
    scores, ids = _compose(idx, q[None], cfg)
    np.testing.assert_array_equal(np.asarray(ids[0]),
                                  np.asarray(full.doc_ids[0]))
    np.testing.assert_allclose(np.asarray(scores[0]),
                               np.asarray(full.scores[0]), rtol=1e-5)


def test_fused_prefilter_matches_unfused_selection(small_corpus, small_index):
    """The megakernel's sel1 equals the four-launch path's sel1 bit-exactly
    (same docs, same order) on the real index, both candidate modes."""
    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[0])
    for mode in ("score_all", "compact"):
        base = dataclasses.replace(CFG, candidate_mode=mode, cand_cap=600,
                                   use_kernels=True)
        fcfg = dataclasses.replace(base, fused_prefilter=True)
        ucfg = dataclasses.replace(base, fused_prefilter=False)
        _, sel_f = engine.phase12_prefilter(idx, q[None], fcfg)
        _, sel_u = engine.phase12_prefilter(idx, q[None], ucfg)
        np.testing.assert_array_equal(np.asarray(sel_f), np.asarray(sel_u))


@pytest.mark.parametrize("mode", ["score_all", "compact"])
def test_fused_retrieve_matches_reference_engine(small_corpus, small_index,
                                                 mode):
    """End-to-end: the fully fused kernel engine (prefilter + late-
    interaction megakernels) reproduces the pure-jnp reference retrieve
    bit-exactly — ids AND score bits — in both candidate modes."""
    idx, _ = small_index
    queries = jnp.asarray(small_corpus.queries[:2])
    base = dataclasses.replace(CFG, candidate_mode=mode, cand_cap=600)
    ref = engine.retrieve(idx, queries, base)
    fused = engine.retrieve(idx, queries,
                            dataclasses.replace(base, use_kernels=True))
    np.testing.assert_array_equal(np.asarray(fused.doc_ids),
                                  np.asarray(ref.doc_ids))
    np.testing.assert_array_equal(np.asarray(fused.scores),
                                  np.asarray(ref.scores))


@pytest.mark.parametrize("th_r", [None, 0.4])
def test_fused_late_interaction_matches_unfused(small_corpus, small_index,
                                                th_r):
    """The phase-3/4 megakernel's final (scores, ids) equal the
    cinter -> top_k -> pqscore -> top_k path's bit-exactly (same docs, same
    order, same score bits) on the real index, both Eq. 5 and Eq. 6 modes."""
    idx, _ = small_index
    q = jnp.asarray(small_corpus.queries[0])
    base = dataclasses.replace(CFG, th_r=th_r, use_kernels=True)
    fcfg = dataclasses.replace(base, fused_late_interaction=True)
    ucfg = dataclasses.replace(base, fused_late_interaction=False)
    cs, sel1 = engine.phase12_prefilter(idx, q[None], base)
    s_f, i_f = engine.phase34_late_interaction(idx, q[None], fcfg, cs=cs,
                                               sel1=sel1)
    s_u, i_u = engine.phase34_late_interaction(idx, q[None], ucfg, cs=cs,
                                               sel1=sel1)
    np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_u))
    np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s_u))
    # and against the pure-jnp reference engine (no kernels at all)
    s_r, i_r = engine.phase34_late_interaction(
        idx, q[None], dataclasses.replace(base, use_kernels=False), cs=cs,
        sel1=sel1)
    np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s_r))
