"""Sharding rules for recsys state: embedding tables row-shard over
("data","model") (pod axis replicates: data-parallel across pods); everything
else (MLPs, GRUs, capsule maps) is tiny and replicates. Optimizer states
inherit by shape match (adagrad accumulators shard with their tables)."""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

ROW_SHARD_MIN = 100_000  # rows; smaller tables replicate


def _row_axes(mesh: Mesh):
    axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
    return axes if axes else None


def recsys_state_shardings(mesh: Mesh, params_avals: Any, opt_avals: Any
                           ) -> Tuple[Any, Any]:
    rows = _row_axes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_avals)
    specs_by_shape = {}
    out = []
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        big_table = (leaf.ndim >= 2 and leaf.shape[0] >= ROW_SHARD_MIN)
        if big_table and ("tables" in keys or "item_emb" in keys or
                          "cat_emb" in keys or "codes" in keys):
            sp = P(rows, *([None] * (leaf.ndim - 1)))
        else:
            sp = P(*([None] * leaf.ndim))
        specs_by_shape[leaf.shape] = sp
        out.append(NamedSharding(mesh, sp))
    params_sh = jax.tree_util.tree_unflatten(treedef, out)

    def opt_spec(leaf):
        sp = specs_by_shape.get(leaf.shape, P(*([None] * leaf.ndim)))
        return NamedSharding(mesh, sp)

    return params_sh, jax.tree.map(opt_spec, opt_avals)
