"""Partitioning rules and mesh helpers (TP / FSDP / EP / sequence-sharded KV)."""
