"""Parameter/activation partitioning rules.

Params are plain pytrees; rules match on the flattened key path. Policy
(DESIGN.md §4):

  * TP over "model": attention projections on the folded head axis, FFN on
    the hidden axis, experts on the expert axis (EP), vocab on the embedding
    rows / lm_head cols.
  * FSDP over ``fsdp_axes`` (() to disable, ("data",) single-pod,
    ("pod","data") multi-pod): each TP-sharded param additionally shards its
    *other* large axis; optimizer states inherit the param spec (leaves whose
    shape matches the param; factored/scalar states replicate).
  * Uneven dimensions (40 heads on 16-way TP, vocab 49155, Criteo rows) are
    allowed: GSPMD pads — recorded in EXPERIMENTS.md where it costs.

Activation/batch specs live with the arch configs; these rules only cover
state.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def lm_param_spec(key: str, ndim: int, fsdp, stacked: bool = True) -> P:
    """Spec for one LM param. ``stacked``: leading n_layers axis present on
    layer params. ``fsdp``: None or axis name/tuple for the data axes."""
    L = (None,) if stacked else ()

    def spec(*axes):
        return P(*axes)

    if "layers" in key:
        if key.endswith("attn/wq") or key.endswith("attn/wk") or \
                key.endswith("attn/wv"):
            return spec(*L, fsdp, "model")
        if key.endswith("attn/wo"):
            return spec(*L, "model", fsdp)
        if key.endswith("attn/bq") or key.endswith("attn/bk") or \
                key.endswith("attn/bv"):
            return spec(*L, "model")
        if key.endswith("mlp/w_gate") or key.endswith("mlp/w_up") or \
                key.endswith("shared_mlp/w_gate") or key.endswith("shared_mlp/w_up"):
            return spec(*L, fsdp, "model")
        if key.endswith("mlp/w_down") or key.endswith("shared_mlp/w_down"):
            return spec(*L, "model", fsdp)
        if key.endswith("moe/router"):
            return spec(*L, None, None)
        if key.endswith("moe/wi_gate") or key.endswith("moe/wi_up"):
            return spec(*L, "model", fsdp, None)    # EP on expert axis
        if key.endswith("moe/wo"):
            return spec(*L, "model", None, fsdp)
        if "ln" in key or "norm" in key:
            return spec(*L, None)
    if key.startswith("embed"):
        return P("model", fsdp)
    if key.startswith("lm_head"):
        return P(fsdp, "model")
    if key.startswith("proj"):
        return P(None, None)
    if "final_norm" in key:
        return P(None)
    return P(*([None] * ndim))


def lm_state_shardings(mesh: Mesh, params_avals: Any, opt_avals: Any,
                       fsdp) -> Tuple[Any, Any]:
    """NamedSharding trees for (params, opt_state)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_avals)
    specs = {}
    param_tree = []
    for path, leaf in flat:
        k = _key_str(path)
        sp = lm_param_spec(k, leaf.ndim, fsdp)
        sp = _validate(sp, leaf.shape)
        specs[leaf.shape] = sp          # shape -> spec lookup for opt states
        param_tree.append(NamedSharding(mesh, sp))
    params_sh = jax.tree_util.tree_unflatten(treedef, param_tree)

    def opt_spec(leaf):
        sp = specs.get(leaf.shape)
        if sp is None:
            sp = P(*([None] * leaf.ndim))
        return NamedSharding(mesh, sp)

    opt_sh = jax.tree.map(opt_spec, opt_avals)
    return params_sh, opt_sh


def _validate(spec: P, shape) -> P:
    """Drop sharded axes on dims too small to split at all (dim < axis size is
    fine for GSPMD padding, but dim==1/0 axes are pointless)."""
    fixed = []
    for i, ax in enumerate(spec):
        if ax is not None and i < len(shape) and shape[i] <= 1:
            fixed.append(None)
        else:
            fixed.append(ax)
    return P(*fixed)


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, P(*([None] * leaf.ndim))), tree)


def table_sharding(mesh: Mesh, rows_axes=("data", "model")) -> NamedSharding:
    """Row-wise embedding-table sharding (recsys)."""
    return NamedSharding(mesh, P(rows_axes, None))


def batch_spec(mesh: Mesh, data_axes) -> NamedSharding:
    return NamedSharding(mesh, P(data_axes))
