"""Low-overhead hierarchical span tracing for the serving path.

A **span** is one timed region of the serving loop — a batcher drain, a
per-generation cache lookup, a miss-lane execute, a top-k merge, a
maintenance action — recorded with its name, start time, duration, free-
form attributes, and its position in the span tree (``trace_id`` /
``span_id`` / ``parent_id``). Finished spans land in a bounded ring
buffer (oldest dropped first, ``Tracer.dropped`` counts the losses), so a
long-running service can leave tracing on without growing memory.

The module-level API is what instrumented code calls::

    from repro.obs import trace

    with trace.span("service.flush", batch=n):
        ...
    trace.record("batcher.queue_wait", wait_s, batch=n)   # pre-measured

Tracing is **disabled by default**: the module-level tracer is the
:data:`NOOP_TRACER`, whose ``span()`` returns the shared
:data:`NOOP_SPAN` singleton — no allocation, no clock read, no ring
append. ``tests/test_obs.py`` pins that contract, which is what lets the
hot path (``repro.serving.service``, ``repro.core.engine``) keep its
instrumentation unconditionally. Enable with :func:`enable` (or the
scoped :class:`tracing` context manager), export with
:meth:`Tracer.export_jsonl`, and see docs/OBSERVABILITY.md for the span
vocabulary and the measured overhead budget.

Spans nest through a plain stack, so the tracer is single-threaded like
the serving loop it instruments (docs/SERVING.md); ``jax`` dispatch is
asynchronous, so a span around an un-``block_until_ready``'d call times
the dispatch, not the device work — span names note ``dispatch`` where
that applies.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Callable, Optional


class _NoopSpan:
    """The do-nothing span: context manager + ``set()``, all no-ops.

    A single shared instance (:data:`NOOP_SPAN`) is returned by every
    ``span()`` call on the no-op tracer — the identity is part of the
    overhead contract (tests pin ``trace.span("x") is NOOP_SPAN``).
    """

    __slots__ = ()

    def __enter__(self):
        """No-op enter; returns itself so ``as sp`` still binds."""
        return self

    def __exit__(self, exc_type, exc, tb):
        """No-op exit; never swallows exceptions."""
        return False

    def set(self, **attrs):
        """Discard attributes; returns itself for chaining."""
        return self


NOOP_SPAN = _NoopSpan()


class _NoopTracer:
    """The do-nothing tracer installed by default (``enabled`` is False)."""

    enabled = False

    def span(self, name: str, **attrs):
        """-> the shared :data:`NOOP_SPAN` (no allocation, no clock)."""
        return NOOP_SPAN

    def record(self, name: str, duration_s: float, **attrs) -> None:
        """Discard a pre-measured event."""
        return None


NOOP_TRACER = _NoopTracer()


class Span:
    """One open span — a context manager handed out by :meth:`Tracer.span`.

    ``__enter__`` assigns ids (parented under the innermost open span),
    reads the clock, and pushes onto the tracer's stack; ``__exit__`` pops
    and emits the finished record into the ring. ``set(**attrs)`` adds
    attributes mid-span (e.g. a hit count known only after the lookup
    loop). Attribute values should be JSON-able; the exporter falls back
    to ``str()`` for anything that is not.
    """

    __slots__ = ("_tracer", "name", "attrs", "start", "span_id",
                 "parent_id", "trace_id")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        """Built by :meth:`Tracer.span`; not started until ``__enter__``."""
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.start = 0.0
        self.span_id = 0
        self.parent_id: Optional[int] = None
        self.trace_id = 0

    def set(self, **attrs) -> "Span":
        """Merge attributes into the span; returns itself for chaining."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        """Start the span: assign ids, parent under the innermost open
        span (a root span starts a new trace), read the clock LAST so the
        bookkeeping is outside the timed region."""
        t = self._tracer
        self.span_id = t._next_id()
        if t._stack:
            parent = t._stack[-1]
            self.parent_id = parent.span_id
            self.trace_id = parent.trace_id
        else:
            self.parent_id = None
            self.trace_id = self.span_id
        t._stack.append(self)
        self.start = t.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        """Finish the span: read the clock FIRST, pop the stack (popping
        through any unexited children so one leaked span cannot corrupt
        the hierarchy forever), emit the record. An exception inside the
        span marks ``error: true`` and propagates (never swallowed)."""
        t = self._tracer
        end = t.clock()
        while t._stack and t._stack.pop() is not self:
            pass
        rec = {
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "start": self.start,
            "duration_s": end - self.start,
            "attrs": self.attrs,
        }
        if exc_type is not None:
            rec["error"] = True
        t._emit(rec)
        return False


class Tracer:
    """Ring-buffered span collector (``enabled`` is True).

    capacity : finished spans kept; older ones drop off the ring
               (``dropped`` counts them — a dashboard's signal to raise
               the capacity or export more often).
    clock    : injectable monotonic clock in SECONDS (default
               ``time.perf_counter``); deterministic tests inject a fake.
    """

    enabled = True

    def __init__(self, capacity: int = 4096,
                 clock: Callable[[], float] = time.perf_counter):
        """Build an empty tracer; install it with :func:`set_tracer` (or
        use :func:`enable` / :class:`tracing`, which do both)."""
        if capacity < 1:
            raise ValueError(f"capacity={capacity} < 1: the ring must "
                             "hold at least one span")
        self.capacity = int(capacity)
        self.clock = clock
        self.dropped = 0
        self._spans: deque = deque()
        self._stack: list[Span] = []
        self._ids = 0

    def _next_id(self) -> int:
        self._ids += 1
        return self._ids

    def _emit(self, rec: dict) -> None:
        if len(self._spans) >= self.capacity:
            self._spans.popleft()
            self.dropped += 1
        self._spans.append(rec)

    def span(self, name: str, **attrs) -> Span:
        """-> an unstarted :class:`Span` context manager (``with
        tracer.span("name", key=val):``)."""
        return Span(self, name, attrs)

    def record(self, name: str, duration_s: float, **attrs) -> None:
        """Record a PRE-MEASURED event as a finished span ending now.

        For durations measured with a foreign clock (the batcher's
        injectable deadline clock, a staged-swap wait): the span's
        ``start`` is back-dated to ``clock() - duration_s``, and it
        parents under the innermost open span like any other.
        """
        end = self.clock()
        sid = self._next_id()
        parent = self._stack[-1] if self._stack else None
        self._emit({
            "name": name,
            "trace_id": parent.trace_id if parent else sid,
            "span_id": sid,
            "parent_id": parent.span_id if parent else None,
            "start": end - duration_s,
            "duration_s": duration_s,
            "attrs": attrs,
        })

    def finished(self) -> list[dict]:
        """The ring's finished span records, oldest first (a copy)."""
        return list(self._spans)

    def drain(self) -> list[dict]:
        """Pop and return every finished span (the export-loop primitive);
        ``dropped`` keeps its cumulative count."""
        out = list(self._spans)
        self._spans.clear()
        return out

    def export_jsonl(self, path) -> int:
        """Write the finished spans to ``path`` as JSON Lines (one span
        record per line; non-JSON attribute values fall back to ``str``);
        -> the number of spans written. The ring is left intact — pair
        with :meth:`drain` for an incremental export loop."""
        spans = self.finished()
        with open(path, "w") as f:
            for rec in spans:
                f.write(json.dumps(rec, default=str))
                f.write("\n")
        return len(spans)


_tracer = NOOP_TRACER


def get_tracer():
    """The currently installed tracer (:data:`NOOP_TRACER` by default)."""
    return _tracer


def set_tracer(tracer):
    """Install ``tracer`` as the module-level tracer (``None`` restores
    the no-op); -> the previously installed one, so scoped users can
    restore it (:class:`tracing` does exactly that)."""
    global _tracer
    prev = _tracer
    _tracer = NOOP_TRACER if tracer is None else tracer
    return prev


def enable(capacity: int = 4096,
           clock: Callable[[], float] = time.perf_counter) -> Tracer:
    """Install a fresh :class:`Tracer` module-wide and return it."""
    t = Tracer(capacity, clock)
    set_tracer(t)
    return t


def disable():
    """Restore the no-op tracer; -> the tracer that was installed."""
    return set_tracer(NOOP_TRACER)


def span(name: str, **attrs):
    """A span on the CURRENT tracer — the call instrumented code makes.

    Disabled (the default): returns the shared :data:`NOOP_SPAN` with no
    allocation. Enabled: returns a live :class:`Span` context manager.
    """
    return _tracer.span(name, **attrs)


def record(name: str, duration_s: float, **attrs) -> None:
    """A pre-measured event on the CURRENT tracer (no-op when disabled)."""
    return _tracer.record(name, duration_s, **attrs)


class tracing:
    """Scoped tracing: ``with trace.tracing() as tr:`` installs a fresh
    :class:`Tracer` for the block and restores the previous tracer after —
    the benchmark/test-friendly enable that cannot leak an enabled tracer
    into later code."""

    def __init__(self, capacity: int = 4096,
                 clock: Callable[[], float] = time.perf_counter):
        """Same knobs as :class:`Tracer`."""
        self._capacity = capacity
        self._clock = clock
        self._prev = None

    def __enter__(self) -> Tracer:
        """Install a fresh tracer; -> that tracer (read it after the
        block: the reference outlives the installation)."""
        t = Tracer(self._capacity, self._clock)
        self._prev = set_tracer(t)
        return t

    def __exit__(self, exc_type, exc, tb):
        """Restore the previously installed tracer."""
        set_tracer(self._prev)
        return False
