"""Observability: span tracing, the metrics registry, and retrieval
explain (docs/OBSERVABILITY.md).

Three coupled pieces over the serving stack:

* :mod:`repro.obs.trace` — ring-buffered hierarchical span tracer with a
  module-level no-op default; the serving hot path, maintenance loop and
  engine dispatch are instrumented unconditionally because the disabled
  cost is one no-op call.
* :mod:`repro.obs.registry` — Counter/Gauge/Histogram/Summary instruments
  with Prometheus text exposition and a JSON snapshot;
  ``repro.serving.metrics.ServiceMetrics`` is built on it.
* :mod:`repro.obs.explain` — the per-phase candidate-funnel debug path
  (imported lazily: it pulls in ``repro.core.engine``, which itself
  imports the tracer — eager import here would cycle).
"""
from . import trace
from .registry import (Counter, Gauge, Histogram, Metric, MetricsRegistry,
                       Summary)
from .trace import (NOOP_SPAN, NOOP_TRACER, Span, Tracer, disable, enable,
                    get_tracer, record, set_tracer, span, tracing)

__all__ = [
    "trace", "explain",
    "Counter", "Gauge", "Histogram", "Metric", "MetricsRegistry", "Summary",
    "NOOP_SPAN", "NOOP_TRACER", "Span", "Tracer", "disable", "enable",
    "get_tracer", "record", "set_tracer", "span", "tracing",
]


def __getattr__(name):
    """Lazy submodule hook: ``repro.obs.explain`` imports the engine
    (which imports ``repro.obs.trace``), so it loads on first attribute
    access instead of at package import."""
    if name == "explain":
        # importlib, not ``from . import``: the from-import form probes
        # the package with hasattr first, which would re-enter this hook
        import importlib
        return importlib.import_module(".explain", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
