"""Retrieval explain — the per-phase candidate funnel for one query.

EMVB retrieval is a four-stage funnel (PAPER.md): centroid probes select
IVF candidates (§4.1), the Eq. 4 bit-vector pre-filter cuts them to
``n_filter`` survivors (§4.2), the centroid-interaction proxy S̄ keeps the
top ``n_docs`` (§4.3), and PQ late interaction (Eq. 5, or Eq. 6 under the
``th_r`` term filter) ranks the final top-k (§4.4). When a query returns
something odd — or slowly — the question is always *where the funnel cut
what*; PLAID's own analysis (PAPERS.md) is exactly this per-stage
candidate accounting. :func:`explain` answers it for one query by
recomputing the funnel through the PUBLIC phase entry points
(``repro.core.engine.phase1_candidates`` … ``phase4_late_interaction``)
and counting at every stage. ``retrieve`` itself is untouched: the
bit-exactness contracts (fused == unfused, kernels == reference, composed
phases == retrieve — tests/test_engine_phases.py) are what guarantee the
explained top-k IS the served top-k, ids and score bits, in every
dispatch mode (tests/test_obs.py asserts it per config).

:func:`explain_timeline` extends the funnel across a multi-generation
timeline (``ShardedTimeline`` / ``EpochedTimeline``): the final top-k
comes from the real :func:`repro.core.engine.retrieve_timeline`, each
generation reports how many of the final k it contributed (global doc-id
ranges partition the corpus, so contributions sum to k by construction)
plus its own per-phase funnel under the same clamped config the serving
path uses (``adapt_config_to_corpus``).

Phase wall-times (``phase_ms``) are host-measured around each blocking
entry-point call; the FIRST explain for a given (shape, config) includes
jit compilation — warm numbers need a warm-up call, like every jax
timing. This is a debug path: per-query, eager, allocation-happy — wire
the :mod:`repro.obs.trace` spans for production telemetry instead
(docs/OBSERVABILITY.md).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitvector, interaction
from repro.core.engine import (EngineConfig, adapt_config_to_corpus,
                               phase1_candidates, phase2_prefilter,
                               phase3_centroid_interaction,
                               phase4_late_interaction, retrieve_timeline)
from repro.core.store import EpochedTimeline


@dataclasses.dataclass(frozen=True)
class QueryExplain:
    """One query's per-phase funnel over ONE index (local doc ids).

    Counts narrate the funnel top to bottom: ``live_terms`` query terms
    probe ``centroids_probed`` distinct centroids (of a
    ``live_terms * nprobe`` probe budget), whose IVF lists union into
    ``candidates`` bitmap docs (already ANDed with the predicate filter
    when one is set — ``docs_passing_filter`` / ``filter_selectivity``
    report the filter alone); the Eq. 4 pre-filter keeps
    ``n_filter_survivors`` REAL candidates of its ``n_filter_budget``-wide
    selection (the selection is always budget-wide — short candidate sets
    pad with filler ids whose scores are ``-inf``-masked downstream);
    phase 3 scores all ``phase3_docs_scored`` selected docs and keeps
    ``phase4_docs_scored`` for late interaction, where the Eq. 6 ``th_r``
    filter evaluates ``scored_term_fraction`` of the (term, token)
    residual pairs (1.0 when ``th_r`` is None — full Eq. 5).
    ``topk_scores`` / ``topk_ids`` are bit-exact to ``retrieve`` under the
    same config. ``phase_ms`` maps phase name -> blocking wall ms.
    """

    n_q: int
    live_terms: int
    n_centroids: int
    centroids_probed: int
    probe_budget: int
    n_docs_corpus: int
    docs_passing_filter: Optional[int]
    filter_selectivity: Optional[float]
    candidates: int
    candidate_mode: str
    candidate_cap: Optional[int]
    n_filter_budget: int
    n_filter_survivors: int
    phase3_docs_scored: int
    phase4_docs_scored: int
    scored_term_fraction: float
    k: int
    topk_scores: np.ndarray
    topk_ids: np.ndarray
    phase_ms: dict

    def to_dict(self) -> dict:
        """JSON-able dict (arrays -> lists, numpy scalars -> Python)."""
        d = dataclasses.asdict(self)
        d["topk_scores"] = [float(s) for s in self.topk_scores]
        d["topk_ids"] = [int(i) for i in self.topk_ids]
        return d


@dataclasses.dataclass(frozen=True)
class GenerationExplain:
    """One generation's share of a timeline explain: where it sits
    (epoch / generation index, content ``fingerprint``, global id range
    ``[offset, offset + n_docs)``), how many of the final k it contributed
    (``contribution`` — the count of final ids in its range), and its own
    :class:`QueryExplain` ``funnel`` under the clamped per-generation
    config (local ids; add ``offset`` for global)."""

    epoch: int
    generation: int
    fingerprint: str
    offset: int
    n_docs: int
    contribution: int
    funnel: QueryExplain

    def to_dict(self) -> dict:
        """JSON-able dict."""
        d = dataclasses.asdict(self)
        d["funnel"] = self.funnel.to_dict()
        return d


@dataclasses.dataclass(frozen=True)
class TimelineExplain:
    """One query explained across a timeline: the REAL merged top-k
    (``retrieve_timeline`` — global ids, rank-merged across codebook
    epochs when there are several) plus per-generation attribution.
    ``sum(g.contribution for g in generations) == k`` by construction
    (generations' global id ranges partition the corpus)."""

    k: int
    n_generations: int
    n_epochs: int
    topk_scores: np.ndarray
    topk_ids: np.ndarray
    generations: tuple
    merge_ms: float

    def to_dict(self) -> dict:
        """JSON-able dict."""
        return {
            "k": self.k,
            "n_generations": self.n_generations,
            "n_epochs": self.n_epochs,
            "topk_scores": [float(s) for s in self.topk_scores],
            "topk_ids": [int(i) for i in self.topk_ids],
            "generations": [g.to_dict() for g in self.generations],
            "merge_ms": self.merge_ms,
        }


def _timed(thunk):
    """Run ``thunk``, block until its jax outputs are ready, and return
    (result, wall milliseconds)."""
    t0 = time.perf_counter()
    out = thunk()
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) * 1e3


def _one_query(query, q_mask, n_q: int):
    """Normalize a single query (+ optional mask) to batch-of-one arrays;
    rejects real batches (explain is per-query by design)."""
    q = np.asarray(query, dtype=np.float32)
    if q.ndim == 3:
        if q.shape[0] != 1:
            raise ValueError(
                f"explain is per-query but got a batch of {q.shape[0]}; "
                "loop over the batch (each query has its own funnel)")
        q = q[0]
    if q.ndim != 2 or q.shape[0] != n_q:
        raise ValueError(
            f"query has shape {q.shape}: expected ({n_q}, d) — pad/mask "
            "with repro.serving.batcher.pad_query first")
    qm = None
    if q_mask is not None:
        qm = np.asarray(q_mask, dtype=bool).reshape(-1)
        if qm.shape[0] != n_q:
            raise ValueError(
                f"q_mask has {qm.shape[0]} entries, expected {n_q}")
        qm = qm[None]
    return q[None], qm


def explain(index, query, cfg: EngineConfig, *, q_mask=None,
            doc_filter=None) -> QueryExplain:
    """Explain one query's funnel over one :class:`PackedIndex`.

    index      : the ``repro.core.index.PackedIndex`` to search
    query      : (n_q, d) padded query (or a batch of exactly one)
    cfg        : the EXACT config the query would be served with —
                 budgets are used as-is, like ``retrieve`` (clamp with
                 ``adapt_config_to_corpus`` first for small corpora;
                 :func:`explain_timeline` does that per generation)
    q_mask     : optional (n_q,) bool live-term mask
    doc_filter : optional COMPILED ``bitvector.FilterPlan`` (an index
                 alone carries no predicate names to compile an expr
                 against — pass exprs to :func:`explain_timeline`, or
                 compile with ``bitvector.compile_filter`` yourself);
                 overrides ``cfg.doc_filter`` like ``retrieve``'s kwarg

    -> :class:`QueryExplain`; its ``topk_scores`` / ``topk_ids`` are
    bit-exact to ``retrieve(index, query[None], cfg, ...)`` because the
    funnel is recomputed through the public phase entry points whose
    composition IS ``retrieve`` (tests/test_engine_phases.py).
    """
    if doc_filter is not None:
        if not isinstance(doc_filter, bitvector.FilterPlan):
            raise ValueError(
                f"doc_filter is a {type(doc_filter).__name__}: explain() "
                "over a bare index takes a compiled FilterPlan — compile "
                "with bitvector.compile_filter(expr, meta.pred_names), or "
                "use explain_timeline() which compiles per epoch")
        cfg = dataclasses.replace(cfg, doc_filter=doc_filter)
    qb, qm = _one_query(query, q_mask, cfg.n_q)
    phase_ms: dict = {}

    (cs, bits, bitmap), phase_ms["phase1"] = _timed(
        lambda: phase1_candidates(index, qb, cfg, q_mask=qm))
    sel1, phase_ms["phase2"] = _timed(
        lambda: phase2_prefilter(index, qb, cfg, bits=bits, bitmap=bitmap))
    sel2, phase_ms["phase3"] = _timed(
        lambda: phase3_centroid_interaction(index, qb, cfg, q_mask=qm,
                                            cs=cs, sel1=sel1))
    res, phase_ms["phase4"] = _timed(
        lambda: phase4_late_interaction(index, qb, cfg, q_mask=qm,
                                        cs=cs, sel2=sel2))

    n_c = int(index.centroids.shape[0])
    probes = np.asarray(bitvector.masked_topk_centroids(
        cs[0], cfg.th, cfg.nprobe,
        None if qm is None else jnp.asarray(qm[0])))
    centroids_probed = int((np.unique(probes) < n_c).sum())
    live_terms = int(qm[0].sum()) if qm is not None else cfg.n_q

    n_docs_corpus = int(index.codes.shape[0])
    docs_passing = selectivity = None
    if cfg.doc_filter is not None:
        passing = np.asarray(
            bitvector.apply_filter_plan(cfg.doc_filter, index.pred_words))
        docs_passing = int(passing.sum())
        selectivity = docs_passing / max(n_docs_corpus, 1)

    candidates = int(np.asarray(bitmap[0]).sum())
    cand_cap = cfg.cand_cap if cfg.candidate_mode == "compact" else None
    capped = candidates if cand_cap is None else min(candidates, cand_cap)
    n_filter_budget = int(sel1.shape[-1])
    phase4_docs = int(sel2.shape[-1])

    if cfg.th_r is None:
        stf = 1.0
    else:
        rows = jnp.asarray(sel2[0])
        stf = float(interaction.scored_term_fraction(
            jnp.asarray(cs[0]).T,
            jnp.take(index.codes, rows, axis=0),
            jnp.take(index.token_mask(), rows, axis=0),
            cfg.th_r,
            None if qm is None else jnp.asarray(qm[0])))

    return QueryExplain(
        n_q=cfg.n_q, live_terms=live_terms,
        n_centroids=n_c, centroids_probed=centroids_probed,
        probe_budget=live_terms * cfg.nprobe,
        n_docs_corpus=n_docs_corpus,
        docs_passing_filter=docs_passing, filter_selectivity=selectivity,
        candidates=candidates, candidate_mode=cfg.candidate_mode,
        candidate_cap=cand_cap,
        n_filter_budget=n_filter_budget,
        n_filter_survivors=min(capped, n_filter_budget),
        phase3_docs_scored=n_filter_budget, phase4_docs_scored=phase4_docs,
        scored_term_fraction=stf, k=cfg.k,
        topk_scores=np.asarray(res.scores[0]),
        topk_ids=np.asarray(res.doc_ids[0]),
        phase_ms=phase_ms)


def explain_timeline(timeline, query, cfg: EngineConfig, *, q_mask=None,
                     doc_filter=None) -> TimelineExplain:
    """Explain one query across a timeline — final top-k attribution plus
    a per-generation funnel.

    timeline   : a ``ShardedTimeline`` or ``EpochedTimeline``
    doc_filter : a ``bitvector.FilterExpr`` (compiled here per epoch,
                 exactly as ``retrieve_timeline`` does) or a compiled
                 ``FilterPlan``

    The merged ``topk_scores`` / ``topk_ids`` come from the REAL
    :func:`repro.core.engine.retrieve_timeline` (so they are what serving
    returns, epochs rank-merged and all); each generation's
    ``contribution`` counts the final ids inside its global id range, and
    its ``funnel`` re-runs :func:`explain` under the same
    ``adapt_config_to_corpus``-clamped config the per-generation serving
    path uses. Contributions sum to k by construction.
    """
    et = EpochedTimeline.of(timeline)
    qb, qm = _one_query(query, q_mask, cfg.n_q)
    final, merge_ms = _timed(
        lambda: retrieve_timeline(timeline, qb, cfg, qm,
                                  doc_filter=doc_filter))
    ids = np.asarray(final.doc_ids[0])

    rows = []
    for e, (tl, eoff) in enumerate(et):
        df = doc_filter
        if isinstance(df, bitvector.FilterExpr):
            df = bitvector.compile_filter(df, tl.metas[0].pred_names)
        gcfg = cfg if df is None else \
            dataclasses.replace(cfg, doc_filter=df)
        for g, (gen, meta, off) in enumerate(tl):
            lo = eoff + off
            hi = lo + meta.n_docs
            rows.append(GenerationExplain(
                epoch=e, generation=g, fingerprint=tl.fingerprints[g],
                offset=lo, n_docs=meta.n_docs,
                contribution=int(((ids >= lo) & (ids < hi)).sum()),
                funnel=explain(
                    gen, qb,
                    adapt_config_to_corpus(gcfg, meta.n_docs, meta.cap),
                    q_mask=None if qm is None else qm[0])))

    return TimelineExplain(
        k=cfg.k, n_generations=len(rows), n_epochs=len(et.epochs),
        topk_scores=np.asarray(final.scores[0]),
        topk_ids=ids, generations=tuple(rows), merge_ms=merge_ms)
