"""A small metrics registry: Counter / Gauge / Histogram / Summary with
Prometheus text exposition and a JSON snapshot.

``repro.serving.metrics.ServiceMetrics`` used to be a hand-rolled bag of
integer attributes whose ``snapshot()`` had to be edited for every new
instrument. The registry inverts that: subsystems **register** instruments
(get-or-create by name, so a shared registry composes), mutate them
through the instrument handles, and the registry renders every registered
sample into the Prometheus text exposition format (``# HELP`` / ``# TYPE``
comments + ``name{label="value"} 1234`` samples —
``scripts/check_metrics_exposition.py`` lints the output against the
format spec in CI) or a JSON-able dict.

Design constraints, in order:

* **Cheap updates** — ``Counter.inc`` / ``Gauge.set`` are a dict write;
  the serving hot path calls them per batch, not per document.
* **External state without mirroring** — ``bind(fn)`` attaches a zero-arg
  callback so values owned elsewhere (the result cache's cumulative
  counters, the batcher's queue depth) are read at render time instead of
  being copied on every mutation.
* **Conventions enforced, not assumed** — counter names must end in
  ``_total``, metric/label names must match the Prometheus grammar,
  counters reject negative increments; the CI lint then only has to
  check the rendering, not the call sites.

Labels are supported on counters and gauges (e.g. the per-generation
cache hit ratio, labeled by generation fingerprint); histograms and
summaries are unlabeled — the serving layer needs exactly one of each per
reservoir, and unlabeled keeps their sample rendering simple. A
:class:`Summary` does not own samples: it renders quantiles from any
object shaped like ``repro.serving.metrics.LatencyStats`` (``count``,
``total_s``, ``percentile(pct)``), so the existing reservoirs plug in
without a second copy of every latency sample.
"""
from __future__ import annotations

import math
import re
from typing import Callable, Optional, Sequence

_METRIC_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*\Z")
_LABEL_NAME_RE = re.compile(r"[a-zA-Z_][a-zA-Z0-9_]*\Z")

# histogram default: powers of two around micro-batch latencies/sizes
DEFAULT_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0)


def _escape_help(text: str) -> str:
    """Escape a HELP string per the exposition format (backslash, LF)."""
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _escape_label_value(value: str) -> str:
    """Escape a label value (backslash, double quote, LF)."""
    return value.replace("\\", "\\\\").replace('"', '\\"') \
        .replace("\n", "\\n")


def _format_value(v: float) -> str:
    """Render a sample value: integers without a trailing ``.0``,
    non-finite values as the spec's ``+Inf`` / ``-Inf`` / ``NaN``."""
    f = float(v)
    if math.isnan(f):
        return "NaN"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class Metric:
    """Base instrument: a name, HELP text, optional labels, and either
    stored per-labelset values or a bound read callback.

    Subclasses set ``kind`` (the ``# TYPE`` word) and add their mutation
    verbs; rendering is shared through :meth:`samples`.
    """

    kind = "untyped"

    def __init__(self, name: str, help: str,
                 label_names: Sequence[str] = (),
                 fn: Optional[Callable[[], float]] = None):
        """``name`` must match the Prometheus metric-name grammar;
        ``label_names`` likewise. ``fn`` (unlabeled metrics only) is a
        zero-arg callback read at render time — see :meth:`bind`."""
        if not _METRIC_NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in label_names:
            if not _LABEL_NAME_RE.match(ln) or ln.startswith("__"):
                raise ValueError(f"invalid label name {ln!r} on {name}")
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._values: dict[tuple, float] = {}
        self._fn: Optional[Callable[[], float]] = None
        if fn is not None:
            self.bind(fn)

    def bind(self, fn: Callable[[], float]) -> "Metric":
        """Attach a zero-arg callback as this (unlabeled) metric's value
        source — the externally-owned-state hook (cache counters, queue
        depth). Rebinding replaces the callback (the latest owner wins;
        metrics objects are per-service by contract). -> self."""
        if self.label_names:
            raise ValueError(
                f"{self.name} is labeled; bind() supports unlabeled "
                "metrics only (labeled values must be stored)")
        self._fn = fn
        return self

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.label_names):
            raise ValueError(
                f"{self.name} takes labels {self.label_names}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[k]) for k in self.label_names)

    def value(self, **labels) -> float:
        """Current value for one labelset (callback-backed metrics read
        their callback); 0.0 before any write."""
        if self._fn is not None:
            return float(self._fn())
        return self._values.get(self._key(labels), 0.0)

    def samples(self) -> list[tuple[str, tuple, float]]:
        """-> ``[(name_suffix, ((label, value), ...), sample_value)]`` —
        everything the renderers need, sorted by labelset."""
        if self._fn is not None:
            return [("", (), float(self._fn()))]
        if not self.label_names:
            return [("", (), self._values.get((), 0.0))]
        return [("", tuple(zip(self.label_names, key)), v)
                for key, v in sorted(self._values.items())]


class Counter(Metric):
    """Monotonically increasing count. Name MUST end in ``_total`` (the
    Prometheus counter convention, enforced at registration so the
    exposition lint never sees a violation)."""

    kind = "counter"

    def __init__(self, name: str, help: str,
                 label_names: Sequence[str] = (),
                 fn: Optional[Callable[[], float]] = None):
        """See :class:`Metric`; additionally enforces the ``_total``
        suffix."""
        if not name.endswith("_total"):
            raise ValueError(
                f"counter {name!r} must end in '_total' (Prometheus "
                "counter naming convention)")
        super().__init__(name, help, label_names, fn)

    def inc(self, amount: float = 1.0, **labels) -> None:
        """Add ``amount`` (>= 0) to the counter for this labelset."""
        if amount < 0:
            raise ValueError(
                f"counter {self.name} cannot decrease (inc by {amount})")
        key = self._key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount


class Gauge(Metric):
    """A value that goes up and down (queue depth, hit ratio, bytes)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        """Set the gauge for this labelset."""
        self._values[self._key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        """Add ``amount`` (may be negative) to the gauge."""
        key = self._key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        """Subtract ``amount`` from the gauge."""
        self.inc(-amount, **labels)


class Histogram(Metric):
    """Cumulative-bucket histogram (unlabeled).

    ``observe(v)`` lands in every bucket with ``le >= v`` (rendered
    cumulatively, ``+Inf`` bucket included, as the format requires) plus
    ``_sum`` / ``_count``.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        """``buckets``: finite upper bounds, any order; sorted here and
        implicitly completed with ``+Inf``."""
        super().__init__(name, help)
        bs = sorted(float(b) for b in buckets)
        if not bs or any(not math.isfinite(b) for b in bs):
            raise ValueError(
                f"histogram {name} needs >= 1 finite bucket bound "
                "(+Inf is implicit)")
        self.buckets = tuple(bs)
        self._counts = [0] * (len(bs) + 1)     # last slot = +Inf
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        """Record one observation."""
        v = float(value)
        self._sum += v
        self._count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self._counts[i] += 1
                return
        self._counts[-1] += 1

    def value(self, **labels) -> float:
        """The observation count (the scalar a dashboard sanity-checks)."""
        return float(self._count)

    def samples(self) -> list[tuple[str, tuple, float]]:
        """Cumulative ``_bucket`` samples (``le`` labels, ``+Inf`` last),
        then ``_sum`` and ``_count``."""
        out = []
        acc = 0
        for b, c in zip(self.buckets, self._counts):
            acc += c
            out.append(("_bucket", (("le", _format_value(b)),), float(acc)))
        acc += self._counts[-1]
        out.append(("_bucket", (("le", "+Inf"),), float(acc)))
        out.append(("_sum", (), self._sum))
        out.append(("_count", (), float(self._count)))
        return out


class Summary(Metric):
    """Quantile summary rendered from an external reservoir (unlabeled).

    ``stats`` is any object shaped like
    :class:`repro.serving.metrics.LatencyStats`: cumulative ``count`` and
    ``total_s`` attributes plus ``percentile(pct)`` (pct in 0..100). The
    summary stores nothing itself — it renders the reservoir's current
    state, so the serving layer's existing latency reservoirs export
    without duplicating samples.
    """

    kind = "summary"

    def __init__(self, name: str, help: str, stats,
                 quantiles: Sequence[float] = (0.5, 0.95, 0.99)):
        """``quantiles``: fractions in (0, 1) rendered as ``quantile=``
        samples."""
        super().__init__(name, help)
        for q in quantiles:
            if not 0.0 < q < 1.0:
                raise ValueError(f"quantile {q} outside (0, 1)")
        self.stats = stats
        self.quantiles = tuple(quantiles)

    def value(self, **labels) -> float:
        """The reservoir's cumulative observation count."""
        return float(self.stats.count)

    def samples(self) -> list[tuple[str, tuple, float]]:
        """``quantile=`` samples from the reservoir, then ``_sum`` (the
        cumulative total) and ``_count``."""
        out = [("", (("quantile", repr(q)),),
                float(self.stats.percentile(q * 100.0)))
               for q in self.quantiles]
        out.append(("_sum", (), float(self.stats.total_s)))
        out.append(("_count", (), float(self.stats.count)))
        return out


class MetricsRegistry:
    """Named instruments + the two renderers (Prometheus text, JSON).

    Registration is **get-or-create**: asking for an existing name
    returns the existing instrument (kind and labels must match — a
    clash raises instead of silently splitting a metric), so independent
    subsystems can share one registry without coordinating init order.
    """

    def __init__(self):
        """An empty registry."""
        self._metrics: dict[str, Metric] = {}

    def _get_or_create(self, cls, name, args, kwargs):
        existing = self._metrics.get(name)
        if existing is not None:
            if type(existing) is not cls:
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(existing).__name__}, requested {cls.__name__}")
            want = tuple(kwargs.get("label_names", ()))
            if existing.label_names != want:
                raise ValueError(
                    f"metric {name!r} already registered with labels "
                    f"{existing.label_names}, requested {want}")
            return existing
        m = cls(name, *args, **kwargs)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str,
                label_names: Sequence[str] = (),
                fn: Optional[Callable[[], float]] = None) -> Counter:
        """Get-or-create a :class:`Counter`."""
        return self._get_or_create(Counter, name, (help,),
                                   {"label_names": label_names, "fn": fn})

    def gauge(self, name: str, help: str,
              label_names: Sequence[str] = (),
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        """Get-or-create a :class:`Gauge`."""
        return self._get_or_create(Gauge, name, (help,),
                                   {"label_names": label_names, "fn": fn})

    def histogram(self, name: str, help: str,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        """Get-or-create a :class:`Histogram`."""
        return self._get_or_create(Histogram, name, (help,),
                                   {"buckets": buckets})

    def summary(self, name: str, help: str, stats,
                quantiles: Sequence[float] = (0.5, 0.95, 0.99)) -> Summary:
        """Get-or-create a :class:`Summary` over ``stats`` (a
        LatencyStats-shaped reservoir)."""
        return self._get_or_create(Summary, name, (help, stats),
                                   {"quantiles": quantiles})

    def get(self, name: str) -> Optional[Metric]:
        """The registered instrument, or None."""
        return self._metrics.get(name)

    def metrics(self) -> list[Metric]:
        """Every registered instrument, sorted by name."""
        return [self._metrics[k] for k in sorted(self._metrics)]

    def exposition(self) -> str:
        """Render every instrument in the Prometheus text exposition
        format: per metric a ``# HELP`` line, a ``# TYPE`` line, then its
        samples; ends with a newline as the format requires.
        ``scripts/check_metrics_exposition.py`` validates this output in
        CI against a live service."""
        lines = []
        for m in self.metrics():
            lines.append(f"# HELP {m.name} {_escape_help(m.help)}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for suffix, labelpairs, value in m.samples():
                if labelpairs:
                    body = ",".join(
                        f'{k}="{_escape_label_value(str(v))}"'
                        for k, v in labelpairs)
                    label_str = "{" + body + "}"
                else:
                    label_str = ""
                lines.append(
                    f"{m.name}{suffix}{label_str} {_format_value(value)}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """A JSON-able dict per instrument: scalar values for unlabeled
        counters/gauges, ``{label_repr: value}`` for labeled ones,
        count/sum (+ buckets) for histograms and summaries."""
        out: dict = {}
        for m in self.metrics():
            if isinstance(m, Histogram):
                out[m.name] = {
                    "count": m._count, "sum": m._sum,
                    "buckets": {_format_value(b): c for b, c in
                                zip(m.buckets, m._counts)},
                }
            elif isinstance(m, Summary):
                out[m.name] = {"count": float(m.stats.count),
                               "sum": float(m.stats.total_s)}
            elif m.label_names:
                out[m.name] = {
                    ",".join(f"{k}={v}" for k, v in labelpairs): value
                    for _, labelpairs, value in m.samples()}
            else:
                out[m.name] = m.value()
        return out
