import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (architecture x input-shape) cell
on the production meshes, print memory/cost analysis, and append roofline
terms to a JSON log.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
      --shape train_4k --mesh multi
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.json

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init) — hence the unusual module layout. Runs are
resumable: cells already present in --out are skipped unless --force.
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import registry                      # noqa: E402
from repro.launch import analysis, hlo_stats            # noqa: E402
from repro.launch.mesh import make_production_mesh, n_devices  # noqa: E402
from repro.launch.modelflops import model_flops         # noqa: E402
from repro.launch.steps import build_cell, donate_argnums  # noqa: E402


def run_cell(arch: str, shape: str, multi_pod: bool, verbose: bool = True
             ) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = n_devices(mesh)
    spec = registry.get(arch)
    fn, args = build_cell(arch, shape, mesh)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, donate_argnums=donate_argnums(arch, shape)
                          ).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    # trip-count-aware static profile (cost_analysis counts loop bodies once)
    stats = hlo_stats.analyze(hlo)
    roof = analysis.roofline(
        {"flops": stats["flops"], "bytes accessed": stats["bytes"]},
        stats["collective_bytes"], model_flops(spec, shape), chips)
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "argument_bytes_per_chip": mem.argument_size_in_bytes,
        "output_bytes_per_chip": mem.output_size_in_bytes,
        "temp_bytes_per_chip": mem.temp_size_in_bytes,
        "peak_bytes_per_chip": mem.peak_memory_in_bytes,
        "xla_cost_flops_unscaled": float(cost.get("flops", 0.0)),
        "n_collective_sites": stats["n_collective_sites"],
        "collective_by_kind_gib": {
            k: round(v / 2**30, 3)
            for k, v in stats["collective_by_kind"].items()},
        **roof,
    }
    if verbose:
        hbm = 16 * 2**30
        # XLA's peak_memory_in_bytes already covers live argument buffers
        # (observed peak == args on arg-dominated cells); don't double-count
        fit = "FITS" if rec["peak_bytes_per_chip"] < hbm else "OVER-BUDGET"
        print(f"[{arch} x {shape} @ {rec['mesh']}] compile={t_compile:.0f}s "
              f"peak={rec['peak_bytes_per_chip']/2**30:.2f}GiB "
              f"args={rec['argument_bytes_per_chip']/2**30:.2f}GiB ({fit}) "
              f"flops/chip={rec['flops_per_chip']:.3e} "
              f"coll={stats['collective_bytes']/2**30:.2f}GiB "
              f"dom={rec['dominant']}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = registry.names() if (args.all or args.arch is None) \
        else [args.arch]
    for a in archs:
        spec = registry.get(a)
        shapes = [args.shape] if args.shape else list(spec.shapes)
        for s in shapes:
            meshes = {"single": [False], "multi": [True],
                      "both": [False, True]}[args.mesh]
            cells += [(a, s, mp) for mp in meshes]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for rec in json.load(f):
                done[(rec["arch"], rec["shape"], rec["mesh"])] = rec

    results = list(done.values())
    for arch, shape, mp in cells:
        key = (arch, shape, "2x16x16" if mp else "16x16")
        if key in done:
            print(f"skip (cached): {key}")
            continue
        try:
            rec = run_cell(arch, shape, mp)
        except Exception as e:  # noqa: BLE001 — record failures, keep going
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(f"[{arch} x {shape}] FAILED: {rec['error']}")
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_err = sum(1 for r in results if "error" in r)
    print(f"\n{len(results)} cells recorded, {n_err} failures -> {args.out}")


if __name__ == "__main__":
    main()
