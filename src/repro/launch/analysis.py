"""Roofline analysis from compiled SPMD artifacts (no hardware needed).

  compute term    = HLO_FLOPs_per_chip / peak_FLOPs
  memory term     = HLO_bytes_per_chip / HBM_bw
  collective term = collective_bytes_per_chip / ICI_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (computed on the
*partitioned* per-device module). Collective bytes are parsed from the
optimized HLO text: for each all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute we take the result shape and apply the
standard ring-transfer factors (bytes that cross a link per device):

  all-gather       ~ result * (g-1)/g          (device receives the rest)
  all-reduce       ~ 2 * result * (g-1)/g      (reduce-scatter + all-gather)
  reduce-scatter   ~ operand * (g-1)/g = result * (g-1)
  all-to-all       ~ result * (g-1)/g
  collective-permute ~ result

Group size g is parsed from replica_groups (list or iota form).
Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_IOTA_RG_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_RG_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_RG_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_RG_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def parse_collectives(hlo_text: str, default_group: int = 16
                      ) -> Tuple[float, List[Dict]]:
    """Returns (total link bytes per device, per-op breakdown)."""
    ops = []
    total = 0.0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(r"=\s*(\([^)]*\)|\S+)\s+(%?)([\w-]+)", stripped)
        if not m:
            continue
        opname = m.group(3)
        kind = None
        for c in _COLLECTIVES:
            if opname == c or opname.startswith(c + "-start") or \
                    opname == c + "-done":
                kind = c
                break
        if kind is None or opname.endswith("-done"):
            continue
        result_bytes = _shape_bytes(m.group(1))
        g = _group_size(stripped, default_group)
        if kind == "all-gather":
            link = result_bytes * (g - 1) / max(g, 1)
        elif kind == "all-reduce":
            link = 2.0 * result_bytes * (g - 1) / max(g, 1)
        elif kind == "reduce-scatter":
            link = result_bytes * (g - 1)
        elif kind == "all-to-all":
            link = result_bytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            link = float(result_bytes)
        ops.append({"kind": kind, "bytes": result_bytes, "group": g,
                    "link_bytes": link})
        total += link
    return total, ops


def roofline(cost: dict, collective_bytes: float,
             model_flops: float | None = None, n_chips: int = 256) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = collective_bytes / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    out = {
        "flops_per_chip": flops,
        "bytes_per_chip": bytes_acc,
        "collective_bytes_per_chip": collective_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": max(t_compute, t_memory, t_coll),
    }
    if model_flops is not None and flops > 0:
        out["model_flops_total"] = model_flops
        out["useful_flops_ratio"] = model_flops / (flops * n_chips)
        # fraction of peak the step would hit if it ran at the roofline bound
        out["roofline_fraction"] = (model_flops / n_chips / PEAK_FLOPS) / \
            max(out["bound_s"], 1e-30)
    return out
