"""Production mesh definition.

Functions, not module-level constants, so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).

Mesh geometry (TPU v5e pods of 256 chips):
  single pod : (16, 16)        axes ("data", "model")
  multi-pod  : (2, 16, 16)     axes ("pod", "data", "model")
"pod" is an outer data axis (gradients cross the DCI/optical links between
pods — this is where gradient compression pays; EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes that carry the batch (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def fsdp_axes(mesh):
    """Axis (tuple) used for FSDP sharding of params/optimizer state."""
    ax = data_axes(mesh)
    return ax if len(ax) > 1 else ax[0]


def n_devices(mesh) -> int:
    import math
    return math.prod(mesh.devices.shape)
