"""Distributed EMVB serving.

Two retrieval execution plans over the production mesh (DESIGN.md §4):

  * ``retrieve_pjit``    — GSPMD/global-semantics: the engine runs on global
    arrays, XLA inserts collectives. Baseline in EXPERIMENTS.md §Perf.
  * ``retrieve_shardmap``— explicit plan: each device owns a doc shard with a
    *local* IVF, runs the full four-phase pipeline locally for the whole
    query batch, and the per-shard top-k are merged with one all-gather +
    re-top-k (two-level top-k). This is the production plan: collective
    traffic is O(B * k) instead of O(corpus gathers).

Both run on any mesh size (tests use 1 device; the dry-run uses 512).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.engine import (EngineConfig, RetrievalResult,
                               _as_query_batch, _retrieve_batch,
                               _with_filter)
from repro.core.index import PackedIndex
from repro.obs import trace

# jax >= 0.6 exposes shard_map at top level (replication check kw:
# check_vma); 0.4.x has it under experimental (kw: check_rep).
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SM_KW = {"check_vma": False}
else:  # pragma: no cover - exercised on jax 0.4.x containers
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_KW = {"check_rep": False}


def _axis_size(ax: str):
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(ax)
    return jax.lax.psum(1, ax)  # jax 0.4.x


def retrieve_pjit(mesh: Mesh, index: PackedIndex, queries: jax.Array,
                  cfg: EngineConfig) -> RetrievalResult:
    """Global-semantics retrieval (GSPMD chooses the collectives)."""
    from repro.core.engine import retrieve
    with mesh:
        return retrieve(index, queries, cfg)


# ---------------------------------------------------------------------------
# shard_map plan
# ---------------------------------------------------------------------------

def _local_retrieve(index_local: PackedIndex, queries: jax.Array,
                    q_masks: jax.Array, cfg: EngineConfig,
                    axes: Tuple[str, ...]) -> RetrievalResult:
    """Runs on ONE device's doc shard; queries AND q_masks are replicated.

    Goes through the SAME batched pipeline ``retrieve`` uses, so with a
    ``batched_kernels`` config every shard runs its whole query batch as
    one batch-native megakernel launch per fused phase pair."""
    local = _retrieve_batch(index_local, queries, cfg, q_masks)

    # translate local doc ids -> global ids with the shard offset
    shard_id = jnp.int32(0)
    n_shards = 1
    for ax in axes:
        shard_id = shard_id * _axis_size(ax) + jax.lax.axis_index(ax)
        n_shards *= _axis_size(ax)
    n_local = index_local.codes.shape[0]
    global_ids = local.doc_ids + shard_id * n_local

    # two-level top-k: all-gather each shard's k, rerank
    sc = jax.lax.all_gather(local.scores, axes, axis=0, tiled=False)
    gi = jax.lax.all_gather(global_ids, axes, axis=0, tiled=False)
    sc = jnp.moveaxis(sc, 0, 1).reshape(queries.shape[0], -1)   # (B, S*k)
    gi = jnp.moveaxis(gi, 0, 1).reshape(queries.shape[0], -1)
    top_sc, pos = jax.lax.top_k(sc, cfg.k)
    return RetrievalResult(top_sc, jnp.take_along_axis(gi, pos, axis=1))


def make_shardmap_retriever(mesh: Mesh, cfg: EngineConfig):
    """Returns a fn(index_stacked, queries, q_masks=None) -> RetrievalResult.

    ``index_stacked`` leaves carry a leading shard axis (S, ...) where S =
    number of devices; leaf [s] is device s's local index (local doc ids,
    local IVF). Build with ``shard_index``.

    ``q_masks`` (optional (B, n_q) bool) is replicated across shards exactly
    like ``queries`` — every shard applies the same per-term mask to its
    local four-phase pipeline, so the two-level top-k merges shard results
    computed under identical masking. ``None`` fills in an all-True mask,
    which is the bitwise identity.

    ``doc_filter`` (optional compiled ``bitvector.FilterPlan``, keyword)
    evaluates the predicate filter per shard against the shard's local
    ``pred_words`` slice — each shard's four-phase pipeline masks its own
    non-passing docs to -inf, so the two-level top-k merge only ever sees
    passing docs. The plan is static config, so each DISTINCT plan gets
    its own traced shard_map program (memoized here; the unfiltered
    program is traced on first unfiltered call, exactly as before).
    """
    axes = tuple(mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]

    in_specs = (jax.tree.map(lambda _: P(axes), _index_struct()),
                P(*([None])), P(*([None])))
    out_specs = RetrievalResult(P(None), P(None))

    steps: dict = {}   # filtered config -> traced shard_map program

    def _step_for(fcfg: EngineConfig):
        if fcfg not in steps:
            @functools.partial(jax.jit)
            @functools.partial(_shard_map, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, **_SM_KW)
            def step(index_stacked, queries, q_masks):
                index_local = jax.tree.map(lambda x: x[0], index_stacked)
                return _local_retrieve(index_local, queries, q_masks,
                                       fcfg, axes)
            steps[fcfg] = step
        return steps[fcfg]

    def run(index_stacked, queries, q_masks=None, *, doc_filter=None):
        qb = _as_query_batch(queries, q_masks)
        q_masks = (jnp.ones(qb.q.shape[:2], jnp.bool_)
                   if qb.q_mask is None else qb.q_mask)
        return _step_for(_with_filter(cfg, doc_filter))(
            index_stacked, qb.q, q_masks)

    return run


def _index_struct():
    """A PackedIndex-shaped pytree of placeholders (for tree.map of specs)."""
    return PackedIndex(*([0] * len(PackedIndex._fields)))


# ---------------------------------------------------------------------------
# Multi-generation serving (PLAID SHIRTTT): one shard_map plan per immutable
# index generation, merged by score at the top.
# ---------------------------------------------------------------------------

def make_timeline_partial_plans(mesh: Mesh, cfg: EngineConfig, timeline, *,
                                shard_cache: dict = None):
    """Per-generation shard_map execution plans over a
    ``repro.core.store.ShardedTimeline``.

    Reuses the existing shard_map plan PER GENERATION: each generation is
    doc-sharded across the whole mesh (``shard_index``), queried through
    ``make_shardmap_retriever`` (so the per-shard four-phase pipeline, the
    kernel choices, and the two-level top-k all apply unchanged), with the
    generation's global doc-id offset applied to the result. Selection
    budgets are clamped to each generation's PER-SHARD doc count AND token
    cap via ``engine.adapt_config_to_corpus``.

    ``shard_cache`` (optional dict the caller owns) memoizes the stacked
    shard arrays by generation CONTENT fingerprint: across timeline swaps
    (growth, compaction, re-epoching) only generations whose content
    actually changed are re-sharded — the same invalidation-by-construction
    rule the result cache uses. Pass the SAME dict on every rebuild (and
    across epochs — the service invokes the factory once per epoch with
    one dict); it is kept LRU-bounded here, so stale fingerprints age out
    without ever evicting another epoch's still-live entries first.

    Every generation's ``n_docs`` must divide the mesh size (the
    ``shard_index`` block-partition contract). Returns one
    ``plan(queries, q_masks=None) -> RetrievalResult`` (GLOBAL doc ids)
    per generation — the partials ``make_timeline_retriever`` merges and
    ``repro.serving.RetrievalService`` caches per immutable generation.
    """
    from repro.core.engine import adapt_config_to_corpus

    n_shards = 1
    for a in mesh.axis_names:
        n_shards *= mesh.shape[a]
    fps = timeline.fingerprints if shard_cache is not None else None
    # one retriever per DISTINCT clamped config: equal-size generations (the
    # steady-state stream) share a single traced/compiled shard_map program
    # instead of compiling G identical ones
    retrievers: dict = {}
    plans = []
    for g, (gen, meta, off) in enumerate(timeline):
        gcfg = adapt_config_to_corpus(cfg, meta.n_docs // n_shards, meta.cap)
        if gcfg not in retrievers:
            retrievers[gcfg] = make_shardmap_retriever(mesh, gcfg)
        if shard_cache is None:
            stacked = shard_index(gen, n_shards)
        else:
            ckey = (fps[g], n_shards)
            stacked = shard_cache.pop(ckey, None)
            if stacked is None:
                stacked = shard_index(gen, n_shards)
            shard_cache[ckey] = stacked   # (re)insert at LRU tail

        def plan(queries, q_masks=None, doc_filter=None, *, _stacked=stacked,
                 _retriever=retrievers[gcfg], _off=off, _g=g):
            """queries: (B, n_q, d) array or QueryBatch; ``doc_filter`` an
            optional compiled FilterPlan applied on every shard."""
            # dispatch-only span (jax is async); generation attr is the
            # plan's position in the timeline it was built from
            with trace.span("launch.shard_plan", generation=_g,
                            shards=n_shards):
                r = _retriever(_stacked, queries, q_masks,
                               doc_filter=doc_filter)
                return RetrievalResult(r.scores, r.doc_ids + jnp.int32(_off))

        plans.append(plan)
    if shard_cache is not None:
        # LRU bound (insertion order = recency after the pop/reinsert
        # above): stale fingerprints from superseded timelines age out;
        # never evicts this timeline's own entries (they were just
        # refreshed) as long as the bound exceeds one epoch's generations
        while len(shard_cache) > max(32, 2 * len(plans)):
            del shard_cache[next(iter(shard_cache))]
    return plans


def make_timeline_retriever(mesh: Mesh, cfg: EngineConfig, timeline):
    """Sharded serving over a timeline: the per-generation shard_map plans
    (``make_timeline_partial_plans``) merged by score — a third top-k level
    on top of the per-shard merge. Returns
    ``run(queries, q_masks=None) -> RetrievalResult`` over global doc ids.
    """
    from repro.core.engine import merge_partial_topk

    plans = make_timeline_partial_plans(mesh, cfg, timeline)

    def run(queries, q_masks=None, *, doc_filter=None) -> RetrievalResult:
        qb = _as_query_batch(queries, q_masks)
        q_masks = (jnp.ones(qb.q.shape[:2], jnp.bool_)
                   if qb.q_mask is None else qb.q_mask)
        return merge_partial_topk(
            [p(qb.q, q_masks, doc_filter) for p in plans], cfg.k)

    return run


def make_service(mesh: Mesh, cfg: EngineConfig, timeline, **service_kwargs):
    """A ``repro.serving.RetrievalService`` whose cache-MISS lane runs the
    sharded plans: hits are served from host memory, and only the miss
    lane's sub-batch ever reaches the mesh. The plan factory is re-invoked
    on every timeline swap (``add_passages``/``new_generation``/
    maintenance), so changed generations get freshly sharded plans while
    unchanged generations reuse their stacked shard arrays (memoized by
    content fingerprint in a cache this factory owns) AND keep their
    result-cache entries. ``service_kwargs`` pass through to
    ``RetrievalService`` (cache budget, batching knobs, ...).
    """
    from repro.serving import RetrievalService

    shard_cache: dict = {}
    return RetrievalService(
        timeline, cfg,
        plan_factory=lambda tl: make_timeline_partial_plans(
            mesh, cfg, tl, shard_cache=shard_cache),
        **service_kwargs)


def shard_index(index: PackedIndex, n_shards: int) -> PackedIndex:
    """Split a global index into per-shard local indices, stacked on a new
    leading axis. Docs are block-partitioned; each shard's IVF is rebuilt
    with local doc ids. (Host-side, numpy.) If a rebuilt local list exceeds
    the global list_cap a warning reports how many doc-id entries were
    dropped (those docs become unreachable through that centroid on that
    shard)."""
    import warnings

    import numpy as np

    n_docs = int(index.codes.shape[0])
    assert n_docs % n_shards == 0, "pad docs to a shard multiple first"
    per = n_docs // n_shards
    n_c, list_cap = index.ivf.shape

    codes = np.asarray(index.codes).reshape(n_shards, per, -1)
    doc_lens = np.asarray(index.doc_lens).reshape(n_shards, per)
    pred_words = np.asarray(index.pred_words).reshape(n_shards, per)
    res_codes = np.asarray(index.res_codes).reshape(
        n_shards, per, *index.res_codes.shape[1:])
    plaid_res = np.asarray(index.plaid_res)
    if plaid_res.shape[0] == n_docs:
        plaid_res = plaid_res.reshape(n_shards, per, *plaid_res.shape[1:])
    else:  # dummy
        plaid_res = np.broadcast_to(plaid_res, (n_shards, *plaid_res.shape))

    # local IVFs
    ivf = np.asarray(index.ivf)
    ivf_lens_g = np.asarray(index.ivf_lens)
    local_ivf = np.full((n_shards, n_c, list_cap), per, dtype=np.int32)
    local_lens = np.zeros((n_shards, n_c), dtype=np.int32)
    n_dropped = 0
    n_overflowed = 0
    for c in range(n_c):
        docs = ivf[c, :ivf_lens_g[c]]
        for s in range(n_shards):
            mine = docs[(docs >= s * per) & (docs < (s + 1) * per)] - s * per
            ln = min(len(mine), list_cap)
            if len(mine) > ln:
                n_dropped += len(mine) - ln
                n_overflowed += 1
            local_ivf[s, c, :ln] = mine[:ln]
            local_lens[s, c] = ln
    if n_dropped:
        warnings.warn(
            f"shard_index: {n_overflowed} local IVF list(s) overflowed "
            f"list_cap={list_cap}; {n_dropped} doc-id entries dropped — "
            "those docs are unreachable through the overflowed centroid on "
            "their shard. Rebuild with a larger list_cap.",
            stacklevel=2)

    def rep(x):
        return np.broadcast_to(np.asarray(x), (n_shards, *np.shape(x))).copy()

    return PackedIndex(
        centroids=jnp.asarray(rep(index.centroids)),
        codes=jnp.asarray(codes),
        doc_lens=jnp.asarray(doc_lens),
        res_codes=jnp.asarray(res_codes),
        pq_codebooks=jnp.asarray(rep(index.pq_codebooks)),
        ivf=jnp.asarray(local_ivf),
        ivf_lens=jnp.asarray(local_lens),
        plaid_res=jnp.asarray(plaid_res),
        plaid_cutoffs=jnp.asarray(rep(index.plaid_cutoffs)),
        plaid_weights=jnp.asarray(rep(index.plaid_weights)),
        opq_rotation=jnp.asarray(rep(index.opq_rotation)),
        pred_words=jnp.asarray(pred_words),
    )
