"""MODEL_FLOPS — the *algorithmically required* flops of one step, used for
the §Roofline "useful flops" ratio (how much of the compiled compute is the
model vs remat/padding/redundancy).

LM family keeps the classic 6·N·D (train) / 2·N·D (inference) with N =
(active) params. RecSys/GNN/retrieval use exact per-shape formulas: their
parameter counts are dominated by embedding tables that are *looked up*, not
multiplied, per sample — 6·N·D over table params overcounts by orders of
magnitude (refuted hypothesis logged in EXPERIMENTS.md §Perf notes).
"""
from __future__ import annotations

from typing import Optional

from repro.configs.registry import ArchSpec


def _mlp_macs(dims: tuple[int, ...]) -> int:
    return sum(a * b for a, b in zip(dims[:-1], dims[1:]))


def _dlrm_fwd(batch: int) -> float:
    bot = _mlp_macs((13, 512, 256, 128))
    inter = 27 * 27 * 128                       # dot-interaction gram
    top = _mlp_macs((479, 1024, 1024, 512, 256, 1))
    return 2.0 * batch * (bot + inter + top)


def _dcn_fwd(batch: int) -> float:
    d_in = 13 + 26 * 16                          # 429
    cross = 3 * d_in * d_in
    mlp = _mlp_macs((d_in, 1024, 1024, 512)) + (d_in + 512)
    return 2.0 * batch * (cross + mlp)


def _dien_fwd(batch: int) -> float:
    d_in, gru = 36, 108                          # item+cat embed, gru_dim
    per_step = 2 * 3 * (d_in + gru) * gru + gru * d_in   # GRU+AUGRU+attention
    mlp = _mlp_macs((gru + d_in + 36, 200, 80)) + 80
    return 2.0 * batch * (100 * per_step + mlp)


def _mind_fwd(batch: int) -> float:
    seq, d, n_i, iters = 50, 64, 4, 3
    u_hat = seq * d * d                          # shared bilinear map
    routing = iters * 2 * seq * n_i * d
    return 2.0 * batch * (u_hat + routing + n_i * d)


def _gcn_fwd(cell) -> float:
    dims = cell.dims
    feat = dims.get("d_feat", 0)
    if "batch_nodes" in dims:                    # sampled minibatch
        b, f0, f1 = dims["batch_nodes"], dims["fanout0"], dims["fanout1"]
        n_sub = b * (1 + f0 + f0 * f1)
        e_sub = b * (f0 + f0 * f1)
        n1 = b * (1 + f0)                        # nodes needing layer-2 input
        return 2.0 * (n_sub * feat * 16 + e_sub * 16 + n1 * 16 * 41 + e_sub * 41)
    n, e = dims["n_nodes"], dims["n_edges"]
    ncls = {1433: 7, 100: 47, 32: 16}.get(feat, 8)
    return 2.0 * (n * feat * 16 + e * 16 + n * 16 * ncls + e * ncls)


def _emvb_fwd(batch: int) -> float:
    # CS matmul + centroid interaction on n_filter docs + PQ phase on n_docs
    n_q, d, n_c, cap = 32, 128, 1 << 18, 80
    n_filter, n_docs, m = 1024, 256, 16
    cs = n_q * d * n_c
    cinter = n_filter * cap * n_q
    pq = n_docs * cap * n_q * (m + 1)
    return 2.0 * batch * (cs + cinter + pq)


def model_flops(spec: ArchSpec, shape: str) -> Optional[float]:
    cell = spec.shapes[shape]
    mf = spec.model_flops_params or {}
    if spec.family == "lm":
        n = mf.get("n_active") or mf.get("n_params")
        if not n:
            return None
        if cell.kind == "train":
            return 6.0 * n * cell.dims["batch"] * cell.dims["seq"]
        if cell.kind == "prefill":
            return 2.0 * n * cell.dims["batch"] * cell.dims["seq"]
        if cell.kind == "decode":
            return 2.0 * n * cell.dims["batch"]
        return None
    if spec.family == "gnn":
        return 3.0 * _gcn_fwd(cell)              # fwd+bwd = 3x fwd
    if spec.family == "retrieval":
        return _emvb_fwd(cell.dims.get("query_batch", 1))
    if spec.family == "recsys":
        fwd = {"dlrm-mlperf": _dlrm_fwd, "dcn-v2": _dcn_fwd,
               "dien": _dien_fwd, "mind": _mind_fwd}.get(spec.name)
        if fwd is None:
            return None
        if cell.kind == "retrieval":
            b = cell.dims["n_candidates"]
            if spec.name == "mind":
                # user tower once + MaxSim over the candidate corpus
                return _mind_fwd(1) + 2.0 * b * 4 * 64
            return fwd(b)                        # ranking models re-run per cand
        mult = 3.0 if cell.kind == "train" else 1.0
        return mult * fwd(cell.dims["batch"])
    return None
