"""Cell builders: (arch x shape x mesh) -> a jit-lowerable step.

``build_cell`` returns (fn, args) where every leaf of ``args`` is a
ShapeDtypeStruct carrying its NamedSharding — ``jax.jit(fn).lower(*args)``
then produces the SPMD program for the production mesh without allocating a
single real buffer. Used by launch/dryrun.py and benchmarks/roofline.py.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.launch.mesh import data_axes, fsdp_axes, n_devices
from repro.sharding.recsys_rules import recsys_state_shardings
from repro.sharding.rules import lm_state_shardings, replicated
from repro.train import optimizer as opt_lib
from repro.train.trainer import TrainState, TrainerConfig, make_train_step


def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _with_shardings(avals: Any, shardings: Any) -> Any:
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        avals, shardings)


def _optimizer_for(spec: registry.ArchSpec, mesh: Mesh = None):
    if spec.optimizer == "muon":
        # tensor-parallel Newton-Schulz: momentum keeps its param sharding
        # (no reshard — see optimizer.muon docstring for the two refuted
        # resharding designs), lax.map over layers bounds live grams.
        return opt_lib.make("muon", state_dtype=jnp.bfloat16,
                            ns_dtype=jnp.bfloat16)
    return opt_lib.make(spec.optimizer)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _lm_cell(spec, shape_name: str, mesh: Mesh) -> Tuple[Callable, tuple]:
    import dataclasses

    from repro.models import transformer as T

    cell = spec.shapes[shape_name]
    cfg = spec.make_config()
    dax = data_axes(mesh)
    fax = fsdp_axes(mesh) if spec.fsdp else None
    # Context parallelism when head counts don't divide the model axis:
    # left alone GSPMD shards d_head and pays a partial-sum all-reduce of
    # every attention logits block (measured 43 TB/chip on qwen32b prefill;
    # EXPERIMENTS.md §Perf). Seq-shard the q positions instead.
    n_model = mesh.shape["model"]
    if (cell.kind in ("train", "prefill") and
            (cfg.n_heads % n_model or cfg.n_kv_heads % n_model)):
        qg_spec = P(dax, None, "model", None, None, None)
        kv_spec = P(dax, None, None, None, None)
        cfg = dataclasses.replace(
            cfg, attn_act_specs=(qg_spec, kv_spec),
            # Megatron-SP residuals pair with context parallelism: the TP
            # partial-sum all-reduces become reduce-scatters (§Perf iter 3)
            residual_spec=P(dax, "model", None))
    # MoE dispatch: the GShard grouped-einsum mode (moe_block_grouped) was
    # hypothesized to lower to clean all-to-alls, but GSPMD's auto-backward
    # replicates the (g,E,C,d) dispatch tensor and the collective term
    # QUADRUPLED (§Perf cell 2, refuted iteration). The capacity-gather
    # path with a token-sharded output constraint measures best here; the
    # grouped mode stays available via cfg.moe_groups for real-TPU tuning.
    if cfg.is_moe and cell.kind in ("train", "prefill"):
        cfg = dataclasses.replace(
            cfg, residual_spec=cfg.residual_spec or P(dax, "model", None))
    params_avals = T.abstract_params(cfg)

    if cell.kind == "train":
        opt = _optimizer_for(spec, mesh)
        opt_avals = jax.eval_shape(opt.init, params_avals)
        p_sh, o_sh = lm_state_shardings(mesh, params_avals, opt_avals, fax)
        state = TrainState(
            _sds((), jnp.int32, mesh, P()),
            _with_shardings(params_avals, p_sh),
            _with_shardings(opt_avals, o_sh))
        b, s, ga = cell.dims["batch"], cell.dims["seq"], cell.grad_accum
        tok_spec = (P(None, dax, None) if ga > 1 else P(dax, None))
        tok_shape = (ga, b // ga, s) if ga > 1 else (b, s)
        batch = {"tokens": _sds(tok_shape, jnp.int32, mesh, tok_spec),
                 "labels": _sds(tok_shape, jnp.int32, mesh, tok_spec)}
        loss = functools.partial(T.loss_fn, cfg=cfg)
        # FSDP gather hoisting via micro_param_layout was measured and
        # REFUTED here: remat re-gathers weights in the backward regardless,
        # so collective moved only -1.7% while the pinned unsharded params
        # added 10 GiB temp (EXPERIMENTS.md §5.1). Hook left available.
        step = make_train_step(lambda p, bt: loss(p, bt), opt,
                               TrainerConfig(grad_accum=ga))
        return step, (state, batch)

    p_sh = lm_state_shardings(mesh, params_avals,
                              jax.eval_shape(lambda: {}), fax)[0]
    params = _with_shardings(params_avals, p_sh)

    if cell.kind == "prefill":
        b, s = cell.dims["batch"], cell.dims["seq"]
        tokens = _sds((b, s), jnp.int32, mesh, P(dax, None))
        # cache out-sharding matches the decode cells (dh over "model") so
        # prefill -> decode handoff needs no resharding; also keeps the
        # context-parallel k/v (replicated inside attention) from
        # materializing a replicated 137 GB cache.
        cache_spec = NamedSharding(mesh, P(None, dax, None, None, "model"))

        def prefill_fn(p, t):
            logits, cache = T.prefill(p, t, cfg)
            cache = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(x, cache_spec),
                cache)
            return logits, cache
        return prefill_fn, (params, tokens)

    if cell.kind == "decode":
        b, s = cell.dims["batch"], cell.dims["seq"]
        kvh, dh, L = cfg.n_kv_heads, cfg.d_head, cfg.n_layers
        # KV-head counts (2/8) don't divide the 16-way model axis, so the
        # cache shards its head_dim over "model" (contraction-dim sharding ->
        # partial sums + all-reduce; flash-decoding-style).
        if b >= np.prod([mesh.shape[a] for a in dax]):
            cache_spec = P(None, dax, None, None, "model")
            tok_spec = P(dax)
        else:  # long-context single sequence: shard the KV sequence axis
            cache_spec = P(None, None, dax, None, "model")
            tok_spec = P(None)
        cache = T.KVCache(
            _sds((L, b, s, kvh, dh), cfg.dtype, mesh, cache_spec),
            _sds((L, b, s, kvh, dh), cfg.dtype, mesh, cache_spec))
        token = _sds((b,), jnp.int32, mesh, tok_spec)
        pos = _sds((), jnp.int32, mesh, P())
        return (lambda p, c, t, ps: T.decode_step(p, c, t, ps, cfg)), \
            (params, cache, token, pos)

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def _gnn_cell(spec, shape_name: str, mesh: Mesh) -> Tuple[Callable, tuple]:
    from repro.models import gcn

    cell = spec.shapes[shape_name]
    cfg = spec.make_config(shape_name)
    dax = data_axes(mesh)
    all_ax = tuple(mesh.axis_names)
    params_avals = jax.eval_shape(
        lambda: gcn.init_params(jax.random.PRNGKey(0), cfg))
    p_sh = replicated(mesh, params_avals)
    opt = _optimizer_for(spec, mesh)
    opt_avals = jax.eval_shape(opt.init, params_avals)
    state = TrainState(_sds((), jnp.int32, mesh, P()),
                       _with_shardings(params_avals, p_sh),
                       _with_shardings(opt_avals, replicated(mesh, opt_avals)))

    if cell.kind == "train":
        n, e, f = (cell.dims["n_nodes"], cell.dims["n_edges"],
                   cell.dims["d_feat"])
        # pad the edge list to a mesh multiple (masked edges are inert)
        e = _round_up(e, n_devices(mesh))
        batch = {
            "feats": _sds((n, f), jnp.float32, mesh, P(None, None)),
            "edges": _sds((2, e), jnp.int32, mesh, P(None, all_ax)),
            "edge_mask": _sds((e,), jnp.bool_, mesh, P(all_ax)),
            "labels": _sds((n,), jnp.int32, mesh, P(None)),
        }
        loss = functools.partial(gcn.loss_fn, cfg=cfg)
        step = make_train_step(lambda p, b: loss(p, b), opt, TrainerConfig())
        return step, (state, batch)

    if cell.kind == "train_sampled":
        bn = cell.dims["batch_nodes"]
        f0, f1 = cell.dims["fanout0"], cell.dims["fanout1"]
        f = cell.dims["d_feat"]
        n1, n2 = bn * f0, bn * f0 * f1
        batch = {
            "feats0": _sds((bn, f), jnp.float32, mesh, P(dax, None)),
            "feats1": _sds((n1, f), jnp.float32, mesh, P(dax, None)),
            "feats2": _sds((n2, f), jnp.float32, mesh, P(dax, None)),
            "edges0": _sds((2, n1), jnp.int32, mesh, P(None, dax)),
            "edge_mask0": _sds((n1,), jnp.bool_, mesh, P(dax)),
            "edges1": _sds((2, n2), jnp.int32, mesh, P(None, dax)),
            "edge_mask1": _sds((n2,), jnp.bool_, mesh, P(dax)),
            "labels": _sds((bn,), jnp.int32, mesh, P(dax)),
        }
        loss = functools.partial(gcn.loss_fn_sampled, cfg=cfg)
        step = make_train_step(lambda p, b: loss(p, b), opt, TrainerConfig())
        return step, (state, batch)

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def _recsys_batch(arch: str, b: int, mesh: Mesh, dims: dict, cfg) -> dict:
    # batch shards over ALL axes: recsys dense towers have no model-parallel
    # dim, so leaving "model" out replicates their compute 16x (the 6%
    # useful-flops finding in §Roofline; fixed here — §Perf beyond-3-cells)
    dax = tuple(mesh.axis_names)
    ndata = int(np.prod([mesh.shape[a] for a in dax]))
    if b % ndata != 0:
        dax = data_axes(mesh)   # fall back to data-only sharding
        ndata = int(np.prod([mesh.shape[a] for a in dax]))
    if b % ndata != 0:          # e.g. batch=1 retrieval: replicate the batch
        dax = None
    if arch in ("dlrm-mlperf", "dcn-v2"):
        return {
            "dense": _sds((b, cfg.n_dense), jnp.float32, mesh, P(dax, None)),
            "sparse_idx": _sds((b, cfg.n_sparse, cfg.nnz), jnp.int32, mesh,
                               P(dax, None, None)),
            "sparse_valid": _sds((b, cfg.n_sparse, cfg.nnz), jnp.bool_, mesh,
                                 P(dax, None, None)),
            "labels": _sds((b,), jnp.int32, mesh, P(dax)),
        }
    if arch == "dien":
        L = cfg.seq_len
        return {
            "hist_items": _sds((b, L), jnp.int32, mesh, P(dax, None)),
            "hist_cats": _sds((b, L), jnp.int32, mesh, P(dax, None)),
            "hist_valid": _sds((b, L), jnp.bool_, mesh, P(dax, None)),
            "target_item": _sds((b,), jnp.int32, mesh, P(dax)),
            "target_cat": _sds((b,), jnp.int32, mesh, P(dax)),
            "labels": _sds((b,), jnp.int32, mesh, P(dax)),
        }
    if arch == "mind":
        L = cfg.seq_len
        return {
            "hist_items": _sds((b, L), jnp.int32, mesh, P(dax, None)),
            "hist_valid": _sds((b, L), jnp.bool_, mesh, P(dax, None)),
            "target_item": _sds((b,), jnp.int32, mesh, P(dax)),
        }
    raise ValueError(arch)


def _recsys_model(arch: str):
    if arch == "dlrm-mlperf":
        from repro.models.recsys import dlrm as M
    elif arch == "dcn-v2":
        from repro.models.recsys import dcn as M
    elif arch == "dien":
        from repro.models.recsys import dien as M
    elif arch == "mind":
        from repro.models.recsys import mind as M
    else:
        raise ValueError(arch)
    return M


def _pad_recsys_cfg(cfg, mesh: Mesh):
    """Row-shard divisibility: pad big tables to a multiple of the row-shard
    factor (standard pad-to-128-style practice)."""
    import dataclasses
    mult = mesh.shape.get("data", 1) * mesh.shape.get("model", 1)
    kw = {}
    if hasattr(cfg, "vocab_sizes"):
        kw["vocab_sizes"] = tuple(
            _round_up(v, mult) if v >= 100_000 else v
            for v in cfg.vocab_sizes)
    if hasattr(cfg, "vocab_items") and cfg.vocab_items >= 100_000:
        kw["vocab_items"] = _round_up(cfg.vocab_items, mult)
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _recsys_cell(spec, shape_name: str, mesh: Mesh) -> Tuple[Callable, tuple]:
    cell = spec.shapes[shape_name]
    cfg = _pad_recsys_cfg(spec.make_config(), mesh)
    M = _recsys_model(spec.name)
    params_avals = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))

    if cell.kind == "train":
        opt = _optimizer_for(spec, mesh)
        opt_avals = jax.eval_shape(opt.init, params_avals)
        p_sh, o_sh = recsys_state_shardings(mesh, params_avals, opt_avals)
        state = TrainState(_sds((), jnp.int32, mesh, P()),
                           _with_shardings(params_avals, p_sh),
                           _with_shardings(opt_avals, o_sh))
        batch = _recsys_batch(spec.name, cell.dims["batch"], mesh, cell.dims,
                              cfg)
        loss = functools.partial(M.loss_fn, cfg=cfg)
        step = make_train_step(lambda p, b: loss(p, b), opt, TrainerConfig())
        return step, (state, batch)

    p_sh, _ = recsys_state_shardings(mesh, params_avals, {})
    params = _with_shardings(params_avals, p_sh)

    if cell.kind == "serve":
        batch = _recsys_batch(spec.name, cell.dims["batch"], mesh, cell.dims,
                              cfg)
        batch.pop("labels", None)
        return (lambda p, b: M.forward(p, b, cfg)), (params, batch)

    if cell.kind == "retrieval":
        # pad the candidate set to a mesh multiple (1M % 256 != 0 would
        # otherwise fall back to data-only sharding and replicate the
        # ranking compute 16x over "model")
        ncand = _round_up(cell.dims["n_candidates"], n_devices(mesh))
        if spec.name == "mind":
            # multi-interest MaxSim over 1M candidates + top-k (EMVB regime)
            def step(p, b):
                caps = M.user_interests(p, b["hist_items"], b["hist_valid"],
                                        cfg)
                scores = M.score_candidates(caps, p["item_emb"][:ncand])
                return jax.lax.top_k(scores, 100)
            batch = _recsys_batch("mind", cell.dims["batch"], mesh, cell.dims,
                                  cfg)
            batch.pop("target_item")
            return step, (params, batch)
        # ranking models: score `n_candidates` items for one user
        batch = _recsys_batch(spec.name, ncand, mesh, cell.dims, cfg)
        batch.pop("labels", None)

        def step(p, b):
            return jax.lax.top_k(M.forward(p, b, cfg), 100)
        return step, (params, batch)

    raise ValueError(cell.kind)


# ---------------------------------------------------------------------------
# Retrieval family (the paper's own system at MS MARCO production scale)
# ---------------------------------------------------------------------------

def _retrieval_cell(spec, shape_name: str, mesh: Mesh, plan: str = "shardmap"
                    ) -> Tuple[Callable, tuple]:
    from repro.core.engine import retrieve
    from repro.core.index import PackedIndex
    from repro.launch.serve import make_shardmap_retriever

    cell = spec.shapes[shape_name]
    cfg = spec.make_config()
    all_ax = tuple(mesh.axis_names)
    ndev = n_devices(mesh)
    nd = _round_up(cfg.n_docs, ndev)              # doc padding (len-0 docs)
    cap, d, nc, m = cfg.doc_cap, cfg.d, cfg.n_centroids, cfg.m
    ksub = 1 << cfg.nbits
    qb = cell.dims["query_batch"]
    ecfg = cfg.engine

    if plan == "gspmd":
        # baseline plan (§Perf cell 3): global arrays, GSPMD collectives —
        # the IVF row gathers / bitmap scatters cross doc shards
        index = PackedIndex(
            centroids=_sds((nc, d), jnp.float32, mesh, P(None, None)),
            codes=_sds((nd, cap), jnp.int32, mesh, P(all_ax, None)),
            doc_lens=_sds((nd,), jnp.int32, mesh, P(all_ax)),
            res_codes=_sds((nd, cap, m), jnp.uint8, mesh,
                           P(all_ax, None, None)),
            pq_codebooks=_sds((m, ksub, d // m), jnp.float32, mesh,
                              P(None, None, None)),
            ivf=_sds((nc, cfg.list_cap), jnp.int32, mesh, P(all_ax, None)),
            ivf_lens=_sds((nc,), jnp.int32, mesh, P(all_ax)),
            plaid_res=_sds((1, 1, 1), jnp.uint8, mesh, P(None, None, None)),
            plaid_cutoffs=_sds((3,), jnp.float32, mesh, P(None)),
            plaid_weights=_sds((4,), jnp.float32, mesh, P(None)),
            opq_rotation=_sds((d, d), jnp.float32, mesh, P(None, None)),
            pred_words=_sds((nd,), jnp.uint32, mesh, P(all_ax)),
        )
        queries = _sds((qb, ecfg.n_q, d), jnp.float32, mesh,
                       P(None, None, None))
        return (lambda idx, q: retrieve(idx, q, ecfg)), (index, queries)

    # production plan: each device owns a doc shard + local IVF, runs the
    # whole 4-phase pipeline locally, one small all-gather merges top-k
    # (two-level top-k; launch/serve.py). Collective = O(B*k), not O(corpus).
    per = nd // ndev
    shard_spec = P(all_ax)

    def leaf(shape, dtype):
        return _sds((ndev, *shape), dtype, mesh,
                    P(*shard_spec, *([None] * len(shape))))
    index = PackedIndex(
        centroids=leaf((nc, d), jnp.float32),
        codes=leaf((per, cap), jnp.int32),
        doc_lens=leaf((per,), jnp.int32),
        res_codes=leaf((per, cap, m), jnp.uint8),
        pq_codebooks=leaf((m, ksub, d // m), jnp.float32),
        ivf=leaf((nc, cfg.list_cap), jnp.int32),
        ivf_lens=leaf((nc,), jnp.int32),
        plaid_res=leaf((1, 1, 1), jnp.uint8),
        plaid_cutoffs=leaf((3,), jnp.float32),
        plaid_weights=leaf((4,), jnp.float32),
        opq_rotation=leaf((d, d), jnp.float32),
        pred_words=leaf((per,), jnp.uint32),
    )
    queries = _sds((qb, ecfg.n_q, d), jnp.float32, mesh, P(None, None, None))
    step = make_shardmap_retriever(mesh, ecfg)
    return step, (index, queries)


# ---------------------------------------------------------------------------

def build_cell(arch: str, shape_name: str, mesh: Mesh
               ) -> Tuple[Callable, tuple]:
    spec = registry.get(arch)
    if spec.family == "lm":
        return _lm_cell(spec, shape_name, mesh)
    if spec.family == "gnn":
        return _gnn_cell(spec, shape_name, mesh)
    if spec.family == "recsys":
        return _recsys_cell(spec, shape_name, mesh)
    if spec.family == "retrieval":
        return _retrieval_cell(spec, shape_name, mesh)
    raise ValueError(spec.family)


def donate_argnums(arch: str, shape_name: str) -> tuple:
    """Buffer donation: train steps alias state in->out; decode aliases the
    KV cache. Without this the dry-run double-counts the largest buffers."""
    kind = registry.get(arch).shapes[shape_name].kind
    if kind in ("train", "train_sampled"):
        return (0,)
    if kind == "decode":
        return (1,)
    return ()
