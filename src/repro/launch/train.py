"""Training launcher.

On this CPU container it trains the *smoke* variant of any arch on synthetic
data (the full configs are exercised via dryrun.py); on a real fleet the same
entry point takes ``--full`` and the production mesh. Demonstrates the whole
substrate: optimizer choice per arch, grad accumulation, checkpointing,
resume, straggler counters.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --steps 30
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.train import optimizer as opt_lib
from repro.train.trainer import Trainer, TrainerConfig


def lm_batch_fn(vocab: int, batch: int = 8, seq: int = 64):
    def make(step: int):
        k = jax.random.PRNGKey(step)
        toks = jax.random.randint(k, (batch, seq), 0, vocab)
        return {"tokens": toks, "labels": toks}
    return make


def gnn_batch_fn(cfg):
    def make(step: int):
        k = jax.random.PRNGKey(step)
        n, e = 64, 256
        return {
            "feats": jax.random.normal(k, (n, cfg.d_feat)),
            "edges": jax.random.randint(k, (2, e), 0, n),
            "edge_mask": jnp.ones((e,), jnp.bool_),
            "labels": jax.random.randint(k, (n,), 0, cfg.n_classes),
        }
    return make


def recsys_batch_fn(arch: str, cfg, batch: int = 32):
    def make(step: int):
        k = jax.random.PRNGKey(step)
        ks = jax.random.split(k, 8)
        if arch in ("dlrm-mlperf", "dcn-v2"):
            v = min(cfg.vocab_sizes)
            return {
                "dense": jax.random.normal(ks[0], (batch, cfg.n_dense)),
                "sparse_idx": jax.random.randint(
                    ks[1], (batch, cfg.n_sparse, cfg.nnz), 0, v),
                "sparse_valid": jnp.ones((batch, cfg.n_sparse, cfg.nnz),
                                         jnp.bool_),
                "labels": jax.random.randint(ks[2], (batch,), 0, 2),
            }
        if arch == "dien":
            return {
                "hist_items": jax.random.randint(
                    ks[0], (batch, cfg.seq_len), 0, cfg.vocab_items),
                "hist_cats": jax.random.randint(
                    ks[1], (batch, cfg.seq_len), 0, cfg.vocab_cats),
                "hist_valid": jnp.ones((batch, cfg.seq_len), jnp.bool_),
                "target_item": jax.random.randint(ks[2], (batch,), 0,
                                                  cfg.vocab_items),
                "target_cat": jax.random.randint(ks[3], (batch,), 0,
                                                 cfg.vocab_cats),
                "labels": jax.random.randint(ks[4], (batch,), 0, 2),
            }
        if arch == "mind":
            return {
                "hist_items": jax.random.randint(
                    ks[0], (batch, cfg.seq_len), 0, cfg.vocab_items),
                "hist_valid": jnp.ones((batch, cfg.seq_len), jnp.bool_),
                "target_item": jax.random.randint(ks[1], (batch,), 0,
                                                  cfg.vocab_items),
            }
        raise ValueError(arch)
    return make


def build_smoke_trainer(arch: str, ckpt_dir=None, steps_per_ckpt: int = 50,
                        grad_accum: int = 1) -> Trainer:
    spec = registry.get(arch)
    cfg = spec.make_smoke_config()
    key = jax.random.PRNGKey(0)
    tcfg = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=steps_per_ckpt,
                         log_every=5, grad_accum=grad_accum)
    opt = opt_lib.make(spec.optimizer)

    if spec.family == "lm":
        from repro.models import transformer as T
        params = T.init_params(key, cfg)
        loss = lambda p, b: T.loss_fn(p, b, cfg)  # noqa: E731
        make_batch = lm_batch_fn(cfg.vocab)
    elif spec.family == "gnn":
        from repro.models import gcn
        params = gcn.init_params(key, cfg)
        loss = lambda p, b: gcn.loss_fn(p, b, cfg)  # noqa: E731
        make_batch = gnn_batch_fn(cfg)
    elif spec.family == "recsys":
        from repro.launch.steps import _recsys_model
        M = _recsys_model(arch)
        params = M.init_params(key, cfg)
        loss = lambda p, b: M.loss_fn(p, b, cfg)  # noqa: E731
        make_batch = recsys_batch_fn(arch, cfg)
    else:
        raise ValueError(f"no training path for family {spec.family}")

    if grad_accum > 1:
        inner = make_batch

        def make_batch(step):  # noqa: F811
            mbs = [inner(step * grad_accum + i) for i in range(grad_accum)]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *mbs)

    return Trainer(loss, opt, make_batch, tcfg, params)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()
    tr = build_smoke_trainer(args.arch, args.ckpt_dir,
                             grad_accum=args.grad_accum)
    out = tr.run(args.steps)
    for m in out["log"]:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.3f}  {m['sec']*1e3:.0f}ms")
    print(f"done at step {out['final_step']} "
          f"(interrupted={out['interrupted']}, stragglers={out['stragglers']})")


if __name__ == "__main__":
    main()
