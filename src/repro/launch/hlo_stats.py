"""Trip-count-aware static analysis of optimized HLO.

``compiled.cost_analysis()`` counts a while-loop body ONCE, which undercounts
scan-over-layers models by ~n_layers x. This module parses the optimized HLO
text instead and weights every op by the product of enclosing
``known_trip_count``s (propagated through the call graph from ENTRY):

  * FLOPs     : dot ops — 2 * |result| * (contraction size from the lhs
                def-site shape); convolutions likewise if present.
  * HBM bytes : sum of materialized result bytes + parameter reads (fusion
                internals excluded — fusion boundaries are the
                materialization points). An estimate, documented as such.
  * collective link-bytes : per-op ring-transfer factors (see
                launch/analysis.py) weighted by trip counts.

This is the profile source for EXPERIMENTS.md §Roofline (no hardware here).
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"')
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_IOTA_RG_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_RG_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")


def _type_info(type_str: str) -> Tuple[int, List[List[int]]]:
    """bytes and list of dim-lists for a (possibly tuple) type string."""
    total = 0
    shapes = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dl = [int(d) for d in dims.split(",") if d] if dims else []
        n = int(math.prod(dl)) if dl else 1
        total += n * _DTYPE_BYTES[dt]
        shapes.append(dl)
    return total, shapes


class Op:
    __slots__ = ("name", "type_str", "kind", "rest", "bytes", "shapes")

    def __init__(self, name, type_str, kind, rest):
        self.name, self.type_str, self.kind, self.rest = (name, type_str,
                                                          kind, rest)
        self.bytes, self.shapes = _type_info(type_str)


def parse_module(hlo: str) -> Tuple[Dict[str, List[Op]], str]:
    comps: Dict[str, List[Op]] = defaultdict(list)
    entry = None
    cur = None
    for line in hlo.splitlines():
        if line and not line[0].isspace():
            if line.startswith("ENTRY"):
                m = _COMP_RE.match(line)
                if m:
                    cur = m.group(1)
                    entry = cur
                continue
            m = _COMP_RE.match(line)
            if m and "{" in line:
                cur = m.group(1)
            continue
        if cur is None:
            continue
        m = _NAME_RE.match(line)
        if not m:
            continue
        rest = line[m.end():]
        # type: either a parenthesized tuple (may contain /*index=N*/
        # comments) or a single token up to the first space
        if rest.startswith("("):
            depth, i = 0, 0
            for i, ch in enumerate(rest):
                depth += (ch == "(") - (ch == ")")
                if depth == 0:
                    break
            type_str, tail = rest[:i + 1], rest[i + 1:].lstrip()
        else:
            sp = rest.find(" ")
            if sp < 0:
                continue
            type_str, tail = rest[:sp], rest[sp + 1:]
        par = tail.find("(")
        if par < 0:
            continue
        kind = tail[:par].strip().lstrip("%")
        comps[cur].append(Op(m.group(1), type_str, kind, tail[par + 1:]))
    return comps, entry


def _weights(comps: Dict[str, List[Op]], entry: str) -> Dict[str, float]:
    """Execution count of each computation, propagating trip counts."""
    w: Dict[str, float] = defaultdict(float)
    w[entry] = 1.0
    # topological propagation: repeatedly relax (HLO call graphs are DAGs)
    changed = True
    seen_edges = {}
    for name, ops in comps.items():
        edges = []
        for op in ops:
            if op.kind == "while":
                trips = 1
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trips = int(tm.group(1))
                bm = _BODY_RE.search(op.rest)
                if bm:
                    edges.append((bm.group(1), float(trips)))
            elif op.kind in ("fusion", "call", "custom-call", "map",
                             "reduce", "sort", "scatter", "conditional"):
                for cm in _CALLS_RE.finditer(op.rest):
                    edges.append((cm.group(1), 1.0))
        seen_edges[name] = edges
    for _ in range(64):
        changed = False
        for name, edges in seen_edges.items():
            if w.get(name, 0) == 0:
                continue
            for child, mult in edges:
                nv = w[name] * mult
                if w.get(child, 0) < nv:
                    w[child] = nv
                    changed = True
        if not changed:
            break
    return w


def _dot_flops(op: Op, symtab: Dict[str, Op]) -> float:
    _, rshapes = _type_info(op.type_str)
    if not rshapes:
        return 0.0
    out_elems = math.prod(rshapes[0]) if rshapes[0] else 1
    cm = _CDIMS_RE.search(op.rest)
    operands = _OPERANDS_RE.findall(op.rest.split(", lhs_")[0])
    csize = 1
    if cm and operands:
        lhs = symtab.get(operands[0])
        if lhs and lhs.shapes:
            dims = lhs.shapes[0]
            for ci in (int(x) for x in cm.group(1).split(",") if x):
                if ci < len(dims):
                    csize *= dims[ci]
    return 2.0 * out_elems * csize


def _group_size(rest: str, default: int) -> int:
    m = _IOTA_RG_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _LIST_RG_RE.search(rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def _collective_link_bytes(kind: str, nbytes: int, g: int) -> float:
    if kind.startswith("all-gather"):
        return nbytes * (g - 1) / max(g, 1)
    if kind.startswith("all-reduce"):
        return 2.0 * nbytes * (g - 1) / max(g, 1)
    if kind.startswith("reduce-scatter"):
        return float(nbytes) * (g - 1)
    if kind.startswith("all-to-all"):
        return nbytes * (g - 1) / max(g, 1)
    return float(nbytes)  # collective-permute


def analyze(hlo: str, default_group: int = 16) -> dict:
    comps, entry = parse_module(hlo)
    w = _weights(comps, entry)

    # computations that are fusion/reducer bodies never touch HBM themselves
    fusion_bodies = set()
    for ops in comps.values():
        for op in ops:
            if op.kind in ("fusion", "reduce", "scatter", "sort", "map",
                           "custom-call"):
                for cm in _CALLS_RE.finditer(op.rest):
                    fusion_bodies.add(cm.group(1))

    flops = 0.0
    bytes_hbm = 0.0
    coll_bytes = 0.0
    coll_ops: List[dict] = []
    per_kind = defaultdict(float)

    for name, ops in comps.items():
        weight = w.get(name, 0.0)
        if weight == 0.0:
            continue
        in_fusion = name in fusion_bodies
        symtab = {op.name: op for op in ops}
        for op in ops:
            if op.kind == "dot":
                flops += weight * _dot_flops(op, symtab)
            elif op.kind in ("convolution",):
                flops += weight * 2 * op.bytes  # rough; none in our models
            kind = op.kind
            if any(kind == c or kind.startswith(c + "-") for c in
                   _COLLECTIVES):
                if kind.endswith("-done"):
                    continue
                g = _group_size(op.rest, default_group)
                nbytes = op.bytes
                if kind.endswith("-start"):
                    nbytes = nbytes // 2  # (operand, result) tuple
                base = kind.split("-start")[0]
                link = _collective_link_bytes(base, nbytes, g)
                coll_bytes += weight * link
                per_kind[base] += weight * link
                coll_ops.append({"kind": base, "bytes": nbytes, "group": g,
                                 "weight": weight,
                                 "link_bytes": weight * link})
            # HBM traffic estimate: materialized results of non-fusion-internal
            # computations + ENTRY parameter reads (fusion internals stay in
            # VREGs). Parameters of while bodies are NOT re-read wholesale
            # every iteration — the loop reads dynamic slices, whose result
            # bytes are already counted — so only the entry's count.
            if in_fusion:
                continue
            if op.kind == "parameter":
                if name == entry:
                    bytes_hbm += weight * op.bytes
            elif op.kind not in ("tuple", "get-tuple-element", "constant",
                                 "while", "bitcast"):
                bytes_hbm += weight * op.bytes

    return {
        "flops": flops,
        "bytes": bytes_hbm,
        "collective_bytes": coll_bytes,
        "collective_by_kind": dict(per_kind),
        "n_collective_sites": len(coll_ops),
        "top_collectives": sorted(coll_ops, key=lambda o: -o["link_bytes"])[:8],
    }
