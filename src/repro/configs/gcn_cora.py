"""gcn-cora [gnn] 2L d_hidden=16 aggregator=mean norm=sym [arXiv:1609.02907].

The same 2-layer GCN runs four graph regimes (per the assignment, the arch is
gcn-cora at every shape): cora full-batch, reddit-scale sampled minibatch
(real neighbor sampler, fanout 15-10), ogbn-products full-batch, and
block-diagonal batched small molecule graphs.
"""
import jax.numpy as jnp

from repro.models.gcn import GCNConfig
from .registry import ArchSpec, ShapeCell, register


def make_config(shape: str = "full_graph_sm", dtype=jnp.float32) -> GCNConfig:
    feat = {"full_graph_sm": 1433, "minibatch_lg": 602,
            "ogb_products": 100, "molecule": 32}[shape]
    ncls = {"full_graph_sm": 7, "minibatch_lg": 41,
            "ogb_products": 47, "molecule": 16}[shape]
    return GCNConfig(name="gcn-cora", n_layers=2, d_feat=feat, d_hidden=16,
                     n_classes=ncls, aggregator="mean", dtype=dtype)


def make_smoke_config() -> GCNConfig:
    return GCNConfig(name="gcn-smoke", n_layers=2, d_feat=32, d_hidden=8,
                     n_classes=4)


SHAPES = {
    "full_graph_sm": ShapeCell("train", {
        "n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    "minibatch_lg": ShapeCell("train_sampled", {
        "n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024,
        "fanout0": 15, "fanout1": 10, "d_feat": 602}),
    "ogb_products": ShapeCell("train", {
        "n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100}),
    "molecule": ShapeCell("train", {
        # block-diagonal batch of 128 graphs x (30 nodes, 64 edges)
        "n_nodes": 30 * 128, "n_edges": 64 * 128, "d_feat": 32}),
}

SPEC = register(ArchSpec(
    name="gcn-cora", family="gnn", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=SHAPES, optimizer="adamw",
    model_flops_params={"n_params": 23e3, "moe": False},
    notes="EMVB inapplicable (no query-vs-corpus MaxSim stage); "
          "implemented without the technique per DESIGN.md §5"))
