"""dlrm-mlperf [recsys] — MLPerf DLRM benchmark config (Criteo 1TB)
[arXiv:1906.00091]. n_dense=13 n_sparse=26 embed_dim=128
bot=13-512-256-128 top=1024-1024-512-256-1 interaction=dot.

Vocabulary sizes are the public MLPerf / Criteo-Terabyte per-field
cardinalities (~188M rows total -> 96 GB of fp32 tables: the reason tables
shard row-wise over ("data","model") = 256-way; DESIGN.md §4)."""
import jax.numpy as jnp

from repro.models.recsys.dlrm import DLRMConfig
from .registry import ArchSpec, recsys_shapes, register

CRITEO_1TB_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771, 25641295,
    39664984, 585935, 12972, 108, 36)


def make_config(dtype=jnp.float32, use_pq_tables: bool = False) -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-mlperf", n_dense=13, n_sparse=26, embed_dim=128,
        vocab_sizes=CRITEO_1TB_VOCABS, bot_mlp=(512, 256, 128),
        top_mlp=(1024, 1024, 512, 256, 1), nnz=1,
        use_pq_tables=use_pq_tables, dtype=dtype)


def make_smoke_config() -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-smoke", vocab_sizes=(64,) * 26, embed_dim=16,
        bot_mlp=(32, 16), top_mlp=(64, 1), nnz=2)


SPEC = register(ArchSpec(
    name="dlrm-mlperf", family="recsys", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=recsys_shapes(),
    optimizer="adagrad",
    model_flops_params={"n_params": 24.1e9, "moe": False},
    notes="EMVB C3 applies as optional PQ-compressed tables; C1/C2/C4 "
          "inapplicable (score is MLP(dot-interactions), not MaxSim)"))
