"""dcn-v2 [recsys] n_dense=13 n_sparse=26 embed_dim=16 n_cross=3
mlp=1024-1024-512 interaction=cross [arXiv:2008.13535]."""
import jax.numpy as jnp

from repro.models.recsys.dcn import DCNConfig
from .dlrm_mlperf import CRITEO_1TB_VOCABS
from .registry import ArchSpec, recsys_shapes, register


def make_config(dtype=jnp.float32) -> DCNConfig:
    return DCNConfig(
        name="dcn-v2", n_dense=13, n_sparse=26, embed_dim=16,
        vocab_sizes=CRITEO_1TB_VOCABS, n_cross_layers=3,
        mlp_dims=(1024, 1024, 512), nnz=1, dtype=dtype)


def make_smoke_config() -> DCNConfig:
    return DCNConfig(name="dcn-smoke", vocab_sizes=(64,) * 26, embed_dim=8,
                     n_cross_layers=2, mlp_dims=(32, 16), nnz=2)


SPEC = register(ArchSpec(
    name="dcn-v2", family="recsys", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=recsys_shapes(),
    optimizer="adagrad",
    model_flops_params={"n_params": 3.0e9, "moe": False},
    notes="EMVB inapplicable to the cross-network score; PQ-table option "
          "shares the DLRM path"))
