"""mind [recsys] embed_dim=64 n_interests=4 capsule_iters=3
interaction=multi-interest [arXiv:1904.08030].

THE star cell for the paper (DESIGN.md §5): a MIND user is a multi-vector
query (4 interest capsules) and candidate scoring is MaxSim with n_q=4 —
``retrieval_cand`` (1M candidates) runs through the EMVB engine (bit-vector
prefilter + PQ late interaction over the item corpus)."""
import jax.numpy as jnp

from repro.models.recsys.mind import MINDConfig
from .registry import ArchSpec, recsys_shapes, register


def make_config(dtype=jnp.float32) -> MINDConfig:
    return MINDConfig(
        name="mind", vocab_items=1_000_000, embed_dim=64, n_interests=4,
        capsule_iters=3, seq_len=50, dtype=dtype)


def make_smoke_config() -> MINDConfig:
    return MINDConfig(name="mind-smoke", vocab_items=500, embed_dim=16,
                      n_interests=4, capsule_iters=2, seq_len=12)


SPEC = register(ArchSpec(
    name="mind", family="recsys", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=recsys_shapes(),
    optimizer="adamw",
    model_flops_params={"n_params": 64e6, "moe": False},
    notes="EMVB directly applicable (multi-interest == multi-vector); "
          "retrieval_cand uses the EMVB engine with n_q=4"))
