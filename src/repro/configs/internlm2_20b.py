"""internlm2-20b [dense] 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA. [arXiv:2403.17297]"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig
from .registry import ArchSpec, lm_shapes, register


def make_config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", n_layers=48, d_model=6144, n_heads=48,
        n_kv_heads=8, d_head=128, d_ff=16384, vocab=92544, qkv_bias=False,
        dtype=dtype, attn_q_chunk=1024, attn_kv_chunk=2048,
        remat_policy="full")


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b-smoke", n_layers=2, d_model=192, n_heads=6,
        n_kv_heads=2, d_head=32, d_ff=384, vocab=512, dtype=jnp.float32)


SPEC = register(ArchSpec(
    name="internlm2-20b", family="lm", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=lm_shapes(ga_train=4),
    optimizer="adamw",
    model_flops_params={"n_params": 19.9e9, "moe": False}))
