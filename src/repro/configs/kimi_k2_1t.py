"""kimi-k2-1t-a32b [moe] 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (expert)
vocab=163840, MoE 384 experts top-8 + 1 shared expert — trillion-param MoE
(paper-table config) [arXiv:2501 Kimi K2 tech report; unverified tier].

Trained with Muon (the model's actual optimizer) with bf16 momentum — one
state per param is what lets 1T params fit 512 x 16 GB in the train dry-run
(params 2 + grads 2 + momentum 2 = 6 bytes/param -> ~12.3 GB/chip; AdamW's
18 bytes/param would not fit. EXPERIMENTS.md §Dry-run)."""
import jax.numpy as jnp

from repro.models.layers import ModelConfig
from .registry import ArchSpec, lm_shapes, register


def make_config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
        n_kv_heads=8, d_head=128, d_ff=2048, vocab=163840, qkv_bias=False,
        n_experts=384, top_k=8, n_shared_experts=1, capacity_factor=1.0,
        dtype=dtype, attn_q_chunk=1024, attn_kv_chunk=2048)


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke", n_layers=2, d_model=128, n_heads=8,
        n_kv_heads=2, d_head=16, d_ff=64, vocab=512, n_experts=16, top_k=4,
        n_shared_experts=1, dtype=jnp.float32)


SPEC = register(ArchSpec(
    name="kimi-k2-1t-a32b", family="lm", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=lm_shapes(ga_train=8),
    optimizer="muon",
    model_flops_params={"n_params": 1.04e12, "n_active": 32.5e9, "moe": True}))
