"""granite-moe-1b-a400m [moe] 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig
from .registry import ArchSpec, lm_shapes, register


def make_config(dtype=jnp.bfloat16) -> ModelConfig:
    # vocab 49155 padded to 49168 (+13 rows) for even 16-way TP sharding —
    # standard vocab-padding practice (cf. Megatron/MaxText pad-to-128).
    return ModelConfig(
        name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=8, d_head=64, d_ff=512, vocab=49168, qkv_bias=False,
        n_experts=32, top_k=8, dtype=dtype,
        attn_q_chunk=1024, attn_kv_chunk=2048)


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=32, vocab=512, n_experts=8, top_k=2,
        dtype=jnp.float32)


SPEC = register(ArchSpec(
    name="granite-moe-1b-a400m", family="lm", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=lm_shapes(ga_train=1),
    optimizer="adamw", fsdp=False,   # 1.3B total: TP alone suffices
    model_flops_params={"n_params": 1.3e9, "n_active": 0.4e9, "moe": True}))
