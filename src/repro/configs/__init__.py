"""Assigned-architecture configs. ``registry.get(name)`` returns the ArchSpec."""
from . import registry  # noqa: F401
from .registry import get, names  # noqa: F401
