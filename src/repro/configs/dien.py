"""dien [recsys] embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80
interaction=augru [arXiv:1809.03672]. Item vocabulary set to 1M so the
``retrieval_cand`` shape (1M candidates) is in-vocabulary."""
import jax.numpy as jnp

from repro.models.recsys.dien import DIENConfig
from .registry import ArchSpec, recsys_shapes, register


def make_config(dtype=jnp.float32) -> DIENConfig:
    return DIENConfig(
        name="dien", vocab_items=1_000_000, vocab_cats=10_000, embed_dim=18,
        seq_len=100, gru_dim=108, mlp_dims=(200, 80), dtype=dtype)


def make_smoke_config() -> DIENConfig:
    return DIENConfig(name="dien-smoke", vocab_items=200, vocab_cats=20,
                      embed_dim=8, seq_len=12, gru_dim=16, mlp_dims=(32, 16))


SPEC = register(ArchSpec(
    name="dien", family="recsys", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=recsys_shapes(),
    optimizer="adagrad",
    model_flops_params={"n_params": 37e6, "moe": False},
    notes="AUGRU ranking head is not MaxSim -> EMVB filter inapplicable; "
          "retrieval_cand scores 1M candidates through the full model"))
