"""qwen2.5-3b [dense] 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA, QKV bias. [hf:Qwen/Qwen2.5-3B]"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig
from .registry import ArchSpec, lm_shapes, register


def make_config(dtype=jnp.bfloat16) -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", n_layers=36, d_model=2048, n_heads=16,
        n_kv_heads=2, d_head=128, d_ff=11008, vocab=151936, qkv_bias=True,
        dtype=dtype, attn_q_chunk=1024, attn_kv_chunk=2048)


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_head=32, d_ff=256, vocab=512, qkv_bias=True,
        dtype=jnp.float32)


SPEC = register(ArchSpec(
    name="qwen2.5-3b", family="lm", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=lm_shapes(ga_train=2),
    optimizer="adamw", fsdp=False,   # 3B: TP alone leaves ~2 GB/chip of state
    model_flops_params={"n_params": 3.09e9, "moe": False},
    notes="full-attention decode at 500k is linear-cost; run, not skipped"))
