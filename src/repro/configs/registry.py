"""Architecture registry: the 10 assigned architectures (+ EMVB's own
retrieval config) as selectable ``--arch`` entries.

Each ArchSpec bundles: full config (paper-exact numbers, dry-run only),
reduced smoke config (CPU tests), the arch's own shape set, per-shape step
kind, optimizer choice, and dry-run knobs (grad-accum microbatching,
chunked-attention sizes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    kind: str                 # train | prefill | decode | serve | retrieval
    dims: Dict[str, int]
    grad_accum: int = 1       # microbatch factor for the train dry-run


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str               # lm | gnn | recsys | retrieval
    make_config: Callable[..., Any]
    make_smoke_config: Callable[[], Any]
    shapes: Dict[str, ShapeCell]
    optimizer: str = "adamw"
    model_flops_params: Optional[dict] = None   # for 6*N*D roofline term
    # FSDP only where param+optimizer state exceed the per-chip budget under
    # pure TP; for small models it is pure collective overhead (§Perf)
    fsdp: bool = True
    notes: str = ""


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> ArchSpec:
    _ensure_loaded()
    return _REGISTRY[name]


def names() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


_loaded = False


def _ensure_loaded():
    global _loaded
    if _loaded:
        return
    from . import (dcn_v2, dien, dlrm_mlperf, emvb_msmarco, gcn_cora,  # noqa
                   granite_moe_1b, internlm2_20b, kimi_k2_1t, mind,
                   qwen2p5_32b, qwen2p5_3b)
    _loaded = True


# ---------------------------------------------------------------------------
# shared shape sets
# ---------------------------------------------------------------------------

def lm_shapes(*, ga_train: int = 1) -> Dict[str, ShapeCell]:
    """The LM-family shape set: seq_len x global_batch per the assignment."""
    return {
        "train_4k": ShapeCell("train", {"seq": 4096, "batch": 256},
                              grad_accum=ga_train),
        "prefill_32k": ShapeCell("prefill", {"seq": 32768, "batch": 32}),
        "decode_32k": ShapeCell("decode", {"seq": 32768, "batch": 128}),
        "long_500k": ShapeCell("decode", {"seq": 524288, "batch": 1}),
    }


def recsys_shapes(n_items_retrieval: int = 1_000_000) -> Dict[str, ShapeCell]:
    return {
        "train_batch": ShapeCell("train", {"batch": 65536}),
        "serve_p99": ShapeCell("serve", {"batch": 512}),
        "serve_bulk": ShapeCell("serve", {"batch": 262144}),
        "retrieval_cand": ShapeCell("retrieval",
                                    {"batch": 1,
                                     "n_candidates": n_items_retrieval}),
    }
