"""qwen2.5-32b [dense] 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA, QKV bias. [hf:Qwen/Qwen2.5-32B]

40 heads do not divide the 16-way model axis: GSPMD pads the head dim
(recorded in EXPERIMENTS.md — an honest cost of this public config on a
16x16 mesh)."""
import jax.numpy as jnp

from repro.models.layers import ModelConfig
from .registry import ArchSpec, lm_shapes, register


def make_config(dtype=jnp.bfloat16) -> ModelConfig:
    # chunk sizes: §Perf iteration 2 — flash carry HBM traffic scales with
    # (s / kv_chunk); 2048/4096 halves the carry term vs 1024/2048.
    return ModelConfig(
        name="qwen2.5-32b", n_layers=64, d_model=5120, n_heads=40,
        n_kv_heads=8, d_head=128, d_ff=27648, vocab=152064, qkv_bias=True,
        dtype=dtype, attn_q_chunk=2048, attn_kv_chunk=4096,
        remat_policy="full")


def make_smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b-smoke", n_layers=2, d_model=160, n_heads=5,
        n_kv_heads=1, d_head=32, d_ff=320, vocab=512, qkv_bias=True,
        dtype=jnp.float32)


SPEC = register(ArchSpec(
    name="qwen2.5-32b", family="lm", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=lm_shapes(ga_train=4),
    optimizer="adamw",
    model_flops_params={"n_params": 32.8e9, "moe": False}))
