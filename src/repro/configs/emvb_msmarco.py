"""EMVB's own production retrieval config (MS MARCO scale, paper §5):
8.8M passages, ~600M token embeddings (d=128), |C| = 2^18 centroids,
PQ m=16/32 nbits=8, n_q=32. This is the paper's system as a dry-run arch
("--arch emvb-msmarco"), sharded per DESIGN.md §4 (docs over all mesh axes,
centroids/PQ replicated, two-level top-k merge)."""
import dataclasses

from repro.core.engine import EngineConfig
from .registry import ArchSpec, ShapeCell, register


@dataclasses.dataclass(frozen=True)
class EMVBProdConfig:
    name: str = "emvb-msmarco"
    n_docs: int = 8_841_823          # MS MARCO passage count
    doc_cap: int = 80                # padded tokens/passage (avg ~67)
    d: int = 128
    n_centroids: int = 1 << 18
    m: int = 16
    nbits: int = 8
    list_cap: int = 4096
    engine: EngineConfig = EngineConfig(
        n_q=32, nprobe=4, th=0.4, th_r=0.5, n_filter=1024, n_docs=256,
        k=100)
    # cs_dtype="bfloat16" (paper §6 reduced precision) halves CS traffic on
    # real TPUs; on the CPU dry-run backend bf16 is promoted to f32 and the
    # convert copies ADD 42% bytes — measured+refuted in §Perf, left off.


def make_config() -> EMVBProdConfig:
    return EMVBProdConfig()


def make_smoke_config() -> EMVBProdConfig:
    return EMVBProdConfig(
        name="emvb-smoke", n_docs=512, doc_cap=24, n_centroids=128, m=8,
        nbits=4, list_cap=64,
        engine=EngineConfig(n_q=32, nprobe=4, th=0.3, th_r=0.4, n_filter=64,
                            n_docs=16, k=10))


SHAPES = {
    "serve_b32": ShapeCell("retrieve", {"query_batch": 32}),
    "serve_b1": ShapeCell("retrieve", {"query_batch": 1}),
}

SPEC = register(ArchSpec(
    name="emvb-msmarco", family="retrieval", make_config=make_config,
    make_smoke_config=make_smoke_config, shapes=SHAPES, optimizer="adamw",
    model_flops_params={"n_params": 0, "moe": False},
    notes="the paper's own system; latency benchmarks in benchmarks/"))
