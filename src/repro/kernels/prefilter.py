"""Fused Pallas megakernel: bit-vector build + candidate masking + pre-filter
scoring + running top-n_filter selection (EMVB phases 1b-2 in ONE kernel).

The seed engine ran this as four kernels with full-corpus intermediates:

    bitpack(CS) -> bits        (n_c,)  HBM round-trip
    bitfilter(bits, codes)     (n_docs,) full-corpus f array in HBM
    where(bitmap, f, -1)       second full-corpus pass
    top_k(f, n_filter)         third full-corpus pass

This kernel streams document blocks once.  Grid step 0 packs the stacked bit
vectors from the (VMEM-resident) centroid-score matrix into an on-chip table;
every step then gathers the packed words for its (BD, cap) code block, masks
by token validity AND the candidate bitmap, popcounts (Eq. 4), and merges the
block's scores into a running top-``n_filter`` kept on chip.  Nothing of
size n_docs ever touches HBM — the only outputs are the (n_filter,) winners
and the (n_c,) bit table (a free byproduct kept for API compatibility).

Predicate filtering (docs/FILTERING.md) rides the same stream: each step
also loads its (BD,) slice of the index's packed predicate plane and — when
a static word-combine ``plan`` is given — ANDs the plan's verdict into the
candidate bitmap INSIDE the launch, so filtered docs are rejected in the
same pass that scores them (no host-side full-corpus pass mask).  The plan
is a static tuple of (required, forbidden) uint32 mask pairs, so distinct
filters trace distinct (still shape-stable) kernels; ``plan=None`` skips
the predicate load entirely and is bit-identical to the pre-predicate
kernel.

Selection is EXACTLY ``top_k(where(bitmap, F, -1), n_filter)`` including
tie-breaking: scores and doc ids are packed into one monotonic int32 key

    key = (f + 1) << ID_BITS  |  (MAX_ID - doc_id)

so "higher f, then lower doc id" is plain integer order and the running merge
is a single ``top_k`` over (n_filter + BD) keys.  f ranges over [-1, 32]
(34 values) which leaves ID_BITS = 25 id bits inside int32: up to 2^25
(~33.5M) documents per shard — far above any per-shard corpus slice here.

TPU notes: the grid is sequential, so the step-0 bit table and the running
keys live in revisited output blocks (the standard Pallas accumulator
pattern).  The merge's ``lax.top_k`` is the one op a Mosaic build would
replace with a bitonic merge over the 8x128 lanes; everything else is VPU
compare/shift/gather, same as the unfused kernels.  Interpret mode (CPU) is
the tier-1 validation target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.bitvector import apply_filter_plan

DEFAULT_BD = 256
ID_BITS = 25          # (f+1) <= 33 -> 34 << 25 < 2^31: int32-safe
MAX_ID = (1 << ID_BITS) - 1
KEY_INIT = -(2 ** 31)  # python int: jnp scalars would be captured as consts


def _prefilter_kernel(th_ref, cs_ref, qm_ref, codes_ref, mask_ref, bitmap_ref,
                      pred_ref, bits_ref, keys_ref, *, n_filter: int,
                      plan=None):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cs = cs_ref[...]                                    # (n_q, n_c)
        # Compare in the CS dtype (weak-typed-scalar semantics): for bf16 CS
        # the reference rounds th to bf16 before comparing; do the same here
        # so boundary values cannot flip bits between kernel and oracle.
        # Masked (padded / pruned) query terms pack a 0 bit for every
        # centroid, so the popcount below structurally cannot count them.
        live = qm_ref[...] != 0                             # (n_q, 1)
        m = ((cs > th_ref[0].astype(cs.dtype)) & live).astype(jnp.uint32)
        shifts = jax.lax.broadcasted_iota(jnp.uint32, (cs.shape[0], 1), 0)
        # Disjoint bit positions: sum == OR (same pack as kernels/bitpack.py).
        bits_ref[...] = jnp.sum(m << shifts, axis=0, keepdims=True)
        keys_ref[...] = jnp.full((1, n_filter), KEY_INIT, jnp.int32)

    bits = bits_ref[0, :]                                   # (n_c,) u32
    codes = codes_ref[...]                                  # (BD, cap)
    valid = mask_ref[...] != 0                              # (BD, cap)
    cand = bitmap_ref[0, :] != 0                            # (BD,)
    if plan is not None:
        # Predicate filter, fused into the candidate test: evaluate the
        # static word-combine plan on this block's predicate words.
        cand = cand & apply_filter_plan(plan, pred_ref[0, :])
    bd = codes.shape[0]

    idx = jnp.clip(codes, 0, bits.shape[0] - 1)
    words = jnp.take(bits, idx, axis=0)                     # (BD, cap) u32
    words = jnp.where(valid, words, jnp.uint32(0))
    ored = jax.lax.reduce(words, jnp.uint32(0), jax.lax.bitwise_or, (1,))
    f = jax.lax.population_count(ored).astype(jnp.int32)    # (BD,)
    f = jnp.where(cand, f, -1)

    ids = i * bd + jax.lax.broadcasted_iota(jnp.int32, (bd, 1), 0)[:, 0]
    keys = ((f + 1) << ID_BITS) + (MAX_ID - ids)
    merged = jnp.concatenate([keys_ref[0, :], keys])
    top, _ = jax.lax.top_k(merged, n_filter)
    keys_ref[...] = top[None, :]


def _prefilter_batched_kernel(th_ref, cs_ref, qm_ref, codes_ref, mask_ref,
                              bitmap_ref, pred_ref, bits_ref, keys_ref, *,
                              n_filter: int, plan=None):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cs = cs_ref[...]                                    # (B, n_q, n_c)
        # Same pack as the single-query kernel, vectorized over the leading
        # batch axis: per query b, bits[b] is bitwise identical to what
        # ``_prefilter_kernel`` packs from cs[b] / qm[b].
        live = qm_ref[...][..., None] != 0                  # (B, n_q, 1)
        m = ((cs > th_ref[0].astype(cs.dtype)) & live).astype(jnp.uint32)
        shifts = jax.lax.broadcasted_iota(
            jnp.uint32, (1, cs.shape[1], 1), 1)
        bits_ref[...] = jnp.sum(m << shifts, axis=1)        # (B, n_c)
        keys_ref[...] = jnp.full(
            (cs.shape[0], n_filter), KEY_INIT, jnp.int32)

    bits = bits_ref[...]                                    # (B, n_c)
    codes = codes_ref[...]                                  # (Bc, BD, cap)
    valid = mask_ref[...] != 0                              # (Bc, BD, cap)
    cand = bitmap_ref[...] != 0                             # (B, BD)
    if plan is not None:
        # The predicate plane is query-independent: ONE (BD,) word slice
        # serves every query in the batch.
        cand = cand & apply_filter_plan(plan, pred_ref[0, :])[None, :]
    nb, bd = cand.shape

    idx = jnp.clip(codes, 0, bits.shape[1] - 1)
    if codes.shape[0] == 1:
        # Shared corpus block (score_all mode): ONE codes slice serves every
        # query in the batch — the amortization the vmap path cannot do.
        words = jnp.take(bits, idx[0], axis=1)              # (B, BD, cap)
        words = jnp.where(valid[0][None], words, jnp.uint32(0))
    else:
        # Per-query candidate blocks (compact mode): row-aligned gather.
        words = jnp.take_along_axis(
            bits, idx.reshape(nb, -1), axis=1).reshape(idx.shape)
        words = jnp.where(valid, words, jnp.uint32(0))
    ored = jax.lax.reduce(words, jnp.uint32(0), jax.lax.bitwise_or, (2,))
    f = jax.lax.population_count(ored).astype(jnp.int32)    # (B, BD)
    f = jnp.where(cand, f, -1)

    ids = i * bd + jax.lax.broadcasted_iota(jnp.int32, (1, bd), 1)
    keys = ((f + 1) << ID_BITS) + (MAX_ID - ids)
    merged = jnp.concatenate([keys_ref[...], keys], axis=1)
    # Batched top_k reduces each row independently with the same
    # lowest-index tie-breaking as the single-query merge: row b of the
    # running keys is bitwise the single-query kernel's buffer for query b.
    top, _ = jax.lax.top_k(merged, n_filter)
    keys_ref[...] = top


@functools.partial(jax.jit,
                   static_argnames=("n_filter", "block_d", "plan",
                                    "interpret"))
def prefilter_batched(cs: jax.Array, th, codes: jax.Array,
                      token_mask: jax.Array, bitmap: jax.Array,
                      n_filter: int, q_masks: jax.Array | None = None, *,
                      pred_words: jax.Array | None = None, plan=None,
                      block_d: int = DEFAULT_BD,
                      interpret: bool = True) -> tuple[jax.Array, jax.Array,
                                                       jax.Array]:
    """Batch-native fused phases 1b-2: one launch for a whole micro-batch.

    cs         : (B, n_q, n_c) centroid scores per query (fp32 or bf16)
    th         : scalar bit-vector threshold (shared across the batch)
    codes      : (n_docs, cap) int32 — ONE corpus shared by every query
                 (score_all mode), or (B, n_docs, cap) per-query candidate
                 blocks (compact mode)
    token_mask : bool, same leading shape as ``codes``
    bitmap     : (B, n_docs) bool candidate bitmaps
    q_masks    : optional (B, n_q) bool per-query term masks
    pred_words : optional (n_docs,) uint32 packed predicate plane, shared
                 across the batch (query-independent)
    plan       : optional STATIC tuple of (required, forbidden) uint32 mask
                 pairs (``FilterPlan.clauses``); when given, each document
                 block's predicate words are tested in-kernel and the
                 verdict ANDed into ``bitmap``. ``None`` skips the predicate
                 load, bit-identical to the unfiltered kernel.
    -> (scores (B, n_filter) i32, doc_ids (B, n_filter) i32,
        bits (B, n_c) u32)

    Row b of every output is bit-identical to
    ``prefilter(cs[b], th, codes[b or :], ..., q_mask=q_masks[b])`` — ids
    AND score bits, including tie order.  Unlike ``jax.vmap(prefilter)``
    (which lifts the batch into an outer grid axis and re-slices the codes
    block per (query, block) step), this kernel walks the document stream
    ONCE: the (B, n_q, n_c) score table stays VMEM-resident and each
    (BD, cap) codes slice is scored for all B queries before the next
    block loads.
    """
    nb, n_q, n_c = cs.shape
    shared = codes.ndim == 2
    n_docs, cap = codes.shape[-2:]
    assert n_q <= 32, "stacked bitvector packs one query term per uint32 bit"
    assert n_filter <= n_docs, \
        f"n_filter={n_filter} exceeds the {n_docs} documents scored"
    assert n_docs <= MAX_ID, "int32 packed keys support up to 2^25 docs/shard"
    assert bitmap.shape == (nb, n_docs)
    pad = (-n_docs) % block_d
    if shared:
        codesp = jnp.pad(codes, ((0, pad), (0, 0)))[None]
        maskp = jnp.pad(token_mask.astype(jnp.int8), ((0, pad), (0, 0)))[None]
    else:
        codesp = jnp.pad(codes, ((0, 0), (0, pad), (0, 0)))
        maskp = jnp.pad(token_mask.astype(jnp.int8),
                        ((0, 0), (0, pad), (0, 0)))
    bmp = jnp.pad(bitmap.astype(jnp.int8), ((0, 0), (0, pad)))
    ndp = n_docs + pad
    bc = codesp.shape[0]
    th_arr = jnp.asarray([th], jnp.float32)
    qm = (jnp.ones((nb, n_q), jnp.int8) if q_masks is None
          else q_masks.astype(jnp.int8).reshape(nb, n_q))
    # Always pass a predicate operand (zeros dummy when unfiltered) so every
    # plan shares ONE pallas_call signature; plan=None never reads it.
    pw = (jnp.zeros((n_docs,), jnp.uint32) if pred_words is None
          else pred_words)
    pwp = jnp.pad(pw, (0, pad))[None, :]
    kern = functools.partial(_prefilter_batched_kernel, n_filter=n_filter,
                             plan=plan)
    bits, keys = pl.pallas_call(
        kern,
        grid=(ndp // block_d,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),              # th
            pl.BlockSpec((nb, n_q, n_c), lambda i: (0, 0, 0)),  # CS resident
            pl.BlockSpec((nb, n_q), lambda i: (0, 0)),       # q_masks
            pl.BlockSpec((bc, block_d, cap), lambda i: (0, i, 0)),
            pl.BlockSpec((bc, block_d, cap), lambda i: (0, i, 0)),
            pl.BlockSpec((nb, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),    # predicate plane
        ],
        out_specs=[
            pl.BlockSpec((nb, n_c), lambda i: (0, 0)),       # revisited accum
            pl.BlockSpec((nb, n_filter), lambda i: (0, 0)),  # revisited accum
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, n_c), jnp.uint32),
            jax.ShapeDtypeStruct((nb, n_filter), jnp.int32),
        ],
        interpret=interpret,
    )(th_arr, cs, qm, codesp, maskp, bmp, pwp)
    scores = (keys >> ID_BITS) - 1
    doc_ids = MAX_ID - (keys & MAX_ID)
    return scores.astype(jnp.int32), doc_ids.astype(jnp.int32), bits


@functools.partial(jax.jit,
                   static_argnames=("n_filter", "block_d", "plan",
                                    "interpret"))
def prefilter(cs: jax.Array, th, codes: jax.Array, token_mask: jax.Array,
              bitmap: jax.Array, n_filter: int,
              q_mask: jax.Array | None = None, *,
              pred_words: jax.Array | None = None, plan=None,
              block_d: int = DEFAULT_BD,
              interpret: bool = True) -> tuple[jax.Array, jax.Array,
                                               jax.Array]:
    """Fused phases 1b-2 for one query.

    cs         : (n_q, n_c) centroid scores (fp32 or bf16), n_q <= 32
    th         : scalar bit-vector threshold
    codes      : (n_docs, cap) int32 centroid id per token (padded)
    token_mask : (n_docs, cap) bool — True for real tokens
    bitmap     : (n_docs,) bool — candidate docs (IVF union)
    q_mask     : optional (n_q,) bool — masked (padded / pruned) query terms
                 pack a 0 bit, so F(P, q) never counts them (all-ones == no
                 mask, bit for bit)
    pred_words : optional (n_docs,) uint32 packed predicate plane
    plan       : optional STATIC ``FilterPlan.clauses`` tuple — when given,
                 the plan's verdict over ``pred_words`` is ANDed into
                 ``bitmap`` in-kernel (docs/FILTERING.md); ``None`` skips
                 the predicate load, bit-identical to the unfiltered kernel
    -> (scores (n_filter,) int32, doc_ids (n_filter,) int32,
        bits (n_c,) uint32)

    (scores, doc_ids) == ``lax.top_k(where(bitmap & pass, F, -1), n_filter)``
    bit-exactly, including index-order tie-breaking.
    """
    n_q, n_c = cs.shape
    n_docs, cap = codes.shape
    assert n_q <= 32, "stacked bitvector packs one query term per uint32 bit"
    assert n_filter <= n_docs, \
        f"n_filter={n_filter} exceeds the {n_docs} documents scored " \
        f"(compact mode: cand_cap is the document count)"
    assert n_docs <= MAX_ID, "int32 packed keys support up to 2^25 docs/shard"
    pad = (-n_docs) % block_d
    codesp = jnp.pad(codes, ((0, pad), (0, 0)))
    maskp = jnp.pad(token_mask.astype(jnp.int8), ((0, pad), (0, 0)))
    bmp = jnp.pad(bitmap.astype(jnp.int8), (0, pad))[None, :]
    ndp = n_docs + pad
    th_arr = jnp.asarray([th], jnp.float32)
    qm = (jnp.ones((n_q, 1), jnp.int8) if q_mask is None
          else q_mask.astype(jnp.int8).reshape(n_q, 1))
    # Zeros dummy when unfiltered: ONE pallas_call signature per shape, and
    # plan=None statically skips the read.
    pw = (jnp.zeros((n_docs,), jnp.uint32) if pred_words is None
          else pred_words)
    pwp = jnp.pad(pw, (0, pad))[None, :]
    kern = functools.partial(_prefilter_kernel, n_filter=n_filter, plan=plan)
    bits, keys = pl.pallas_call(
        kern,
        grid=(ndp // block_d,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),              # th
            pl.BlockSpec((n_q, n_c), lambda i: (0, 0)),      # CS resident
            pl.BlockSpec((n_q, 1), lambda i: (0, 0)),        # q_mask
            pl.BlockSpec((block_d, cap), lambda i: (i, 0)),
            pl.BlockSpec((block_d, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),
            pl.BlockSpec((1, block_d), lambda i: (0, i)),    # predicate plane
        ],
        out_specs=[
            pl.BlockSpec((1, n_c), lambda i: (0, 0)),        # revisited accum
            pl.BlockSpec((1, n_filter), lambda i: (0, 0)),   # revisited accum
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n_c), jnp.uint32),
            jax.ShapeDtypeStruct((1, n_filter), jnp.int32),
        ],
        interpret=interpret,
    )(th_arr, cs, qm, codesp, maskp, bmp, pwp)
    keys = keys[0]
    scores = (keys >> ID_BITS) - 1
    doc_ids = MAX_ID - (keys & MAX_ID)
    return scores.astype(jnp.int32), doc_ids.astype(jnp.int32), bits[0]
