"""Pallas kernel: column-wise centroid interaction (EMVB C2, Eq. 2).

cs_t (n_c, n_q) fp32, codes (docs, cap) int32 -> S̄ (docs,) fp32
    S̄[p] = sum_i max_t cs_t[codes[p, t], i]

TPU schedule (mirrors paper §4.3, adapted): the paper transposes CS so the
reduction walks contiguous memory and max-reduces with AVX512 compare+blend;
here rows of CS^T are gathered into a (BD*cap, n_q) VMEM block and the
token-axis max is a VPU ``maximum`` accumulation (compare+select), with the
final n_q-sum an 8x128 cross-lane reduce.

VMEM contract: cs_t must fit in VMEM. At |C|=2^18, n_q=32 this is 32 MiB fp32
— larger than a v5e core's VMEM, which is exactly why the production config
shards the centroid axis 16-way over the model axis (local table 2 MiB); see
DESIGN.md §4. The kernel is written against the local shard.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.interaction import term_sum

DEFAULT_BD = 128
NEG = -1e9


def sbar_block(cs_t: jax.Array, codes: jax.Array, valid: jax.Array,
               qlive: jax.Array | None = None) -> jax.Array:
    """S̄ for one (BD, cap) block: cs_t (n_c, n_q), valid bool -> (BD,).

    qlive optional (n_q,) bool: masked (padded / pruned) query terms
    contribute 0 to the sum instead of a spurious per-term max (exactly the
    jnp reference's zeroing — adding 0.0 is fp-exact, so the all-live mask
    is the identity).

    Shared by this kernel and the pass-1 stream of ``pqinter.py`` — the
    gather/mask/max/sum order here is the SAME one the jnp reference
    (``interaction.centroid_interaction``) uses, which is what keeps kernel
    S̄ (and therefore phase-3 selection order) bitwise equal to it. Keep the
    three in lockstep."""
    idx = jnp.clip(codes, 0, cs_t.shape[0] - 1)
    pt = jnp.take(cs_t, idx, axis=0)                       # (BD, cap, n_q)
    pt = jnp.where(valid[..., None], pt, NEG)
    colmax = jnp.max(pt, axis=1)                           # (BD, n_q)
    if qlive is not None:
        colmax = jnp.where(qlive, colmax, 0.0)
    return term_sum(colmax)                                # (BD,)


def sbar_block_batched(cs_t: jax.Array, codes: jax.Array, valid: jax.Array,
                       qlive: jax.Array) -> jax.Array:
    """Batched ``sbar_block``: cs_t (B, n_c, n_q), codes/valid (B, BD, cap),
    qlive (B, n_q) -> (B, BD).

    Row b is bitwise equal to ``sbar_block(cs_t[b], codes[b], valid[b],
    qlive[b])`` — the gather/mask/max/sum sequence is the same per-query
    computation vectorized over a leading batch axis (``take_along_axis``
    gathers the same rows ``jnp.take`` does per query; the max and the
    ``term_sum`` chain reduce each row independently in the same order).
    Used by the pass-1 stream of the batched ``pqinter`` kernel — keep in
    lockstep with ``sbar_block`` and the jnp reference."""
    nb, bd, cap = codes.shape
    n_q = cs_t.shape[2]
    idx = jnp.clip(codes, 0, cs_t.shape[1] - 1)
    pt = jnp.take_along_axis(cs_t, idx.reshape(nb, bd * cap, 1), axis=1)
    pt = pt.reshape(nb, bd, cap, n_q)
    pt = jnp.where(valid[..., None], pt, NEG)
    colmax = jnp.max(pt, axis=2)                           # (B, BD, n_q)
    colmax = jnp.where(qlive[:, None, :], colmax, 0.0)
    return term_sum(colmax)                                # (B, BD)


def _cinter_kernel(cs_t_ref, codes_ref, mask_ref, qm_ref, out_ref):
    cs_t = cs_t_ref[...]                                   # (n_c, n_q)
    codes = codes_ref[...]                                 # (BD, cap)
    valid = mask_ref[...] != 0                             # (BD, cap) int8
    qlive = qm_ref[0, :] != 0                              # (n_q,)
    out_ref[...] = sbar_block(cs_t, codes, valid, qlive)[None, :]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def cinter(cs_t: jax.Array, codes: jax.Array, token_mask: jax.Array,
           q_mask: jax.Array | None = None, *,
           block_d: int = DEFAULT_BD, interpret: bool = True) -> jax.Array:
    """cs_t (n_c, n_q); codes/token_mask (docs, cap) -> (docs,) fp32.
    q_mask optional (n_q,) bool — masked terms are excluded from S̄."""
    n_docs, cap = codes.shape
    n_c, n_q = cs_t.shape
    pad = (-n_docs) % block_d
    codesp = jnp.pad(codes, ((0, pad), (0, 0)))
    maskp = jnp.pad(token_mask.astype(jnp.int8), ((0, pad), (0, 0)))
    ndp = n_docs + pad
    qm = (jnp.ones((1, n_q), jnp.int8) if q_mask is None
          else q_mask.astype(jnp.int8).reshape(1, n_q))
    out = pl.pallas_call(
        _cinter_kernel,
        grid=(ndp // block_d,),
        in_specs=[
            pl.BlockSpec((n_c, n_q), lambda i: (0, 0)),          # resident
            pl.BlockSpec((block_d, cap), lambda i: (i, 0)),
            pl.BlockSpec((block_d, cap), lambda i: (i, 0)),
            pl.BlockSpec((1, n_q), lambda i: (0, 0)),            # q_mask
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, ndp), jnp.float32),
        interpret=interpret,
    )(cs_t, codesp, maskp, qm)
    return out[0, :n_docs]
