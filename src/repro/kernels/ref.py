"""Pure-jnp oracles for every Pallas kernel in this package.

These delegate to the reference math in ``repro.core`` (which is itself pure
jnp and tested end-to-end), so kernels and engine are checked against one
single source of truth. Every oracle mirrors its kernel's optional
``q_mask`` (query-term mask; True = live term) so masked sweeps check the
same contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitvector as _bv
from repro.core import interaction as _ia
from repro.core.pq import PQCodebooks, build_lut  # noqa: F401  (test helper)


def bitpack(cs: jax.Array, th: float,
            q_mask: jax.Array | None = None) -> jax.Array:
    """cs (n_q, n_c), th -> (n_c,) uint32."""
    return _bv.build_bitvectors(cs, th, q_mask)


def bitfilter(bits: jax.Array, codes: jax.Array,
              token_mask: jax.Array) -> jax.Array:
    """bits (n_c,) u32; codes/mask (docs, cap) -> (docs,) int32.
    No q_mask: masked terms are already 0 bits in ``bits``."""
    return _bv.filter_score(bits, codes, token_mask)


def cinter(cs_t: jax.Array, codes: jax.Array, token_mask: jax.Array,
           q_mask: jax.Array | None = None) -> jax.Array:
    """cs_t (n_c, n_q); codes/mask (docs, cap) -> (docs,) fp32."""
    return _ia.centroid_interaction(cs_t, codes, token_mask, q_mask)


def pqscore(cs_t: jax.Array, lut: jax.Array, codes: jax.Array,
            res_codes: jax.Array, token_mask: jax.Array,
            th_r: float | None,
            q_mask: jax.Array | None = None) -> jax.Array:
    """Fused PQ late interaction oracle -> (docs,) fp32."""
    return _ia.late_interaction_pq(cs_t, lut, codes, res_codes, token_mask,
                                   th_r, q_mask=q_mask)


def pqinter(cs_t: jax.Array, lut: jax.Array, codes: jax.Array,
            res_codes: jax.Array, token_mask: jax.Array,
            th_r: float | None, n_docs: int, k: int,
            q_mask: jax.Array | None = None) -> tuple[
                jax.Array, jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused phases 3-4 megakernel: centroid interaction ->
    top-n_docs -> PQ late interaction (Eq. 5/6) -> top-k, composed exactly
    like the unfused engine. -> (scores (k,) f32, pos (k,) i32,
    sel2 (n_docs,) i32, sbar (n_docs,) f32); positions index the survivor
    axis, both selections in ``lax.top_k`` order (ties: lowest first)."""
    sbar = _ia.centroid_interaction(cs_t, codes, token_mask, q_mask)
    sbar2, sel2 = jax.lax.top_k(sbar, n_docs)
    scores = _ia.late_interaction_pq(
        cs_t, lut, jnp.take(codes, sel2, axis=0),
        jnp.take(res_codes, sel2, axis=0),
        jnp.take(token_mask, sel2, axis=0), th_r, q_mask=q_mask)
    top_s, top_local = jax.lax.top_k(scores, k)
    return (top_s, jnp.take(sel2, top_local).astype(jnp.int32),
            sel2.astype(jnp.int32), sbar2.astype(jnp.float32))


def prefilter(cs: jax.Array, th, codes: jax.Array, token_mask: jax.Array,
              bitmap: jax.Array, n_filter: int,
              q_mask: jax.Array | None = None) -> tuple[jax.Array,
                                                        jax.Array]:
    """Oracle for the fused phases 1b-2 megakernel: bitpack -> Eq. 4 filter
    -> candidate masking -> top-n_filter.  -> (scores, doc_ids), both
    (n_filter,) int32, in ``lax.top_k`` order (ties: lowest doc id first)."""
    bits = _bv.build_bitvectors(cs, th, q_mask)
    f = _bv.filter_score(bits, codes, token_mask)
    f = jnp.where(bitmap, f, -1)
    scores, ids = jax.lax.top_k(f, n_filter)
    return scores.astype(jnp.int32), ids.astype(jnp.int32)
