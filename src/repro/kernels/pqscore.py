"""Pallas kernel: fused PQ late interaction with dynamic term filter
(EMVB C3+C4, Eq. 5/6).

Per document tile, entirely in VMEM:
    score[p] = sum_i max_{t in J̄_i} ( cs_t[codes[p,t], i]            (centroid)
                                     + sum_s lut[i, s, res[p,t,s]] )  (residual)
with J̄_i = {t : centroid > th_r} and the Eq. 5 fallback when J̄_i = ∅.

This is the paper's core §4.4 claim made structural: the PQ LUT
(n_q x m x 256 fp32 = 0.5–1 MiB) and the centroid-score table live in VMEM,
token codes stream HBM->VMEM once, and **no decompressed residual ever touches
HBM** — the 5x decompression cost in PLAID's Fig. 1 simply has no analogue.
The m-subspace accumulation is a static unrolled loop so the intermediate is
one (BD, cap, n_q) block rather than a 4-D tensor.

VMEM contract: same as ``cinter`` — cs_t is the per-shard slice at production
scale (DESIGN.md §4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.interaction import term_sum

DEFAULT_BD = 32
NEG = -1e9


def eq56_block(cs_t: jax.Array, lut2: jax.Array, codes: jax.Array,
               res: jax.Array, valid: jax.Array, thr: jax.Array, *,
               m: int, ksub: int, use_filter: bool,
               qlive: jax.Array | None = None) -> jax.Array:
    """Eq. 5/6 PQ late-interaction scores for one (BD, cap) block -> (BD,).

    cs_t (n_c, n_q); lut2 (m*K, n_q) flattened LUT; res (BD, cap, m) any int
    dtype; valid (BD, cap) bool; thr scalar (ignored unless ``use_filter``).
    qlive optional (n_q,) bool: masked (padded / pruned) query terms
    contribute 0 to the final sum — no per-term max, no Eq. 6 fallback —
    mirroring the reference's zeroing (fp-exact; all-live is the identity).

    Shared by this kernel and the pass-2 stream of ``pqinter.py``. The
    subspace accumulation is the SAME static unroll, in the SAME s = 0..m-1
    order, as the jnp reference ``interaction._lut_gather`` — identical
    reduction order is what keeps kernel scores bitwise equal to the
    reference (and the unroll keeps the intermediate one (BD, cap, n_q)
    block instead of a 4-D tensor). The Eq. 6 threshold comparison happens
    in the centroid dtype, matching the reference's weak-typed-scalar
    semantics under bf16 CS. Keep the three in lockstep."""
    idx = jnp.clip(codes, 0, cs_t.shape[0] - 1)
    centroid = jnp.take(cs_t, idx, axis=0)                  # (BD, cap, n_q)
    res32 = res.astype(jnp.int32)
    residual = jnp.take(lut2, res32[..., 0], axis=0)        # (BD, cap, n_q)
    for s in range(1, m):                                   # static unroll
        residual = residual + jnp.take(lut2, res32[..., s] + s * ksub,
                                       axis=0)
    full = jnp.where(valid[..., None], centroid + residual, NEG)
    if use_filter:
        keep = (centroid > thr.astype(centroid.dtype)) & valid[..., None]
        masked_max = jnp.max(jnp.where(keep, full, NEG), axis=1)
        full_max = jnp.max(full, axis=1)
        any_keep = jnp.any(keep, axis=1)
        colmax = jnp.where(any_keep, masked_max, full_max)  # (BD, n_q)
    else:
        colmax = jnp.max(full, axis=1)
    if qlive is not None:
        colmax = jnp.where(qlive, colmax, 0.0)
    return term_sum(colmax)


def eq56_block_batched(cs_t: jax.Array, lut2: jax.Array, codes: jax.Array,
                       res: jax.Array, valid: jax.Array, thr: jax.Array, *,
                       m: int, ksub: int, use_filter: bool,
                       qlive: jax.Array) -> jax.Array:
    """Batched ``eq56_block``: cs_t (B, n_c, n_q), lut2 (B, m*K, n_q),
    codes/valid (B, BD, cap), res (B, BD, cap, m), qlive (B, n_q) -> (B, BD).

    Row b is bitwise equal to ``eq56_block(cs_t[b], lut2[b], ...)``: the
    subspace accumulation is the SAME static unroll in the SAME s = 0..m-1
    order (per-row gathers via ``take_along_axis`` fetch the rows
    ``jnp.take`` fetches per query), the Eq. 6 comparison happens in the
    centroid dtype, and the max/``term_sum`` reductions act per row.  Used
    by the pass-2 stream of the batched ``pqinter`` kernel — keep in
    lockstep with ``eq56_block`` and the jnp reference."""
    nb, bd, cap = codes.shape
    n_q = cs_t.shape[2]
    idx = jnp.clip(codes, 0, cs_t.shape[1] - 1)
    centroid = jnp.take_along_axis(
        cs_t, idx.reshape(nb, bd * cap, 1), axis=1).reshape(nb, bd, cap, n_q)
    res32 = res.astype(jnp.int32)

    def _gather(sub):
        return jnp.take_along_axis(
            lut2, sub.reshape(nb, bd * cap, 1),
            axis=1).reshape(nb, bd, cap, n_q)

    residual = _gather(res32[..., 0])
    for s in range(1, m):                                   # static unroll
        residual = residual + _gather(res32[..., s] + s * ksub)
    full = jnp.where(valid[..., None], centroid + residual, NEG)
    if use_filter:
        keep = (centroid > thr.astype(centroid.dtype)) & valid[..., None]
        masked_max = jnp.max(jnp.where(keep, full, NEG), axis=2)
        full_max = jnp.max(full, axis=2)
        any_keep = jnp.any(keep, axis=2)
        colmax = jnp.where(any_keep, masked_max, full_max)  # (B, BD, n_q)
    else:
        colmax = jnp.max(full, axis=2)
    colmax = jnp.where(qlive[:, None, :], colmax, 0.0)
    return term_sum(colmax)


def _pqscore_kernel(cs_t_ref, lut2_ref, codes_ref, res_ref, mask_ref, thr_ref,
                    qm_ref, out_ref, *, m: int, ksub: int, use_filter: bool):
    scores = eq56_block(cs_t_ref[...], lut2_ref[...], codes_ref[...],
                        res_ref[...], mask_ref[...] != 0, thr_ref[0],
                        m=m, ksub=ksub, use_filter=use_filter,
                        qlive=qm_ref[0, :] != 0)
    out_ref[...] = scores[None, :]


@functools.partial(jax.jit,
                   static_argnames=("th_r", "block_d", "interpret"))
def pqscore(cs_t: jax.Array, lut: jax.Array, codes: jax.Array,
            res_codes: jax.Array, token_mask: jax.Array,
            th_r: float | None, q_mask: jax.Array | None = None, *,
            block_d: int = DEFAULT_BD, interpret: bool = True) -> jax.Array:
    """cs_t (n_c, n_q); lut (n_q, m, K); codes (docs, cap);
    res_codes (docs, cap, m) uint8 -> (docs,) fp32 final scores.
    q_mask optional (n_q,) bool — masked terms contribute nothing."""
    n_docs, cap = codes.shape
    n_c, n_q = cs_t.shape
    _, m, ksub = lut.shape
    pad = (-n_docs) % block_d
    codesp = jnp.pad(codes, ((0, pad), (0, 0)))
    resp = jnp.pad(res_codes.astype(jnp.int32), ((0, pad), (0, 0), (0, 0)))
    maskp = jnp.pad(token_mask.astype(jnp.int8), ((0, pad), (0, 0)))
    ndp = n_docs + pad
    lut2 = lut.transpose(1, 2, 0).reshape(m * ksub, n_q)
    thr = jnp.asarray([0.0 if th_r is None else th_r], jnp.float32)
    qm = (jnp.ones((1, n_q), jnp.int8) if q_mask is None
          else q_mask.astype(jnp.int8).reshape(1, n_q))

    kern = functools.partial(_pqscore_kernel, m=m, ksub=ksub,
                             use_filter=th_r is not None)
    out = pl.pallas_call(
        kern,
        grid=(ndp // block_d,),
        in_specs=[
            pl.BlockSpec((n_c, n_q), lambda i: (0, 0)),          # resident
            pl.BlockSpec((m * ksub, n_q), lambda i: (0, 0)),     # resident LUT
            pl.BlockSpec((block_d, cap), lambda i: (i, 0)),
            pl.BlockSpec((block_d, cap, m), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_d, cap), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, n_q), lambda i: (0, 0)),            # q_mask
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, ndp), jnp.float32),
        interpret=interpret,
    )(cs_t, lut2, codesp, resp, maskp, thr, qm)
    return out[0, :n_docs]
