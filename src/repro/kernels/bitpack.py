"""Pallas kernel: threshold + bit-pack the centroid score matrix (EMVB C1a).

CS (n_q<=32, n_c) fp32  ->  bits (n_c,) uint32 with bit i = CS[i, c] > th.

TPU schedule: tile the centroid axis into (n_q, BC) VMEM blocks (BC a
multiple of 128 lanes); the pack is a VPU compare + shift + sum over the
sublane axis — branchless by construction, the TPU analogue of the paper's
"VecBranchless" AVX512 routine (no compressstore needed because we keep the
*dense* word array; see DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BC = 512


def _bitpack_kernel(th_ref, cs_ref, qm_ref, out_ref):
    cs = cs_ref[...]                                   # (n_q, BC)
    n_q = cs.shape[0]
    live = qm_ref[...] != 0                            # (n_q, 1)
    mask = ((cs > th_ref[0]) & live).astype(jnp.uint32)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (n_q, 1), 0)
    # Disjoint bit positions: sum == OR. Keep the reduce in uint32.
    out_ref[...] = jnp.sum(mask << shifts, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def bitpack(cs: jax.Array, th, q_mask: jax.Array | None = None, *,
            block_c: int = DEFAULT_BC, interpret: bool = True) -> jax.Array:
    """cs (n_q, n_c) fp32, th scalar -> (n_c,) uint32.

    q_mask optional (n_q,) bool: masked (padded / pruned) query terms pack a
    0 bit for every centroid, so Eq. 4's popcount cannot count them. The AND
    with an all-ones mask is the bitwise identity, so omitting the mask is
    exactly today's behavior.
    """
    n_q, n_c = cs.shape
    assert n_q <= 32
    pad = (-n_c) % block_c
    csp = jnp.pad(cs, ((0, 0), (0, pad)), constant_values=-jnp.inf)
    ncp = n_c + pad
    th_arr = jnp.asarray([th], jnp.float32)
    if q_mask is None:
        qm = jnp.ones((n_q, 1), jnp.int8)
    else:
        qm = q_mask.astype(jnp.int8).reshape(n_q, 1)
    out = pl.pallas_call(
        _bitpack_kernel,
        grid=(ncp // block_c,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),                  # th (smem-ish)
            pl.BlockSpec((n_q, block_c), lambda i: (0, i)),
            pl.BlockSpec((n_q, 1), lambda i: (0, 0)),            # q_mask
        ],
        out_specs=pl.BlockSpec((1, block_c), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, ncp), jnp.uint32),
        interpret=interpret,
    )(th_arr, csp, qm)
    return out[0, :n_c]
