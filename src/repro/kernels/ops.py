"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware set ``repro.kernels.ops.INTERPRET = False`` (or pass through the
engine config) to compile the Mosaic kernels.
"""
from __future__ import annotations

import jax

from . import bitpack as _bitpack
from . import bitfilter as _bitfilter
from . import cinter as _cinter
from . import pqscore as _pqscore

INTERPRET = True


def bitpack(cs: jax.Array, th: float) -> jax.Array:
    return _bitpack.bitpack(cs, th, interpret=INTERPRET)


def bitfilter(bits: jax.Array, codes: jax.Array, token_mask: jax.Array) -> jax.Array:
    return _bitfilter.bitfilter(bits, codes, token_mask, interpret=INTERPRET)


def cinter(cs_t: jax.Array, codes: jax.Array, token_mask: jax.Array) -> jax.Array:
    return _cinter.cinter(cs_t, codes, token_mask, interpret=INTERPRET)


def pqscore(cs_t: jax.Array, lut: jax.Array, codes: jax.Array,
            res_codes: jax.Array, token_mask: jax.Array,
            th_r: float | None) -> jax.Array:
    return _pqscore.pqscore(cs_t, lut, codes, res_codes, token_mask, th_r,
                            interpret=INTERPRET)
