"""Jit'd public wrappers around the Pallas kernels.

``interpret`` selects Pallas interpret mode (the CPU validation target for
this container) vs compiled Mosaic on real TPU hardware.  It is a plain
keyword argument plumbed from ``EngineConfig.kernel_interpret`` — there is no
module-level mutable state (the former ``INTERPRET`` global leaked one
process-wide choice into every caller and could not be jit-cached per mode).

``q_mask`` (optional (n_q,) bool, True = live term) threads the query-term
mask through every kernel that consumes the query-term axis: ``bitpack`` and
``prefilter`` pack a 0 bit for masked terms, ``cinter``/``pqscore``/
``pqinter`` exclude masked rows from the per-term max sums. ``bitfilter``
takes no mask — it only sees the already-masked packed words.
"""
from __future__ import annotations

import jax

from . import bitpack as _bitpack
from . import bitfilter as _bitfilter
from . import cinter as _cinter
from . import pqinter as _pqinter
from . import pqscore as _pqscore
from . import prefilter as _prefilter


def bitpack(cs: jax.Array, th: float, q_mask: jax.Array | None = None, *,
            interpret: bool = True) -> jax.Array:
    """Phase-1a kernel: threshold the (n_q, n_centroids) centroid-score
    matrix at ``th`` and pack each centroid's passing query-term set into
    one uint32 word (EMVB's stacked bit vectors) -> (n_centroids,)."""
    return _bitpack.bitpack(cs, th, q_mask, interpret=interpret)


def bitfilter(bits: jax.Array, codes: jax.Array, token_mask: jax.Array, *,
              interpret: bool = True) -> jax.Array:
    """Phase-1b kernel: OR the packed words of each doc's token centroids
    (EMVB Eq. 4) -> (n_docs,) uint32 candidate words (0 = no query term
    close to any token; popcount = evidence strength)."""
    return _bitfilter.bitfilter(bits, codes, token_mask, interpret=interpret)


def cinter(cs_t: jax.Array, codes: jax.Array, token_mask: jax.Array,
           q_mask: jax.Array | None = None, *,
           interpret: bool = True) -> jax.Array:
    """Phase-2 kernel: centroid-interaction approximate scores — per doc,
    sum over query terms of the max centroid score across its tokens ->
    (n_docs,) f32."""
    return _cinter.cinter(cs_t, codes, token_mask, q_mask,
                          interpret=interpret)


def pqscore(cs_t: jax.Array, lut: jax.Array, codes: jax.Array,
            res_codes: jax.Array, token_mask: jax.Array,
            th_r: float | None, q_mask: jax.Array | None = None, *,
            interpret: bool = True) -> jax.Array:
    """Phase-4 kernel: PQ late-interaction over the survivor block —
    centroid score + residual LUT sum per (term, token), optionally
    skipping tokens below ``th_r``, maxed over tokens and summed over live
    terms -> (n_sel,) f32."""
    return _pqscore.pqscore(cs_t, lut, codes, res_codes, token_mask, th_r,
                            q_mask, interpret=interpret)


def prefilter(cs: jax.Array, th: float, codes: jax.Array,
              token_mask: jax.Array, bitmap: jax.Array, n_filter: int,
              q_mask: jax.Array | None = None, *,
              pred_words: jax.Array | None = None, plan=None,
              interpret: bool = True):
    """Fused phases 1b-2 megakernel -> (scores, doc_ids, bits).

    ``pred_words`` ((n_docs,) uint32 packed predicate plane) + ``plan``
    (static ``FilterPlan.clauses``) evaluate the predicate filter in-kernel
    and AND it into ``bitmap``; ``plan=None`` is unfiltered."""
    return _prefilter.prefilter(cs, th, codes, token_mask, bitmap, n_filter,
                                q_mask, pred_words=pred_words, plan=plan,
                                interpret=interpret)


def pqinter(cs_t: jax.Array, lut: jax.Array, codes: jax.Array,
            res_codes: jax.Array, token_mask: jax.Array,
            th_r: float | None, n_docs: int, k: int,
            q_mask: jax.Array | None = None, *,
            doc_pass: jax.Array | None = None,
            interpret: bool = True):
    """Fused phases 3-4 megakernel -> (scores, pos, sel2, sbar).

    ``doc_pass`` ((n_filter,) bool predicate-filter verdict per survivor)
    masks non-passing rows to -inf in both selections; ``None`` == all
    passing."""
    return _pqinter.pqinter(cs_t, lut, codes, res_codes, token_mask, th_r,
                            n_docs, k, q_mask, doc_pass=doc_pass,
                            interpret=interpret)


def prefilter_batched(cs: jax.Array, th, codes: jax.Array,
                      token_mask: jax.Array, bitmap: jax.Array,
                      n_filter: int, q_masks: jax.Array | None = None, *,
                      pred_words: jax.Array | None = None, plan=None,
                      interpret: bool = True):
    """Batch-native phases 1b-2 megakernel -> (scores, doc_ids, bits), each
    with a leading batch axis; row b bit-identical to ``prefilter`` on
    query b.  ``codes``/``token_mask`` are (n_docs, cap) shared or
    (B, n_docs, cap) per-query candidate blocks; ``pred_words``/``plan``
    (batch-shared) as in ``prefilter``."""
    return _prefilter.prefilter_batched(cs, th, codes, token_mask, bitmap,
                                        n_filter, q_masks,
                                        pred_words=pred_words, plan=plan,
                                        interpret=interpret)


def pqinter_batched(cs_t: jax.Array, lut: jax.Array, codes: jax.Array,
                    res_codes: jax.Array, token_mask: jax.Array,
                    th_r: float | None, n_docs: int, k: int,
                    q_masks: jax.Array | None = None, *,
                    doc_pass: jax.Array | None = None,
                    interpret: bool = True):
    """Batch-native phases 3-4 megakernel -> (scores, pos, sel2, sbar),
    each with a leading batch axis; row b bit-identical to ``pqinter`` on
    query b.  ``doc_pass`` is (B, n_filter) per-survivor verdicts."""
    return _pqinter.pqinter_batched(cs_t, lut, codes, res_codes, token_mask,
                                    th_r, n_docs, k, q_masks,
                                    doc_pass=doc_pass, interpret=interpret)
