"""Pallas TPU kernels for EMVB's four hot spots (+ jnp oracles in ref.py)."""
from . import ops, ref  # noqa: F401
