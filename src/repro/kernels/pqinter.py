"""Fused Pallas megakernel: centroid interaction + phase-3 selection + PQ
late interaction with the dynamic term filter + final top-k (EMVB phases 3-4
in ONE launch).

The unfused engine runs the tail of the pipeline as two kernels with
full-survivor intermediates and two host-side selections:

    cinter(cs_t, sel1 codes)      (n_filter,) S̄ array in HBM     [§4.3]
    top_k(S̄, n_docs)             host selection -> sel2
    gather codes/res for sel2     second HBM gather
    pqscore(lut, sel2 codes)      (n_docs,) score array in HBM    [§4.4]
    top_k(scores, k)              host selection -> final

This kernel does all five steps in one ``pallas_call``, as two statically
unrolled block loops inside a single kernel invocation (the standard
"grid over independent work, inner loop over the stream" Pallas shape —
here the whole computation is one sequential stream, so the grid is 1):

  * pass 1 walks (BD1, cap) blocks of the phase-2 survivors, gathers their
    centroid columns from the VMEM-resident CS^T, max-reduces to the
    column-wise centroid interaction S̄ (Eq. 2), and merges each block into
    a running top-``n_docs`` buffer of (S̄, survivor position) pairs —
    phase 3's selection, kept on chip.
  * pass 2 walks that buffer in phase-3 rank order, gathers the winners'
    token codes and PQ residual codes, applies the dynamic term filter
    (Eq. 5 when ``th_r is None``, Eq. 6 otherwise — filtered (term, token)
    pairs are masked to -1e9 so they never win the max, i.e. only surviving
    terms contribute a LUT score), and merges each (BD2,) block of final
    scores into a running top-``k``.

Nothing of size ``(n_docs, cap, n_q)`` is ever materialized in HBM — the
centroid+residual score tensor only exists one (BD2, cap, n_q) tile at a
time, and the only outputs are the (k,) winners plus the (n_docs,) phase-3
selection (a free byproduct kept for the phase-split API). The LUT gather
mirrors the reference ``_lut_gather`` exactly — same static unroll, same
subspace accumulation order — because identical reduction order is what
keeps the final scores bitwise equal to the oracle.

Bit-exactness: both running merges are plain ``lax.top_k`` over
[buffer ++ block] concatenations. The buffer is kept sorted (score
descending, survivor position ascending within ties) and every block's
positions exceed everything already seen, so ``top_k``'s lowest-index
tie-breaking reproduces the reference ``top_k`` over the full score array
exactly — same docs, same order, including ties. The per-doc math is the
same gather/where/max/sum sequence as the jnp reference, so scores agree
bitwise and ties resolve identically (tests/test_kernels.py asserts this on
tie-heavy quantized score distributions).

Why not a multi-step grid with revisited accumulator blocks (the
``prefilter.py`` pattern)? Interpret mode — the tier-1 validation target —
lowers the grid to a ``lax.while_loop`` that re-slices EVERY input block and
writes it back into the loop carry on EVERY step; with the (n_filter, cap,
m) residual codes and the flattened LUT necessarily resident (pass 2
gathers arbitrary rows), that carry traffic alone cost more than the whole
unfused pair. A single grid step with static python-unrolled block loops
keeps the identical running-merge algorithm but slices each input exactly
once, and the merge carries are (n_docs,)/(k,) sized.

TPU notes: VMEM contract — CS^T (per-shard slice at production scale,
DESIGN.md §4), the flattened LUT, and the (n_filter, cap[, m]) survivor
arrays must all be resident, ~2.5 MiB at the paper's n_filter=512, cap=48,
m=16 shapes (uint8 residual codes). A Mosaic build would re-block the full-
array reads into (BD, cap) VMEM tiles behind double-buffered DMA and
replace the merge ``lax.top_k`` with a bitonic merge over the 8x128 lanes;
the row gather by phase-3 winner position is the one dynamic-DMA op without
an unfused analogue. Everything else is VPU gather/compare/select, same as
the unfused ``cinter``/``pqscore`` kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cinter import sbar_block, sbar_block_batched
from .pqscore import eq56_block, eq56_block_batched

MAX_BD1 = 512         # pass-1 block cap (S̄ is cheap: one gather + max/sum)
MAX_BD2 = 64          # pass-2 block cap (PQ scoring is the heavy stage)
MAX_BB = 8            # batched kernel: queries per grid step (VMEM bound)
NEG_INF = float("-inf")  # buffer init / padding: below any real score


def _pqinter_kernel(thr_ref, cs_t_ref, lut2_ref, codes_ref, res_ref,
                    mask_ref, qm_ref, pass_ref, sbar_ref, pos_ref, tops_ref,
                    topp_ref, *, m: int, ksub: int, use_filter: bool,
                    n_docs: int, k: int, bd1: int, bd2: int, nf: int,
                    nd_pad: int):
    cs_t = cs_t_ref[...]                                    # (n_c, n_q)
    codes = codes_ref[...]                                  # (nfp, cap)
    valid_all = mask_ref[...] != 0                          # (nfp, cap)
    qlive = qm_ref[0, :] != 0                               # (n_q,)
    pass_all = pass_ref[0, :] != 0                          # (nfp,)
    nfp = codes.shape[0]

    # ---- pass 1: S̄ blocks + running top-n_docs (sbar, position) ----------
    # Buffer-init entries carry position -1: with a predicate filter, real
    # rows can be -inf too, and an init entry that survives the -inf ties
    # must be recognizable in pass 2 (a position-0 init would be RESCORED
    # as survivor 0, duplicating a real doc in the top-k). Unfiltered, init
    # entries only ever sit at ranks >= n_filter, where ``live`` already
    # masks them — bit-identical to the previous zeros init.
    sbar_buf = jnp.full((nd_pad,), NEG_INF, jnp.float32)
    pos_buf = jnp.full((nd_pad,), -1, jnp.int32)
    for i in range(nfp // bd1):                             # static unroll
        start = i * bd1
        c = jax.lax.slice_in_dim(codes, start, start + bd1)
        v = jax.lax.slice_in_dim(valid_all, start, start + bd1)
        sbar = sbar_block(cs_t, c, v, qlive)                # (BD1,)
        rows = start + jax.lax.broadcasted_iota(jnp.int32, (bd1, 1), 0)[:, 0]
        p = jax.lax.slice_in_dim(pass_all, start, start + bd1)
        # exact-f32 cast (bf16 CS promotes losslessly; order/ties preserved);
        # padded rows AND predicate-filtered survivors rank below every real
        # passing doc, even all-token-masked ones
        sbar = jnp.where((rows < nf) & p, sbar.astype(jnp.float32), NEG_INF)
        merged_s = jnp.concatenate([sbar_buf, sbar])
        merged_p = jnp.concatenate([pos_buf, rows])
        sbar_buf, sel = jax.lax.top_k(merged_s, nd_pad)
        pos_buf = jnp.take(merged_p, sel)
    sbar_ref[...] = sbar_buf[None, :]
    pos_ref[...] = pos_buf[None, :]

    # ---- pass 2: Eq. 5/6 PQ scores in phase-3 rank order + running top-k --
    lut2 = lut2_ref[...]                                    # (m*K, n_q)
    res_all = res_ref[...]                                  # (nfp, cap, m)
    tops_buf = jnp.full((k,), NEG_INF, jnp.float32)
    topp_buf = jnp.zeros((k,), jnp.int32)
    for j in range(nd_pad // bd2):                          # static unroll
        start = j * bd2
        pos = jax.lax.slice_in_dim(pos_buf, start, start + bd2)
        lane = start + jax.lax.broadcasted_iota(jnp.int32, (bd2, 1), 0)[:, 0]
        live = lane < n_docs                                # buffer tail is
        posc = jnp.clip(pos, 0, nfp - 1)                    # rank > n_docs
        c = jnp.take(codes, posc, axis=0)                   # (BD2, cap)
        res = jnp.take(res_all, posc, axis=0)               # (BD2, cap, m)
        valid = jnp.take(valid_all, posc, axis=0) & live[:, None]
        score = eq56_block(cs_t, lut2, c, res, valid, thr_ref[0],
                           m=m, ksub=ksub, use_filter=use_filter,
                           qlive=qlive)
        # gather the pass bit by survivor position: a filtered doc that
        # still occupies a phase-3 slot must not reach the top-k; buffer
        # fillers (pos < 0) are never rescored
        ok = live & (pos >= 0) & jnp.take(pass_all, posc)
        score = jnp.where(ok, score, NEG_INF)
        merged_s = jnp.concatenate([tops_buf, score])
        merged_p = jnp.concatenate([topp_buf, pos])
        tops_buf, sel = jax.lax.top_k(merged_s, k)
        topp_buf = jnp.take(merged_p, sel)
    tops_ref[...] = tops_buf[None, :]
    topp_ref[...] = topp_buf[None, :]


@functools.partial(jax.jit,
                   static_argnames=("th_r", "n_docs", "k", "block_d1",
                                    "block_d2", "interpret"))
def pqinter(cs_t: jax.Array, lut: jax.Array, codes: jax.Array,
            res_codes: jax.Array, token_mask: jax.Array,
            th_r: float | None, n_docs: int, k: int,
            q_mask: jax.Array | None = None, *,
            doc_pass: jax.Array | None = None,
            block_d1: int | None = None, block_d2: int | None = None,
            interpret: bool = True) -> tuple[jax.Array, jax.Array,
                                             jax.Array, jax.Array]:
    """Fused phases 3-4 for one query, over the phase-2 survivor set.

    cs_t       : (n_c, n_q) centroid scores, transposed (fp32 or bf16)
    lut        : (n_q, m, K) PQ inner-product LUT for this query
    codes      : (n_filter, cap) int32 token centroid ids of the survivors
    res_codes  : (n_filter, cap, m) PQ codes of the survivors' residuals
    token_mask : (n_filter, cap) bool — True for real tokens
    th_r       : None -> Eq. 5 (score every term); float -> Eq. 6 filter
    n_docs     : phase-3 selection size
    k          : final result count
    q_mask     : optional (n_q,) bool — masked (padded / pruned) terms are
                 excluded from BOTH passes: no row in S̄'s sum, no MaxSim
                 term in Eq. 5/6 (all-ones == no mask, bit for bit)
    doc_pass   : optional (n_filter,) bool — predicate-filter verdict per
                 survivor (docs/FILTERING.md). False rows are masked to -inf
                 in BOTH selections, exactly like the unfused phase-3/4
                 masking, so filtered docs can never reach the top-k
                 (all-ones == no filter, bit for bit)
    -> (scores (k,) f32, pos (k,) i32, sel2 (n_docs,) i32, sbar (n_docs,) f32)

    ``pos``/``sel2`` index the n_filter survivor axis (the caller translates
    through its sel1). (scores, pos) == the unfused
    ``top_k(pqscore(top_k(cinter(...), n_docs) docs), k)`` composition
    bit-exactly, including index-order tie-breaking at both selections;
    ``sel2``/``sbar`` are the phase-3 selection and its S̄ values.
    """
    nf, cap = codes.shape
    n_c, n_q = cs_t.shape
    _, m, ksub = lut.shape
    assert k <= n_docs <= nf, \
        f"need k <= n_docs <= n_filter, got {k}/{n_docs}/{nf}"
    # NOTE: keep this wrapper in lockstep with ``pqinter_batched`` below —
    # the batched kernel is the same two-pass algorithm vectorized over a
    # leading batch axis, and bit-exactness between them is a tested
    # contract.
    if block_d1 is None:
        block_d1 = min(MAX_BD1, nf + (-nf) % 8)
    if block_d2 is None:
        block_d2 = min(MAX_BD2, n_docs + (-n_docs) % 8)
    pad1 = (-nf) % block_d1
    nd_pad = n_docs + ((-n_docs) % block_d2)
    codesp = jnp.pad(codes, ((0, pad1), (0, 0)))
    # residual codes stay uint8 end-to-end; the int32 offset cast happens at
    # the in-kernel gather, exactly where the reference _lut_gather does it
    resp = jnp.pad(res_codes, ((0, pad1), (0, 0), (0, 0)))
    maskp = jnp.pad(token_mask.astype(jnp.int8), ((0, pad1), (0, 0)))
    nfp = nf + pad1
    lut2 = lut.transpose(1, 2, 0).reshape(m * ksub, n_q)
    thr = jnp.asarray([0.0 if th_r is None else th_r], jnp.float32)
    qm = (jnp.ones((1, n_q), jnp.int8) if q_mask is None
          else q_mask.astype(jnp.int8).reshape(1, n_q))
    # All-ones default == no filter; padded rows are already rejected by the
    # rows < nf test, so the pad value is irrelevant (ones keeps it uniform).
    dp = (jnp.ones((nf,), jnp.int8) if doc_pass is None
          else doc_pass.astype(jnp.int8))
    dpp = jnp.pad(dp, (0, pad1), constant_values=1)[None, :]
    kern = functools.partial(
        _pqinter_kernel, m=m, ksub=ksub, use_filter=th_r is not None,
        n_docs=n_docs, k=k, bd1=block_d1, bd2=block_d2, nf=nf, nd_pad=nd_pad)
    sbar, pos, tops, topp = pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),              # th_r
            pl.BlockSpec((n_c, n_q), lambda i: (0, 0)),      # CS^T
            pl.BlockSpec((m * ksub, n_q), lambda i: (0, 0)),  # LUT
            pl.BlockSpec((nfp, cap), lambda i: (0, 0)),      # codes
            pl.BlockSpec((nfp, cap, m), lambda i: (0, 0, 0)),  # residual codes
            pl.BlockSpec((nfp, cap), lambda i: (0, 0)),      # token mask
            pl.BlockSpec((1, n_q), lambda i: (0, 0)),        # q_mask
            pl.BlockSpec((1, nfp), lambda i: (0, 0)),        # doc_pass
        ],
        out_specs=[
            pl.BlockSpec((1, nd_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, nd_pad), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, nd_pad), jnp.float32),
            jax.ShapeDtypeStruct((1, nd_pad), jnp.int32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        interpret=interpret,
    )(thr, cs_t, lut2, codesp, resp, maskp, qm, dpp)
    return tops[0], topp[0], pos[0, :n_docs], sbar[0, :n_docs]


def _pqinter_batched_kernel(thr_ref, cs_t_ref, lut2_ref, codes_ref, res_ref,
                            mask_ref, qm_ref, pass_ref, sbar_ref, pos_ref,
                            tops_ref, topp_ref, *, m: int, ksub: int,
                            use_filter: bool, n_docs: int, k: int, bd1: int,
                            bd2: int, nf: int, nd_pad: int):
    cs_t = cs_t_ref[...]                                    # (BB, n_c, n_q)
    codes = codes_ref[...]                                  # (BB, nfp, cap)
    valid_all = mask_ref[...] != 0                          # (BB, nfp, cap)
    qlive = qm_ref[...] != 0                                # (BB, n_q)
    pass_all = pass_ref[...] != 0                           # (BB, nfp)
    bb, nfp, _ = codes.shape

    # ---- pass 1: batched S̄ blocks + per-row running top-n_docs -----------
    # init position -1: see the single-query kernel's pass-1 comment
    sbar_buf = jnp.full((bb, nd_pad), NEG_INF, jnp.float32)
    pos_buf = jnp.full((bb, nd_pad), -1, jnp.int32)
    for i in range(nfp // bd1):                             # static unroll
        start = i * bd1
        c = jax.lax.slice_in_dim(codes, start, start + bd1, axis=1)
        v = jax.lax.slice_in_dim(valid_all, start, start + bd1, axis=1)
        sbar = sbar_block_batched(cs_t, c, v, qlive)        # (BB, BD1)
        rows = start + jax.lax.broadcasted_iota(jnp.int32, (1, bd1), 1)
        p = jax.lax.slice_in_dim(pass_all, start, start + bd1, axis=1)
        sbar = jnp.where((rows < nf) & p, sbar.astype(jnp.float32), NEG_INF)
        merged_s = jnp.concatenate([sbar_buf, sbar], axis=1)
        merged_p = jnp.concatenate(
            [pos_buf, jnp.broadcast_to(rows, (bb, bd1))], axis=1)
        # per-row top_k: same lowest-index tie-breaking as the single-query
        # merge, applied to each query's buffer independently
        sbar_buf, sel = jax.lax.top_k(merged_s, nd_pad)
        pos_buf = jnp.take_along_axis(merged_p, sel, axis=1)
    sbar_ref[...] = sbar_buf
    pos_ref[...] = pos_buf

    # ---- pass 2: batched Eq. 5/6 in phase-3 rank order + running top-k ----
    lut2 = lut2_ref[...]                                    # (BB, m*K, n_q)
    res_all = res_ref[...]                                  # (BB, nfp, cap, m)
    tops_buf = jnp.full((bb, k), NEG_INF, jnp.float32)
    topp_buf = jnp.zeros((bb, k), jnp.int32)
    for j in range(nd_pad // bd2):                          # static unroll
        start = j * bd2
        pos = jax.lax.slice_in_dim(pos_buf, start, start + bd2, axis=1)
        lane = start + jax.lax.broadcasted_iota(jnp.int32, (1, bd2), 1)
        live = lane < n_docs                                # (1, BD2)
        posc = jnp.clip(pos, 0, nfp - 1)
        c = jnp.take_along_axis(codes, posc[..., None], axis=1)
        res = jnp.take_along_axis(res_all, posc[..., None, None], axis=1)
        valid = (jnp.take_along_axis(valid_all, posc[..., None], axis=1)
                 & live[..., None])
        score = eq56_block_batched(cs_t, lut2, c, res, valid, thr_ref[0],
                                   m=m, ksub=ksub, use_filter=use_filter,
                                   qlive=qlive)
        # same per-row pass gather as the single-query kernel's pass 2
        pas = jnp.take_along_axis(pass_all, posc, axis=1)
        score = jnp.where(live & (pos >= 0) & pas, score, NEG_INF)
        merged_s = jnp.concatenate([tops_buf, score], axis=1)
        merged_p = jnp.concatenate([topp_buf, pos], axis=1)
        tops_buf, sel = jax.lax.top_k(merged_s, k)
        topp_buf = jnp.take_along_axis(merged_p, sel, axis=1)
    tops_ref[...] = tops_buf
    topp_ref[...] = topp_buf


@functools.partial(jax.jit,
                   static_argnames=("th_r", "n_docs", "k", "block_b",
                                    "block_d1", "block_d2", "interpret"))
def pqinter_batched(cs_t: jax.Array, lut: jax.Array, codes: jax.Array,
                    res_codes: jax.Array, token_mask: jax.Array,
                    th_r: float | None, n_docs: int, k: int,
                    q_masks: jax.Array | None = None, *,
                    doc_pass: jax.Array | None = None,
                    block_b: int | None = None, block_d1: int | None = None,
                    block_d2: int | None = None,
                    interpret: bool = True) -> tuple[jax.Array, jax.Array,
                                                     jax.Array, jax.Array]:
    """Batch-native fused phases 3-4: one launch for a whole micro-batch.

    cs_t       : (B, n_c, n_q) per-query transposed centroid scores
    lut        : (B, n_q, m, K) per-query PQ LUTs
    codes      : (B, n_filter, cap) survivors' token centroid ids
    res_codes  : (B, n_filter, cap, m) survivors' PQ residual codes
    token_mask : (B, n_filter, cap) bool
    th_r, n_docs, k : as in ``pqinter`` (shared across the batch)
    q_masks    : optional (B, n_q) bool per-query term masks
    doc_pass   : optional (B, n_filter) bool per-survivor predicate-filter
                 verdicts (as in ``pqinter``; all-ones == no filter)
    -> (scores (B, k), pos (B, k), sel2 (B, n_docs), sbar (B, n_docs))

    Row b of every output is bit-identical to ``pqinter(cs_t[b], lut[b],
    ..., q_mask=q_masks[b])``.  The grid walks the batch in ``block_b``-query
    steps; within a step the two statically unrolled block passes run the
    SAME running-merge algorithm as the single-query kernel, vectorized over
    the step's queries (batched ``lax.top_k`` reduces each row independently
    with identical tie-breaking).  Versus ``jax.vmap(pqinter)`` — which in
    interpret mode re-slices every resident operand once per query — this
    launch slices each query's operands exactly once and amortizes the
    interpreter's per-step overhead over ``block_b`` queries of vectorized
    VPU work.  VMEM contract: ``block_b`` times the single-query residency
    (CS^T + LUT + survivor arrays), so ~``block_b`` * 2.5 MiB at paper
    shapes — the default ``MAX_BB = 8`` keeps that within a v5e core's
    16 MiB VMEM.
    """
    nb, nf, cap = codes.shape
    _, n_c, n_q = cs_t.shape
    _, _, m, ksub = lut.shape
    assert k <= n_docs <= nf, \
        f"need k <= n_docs <= n_filter, got {k}/{n_docs}/{nf}"
    if block_b is None:
        block_b = min(MAX_BB, nb)
    if block_d1 is None:
        block_d1 = min(MAX_BD1, nf + (-nf) % 8)
    if block_d2 is None:
        block_d2 = min(MAX_BD2, n_docs + (-n_docs) % 8)
    pad1 = (-nf) % block_d1
    nd_pad = n_docs + ((-n_docs) % block_d2)
    padb = (-nb) % block_b
    nbp = nb + padb
    # Pad the batch with all-zero queries (zero CS, zero LUT, all-masked
    # tokens and terms): their rows compute finite garbage that is sliced
    # off below and never mixes into real rows (all reductions are per-row).
    csp = jnp.pad(cs_t, ((0, padb), (0, 0), (0, 0)))
    lutp = jnp.pad(lut, ((0, padb), (0, 0), (0, 0), (0, 0)))
    codesp = jnp.pad(codes, ((0, padb), (0, pad1), (0, 0)))
    resp = jnp.pad(res_codes, ((0, padb), (0, pad1), (0, 0), (0, 0)))
    maskp = jnp.pad(token_mask.astype(jnp.int8),
                    ((0, padb), (0, pad1), (0, 0)))
    nfp = nf + pad1
    lut2 = lutp.transpose(0, 2, 3, 1).reshape(nbp, m * ksub, n_q)
    thr = jnp.asarray([0.0 if th_r is None else th_r], jnp.float32)
    qm = (jnp.ones((nb, n_q), jnp.int8) if q_masks is None
          else q_masks.astype(jnp.int8).reshape(nb, n_q))
    qm = jnp.pad(qm, ((0, padb), (0, 0)))
    dp = (jnp.ones((nb, nf), jnp.int8) if doc_pass is None
          else doc_pass.astype(jnp.int8))
    dpp = jnp.pad(dp, ((0, padb), (0, pad1)), constant_values=1)
    kern = functools.partial(
        _pqinter_batched_kernel, m=m, ksub=ksub, use_filter=th_r is not None,
        n_docs=n_docs, k=k, bd1=block_d1, bd2=block_d2, nf=nf, nd_pad=nd_pad)
    sbar, pos, tops, topp = pl.pallas_call(
        kern,
        grid=(nbp // block_b,),
        in_specs=[
            pl.BlockSpec((1,), lambda b: (0,)),              # th_r
            pl.BlockSpec((block_b, n_c, n_q), lambda b: (b, 0, 0)),
            pl.BlockSpec((block_b, m * ksub, n_q), lambda b: (b, 0, 0)),
            pl.BlockSpec((block_b, nfp, cap), lambda b: (b, 0, 0)),
            pl.BlockSpec((block_b, nfp, cap, m), lambda b: (b, 0, 0, 0)),
            pl.BlockSpec((block_b, nfp, cap), lambda b: (b, 0, 0)),
            pl.BlockSpec((block_b, n_q), lambda b: (b, 0)),
            pl.BlockSpec((block_b, nfp), lambda b: (b, 0)),  # doc_pass
        ],
        out_specs=[
            pl.BlockSpec((block_b, nd_pad), lambda b: (b, 0)),
            pl.BlockSpec((block_b, nd_pad), lambda b: (b, 0)),
            pl.BlockSpec((block_b, k), lambda b: (b, 0)),
            pl.BlockSpec((block_b, k), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, nd_pad), jnp.float32),
            jax.ShapeDtypeStruct((nbp, nd_pad), jnp.int32),
            jax.ShapeDtypeStruct((nbp, k), jnp.float32),
            jax.ShapeDtypeStruct((nbp, k), jnp.int32),
        ],
        interpret=interpret,
    )(thr, csp, lut2, codesp, resp, maskp, qm, dpp)
    return (tops[:nb], topp[:nb], pos[:nb, :n_docs], sbar[:nb, :n_docs])
