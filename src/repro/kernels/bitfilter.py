"""Pallas kernel: bit-vector document pre-filter F(P,q) (EMVB C1b, Eq. 4).

bits (n_c,) uint32, codes (n_docs, cap) int32 -> F (n_docs,) int32
    F[p] = popcount( OR_t bits[codes[p, t]] )

Query-term masking: this kernel needs NO q_mask operand — masked (padded /
pruned) query terms are already packed as 0 bits by ``bitpack``/the fused
prefilter, so the popcount structurally cannot count them. The mask enters
the pipeline exactly once, at bit-pack time.

TPU schedule: the packed word table is tiny (n_c=2^18 -> 1 MiB) and stays
resident in VMEM for the whole sweep; documents are tiled (BD, cap) per grid
step. Per tile: one uint32 gather per token, a bitwise-OR reduction along the
token axis in VREGs, then ``lax.population_count`` — this is the 30x-cheaper
filter of paper Fig. 4, with the CPU word-at-a-time loop replaced by an
8x128-lane sweep.

Sharding contract: under the production mesh the centroid axis may be sharded
(model axis); each shard then holds its local ``bits`` slice and local codes
are pre-translated — the kernel itself is shard-oblivious.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BD = 256


def _bitfilter_kernel(bits_ref, codes_ref, mask_ref, out_ref):
    bits = bits_ref[...]                                  # (n_c,)
    codes = codes_ref[...]                                # (BD, cap)
    valid = mask_ref[...]                                 # (BD, cap) int8
    idx = jnp.clip(codes, 0, bits.shape[0] - 1)
    words = jnp.take(bits, idx, axis=0)                   # (BD, cap) u32
    words = jnp.where(valid != 0, words, jnp.uint32(0))
    ored = jax.lax.reduce(words, jnp.uint32(0), jax.lax.bitwise_or, (1,))
    out_ref[...] = jax.lax.population_count(ored).astype(jnp.int32)[None, :]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def bitfilter(bits: jax.Array, codes: jax.Array, token_mask: jax.Array, *,
              block_d: int = DEFAULT_BD, interpret: bool = True) -> jax.Array:
    """bits (n_c,) u32; codes/token_mask (n_docs, cap) -> (n_docs,) int32."""
    n_docs, cap = codes.shape
    pad = (-n_docs) % block_d
    codesp = jnp.pad(codes, ((0, pad), (0, 0)))
    maskp = jnp.pad(token_mask.astype(jnp.int8), ((0, pad), (0, 0)))
    ndp = n_docs + pad
    out = pl.pallas_call(
        _bitfilter_kernel,
        grid=(ndp // block_d,),
        in_specs=[
            pl.BlockSpec((bits.shape[0],), lambda i: (0,)),      # resident
            pl.BlockSpec((block_d, cap), lambda i: (i, 0)),
            pl.BlockSpec((block_d, cap), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, ndp), jnp.int32),
        interpret=interpret,
    )(bits, codesp, maskp)
    return out[0, :n_docs]
