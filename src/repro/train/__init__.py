"""Training substrate: optimizers, trainer loop, checkpointing, compression."""
