"""Fault-tolerant training loop.

Features (see DESIGN.md §4):
  * gradient accumulation via ``lax.scan`` over microbatches;
  * optional int8 gradient compression round-trip (models the compressed
    cross-pod all-reduce);
  * periodic + SIGTERM-safe checkpointing (atomic rename), resume-from-latest
    with deterministic data skipping (batches are a pure function of step);
  * straggler watch: per-step wall-time EWMA; steps slower than
    ``straggler_factor``x the EWMA are counted and logged — on a real fleet
    this signal feeds the reconfiguration hook ``on_straggler``;
  * elastic restart: checkpoints are mesh-agnostic (train/checkpoint.py),
    so a resumed job may run on a different device count.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import checkpoint as ckpt_lib
from .compression import compress_tree
from .optimizer import Optimizer


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt_state: Any


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    grad_accum: int = 1
    compress_grads: bool = False
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    ckpt_chunks: int = 1
    log_every: int = 10
    straggler_factor: float = 3.0


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    cfg: TrainerConfig,
                    micro_param_layout: Optional[Callable] = None) -> Callable:
    """loss_fn(params, batch) -> scalar. Returns step fn
    (state, batch) -> (state, metrics). With grad_accum > 1, ``batch`` leaves
    must have a leading (grad_accum, ...) microbatch axis.

    ``micro_param_layout``: optional params -> params layout transform
    applied ONCE before the microbatch scan (e.g. drop the FSDP axis so the
    weight all-gather is hoisted out of the loop instead of re-issued every
    microbatch — the LM-train collective bound in EXPERIMENTS.md §Perf).
    Gradients still accumulate (and the optimizer still runs) in the
    original sharded layout."""

    def compute_grads(params, batch):
        if cfg.grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, grads

        pfull = micro_param_layout(params) if micro_param_layout else params

        def micro(carry, mb):
            acc_loss, acc_g = carry
            loss, g = jax.value_and_grad(loss_fn)(pfull, mb)
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_g, g)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), _ = jax.lax.scan(micro, (jnp.float32(0), zeros),
                                           batch)
        inv = 1.0 / cfg.grad_accum
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = compute_grads(state.params, batch)
        if cfg.compress_grads:
            grads = compress_tree(grads)
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return (TrainState(state.step + 1, new_params, new_opt),
                {"loss": loss, "grad_norm": gnorm})

    return train_step


class Trainer:
    def __init__(self, loss_fn: Callable, optimizer: Optimizer,
                 make_batch: Callable[[int], Any], cfg: TrainerConfig,
                 init_params: Any,
                 on_straggler: Optional[Callable[[int, float], None]] = None,
                 jit: bool = True):
        self.cfg = cfg
        self.optimizer = optimizer
        self.make_batch = make_batch
        self.on_straggler = on_straggler
        step_fn = make_train_step(loss_fn, optimizer, cfg)
        self.step_fn = jax.jit(step_fn) if jit else step_fn
        self.state = TrainState(jnp.int32(0), init_params,
                                optimizer.init(init_params))
        self._stop = False
        self.metrics_log: list[dict] = []
        self.straggler_steps = 0

    # -- fault tolerance -----------------------------------------------------
    def _install_sigterm(self):
        def handler(signum, frame):
            self._stop = True  # finish current step, checkpoint, exit
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)

    def save(self):
        if self.cfg.ckpt_dir is None:
            return
        tree = {"params": self.state.params, "opt": self.state.opt_state}
        ckpt_lib.save(self.cfg.ckpt_dir, tree, int(self.state.step),
                      n_chunks=self.cfg.ckpt_chunks)

    def maybe_resume(self) -> int:
        if self.cfg.ckpt_dir is None:
            return 0
        step = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if step is None:
            return 0
        tree_like = {"params": self.state.params, "opt": self.state.opt_state}
        tree, step = ckpt_lib.restore(self.cfg.ckpt_dir, tree_like)
        params = jax.tree.map(lambda like, a: jnp.asarray(a, like.dtype),
                              self.state.params, tree["params"])
        opt = jax.tree.map(lambda like, a: jnp.asarray(a, like.dtype),
                           self.state.opt_state, tree["opt"])
        self.state = TrainState(jnp.int32(step), params, opt)
        return step

    # -- main loop ------------------------------------------------------------
    def run(self, n_steps: int) -> dict:
        self._install_sigterm()
        start = self.maybe_resume()   # deterministic skip: batches keyed by step
        ewma = None
        for step in range(start, n_steps):
            if self._stop:
                break
            batch = self.make_batch(step)
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > self.cfg.straggler_factor * ewma and step > start + 2:
                self.straggler_steps += 1
                if self.on_straggler:
                    self.on_straggler(step, dt)
            metrics.update(step=step + 1, sec=dt)
            if (step + 1) % self.cfg.log_every == 0 or step == n_steps - 1:
                self.metrics_log.append(metrics)
            if self.cfg.ckpt_dir and (step + 1) % self.cfg.ckpt_every == 0:
                self.save()
        if self._stop:
            self.save()
        return {"final_step": int(self.state.step),
                "interrupted": self._stop,
                "stragglers": self.straggler_steps,
                "log": self.metrics_log}
