"""Optimizers as (init, update) pairs over plain pytrees (optax-style, but
self-contained — nothing external is installed here).

  adamw     — default for dense LMs / recsys / GNN.
  adagrad   — classic recsys embedding-table choice (1 fp32 state).
  adafactor — factored second moments; the memory-lean choice for 20B+.
  muon      — momentum + Newton–Schulz orthogonalization on 2D params
              (Kimi K2's actual optimizer; 1 state per param, which is what
              makes the 1T-param dry-run fit — see EXPERIMENTS.md §Dry-run).

States are stored in fp32 except muon/adamw ``momentum_dtype`` which can be
bf16 for the ZeRO-lean configs.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def _tree_map(f, *trees):
    return jax.tree.map(f, *trees)


# ---------------------------------------------------------------------------

def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01,
          state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = _tree_map(lambda p: jnp.zeros(p.shape, state_dtype), params)
        return {"m": zeros,
                "v": _tree_map(lambda p: jnp.zeros(p.shape, state_dtype),
                               params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        b1c = 1 - b1 ** c.astype(jnp.float32)
        b2c = 1 - b2 ** c.astype(jnp.float32)
        m = _tree_map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                      state["m"], grads)
        v = _tree_map(lambda v, g: b2 * v + (1 - b2) *
                      jnp.square(g.astype(v.dtype)), state["v"], grads)
        def upd(p, m, v):
            step = (m / b1c) / (jnp.sqrt(v / b2c) + eps)
            return (p.astype(jnp.float32) - lr * (step + weight_decay *
                    p.astype(jnp.float32))).astype(p.dtype)
        new_params = _tree_map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)


def adagrad(lr: float = 1e-2, eps: float = 1e-10) -> Optimizer:
    def init(params):
        return {"acc": _tree_map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)}

    def update(grads, state, params):
        acc = _tree_map(lambda a, g: a + jnp.square(g.astype(jnp.float32)),
                        state["acc"], grads)
        new_params = _tree_map(
            lambda p, g, a: (p.astype(jnp.float32) -
                             lr * g.astype(jnp.float32) /
                             (jnp.sqrt(a) + eps)).astype(p.dtype),
            params, grads, acc)
        return new_params, {"acc": acc}

    return Optimizer(init, update)


def adafactor(lr: float = 1e-2, eps: float = 1e-30,
              decay: float = 0.8, clip_rms: float = 1.0) -> Optimizer:
    """Factored second moments for >=2D params (row/col accumulators over the
    trailing two axes), full accumulator otherwise."""
    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                         jnp.float32)}
            return {"full": jnp.zeros(p.shape, jnp.float32)}
        return {"v": _tree_map(one, params, ),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        beta = 1.0 - (c.astype(jnp.float32)) ** (-decay)

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        flat_v = treedef.flatten_up_to(state["v"])
        new_p, new_v = [], []
        for g, p, v in zip(flat_g, flat_p, flat_v):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if g.ndim >= 2:
                row = beta * v["row"] + (1 - beta) * g2.mean(axis=-1)
                col = beta * v["col"] + (1 - beta) * g2.mean(axis=-2)
                denom = (row[..., None] / jnp.maximum(
                    row.mean(axis=-1, keepdims=True)[..., None], eps)) * \
                    col[..., None, :]
                upd = g32 * jax.lax.rsqrt(jnp.maximum(denom, eps))
                nv = {"row": row, "col": col}
            else:
                full = beta * v["full"] + (1 - beta) * g2
                upd = g32 * jax.lax.rsqrt(jnp.maximum(full, eps))
                nv = {"full": full}
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-12)
            upd = upd / jnp.maximum(1.0, rms / clip_rms)
            new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
            new_v.append(nv)
        return (jax.tree_util.tree_unflatten(treedef, new_p),
                {"v": jax.tree_util.tree_unflatten(treedef, new_v),
                 "count": c})

    return Optimizer(init, update)


def _newton_schulz(g: jax.Array, steps: int = 5,
                   dtype=jnp.float32) -> jax.Array:
    """Orthogonalize a 2D matrix via the quintic Newton–Schulz iteration
    (Jordan et al.; used by Muon). ``dtype=bf16`` is the practitioner
    standard (NS is self-correcting); fp32 norm for stability."""
    a, b, c = 3.4445, -4.7750, 2.0315
    x = g.astype(jnp.float32)
    transpose = x.shape[0] > x.shape[1]
    if transpose:
        x = x.T
    x = (x / (jnp.linalg.norm(x) + 1e-7)).astype(dtype)
    for _ in range(steps):
        xxt = x @ x.T
        x = a * x + (b * xxt + c * (xxt @ xxt)) @ x
    return (x.T if transpose else x)


def muon(lr: float = 0.02, momentum: float = 0.95, ns_steps: int = 5,
         adamw_lr: float = 3e-4, state_dtype=jnp.float32,
         mats_spec=None, ns_dtype=jnp.float32) -> Optimizer:
    """Muon for >=2D params (leading axes folded), AdamW-like fallback for
    vectors/scalars. Single momentum state per param.

    Distributed execution ("tensor-parallel Newton–Schulz", §Perf 3.2):
    leading batch axes (layer stack / expert axis) are kept UNFOLDED and the
    momentum keeps its natural param sharding — NS runs with the matrix's
    row dim sharded wherever FSDP put it; the per-step gram contracts over
    that dim and GSPMD inserts one all-reduce of the (small) gram per step.
    The layer axis runs under ``lax.map`` so only one layer's grams are live
    at a time. Two refuted designs are logged in §Perf: (a) reshape-folding
    (L, E) merges an unsharded-major dim with the EP-sharded expert dim —
    unrepresentable, GSPMD answers with full all-gathers; (b) resharding to
    a matrix-sharded layout (``mats_spec``) — the reshard materializes a
    gather-then-slice 84 GiB intermediate. ``mats_spec`` (callable shape ->
    Optional[PartitionSpec]) remains available for meshes where that
    reshard is cheap. ``ns_dtype=bf16`` halves NS compute/memory
    (practitioner standard)."""
    def init(params):
        return {"mu": _tree_map(lambda p: jnp.zeros(p.shape, state_dtype),
                                params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        mu = _tree_map(lambda m, g: momentum * m + g.astype(m.dtype),
                       state["mu"], grads)

        def upd(p, m):
            if p.ndim >= 2:
                # leading axes are batch dims, kept unfolded (docstring)
                mats = m
                sp = mats_spec(m.shape) if mats_spec is not None else None
                if sp is not None:
                    mats = jax.lax.with_sharding_constraint(mats, sp)
                fn = lambda x: _newton_schulz(x, ns_steps, ns_dtype)  # noqa
                for _ in range(m.ndim - 3):
                    fn = jax.vmap(fn)
                if m.ndim >= 3:
                    # sequential over the outermost (layer) axis: bounds the
                    # live gram memory to one layer's worth
                    o = jax.lax.map(fn, mats)
                else:
                    o = fn(mats)
                scale = jnp.sqrt(jnp.maximum(1.0, m.shape[-2] / m.shape[-1]))
                return (p.astype(jnp.float32) - lr * scale *
                        o.astype(jnp.float32)).astype(p.dtype)
            return (p.astype(jnp.float32) -
                    adamw_lr * m.astype(jnp.float32)).astype(p.dtype)
        return _tree_map(upd, params, mu), {"mu": mu, "count": c}

    return Optimizer(init, update)


REGISTRY = {
    "adamw": adamw,
    "adagrad": adagrad,
    "adafactor": adafactor,
    "muon": muon,
}


def make(name: str, **kw) -> Optimizer:
    return REGISTRY[name](**kw)
