"""Gradient compression for cheaper cross-pod all-reduce.

int8 per-leaf quantization with a per-leaf fp32 scale (stochastic rounding
optional). In the distributed trainer the intended schedule is
quantize -> reduce-scatter(int8→int32 accum) -> dequantize; on the single
process here the same code path runs as quantize->dequantize around the
(virtual) collective so that accuracy impact is honestly measured, and the
4x byte reduction is credited analytically in the roofline's collective term
(EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array, key: jax.Array | None = None
                  ) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)).astype(jnp.float32), 1e-12) / 127.0
    x = g.astype(jnp.float32) / scale
    if key is not None:  # stochastic rounding
        x = jnp.floor(x + jax.random.uniform(key, x.shape))
    else:
        x = jnp.round(x)
    return jnp.clip(x, -127, 127).astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32
                    ) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads: Any, key: jax.Array | None = None) -> Any:
    """Round-trip the whole gradient pytree through int8."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if key is not None:
        keys = list(jax.random.split(key, len(leaves)))
    else:
        keys = [None] * len(leaves)
    out = []
    for g, k in zip(leaves, keys):
        q, s = quantize_int8(g, k)
        out.append(dequantize_int8(q, s, g.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
