"""Sharded, elastic checkpointing.

Format: ``<dir>/step_<N>/``
  manifest.json  — tree structure, leaf paths/shapes/dtypes, chunk count,
                   mesh shape at save time, step
  <leaf-key>.c<i>.npy — leaf chunks, split along axis 0 into ``n_chunks``
                   pieces (one per host-shard in a real deployment; the same
                   files are written by every host that owns the shard, so a
                   node loss never loses data as long as one replica
                   survives).

Restore is *elastic*: chunks are concatenated and the result re-sharded to
whatever mesh the restoring job runs — device counts do not need to match
(the manifest records the save-time mesh purely for bookkeeping).
Atomicity: writes go to ``<dir>/.tmp_step_<N>`` and are renamed at the end
(POSIX rename = atomic publish), so a mid-save crash never corrupts the
latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts)


def save(ckpt_dir: str, tree: Any, step: int, *, n_chunks: int = 1,
         extra_meta: Optional[dict] = None) -> str:
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "n_chunks": n_chunks,
                "extra": extra_meta or {}, "leaves": []}
    for path, leaf in leaves_with_paths:
        key = _leaf_key(path)
        arr = np.asarray(leaf)
        manifest["leaves"].append({
            "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)})
        chunks = np.array_split(arr, n_chunks, axis=0) if arr.ndim else [arr]
        for i, c in enumerate(chunks):
            np.save(os.path.join(tmp, f"{key}.c{i}.npy"), c)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None
            ) -> Tuple[Any, int]:
    """tree_like: pytree with the target structure (values may be abstract).
    Returns (tree of np arrays matching tree_like's structure, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    n_chunks = manifest["n_chunks"]
    by_key = {}
    for leaf in manifest["leaves"]:
        key = leaf["key"]
        if len(leaf["shape"]) == 0:
            arr = np.load(os.path.join(d, f"{key}.c0.npy"))
        else:
            arr = np.concatenate(
                [np.load(os.path.join(d, f"{key}.c{i}.npy"))
                 for i in range(n_chunks)], axis=0)
        by_key[key] = arr.reshape(leaf["shape"]).astype(leaf["dtype"])

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    out = []
    for path, like in leaves_with_paths:
        key = _leaf_key(path)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        out.append(by_key[key])
    return jax.tree_util.tree_unflatten(treedef, out), step
