"""Mixture-of-Experts FFN with top-k routing and per-expert capacity.

Dispatch strategy ("capacity gather", DESIGN.md §4): instead of the GShard
(T, E, C) one-hot dispatch tensor — O(T·E·C) memory, hopeless at E=384 — each
expert gathers its top-C tokens directly:

  1. router logits (T, E); token-side top-k selection mask + renormalized
     gate weights;
  2. expert-side: top-C tokens per expert from the masked gate matrix
     transposed -> token ids (E, C) + weights (E, C);
  3. gather (E, C, d), per-expert SwiGLU via batched einsum (grouped GEMM),
     scatter-add back weighted outputs.

Memory is O(T·top_k·d) (the unavoidable token-copy cost) and the expert axis
shards cleanly over the mesh "model" axis (EP). Tokens over capacity are
dropped (standard); capacity_factor sizes C = ceil(T·top_k/E · cf).

An auxiliary load-balance loss (Switch-style) is returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import ModelConfig, Params


def moe_block(p: Params, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar). Dispatch mode:
    ``cfg.moe_groups > 0`` -> grouped GShard dispatch (production, all-to-all
    under GSPMD); else capacity-gather (single-host friendly)."""
    if cfg.moe_groups:
        return moe_block_grouped(p, x, cfg)
    return _moe_block_gather(p, x, cfg)


def moe_block_grouped(p: Params, x: jax.Array, cfg: ModelConfig
                      ) -> tuple[jax.Array, jax.Array]:
    """GShard-style grouped dispatch (§Perf cell 2, iteration 3).

    Tokens split into ``moe_groups`` groups (one per token shard); each group
    selects its top-C tokens PER EXPERT locally and dispatches with a
    (g, t_l, E, C) one-hot einsum — the canonical pattern GSPMD lowers to an
    all-to-all when the group axis is token-sharded and the expert axis is
    EP-sharded (``cfg.moe_specs``), replacing the capacity-gather's global
    token gather/scatter that XLA answered with per-layer all-reduces of the
    full (T, d) activation (measured 5.7 TiB/chip/step on kimi-k2).

    Per-group capacity C = ceil(t_l·k/E·cf) keeps the same expected drop
    rate as the global formulation (standard in GShard/Switch).
    """
    b, s, d = x.shape
    g = cfg.moe_groups
    t = b * s
    assert t % g == 0, (t, g)
    tl = t // g
    e, k = cfg.n_experts, cfg.top_k
    cap = int(max(1, -(-tl * k // e) * max(1.0, cfg.capacity_factor)))
    cap = min(cap, tl)
    xg = x.reshape(g, tl, d)
    tok_spec, exp_spec = cfg.moe_specs or (None, None)
    if tok_spec is not None:
        xg = jax.lax.with_sharding_constraint(xg, tok_spec)

    logits = xg.astype(jnp.float32) @ p["router"]              # (g, tl, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                       # (g, tl, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    sel = (jax.nn.one_hot(topi, e, dtype=jnp.float32) *
           topv[..., None]).sum(-2)                            # (g, tl, E)

    # position of each token in its expert's queue; drop beyond capacity
    mask = sel > 0
    pos = jnp.cumsum(mask, axis=1) - 1                         # (g, tl, E)
    keep = mask & (pos < cap)
    disp = (keep[..., None] &
            (pos[..., None] == jnp.arange(cap)))               # (g,tl,E,C)
    disp_x = disp.astype(cfg.dtype)
    xdisp = jnp.einsum("gtec,gtd->gecd", disp_x, xg)           # (g,E,C,d)
    if exp_spec is not None:
        xdisp = jax.lax.with_sharding_constraint(xdisp, exp_spec)

    hidden = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xdisp, p["wi_gate"])) \
        * jnp.einsum("gecd,edf->gecf", xdisp, p["wi_up"])
    yexp = jnp.einsum("gecf,efd->gecd", hidden, p["wo"])       # (g,E,C,d)

    comb = (disp * sel[..., None]).astype(cfg.dtype)           # gated one-hot
    out = jnp.einsum("gtec,gecd->gtd", comb, yexp)
    if tok_spec is not None:
        out = jax.lax.with_sharding_constraint(out, tok_spec)

    me = probs.mean(axis=(0, 1))                               # (E,)
    ce = mask.astype(jnp.float32).mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return out.reshape(b, s, d), aux


def _moe_block_gather(p: Params, x: jax.Array, cfg: ModelConfig
                      ) -> tuple[jax.Array, jax.Array]:
    """Capacity-gather dispatch (module docstring strategy)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                       # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # token-side selection mask with renormalized gates
    sel = jnp.zeros((t, e), jnp.float32)
    sel = sel.at[jnp.arange(t)[:, None], topi].set(topv)       # (T, E)

    # expert-side capacity gather
    cap = int(max(1, min(t, round(t * k / e * cfg.capacity_factor))))
    gates_te = sel.T                                           # (E, T)
    gw, gidx = jax.lax.top_k(gates_te, cap)                    # (E, C)
    xg = jnp.take(xf, gidx.reshape(-1), axis=0)                # (E*C, d)
    xg = xg.reshape(e, cap, d)

    # grouped SwiGLU: (E, C, d) x (E, d, f) -> (E, C, f)
    hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, p["wi_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xg, p["wi_up"])
    yexp = jnp.einsum("ecf,efd->ecd", hidden, p["wo"])         # (E, C, d)

    # weighted scatter-add back to tokens (zero-gate rows contribute nothing)
    yw = yexp * gw[..., None].astype(yexp.dtype)
    out = jnp.zeros((t, d), yexp.dtype)
    out = out.at[gidx.reshape(-1)].add(yw.reshape(-1, d))
    if cfg.residual_spec is not None:
        # token-sharded output: the cross-expert scatter partials combine
        # with a reduce-scatter instead of a full all-reduce (§Perf)
        from jax.sharding import PartitionSpec as P
        sp = cfg.residual_spec
        out = jax.lax.with_sharding_constraint(
            out.reshape(b, s, d), P(*sp)).reshape(t, d)

    # Switch-style load-balance aux loss
    me = probs.mean(axis=0)                                    # (E,)
    ce = (sel > 0).astype(jnp.float32).mean(axis=0)
    aux = e * jnp.sum(me * ce)
    return out.reshape(b, s, d), aux
