"""DLRM (Naumov et al., arXiv:1906.00091) — MLPerf benchmark config.

13 dense features -> bottom MLP; 26 sparse fields -> per-field EmbeddingBag
(multi-hot, sum-reduced); pairwise dot interaction over the 27 feature
vectors; top MLP -> CTR logit. Criteo-1TB vocabulary sizes (public MLPerf
config) are in ``repro.configs.dlrm_mlperf``.

Tables may be PQ-compressed (``use_pq_tables=True``) — the beyond-paper
application of EMVB's C3 recorded in DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .embedding_bag import embedding_bag, embedding_bag_pq, init_mlp, mlp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 128
    vocab_sizes: Tuple[int, ...] = (1000,) * 26
    bot_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    nnz: int = 1                  # multi-hot width per field
    use_pq_tables: bool = False
    pq_m: int = 16
    pq_k: int = 256
    dtype: Any = jnp.float32


def init_params(key: jax.Array, cfg: DLRMConfig) -> Params:
    keys = jax.random.split(key, cfg.n_sparse + 3)
    p: Params = {"tables": {}}
    for f, v in enumerate(cfg.vocab_sizes):
        if cfg.use_pq_tables:
            p["tables"][f"t{f}"] = {
                "codes": jax.random.randint(keys[f], (v, cfg.pq_m), 0,
                                            cfg.pq_k).astype(jnp.uint8),
                "codebooks": (jax.random.normal(
                    keys[f], (cfg.pq_m, cfg.pq_k, cfg.embed_dim // cfg.pq_m))
                    * 0.05).astype(cfg.dtype),
            }
        else:
            p["tables"][f"t{f}"] = (jax.random.normal(keys[f], (v, cfg.embed_dim))
                                    * 0.05).astype(cfg.dtype)
    p["bot"] = init_mlp(keys[-3], [cfg.n_dense, *cfg.bot_mlp], cfg.dtype)
    n_feat = cfg.n_sparse + 1
    n_pairs = n_feat * (n_feat - 1) // 2
    p["top"] = init_mlp(keys[-2], [n_pairs + cfg.bot_mlp[-1], *cfg.top_mlp],
                        cfg.dtype)
    return p


def forward(params: Params, batch: dict, cfg: DLRMConfig) -> jax.Array:
    """batch: dense (B, 13) fp32; sparse_idx (B, 26, nnz) int32;
    sparse_valid (B, 26, nnz) bool -> logits (B,)."""
    dense = mlp(params["bot"], batch["dense"].astype(cfg.dtype),
                final_act=True)                                 # (B, D)
    embs = []
    for f in range(cfg.n_sparse):
        t = params["tables"][f"t{f}"]
        idx = batch["sparse_idx"][:, f]
        val = batch["sparse_valid"][:, f]
        if cfg.use_pq_tables:
            embs.append(embedding_bag_pq(t["codes"], t["codebooks"], idx, val))
        else:
            embs.append(embedding_bag(t, idx, val))
    z = jnp.stack([dense, *embs], axis=1)                       # (B, 27, D)
    inter = jnp.einsum("bid,bjd->bij", z, z)                    # (B, 27, 27)
    iu, ju = jnp.triu_indices(z.shape[1], k=1)
    pairs = inter[:, iu, ju]                                    # (B, n_pairs)
    top_in = jnp.concatenate([dense, pairs.astype(cfg.dtype)], axis=-1)
    return mlp(params["top"], top_in)[:, 0]


def loss_fn(params: Params, batch: dict, cfg: DLRMConfig) -> jax.Array:
    logits = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))
