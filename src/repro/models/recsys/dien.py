"""DIEN (Zhou et al., arXiv:1809.03672) — interest evolution with AUGRU.

User behaviour sequence -> GRU interest extractor -> attention vs target item
-> AUGRU (attention-modulated update gate) interest evolver -> final state
concat target/profile -> MLP(200, 80) -> CTR logit. GRU/AUGRU run under
``lax.scan``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .embedding_bag import init_mlp, mlp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    vocab_items: int = 100000
    vocab_cats: int = 1000
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: Tuple[int, ...] = (200, 80)
    dtype: Any = jnp.float32

    @property
    def item_dim(self) -> int:
        return 2 * self.embed_dim  # item embedding ++ category embedding


def _init_gru(key, d_in, d_h, dtype):
    k = jax.random.split(key, 3)
    s_in, s_h = 1 / jnp.sqrt(d_in), 1 / jnp.sqrt(d_h)
    return {
        "wx": (jax.random.normal(k[0], (d_in, 3 * d_h)) * s_in).astype(dtype),
        "wh": (jax.random.normal(k[1], (d_h, 3 * d_h)) * s_h).astype(dtype),
        "b": jnp.zeros((3 * d_h,), dtype),
    }


def _gru_cell(p, h, x, att=None):
    """Standard GRU; if ``att`` (B, 1) is given, the update gate is scaled by
    it (AUGRU, the DIEN contribution)."""
    gx = x @ p["wx"] + p["b"]
    gh = h @ p["wh"]
    xr, xz, xn = jnp.split(gx, 3, axis=-1)
    hr, hz, hn = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(xr + hr)
    z = jax.nn.sigmoid(xz + hz)
    n = jnp.tanh(xn + r * hn)
    if att is not None:
        z = att * z
    return (1.0 - z) * h + z * n


def init_params(key: jax.Array, cfg: DIENConfig) -> Params:
    keys = jax.random.split(key, 6)
    d_in = cfg.item_dim
    return {
        "item_emb": (jax.random.normal(keys[0], (cfg.vocab_items, cfg.embed_dim))
                     * 0.05).astype(cfg.dtype),
        "cat_emb": (jax.random.normal(keys[1], (cfg.vocab_cats, cfg.embed_dim))
                    * 0.05).astype(cfg.dtype),
        "gru1": _init_gru(keys[2], d_in, cfg.gru_dim, cfg.dtype),
        "gru2": _init_gru(keys[3], cfg.gru_dim, cfg.gru_dim, cfg.dtype),
        "att_w": (jax.random.normal(keys[4], (d_in, cfg.gru_dim)) * 0.05
                  ).astype(cfg.dtype),
        "head": init_mlp(keys[5],
                         [cfg.gru_dim + 2 * d_in, *cfg.mlp_dims, 1], cfg.dtype),
    }


def _embed_items(params, items, cats):
    ie = jnp.take(params["item_emb"], items, axis=0)
    ce = jnp.take(params["cat_emb"], cats, axis=0)
    return jnp.concatenate([ie, ce], axis=-1)


def forward(params: Params, batch: dict, cfg: DIENConfig) -> jax.Array:
    """batch: hist_items/hist_cats (B, L) int32, hist_valid (B, L) bool,
    target_item/target_cat (B,) int32 -> logits (B,)."""
    hist = _embed_items(params, batch["hist_items"], batch["hist_cats"])
    target = _embed_items(params, batch["target_item"], batch["target_cat"])
    valid = batch["hist_valid"].astype(cfg.dtype)
    b = hist.shape[0]
    h0 = jnp.zeros((b, cfg.gru_dim), cfg.dtype)

    # interest extractor GRU over the sequence
    def step1(h, xv):
        x, v = xv
        hn = _gru_cell(params["gru1"], h, x)
        h = v[:, None] * hn + (1 - v)[:, None] * h
        return h, h
    _, states = jax.lax.scan(step1, h0, (hist.swapaxes(0, 1),
                                         valid.swapaxes(0, 1)))
    states = states.swapaxes(0, 1)                            # (B, L, H)

    # attention of target vs extracted interests
    att_logits = jnp.einsum("bd,dh,blh->bl", target, params["att_w"], states)
    att_logits = jnp.where(batch["hist_valid"], att_logits, -1e9)
    att = jax.nn.softmax(att_logits.astype(jnp.float32), axis=-1
                         ).astype(cfg.dtype)                   # (B, L)

    # AUGRU interest evolution
    def step2(h, sva):
        s, v, a = sva
        hn = _gru_cell(params["gru2"], h, s, att=a[:, None])
        h = v[:, None] * hn + (1 - v)[:, None] * h
        return h, None
    h_final, _ = jax.lax.scan(step2, h0, (states.swapaxes(0, 1),
                                          valid.swapaxes(0, 1),
                                          att.swapaxes(0, 1)))

    hist_mean = (hist * valid[..., None]).sum(1) / \
        jnp.maximum(valid.sum(1, keepdims=True), 1)
    feat = jnp.concatenate([h_final, target, hist_mean], axis=-1)
    return mlp(params["head"], feat)[:, 0]


def loss_fn(params: Params, batch: dict, cfg: DIENConfig) -> jax.Array:
    logits = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))
