"""DCN-v2 (Wang et al., arXiv:2008.13535) — cross network + deep MLP.

x_{l+1} = x_0 ⊙ (W_l x_l + b_l) + x_l   (full-rank cross layers), stacked
combination: cross tower then deep tower on its output -> logit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .embedding_bag import embedding_bag, init_mlp, mlp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class DCNConfig:
    name: str = "dcn"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 16
    vocab_sizes: Tuple[int, ...] = (1000,) * 26
    n_cross_layers: int = 3
    mlp_dims: Tuple[int, ...] = (1024, 1024, 512)
    nnz: int = 1
    dtype: Any = jnp.float32

    @property
    def x0_dim(self) -> int:
        return self.n_dense + self.n_sparse * self.embed_dim


def init_params(key: jax.Array, cfg: DCNConfig) -> Params:
    keys = jax.random.split(key, cfg.n_sparse + cfg.n_cross_layers + 2)
    p: Params = {"tables": {}}
    for f, v in enumerate(cfg.vocab_sizes):
        p["tables"][f"t{f}"] = (jax.random.normal(keys[f], (v, cfg.embed_dim))
                                * 0.05).astype(cfg.dtype)
    d0 = cfg.x0_dim
    p["cross"] = [{
        "w": (jax.random.normal(keys[cfg.n_sparse + i], (d0, d0)) /
              jnp.sqrt(d0)).astype(cfg.dtype),
        "b": jnp.zeros((d0,), cfg.dtype)}
        for i in range(cfg.n_cross_layers)]
    p["deep"] = init_mlp(keys[-2], [d0, *cfg.mlp_dims], cfg.dtype)
    p["head"] = init_mlp(keys[-1], [cfg.mlp_dims[-1], 1], cfg.dtype)
    return p


def forward(params: Params, batch: dict, cfg: DCNConfig) -> jax.Array:
    embs = [embedding_bag(params["tables"][f"t{f}"],
                          batch["sparse_idx"][:, f],
                          batch["sparse_valid"][:, f])
            for f in range(cfg.n_sparse)]
    x0 = jnp.concatenate([batch["dense"].astype(cfg.dtype), *embs], axis=-1)
    x = x0
    for lp in params["cross"]:
        x = x0 * (x @ lp["w"] + lp["b"]) + x
    x = mlp(params["deep"], x, final_act=True)
    return mlp(params["head"], x)[:, 0]


def loss_fn(params: Params, batch: dict, cfg: DCNConfig) -> jax.Array:
    logits = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))
