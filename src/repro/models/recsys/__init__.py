"""RecSys architectures: DLRM, DCN-v2, DIEN, MIND + EmbeddingBag substrate."""
