"""EmbeddingBag in JAX — gather + segment-reduce (no native op exists; this
IS part of the system per the assignment).

Layout: per-field tables, multi-hot indices padded to ``nnz`` per (sample,
field) with a validity mask. Reduction 'sum' or 'mean'.

Beyond-paper option (DESIGN.md §5): PQ-compressed tables — rows stored as m
uint8 codes and decoded through the EMVB PQ codebooks at lookup time. This
reuses the paper's C3 machinery to shrink recsys embedding memory by
dim*4/m (e.g. 32x for dim=128, m=16), the dominant memory term in DLRM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table: jax.Array, idx: jax.Array, valid: jax.Array,
                  mode: str = "sum") -> jax.Array:
    """table (V, D); idx (..., nnz) int32; valid (..., nnz) bool -> (..., D)."""
    rows = jnp.take(table, jnp.clip(idx, 0, table.shape[0] - 1), axis=0)
    rows = jnp.where(valid[..., None], rows, 0.0)
    out = rows.sum(axis=-2)
    if mode == "mean":
        out = out / jnp.maximum(valid.sum(axis=-1, keepdims=True), 1)
    return out


def embedding_bag_pq(codes: jax.Array, codebooks: jax.Array, idx: jax.Array,
                     valid: jax.Array, mode: str = "sum") -> jax.Array:
    """PQ-compressed lookup. codes (V, m) uint8; codebooks (m, K, dsub)."""
    m, k, dsub = codebooks.shape
    row_codes = jnp.take(codes, jnp.clip(idx, 0, codes.shape[0] - 1),
                         axis=0).astype(jnp.int32)          # (..., nnz, m)
    # decode: out[..., s, :] = codebooks[s, code_s]
    s_idx = jnp.broadcast_to(jnp.arange(m), row_codes.shape)
    rows = codebooks[s_idx, row_codes]                       # (..., nnz, m, dsub)
    rows = rows.reshape(*row_codes.shape[:-1], m * dsub)
    rows = jnp.where(valid[..., None], rows, 0.0)
    out = rows.sum(axis=-2)
    if mode == "mean":
        out = out / jnp.maximum(valid.sum(axis=-1, keepdims=True), 1)
    return out


def mlp(params: list, x: jax.Array, final_act: bool = False) -> jax.Array:
    for i, lp in enumerate(params):
        x = x @ lp["w"] + lp["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def init_mlp(key: jax.Array, dims: list, dtype=jnp.float32) -> list:
    keys = jax.random.split(key, len(dims) - 1)
    return [{"w": (jax.random.normal(keys[i], (dims[i], dims[i + 1])) /
                   jnp.sqrt(dims[i])).astype(dtype),
             "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)]
