"""MIND (Li et al., arXiv:1904.08030) — multi-interest retrieval with
capsule routing. **The star cell for EMVB applicability** (DESIGN.md §5):
a MIND user is a *multi-vector* representation (n_interests capsules) and
candidate scoring is exactly late interaction with n_q = n_interests —
``retrieval_cand`` runs through the EMVB engine.

Behaviour-to-Interest (B2I) dynamic routing, 3 iterations; label-aware
attention for the training loss; serving score = max_k (interest_k . item).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    vocab_items: int = 200000
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50
    pow_label_aware: float = 2.0
    dtype: Any = jnp.float32


def init_params(key: jax.Array, cfg: MINDConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "item_emb": (jax.random.normal(k1, (cfg.vocab_items, cfg.embed_dim))
                     * 0.05).astype(cfg.dtype),
        # shared bilinear routing map S (B2I routing, Eq. 4 of the paper)
        "s": (jax.random.normal(k2, (cfg.embed_dim, cfg.embed_dim)) *
              (1.0 / jnp.sqrt(cfg.embed_dim))).astype(cfg.dtype),
    }


def _squash(x: jax.Array) -> jax.Array:
    n2 = jnp.sum(x * x, axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def user_interests(params: Params, hist_items: jax.Array, hist_valid: jax.Array,
                   cfg: MINDConfig) -> jax.Array:
    """hist (B, L) -> interest capsules (B, K, D), L2-normalized."""
    e = jnp.take(params["item_emb"], hist_items, axis=0)      # (B, L, D)
    eh = e @ params["s"]                                       # (B, L, D)
    b_sz, seq_len, d = e.shape
    k = cfg.n_interests
    # routing logits init: fixed (deterministic) per-position pattern — the
    # paper uses random init; a fixed hash keeps the fn jit-pure.
    blogit = jnp.sin(jnp.arange(seq_len)[:, None]
                     * (1.0 + jnp.arange(k))[None, :])
    blogit = jnp.broadcast_to(blogit, (b_sz, seq_len, k)).astype(jnp.float32)
    neg = -1e9
    caps = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(jnp.where(hist_valid[..., None], blogit, neg),
                           axis=1)                             # over L
        caps = _squash(jnp.einsum("blk,bld->bkd", w.astype(cfg.dtype), eh))
        blogit = blogit + jnp.einsum("bkd,bld->blk", caps, eh).astype(jnp.float32)
    caps = caps / jnp.maximum(jnp.linalg.norm(caps, axis=-1, keepdims=True),
                              1e-9)
    return caps                                                # (B, K, D)


def score_candidates(interests: jax.Array, item_embs: jax.Array) -> jax.Array:
    """Late interaction with n_q = K: max_k interest_k . item.
    interests (B, K, D); item_embs (N, D) -> (B, N)."""
    return jnp.einsum("bkd,nd->bkn", interests, item_embs).max(axis=1)


def forward(params: Params, batch: dict, cfg: MINDConfig) -> jax.Array:
    """Training-style forward: label-aware attention score of target item."""
    caps = user_interests(params, batch["hist_items"], batch["hist_valid"], cfg)
    tgt = jnp.take(params["item_emb"], batch["target_item"], axis=0)
    att = jnp.einsum("bkd,bd->bk", caps, tgt)
    w = jax.nn.softmax(cfg.pow_label_aware * att.astype(jnp.float32), axis=-1)
    v_user = jnp.einsum("bk,bkd->bd", w.astype(cfg.dtype), caps)
    return jnp.einsum("bd,bd->b", v_user, tgt)


def loss_fn(params: Params, batch: dict, cfg: MINDConfig) -> jax.Array:
    """Sampled-softmax-style in-batch loss over target items."""
    caps = user_interests(params, batch["hist_items"], batch["hist_valid"], cfg)
    tgt = jnp.take(params["item_emb"], batch["target_item"], axis=0)  # (B, D)
    att = jnp.einsum("bkd,jd->bkj", caps, tgt)                 # (B, K, B)
    scores = att.max(axis=1).astype(jnp.float32)               # (B, B)
    labels = jnp.arange(scores.shape[0])
    logp = jax.nn.log_softmax(scores, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
