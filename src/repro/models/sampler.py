"""Uniform neighbor sampler for mini-batch GNN training (GraphSAGE-style).

Real sampler over a padded neighbor table (CSR rows padded to max_degree with
a sentinel): for each seed, draw ``fanout`` neighbors uniformly with
replacement (the standard trick that keeps shapes static on TPU; invalid
draws — padding — are masked, not resampled). Produces per-hop node-id arrays
and block edge lists consumable by ``gcn.forward_sampled``.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp


def pad_adjacency(row_ptr, col_idx, n_nodes: int, max_degree: int,
                  sentinel: int):
    """CSR -> padded (n_nodes, max_degree) neighbor table + (n_nodes,) degree."""
    import numpy as np
    nbr = np.full((n_nodes, max_degree), sentinel, dtype=np.int32)
    deg = np.zeros((n_nodes,), dtype=np.int32)
    for v in range(n_nodes):
        lo, hi = row_ptr[v], row_ptr[v + 1]
        d = min(hi - lo, max_degree)
        nbr[v, :d] = col_idx[lo:lo + d]
        deg[v] = d
    return jnp.asarray(nbr), jnp.asarray(deg)


def sample_hop(key: jax.Array, seeds: jax.Array, nbr_table: jax.Array,
               degrees: jax.Array, fanout: int
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """seeds (B,) -> (neighbors (B*fanout,), edges (2, B*fanout), mask)."""
    b = seeds.shape[0]
    deg = jnp.take(degrees, seeds)                          # (B,)
    draw = jax.random.randint(key, (b, fanout), 0, 1 << 30)
    col = draw % jnp.maximum(deg, 1)[:, None]               # (B, fanout)
    nbrs = jnp.take(nbr_table, seeds, axis=0)               # (B, max_deg)
    picked = jnp.take_along_axis(nbrs, col, axis=1)         # (B, fanout)
    valid = (deg > 0)[:, None] & jnp.ones((b, fanout), jnp.bool_)
    src = picked.reshape(-1)                                # hop-(i+1) ids
    dst = jnp.repeat(jnp.arange(b, dtype=jnp.int32), fanout)
    return src, jnp.stack([jnp.arange(b * fanout, dtype=jnp.int32), dst]), \
        valid.reshape(-1)


def sample_blocks(key: jax.Array, seeds: jax.Array, nbr_table: jax.Array,
                  degrees: jax.Array, fanouts: List[int]):
    """Layered sampling. Returns (node_ids per hop, blocks) where
    blocks[i] = {'edges' (2, E_i) [src -> local hop-(i+1) idx, dst -> local
    hop-i idx], 'edge_mask'}."""
    hop_nodes = [seeds]
    blocks = []
    cur = seeds
    for i, f in enumerate(fanouts):
        key, sub = jax.random.split(key)
        src_ids, edges, mask = sample_hop(sub, cur, nbr_table, degrees, f)
        hop_nodes.append(src_ids)
        blocks.append({"edges": edges, "edge_mask": mask})
        cur = src_ids
    return hop_nodes, blocks
