"""Shared transformer layers: norms, RoPE, GQA attention (optional QKV bias),
SwiGLU MLP. Parameters are plain pytrees (nested dicts) so sharding rules can
be assigned by path patterns (repro.sharding.rules).

Compute dtype policy: matmuls in ``cfg.dtype`` (bf16 on TPU), softmax and
norm statistics in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "lm"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 2
    d_head: int = 32
    d_ff: int = 256
    vocab: int = 1024
    qkv_bias: bool = False            # Qwen2.5 uses QKV bias
    causal: bool = True               # False for the ColBERT encoder
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    dtype: Any = jnp.float32          # bf16 for dry-run / TPU
    # MoE (0 experts -> dense FFN)
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # retrieval-encoder head (ColBERT): project to dim>0
    out_proj: int = 0
    tie_embeddings: bool = False
    # flash-style chunked causal attention (0 = dense); used when causal,
    # no cache, and seq_len >= attn_chunk_min_seq (dense logits at 4k fit
    # HBM once TP shards the heads; chunking only pays at 8k+)
    attn_q_chunk: int = 0
    attn_kv_chunk: int = 0
    attn_chunk_min_seq: int = 8192
    # sequence-parallel attention (context parallelism): PartitionSpecs
    # (q_spec, kv_spec) forced on q / k,v right before attention. Used when
    # the arch's head counts don't divide the model axis (40H/8KV vs 16):
    # left to itself GSPMD shards d_head 2-way and pays a partial-sum
    # all-reduce of every flash logits block INSIDE the chunk scans (§Perf).
    # q gets seq-sharded over "model", k/v replicated -> attention is
    # collective-free; requires a mesh context at trace time. None = off.
    attn_act_specs: Any = None
    # Megatron-SP residual stream: PartitionSpec forced on x after each
    # residual add (seq over "model") — turns the TP partial-sum all-reduces
    # into reduce-scatters and keeps norms on 1/16th of the tokens.
    residual_spec: Any = None
    # MoE grouped dispatch (GShard): number of token groups (0 = capacity-
    # gather path) and (token_spec, expert_spec) PartitionSpecs for the
    # (g, t_l, ...) / (g, E, C, d) dispatch tensors.
    moe_groups: int = 0
    moe_specs: Any = None
    # activation-checkpoint policy for the layer scan: "dots" saves matmul
    # outputs with no batch dims (cheap recompute, more memory), "full"
    # saves nothing (max recompute, min memory — buys smaller grad_accum,
    # which is what bounds the per-microbatch FSDP gather count; §Perf).
    remat_policy: str = "dots"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def init_layer_params(key: jax.Array, cfg: ModelConfig) -> Params:
    """One transformer block's params (unstacked)."""
    ks = jax.random.split(key, 12)
    h, kv, dh, d, f = (cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model,
                       cfg.d_ff)
    p: Params = {
        "attn": {
            "wq": _dense_init(ks[0], (d, h * dh), cfg.dtype),
            "wk": _dense_init(ks[1], (d, kv * dh), cfg.dtype),
            "wv": _dense_init(ks[2], (d, kv * dh), cfg.dtype),
            "wo": _dense_init(ks[3], (h * dh, d), cfg.dtype),
        },
        "ln1": {"scale": jnp.ones((d,), cfg.dtype)},
        "ln2": {"scale": jnp.ones((d,), cfg.dtype)},
    }
    if cfg.qkv_bias:
        p["attn"]["bq"] = jnp.zeros((h * dh,), cfg.dtype)
        p["attn"]["bk"] = jnp.zeros((kv * dh,), cfg.dtype)
        p["attn"]["bv"] = jnp.zeros((kv * dh,), cfg.dtype)
    if cfg.is_moe:
        e = cfg.n_experts
        p["moe"] = {
            "router": _dense_init(ks[4], (d, e), jnp.float32),
            "wi_gate": _dense_init(ks[5], (e, d, f), cfg.dtype),
            "wi_up": _dense_init(ks[6], (e, d, f), cfg.dtype),
            "wo": _dense_init(ks[7], (e, f, d), cfg.dtype),
        }
        if cfg.n_shared_experts:
            fs = f * cfg.n_shared_experts
            p["shared_mlp"] = {
                "w_gate": _dense_init(ks[8], (d, fs), cfg.dtype),
                "w_up": _dense_init(ks[9], (d, fs), cfg.dtype),
                "w_down": _dense_init(ks[10], (fs, d), cfg.dtype),
            }
    else:
        p["mlp"] = {
            "w_gate": _dense_init(ks[4], (d, f), cfg.dtype),
            "w_up": _dense_init(ks[5], (d, f), cfg.dtype),
            "w_down": _dense_init(ks[6], (f, d), cfg.dtype),
        }
    return p


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope_tables(positions: jax.Array, d_head: int,
                theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) int32 -> cos/sin (..., d_head//2) fp32."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, Dh); cos/sin (..., S, Dh//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c, s = cos[..., None, :], sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


def gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: Optional[jax.Array]) -> jax.Array:
    """q (B,S,H,Dh), k/v (B,T,KV,Dh) -> (B,S,H,Dh). Softmax in fp32."""
    b, s, h, dh = q.shape
    kv = k.shape[2]
    groups = h // kv
    qg = q.reshape(b, s, kv, groups, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(dh).astype(jnp.float32)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, dh)


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             q_chunk: int, kv_chunk: int,
                             act_specs=None) -> jax.Array:
    """Flash-style causal attention in pure JAX: online softmax over KV
    chunks under a scan over query chunks. Peak intermediate is
    (B, KV, G, q_chunk, kv_chunk) instead of (B, KV, G, S, S) — what makes
    the 32k prefill cells fit HBM (DESIGN.md §4).

    q (B,S,H,Dh), k/v (B,S,KV,Dh) -> (B,S,H,Dh). Requires S % chunks == 0.

    ``act_specs=(qg_spec, kv_spec)``: context parallelism for head counts
    that don't divide the model axis — the *within-chunk* q position dim of
    qg (B, nq, q_chunk, KV, G, Dh) is seq-sharded (the scan axis nq must
    stay unsharded: scan is sequential), k/v chunks are replicated, so the
    flash inner loop is collective-free.
    """
    b, s, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    nq, nk = s // q_chunk, s // kv_chunk
    qg = q.reshape(b, nq, q_chunk, kv, g, dh)
    kc = k.reshape(b, nk, kv_chunk, kv, dh)
    vc = v.reshape(b, nk, kv_chunk, kv, dh)
    if act_specs is not None:
        qg_spec, kv_spec = act_specs
        qg = jax.lax.with_sharding_constraint(qg, qg_spec)
        kc = jax.lax.with_sharding_constraint(kc, kv_spec)
        vc = jax.lax.with_sharding_constraint(vc, kv_spec)

    def q_step(_, qi):
        qblk, qidx = qi                              # (B, qc, KV, G, Dh)
        q_pos = qidx * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, lsum, acc = carry
            kblk, vblk, kidx = ki
            k_pos = kidx * kv_chunk + jnp.arange(kv_chunk)
            logits = jnp.einsum("bqkgd,btkd->bkgqt", qblk,
                                kblk).astype(jnp.float32) * scale
            causal = q_pos[:, None] >= k_pos[None, :]
            logits = jnp.where(causal[None, None, None], logits, -1e30)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            lsum_new = lsum * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(qblk.dtype),
                            vblk).astype(jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, lsum_new, acc_new), None

        m0 = jnp.full((b, kv, g, q_chunk), -1e30, jnp.float32)
        lsum0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kv, g, q_chunk, dh), jnp.float32)
        (m, lsum, acc), _ = jax.lax.scan(
            kv_step, (m0, lsum0, a0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1),
             jnp.arange(nk, dtype=jnp.int32)))
        out = (acc / jnp.maximum(lsum, 1e-30)[..., None]).astype(q.dtype)
        return None, out.transpose(0, 3, 1, 2, 4)    # (B, qc, KV, G, Dh)

    _, outs = jax.lax.scan(q_step, None,
                           (qg.swapaxes(0, 1),
                            jnp.arange(nq, dtype=jnp.int32)))
    out = outs.swapaxes(0, 1).reshape(b, s, h, dh)
    return out


def attention_block(p: Params, x: jax.Array, cfg: ModelConfig,
                    positions: jax.Array, mask: Optional[jax.Array],
                    cache: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None
                    ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Returns (out, (k, v)).

    Without ``cache``: k/v are this call's keys/values (for the caller to
    stack into a prefill cache). With ``cache=(k_layer, v_layer, pos)``
    (decode): the new k/v are merged into the cache at ``pos``, attention
    runs over the merged cache, and the merged (k, v) are returned.
    """
    b, s, d = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kvh, dh)
    v = v.reshape(b, s, kvh, dh)
    cos, sin = rope_tables(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if cache is not None:
        k_layer, v_layer, pos = cache
        k = jax.lax.dynamic_update_slice_in_dim(k_layer, k.astype(k_layer.dtype),
                                                pos, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(v_layer, v.astype(v_layer.dtype),
                                                pos, axis=1)
    use_chunked = (cache is None and cfg.causal and cfg.attn_q_chunk > 0 and
                   s >= cfg.attn_chunk_min_seq and
                   s % cfg.attn_q_chunk == 0 and s % cfg.attn_kv_chunk == 0)
    if use_chunked:
        out = chunked_causal_attention(q, k, v, cfg.attn_q_chunk,
                                       cfg.attn_kv_chunk,
                                       act_specs=cfg.attn_act_specs)
    else:
        if cfg.attn_act_specs is not None and cache is None:
            # dense path context parallelism: q seq-sharded, k/v replicated
            qg_spec, _ = cfg.attn_act_specs
            from jax.sharding import PartitionSpec as P
            q = jax.lax.with_sharding_constraint(
                q, P(qg_spec[0], qg_spec[2], None, None))
            kv4 = P(qg_spec[0], None, None, None)
            k = jax.lax.with_sharding_constraint(k, kv4)
            v = jax.lax.with_sharding_constraint(v, kv4)
        out = gqa_attention(q, k, v, mask)
    return out.reshape(b, s, h * dh) @ p["wo"], (k, v)


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
