"""ColBERT-style multi-vector encoder — the model side of the paper's system
(ColBERTv2 produces the embeddings EMVB indexes; paper §5).

A bidirectional transformer over token ids, projected to ``out_proj`` dims and
L2-normalized: one vector per token. Trained with an in-batch contrastive
MaxSim loss; optional STE product quantization of residuals *during* training
reproduces JMPQ ("joint optimization of PQ with the fine-tuning", Fang et al.
2022) inside this framework.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import ModelConfig, Params
from .transformer import forward_hidden, init_params as _init_lm


def make_config(*, n_layers=4, d_model=256, n_heads=4, d_head=64, d_ff=512,
                vocab=30522, out_dim=128, dtype=jnp.float32) -> ModelConfig:
    return ModelConfig(name="colbert", n_layers=n_layers, d_model=d_model,
                       n_heads=n_heads, n_kv_heads=n_heads, d_head=d_head,
                       d_ff=d_ff, vocab=vocab, causal=False, out_proj=out_dim,
                       dtype=dtype)


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    return _init_lm(key, cfg)


def encode(params: Params, tokens: jax.Array, valid: jax.Array,
           cfg: ModelConfig) -> jax.Array:
    """tokens/valid (B, S) -> per-token embeddings (B, S, out_dim), zeroed at
    padding, L2-normalized elsewhere."""
    # bidirectional attention restricted to valid tokens
    attn_mask = (valid[:, None, :] & valid[:, :, None])[:, None, None, :, :]
    h, _ = forward_hidden(params, tokens, cfg, attn_mask=attn_mask,
                          remat=False)
    e = h @ params["proj"]
    e = e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True), 1e-9)
    return jnp.where(valid[..., None], e, 0.0)


def maxsim_scores(qe: jax.Array, qv: jax.Array, de: jax.Array,
                  dv: jax.Array) -> jax.Array:
    """In-batch late-interaction score matrix.

    qe (B, Sq, d) queries, de (B, Sd, d) docs -> (B, B) MaxSim scores."""
    sim = jnp.einsum("iqd,jtd->ijqt", qe, de)
    sim = jnp.where(dv[None, :, None, :], sim, -1e9)
    best = sim.max(axis=-1)                          # (B, B, Sq)
    best = jnp.where(qv[:, None, :], best, 0.0)
    return best.sum(axis=-1)


def contrastive_loss(params: Params, batch: dict, cfg: ModelConfig,
                     pq_codebooks: Optional[jax.Array] = None) -> jax.Array:
    """In-batch softmax over MaxSim scores; diagonal = positives.

    With ``pq_codebooks`` (m, K, dsub): JMPQ-style — document embeddings are
    STE-quantized (centroid-free variant: direct PQ of the token embedding),
    so the encoder co-adapts with the quantizer.
    """
    qe = encode(params, batch["q_tokens"], batch["q_valid"], cfg)
    de = encode(params, batch["d_tokens"], batch["d_valid"], cfg)
    if pq_codebooks is not None:
        from repro.core.pq import PQCodebooks, pq_ste
        b, s, d = de.shape
        de = pq_ste(de.reshape(-1, d), PQCodebooks(pq_codebooks)).reshape(b, s, d)
    scores = maxsim_scores(qe, batch["q_valid"], de, batch["d_valid"])
    labels = jnp.arange(scores.shape[0])
    logp = jax.nn.log_softmax(scores.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
