"""Model zoo: assigned architectures + the ColBERT-style retrieval encoder."""
