"""GCN (Kipf & Welling, arXiv:1609.02907) via edge-index message passing.

JAX has no CSR SpMM — message passing is built from gather + segment_sum over
an edge list (this IS the system, per the assignment): for symmetric
normalization Ã = D^-1/2 (A + I) D^-1/2,

    h' = Ã h W  ==  segment_sum( (deg_s deg_d)^-1/2 * h[src], dst ) W

Two execution modes:
  * full-graph (cora / ogbn-products): one edge list, optionally sharded
    across the mesh (partial segment_sum per shard + all-reduce under GSPMD);
  * sampled mini-batch (reddit-scale `minibatch_lg`): layered fanout
    subgraphs from ``sampler.py``, aggregated layer by layer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn"
    n_layers: int = 2
    d_feat: int = 1433
    d_hidden: int = 16
    n_classes: int = 7
    aggregator: str = "mean"   # 'mean' (sym-normalized) per the cora config
    dtype: Any = jnp.float32


def init_params(key: jax.Array, cfg: GCNConfig) -> Params:
    dims = [cfg.d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(key, cfg.n_layers)
    return {
        f"layer{i}": {
            "w": (jax.random.normal(keys[i], (dims[i], dims[i + 1])) *
                  (1.0 / jnp.sqrt(dims[i]))).astype(cfg.dtype),
            "b": jnp.zeros((dims[i + 1],), cfg.dtype),
        }
        for i in range(cfg.n_layers)
    }


def _degrees(edges: jax.Array, n_nodes: int, edge_mask: jax.Array) -> jax.Array:
    ones = edge_mask.astype(jnp.float32)
    deg = jax.ops.segment_sum(ones, edges[1], num_segments=n_nodes)
    return deg + 1.0  # + self loop


def propagate(x: jax.Array, edges: jax.Array, edge_mask: jax.Array,
              n_nodes: int) -> jax.Array:
    """One sym-normalized propagation Ã x. edges (2, E) [src, dst] int32."""
    deg = _degrees(edges, n_nodes, edge_mask)
    inv_sqrt = jax.lax.rsqrt(deg)
    src, dst = edges[0], edges[1]
    coef = (jnp.take(inv_sqrt, src) * jnp.take(inv_sqrt, dst) *
            edge_mask.astype(jnp.float32))
    msg = jnp.take(x, src, axis=0) * coef[:, None]
    agg = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
    return agg + x * (inv_sqrt * inv_sqrt)[:, None]  # self loop


def forward(params: Params, feats: jax.Array, edges: jax.Array,
            edge_mask: jax.Array, cfg: GCNConfig) -> jax.Array:
    """feats (N, F) -> logits (N, n_classes)."""
    n = feats.shape[0]
    x = feats.astype(cfg.dtype)
    for i in range(cfg.n_layers):
        x = propagate(x, edges, edge_mask, n)
        lp = params[f"layer{i}"]
        x = x @ lp["w"] + lp["b"]
        if i < cfg.n_layers - 1:
            x = jax.nn.relu(x)
    return x


def loss_fn(params: Params, batch: dict, cfg: GCNConfig) -> jax.Array:
    """batch: feats (N,F), edges (2,E), edge_mask (E,), labels (N,) int32
    (-1 = unlabeled)."""
    logits = forward(params, batch["feats"], batch["edges"],
                     batch["edge_mask"], cfg).astype(jnp.float32)
    labels = batch["labels"]
    valid = labels >= 0
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, jnp.maximum(labels, 0)[:, None],
                               axis=-1)[:, 0]
    return jnp.where(valid, nll, 0.0).sum() / jnp.maximum(valid.sum(), 1)


# ---------------------------------------------------------------------------
# sampled mini-batch forward (GraphSAGE-style layered blocks)
# ---------------------------------------------------------------------------

def forward_sampled(params: Params, blocks: List[dict], seed_feats: jax.Array,
                    layer_feats: List[jax.Array], cfg: GCNConfig) -> jax.Array:
    """blocks[i]: {'edges': (2, Ei) int32 — src indexes layer i+1 nodes, dst
    indexes layer i nodes; 'edge_mask': (Ei,)}; layer_feats[i] = features of
    layer-i nodes ((N_i, F)); layer 0 = seed nodes. Aggregation runs from the
    outermost layer inward."""
    xs = [seed_feats] + layer_feats  # xs[i] = features at hop i
    h = [x.astype(cfg.dtype) for x in xs]
    for li in range(cfg.n_layers):
        # layer li produces representations for hops 0..len(h)-2, each
        # aggregating from one hop further out; the hop list shrinks by one.
        new_h = []
        for hop in range(len(h) - 1):
            edges = blocks[hop]["edges"]
            emask = blocks[hop]["edge_mask"]
            n_dst = h[hop].shape[0]
            deg = jax.ops.segment_sum(emask.astype(jnp.float32), edges[1],
                                      num_segments=n_dst) + 1.0
            msg = jnp.take(h[hop + 1], edges[0], axis=0) * \
                emask.astype(cfg.dtype)[:, None]
            agg = jax.ops.segment_sum(msg, edges[1], num_segments=n_dst)
            mixed = (agg + h[hop]) / deg[:, None]
            lp = params[f"layer{li}"]
            out = mixed @ lp["w"] + lp["b"]
            if li < cfg.n_layers - 1:
                out = jax.nn.relu(out)
            new_h.append(out)
        h = new_h
    return h[0]


def loss_fn_sampled(params: Params, batch: dict, cfg: GCNConfig) -> jax.Array:
    blocks = [{"edges": batch[f"edges{i}"], "edge_mask": batch[f"edge_mask{i}"]}
              for i in range(cfg.n_layers)]
    layer_feats = [batch[f"feats{i + 1}"] for i in range(cfg.n_layers)]
    logits = forward_sampled(params, blocks, batch["feats0"], layer_feats,
                             cfg).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    return nll.mean()
