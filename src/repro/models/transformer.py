"""Decoder-only transformer LM (dense or MoE) with GQA, RoPE, SwiGLU.

Layer parameters are *stacked* along a leading ``n_layers`` axis and the
layer stack runs under ``lax.scan`` (+ optional remat): one layer is compiled
once regardless of depth — essential for the 61-layer/1T dry-run configs.

Entry points:
  init_params / abstract_params        (abstract via jax.eval_shape)
  forward(params, tokens)              full causal forward -> logits
  loss_fn(params, batch)               next-token CE (+ MoE aux)
  prefill(params, tokens)              -> (logits, KVCache)
  decode_step(params, cache, tok, pos) -> (logits, KVCache)
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import (ModelConfig, Params, attention_block, init_layer_params,
                     rms_norm, swiglu)
from .moe import moe_block


class KVCache(NamedTuple):
    k: jax.Array  # (L, B, S, KV, Dh)
    v: jax.Array  # (L, B, S, KV, Dh)


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    k_embed, k_layers, k_head, k_proj = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer_params(k, cfg))(layer_keys)
    p: Params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) *
                  0.02).astype(cfg.dtype),
        "layers": layers,
        "final_norm": {"scale": jnp.ones((cfg.d_model,), cfg.dtype)},
    }
    if not cfg.tie_embeddings and cfg.vocab > 0:
        p["lm_head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.vocab)) *
                        0.02).astype(cfg.dtype)
    if cfg.out_proj:
        p["proj"] = (jax.random.normal(k_proj, (cfg.d_model, cfg.out_proj)) *
                     0.02).astype(cfg.dtype)
    return p


def abstract_params(cfg: ModelConfig):
    """Param pytree of ShapeDtypeStructs — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# layer body
# ---------------------------------------------------------------------------

def _layer(lp: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array,
           mask: Optional[jax.Array],
           cache=None) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array],
                                jax.Array]:
    def _sp(t):
        # Megatron-SP: residual stream sharded on seq (ModelConfig docs)
        if cfg.residual_spec is not None and cache is None:
            return jax.lax.with_sharding_constraint(t, cfg.residual_spec)
        return t

    h, kv = attention_block(lp["attn"], rms_norm(x, lp["ln1"]["scale"],
                                                 cfg.norm_eps),
                            cfg, positions, mask, cache)
    x = _sp(x + h)
    hin = rms_norm(x, lp["ln2"]["scale"], cfg.norm_eps)
    if cfg.is_moe:
        ff, aux = moe_block(lp["moe"], hin, cfg)
        if cfg.n_shared_experts:
            ff = ff + swiglu(lp["shared_mlp"], hin)
    else:
        ff, aux = swiglu(lp["mlp"], hin), jnp.float32(0)
    return _sp(x + ff), kv, aux


# ---------------------------------------------------------------------------
# training forward
# ---------------------------------------------------------------------------

def forward_hidden(params: Params, tokens: jax.Array, cfg: ModelConfig,
                   attn_mask: Optional[jax.Array] = None,
                   remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> (hidden (B, S, d), moe_aux scalar)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, axis=0)
    if attn_mask is not None:
        mask = attn_mask
    elif cfg.causal:
        mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    else:
        mask = None

    def body(carry, lp):
        x, aux = carry
        x, _, a = _layer(lp, x, cfg, positions, mask)
        return (x, aux + a), None

    if remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)), params["layers"])
    return rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps), aux


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> (logits (B, S, V), moe_aux)."""
    h, aux = forward_hidden(params, tokens, cfg, remat=remat)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head, aux


def loss_fn(params: Params, batch: dict, cfg: ModelConfig,
            aux_weight: float = 0.01) -> jax.Array:
    """batch: tokens (B, S) int32, labels (B, S) int32 (-1 = ignore)."""
    logits, aux = forward(params, batch["tokens"], cfg)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    valid = labels >= 0
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, lse - picked, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1) + aux_weight * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------

def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig
            ) -> Tuple[jax.Array, KVCache]:
    """tokens (B, S) -> (last-position logits (B, V), cache)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, axis=0)
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))

    def body(x, lp):
        x, (k, v), _ = _layer(lp, x, cfg, positions, mask)
        return x, (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    h = rms_norm(x[:, -1], params["final_norm"]["scale"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head, KVCache(ks, vs)


def decode_step(params: Params, cache: KVCache, token: jax.Array,
                pos: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, KVCache]:
    """One decode step. token (B,) int32; pos scalar int32 = index of the new
    token (cache holds ``pos`` valid entries before the call).

    cache k/v (L, B, S, KV, Dh); the new token's k/v are written at ``pos``
    and attention runs over positions <= pos.
    """
    b = token.shape[0]
    s_max = cache.k.shape[2]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(cfg.dtype)
    positions = jnp.full((b, 1), pos, jnp.int32)
    att_mask = (jnp.arange(s_max) <= pos)[None, None, None, None, :]

    def body(x, scanned):
        lp, k_layer, v_layer = scanned
        x, (k_merged, v_merged), _ = _layer(lp, x, cfg, positions, att_mask,
                                            cache=(k_layer, v_layer, pos))
        return x, (k_merged, v_merged)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
    h = rms_norm(x[:, 0], params["final_norm"]["scale"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ head, KVCache(ks, vs)
