"""EMVB contributions C2 (column-wise centroid interaction) and C3+C4
(PQ late interaction with dynamic per-term filtering) — paper §4.3–4.4.

All functions are fixed-shape, jit/vmap/pjit-compatible jnp references; the
Pallas kernels in ``repro.kernels.cinter`` / ``repro.kernels.pqscore``
implement the same math with explicit VMEM tiling.

Shape conventions
-----------------
  n_q      query terms (32 for ColBERT, 4 for MIND)
  n_c      number of centroids
  cap      padded tokens per document
  nf / nd  number of docs surviving phase-2 / phase-3 selection
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e9


def term_sum(colmax: jax.Array) -> jax.Array:
    """Sum (..., n_q) per-term maxima over the query-term axis in a FIXED
    left-to-right order (statically unrolled chain; n_q <= 32).

    Why not ``jnp.sum``: XLA is free to pick the reduction tree per shape,
    so a padded query (n_q=32, masked tail zeroed) and its unpadded prefix
    (n_q=20) could parenthesize the SAME live terms differently — a 1-ulp
    drift that breaks the padded == prefix bit-exactness contract. A fixed
    chain makes the contract a mathematical identity: adding 0.0 to any
    partial sum is exact, so zeroed (masked) slots are no-ops wherever they
    sit. Used by the jnp reference AND every kernel (sbar_block /
    eq56_block) — identical order is what keeps them bitwise equal; keep
    them in lockstep.

    Half-precision inputs accumulate in f32 and round ONCE at the end —
    the same semantics ``jnp.sum`` gives bf16 (upcast-for-computation),
    and the only ordering that stays deterministic under Pallas interpret
    mode, which computes bf16 chains at f32 precision without per-add
    rounding."""
    acc = colmax
    if colmax.dtype in (jnp.bfloat16, jnp.float16):
        acc = colmax.astype(jnp.float32)
    out = acc[..., 0]
    for i in range(1, acc.shape[-1]):
        out = out + acc[..., i]
    return out.astype(colmax.dtype)


def gather_centroid_scores(cs_t: jax.Array, codes: jax.Array) -> jax.Array:
    """Build P̃^T for a batch of docs by gathering rows of CS^T (paper §4.3).

    cs_t  : (n_c, n_q)   transposed centroid-score matrix (one query)
    codes : (docs, cap)  int32 token centroid ids
    ->    (docs, cap, n_q)
    """
    return jnp.take(cs_t, jnp.clip(codes, 0, cs_t.shape[0] - 1), axis=0)


def centroid_interaction(cs_t: jax.Array, codes: jax.Array,
                         token_mask: jax.Array,
                         q_mask: jax.Array | None = None) -> jax.Array:
    """Approximate passage score S̄ (paper Eq. 2) via column-wise max-reduce.

    cs_t (n_c, n_q); codes/token_mask (docs, cap) -> (docs,)
    q_mask optional (n_q,) bool — masked (padded / pruned) query terms
    contribute 0 to the sum instead of a spurious per-term max. Zeroing
    (rather than dropping) keeps the shape static; adding 0.0 is exact in
    fp, so a masked score equals the unpadded-prefix score bit for bit.
    """
    pt = gather_centroid_scores(cs_t, codes)             # (docs, cap, n_q)
    pt = jnp.where(token_mask[..., None], pt, NEG)
    colmax = jnp.max(pt, axis=-2)                        # (docs, n_q)
    if q_mask is not None:
        colmax = jnp.where(q_mask, colmax, 0.0)
    return term_sum(colmax)


def centroid_interaction_batch(cs_t: jax.Array, codes: jax.Array,
                               token_mask: jax.Array) -> jax.Array:
    """cs_t (B, n_c, n_q); codes/mask (B, docs, cap) -> (B, docs)."""
    return jax.vmap(centroid_interaction)(cs_t, codes, token_mask)


def maxsim(q: jax.Array, doc_emb: jax.Array, token_mask: jax.Array) -> jax.Array:
    """Exact late interaction (paper Eq. 3) on full-precision embeddings.

    q (n_q, d); doc_emb (docs, cap, d); token_mask (docs, cap) -> (docs,)
    """
    sim = jnp.einsum("qd,ntd->nqt", q, doc_emb)
    sim = jnp.where(token_mask[:, None, :], sim, NEG)
    return jnp.max(sim, axis=-1).sum(axis=-1)


def late_interaction_pq(cs_t: jax.Array, lut: jax.Array, codes: jax.Array,
                        res_codes: jax.Array, token_mask: jax.Array,
                        th_r: float | None,
                        centroid: jax.Array | None = None,
                        q_mask: jax.Array | None = None) -> jax.Array:
    """PQ late interaction with optional dynamic term filter (Eq. 5 / Eq. 6).

    cs_t       : (n_c, n_q)       centroid scores, transposed (one query)
    lut        : (n_q, m, K)      PQ inner-product LUT for this query
    codes      : (docs, cap)      token centroid ids
    res_codes  : (docs, cap, m)   PQ codes of token residuals
    token_mask : (docs, cap)
    th_r       : None -> Eq. 5 (score every term);
                 float -> Eq. 6: per query term i, max over
                 J̄_i = {j : centroid_score_ij > th_r}; fall back to Eq. 5
                 for terms with empty J̄_i.
    centroid   : optional precomputed exact centroid term (docs, cap, n_q) —
                 used when cs_t is reduced-precision (cs_dtype=bf16) so the
                 FINAL scores stay exact while phases 1-3 ride the cheap CS.
    q_mask     : optional (n_q,) bool — masked (padded / pruned) terms are
                 excluded from the MaxSim sum entirely: no per-term max, no
                 Eq. 6 fallback. Zeroing keeps shapes static and fp-exact.
    -> (docs,) final scores
    """
    if centroid is None:
        centroid = gather_centroid_scores(cs_t, codes)            # (docs, cap, n_q)
    # residual[d, t, i] = sum_s lut[i, s, res_codes[d, t, s]]
    idx = res_codes.astype(jnp.int32)                              # (docs, cap, m)
    # lut (n_q, m, K) -> gather along K with idx (docs, cap, m)
    gathered = _lut_gather(lut, idx)                               # (docs, cap, n_q)
    full = centroid + gathered
    full = jnp.where(token_mask[..., None], full, NEG)

    if th_r is None:
        colmax = jnp.max(full, axis=-2)
    else:
        keep = (centroid > th_r) & token_mask[..., None]           # (docs, cap, n_q)
        masked = jnp.where(keep, full, NEG)
        masked_max = jnp.max(masked, axis=-2)                      # (docs, n_q)
        full_max = jnp.max(full, axis=-2)
        any_keep = jnp.any(keep, axis=-2)
        colmax = jnp.where(any_keep, masked_max, full_max)
    if q_mask is not None:
        colmax = jnp.where(q_mask, colmax, 0.0)
    return term_sum(colmax)


def _lut_gather(lut: jax.Array, idx: jax.Array) -> jax.Array:
    """lut (n_q, m, K), idx (docs, cap, m) int32 -> (docs, cap, n_q).

    Per-subspace gathers over a transposed flat (m*K, n_q) table, accumulated
    in a static unrolled loop: each token's lookups read contiguous n_q-wide
    rows and the running (docs, cap, n_q) accumulator never materializes the
    (docs, cap, m, n_q) tensor the ``take(...).sum(-2)`` form does (~6x
    faster at k=1000 shapes, which itself beat the broadcasting 5-D
    take_along_axis form 1.8x; measured in §Perf notes). The s = 0..m-1
    accumulation order is the SAME one the Pallas kernels use, so kernel
    scores stay bitwise equal to this reference."""
    n_q, m, k = lut.shape
    flat = lut.reshape(n_q, m * k).T                       # (m*K, n_q)
    # int32 before the offset add: uint8 codes would wrap at m*K > 255
    idx32 = idx.astype(jnp.int32)
    out = jnp.take(flat, idx32[..., 0], axis=0)            # (docs, cap, n_q)
    for s in range(1, m):
        out = out + jnp.take(flat, idx32[..., s] + s * k, axis=0)
    return out


def late_interaction_pq_compact(cs_t: jax.Array, lut: jax.Array,
                                codes: jax.Array, res_codes: jax.Array,
                                token_mask: jax.Array, th_r: float,
                                cap_c: int,
                                q_mask: jax.Array | None = None) -> jax.Array:
    """TPU-adapted Eq. 6 (DESIGN.md §2 mode (b)): per-token compaction.

    A token is *kept* when ANY query term finds its centroid close
    (max_i CS[i, code] > th_r) — a superset of every J̄_i, computed with ONE
    scalar gather per token from the precomputed per-centroid row max. The
    cap_c buffer holds kept tokens first, then the best remaining tokens by
    keymax; the expensive centroid and LUT gathers run on cap_c << cap
    tokens. Terms whose J̄_i is empty fall back to the max over buffered
    tokens — keymax upper-bounds every term's centroid score, so the token
    achieving a term's true max ranks high under keymax and is (almost
    always) buffered; the paper's own observation that q·C̄ leads the max
    makes the residual tail of the fallback benign.

    q_mask (optional (n_q,) bool): masked terms are excluded from keymax
    (so they cannot keep tokens alive) AND from the final sum.
    """
    n_c = cs_t.shape[0]
    if q_mask is not None:
        row_max = jnp.max(jnp.where(q_mask[None, :], cs_t, NEG), axis=1)
    else:
        row_max = jnp.max(cs_t, axis=1)                    # (n_c,)
    keymax = jnp.take(row_max, jnp.clip(codes, 0, n_c - 1))
    keep = (keymax > th_r) & token_mask                    # (docs, cap)
    # rank: kept tokens first, best-centroid ordering inside each class
    rank = jnp.where(token_mask, keep.astype(jnp.float32) * 2.0 +
                     jax.nn.sigmoid(keymax), -1.0)
    _, sel = jax.lax.top_k(rank, cap_c)                    # (docs, cap_c)
    codes_c = jnp.take_along_axis(codes, sel, axis=1)
    mask_c = jnp.take_along_axis(token_mask, sel, axis=1)  # all valid tokens
    res_c = jnp.take_along_axis(res_codes, sel[..., None], axis=1)

    centroid = gather_centroid_scores(cs_t, codes_c)       # (docs, cap_c, n_q)
    full = centroid + _lut_gather(lut, res_c)
    full = jnp.where(mask_c[..., None], full, NEG)
    keep_t = (centroid > th_r) & mask_c[..., None]
    masked_max = jnp.max(jnp.where(keep_t, full, NEG), axis=-2)
    comp_max = jnp.max(full, axis=-2)
    any_keep = jnp.any(keep_t, axis=-2)
    colmax = jnp.where(any_keep, masked_max, comp_max)
    if q_mask is not None:
        colmax = jnp.where(q_mask, colmax, 0.0)
    return term_sum(colmax)


def scored_term_fraction(cs_t: jax.Array, codes: jax.Array,
                         token_mask: jax.Array, th_r: float,
                         q_mask: jax.Array | None = None) -> jax.Array:
    """Fraction of (term, token) residual evaluations kept by the Eq. 6 filter
    (paper Fig. 5, right). Returns a scalar in [0, 1]. Masked query terms
    (q_mask False) count in NEITHER the numerator NOR the denominator — the
    ratio is over live (term, token) pairs only."""
    centroid = gather_centroid_scores(cs_t, codes)
    keep = (centroid > th_r) & token_mask[..., None]
    n_terms = cs_t.shape[1]
    if q_mask is not None:
        keep = keep & q_mask
        n_terms = jnp.sum(q_mask)
    # denominator is separable: (# valid tokens) x (# live terms)
    return jnp.sum(keep) / jnp.maximum(jnp.sum(token_mask) * n_terms, 1)


def token_compaction_mask(cs_t: jax.Array, codes: jax.Array,
                          token_mask: jax.Array, th_r: float) -> jax.Array:
    """TPU-adapted per-token filter (DESIGN.md §2): a token is skipped when NO
    query term finds its centroid close, i.e. max_i centroid_ij <= th_r.
    Conservative superset of the paper's per-(i,j) criterion along i.
    -> (docs, cap) bool mask of tokens whose residuals must be scored."""
    centroid = gather_centroid_scores(cs_t, codes)
    return (jnp.max(centroid, axis=-1) > th_r) & token_mask
