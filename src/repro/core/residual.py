"""ColBERTv2 / PLAID b-bit residual codec — the *baseline* compressor.

Each residual dimension is bucketized into 2^b quantile buckets (b ∈ {1, 2});
codes are bit-packed 8/b per byte. Scoring requires an explicit decompression
step (centroid + bucket value) — exactly the cost the paper's PQ replaces.
Implemented faithfully so benchmarks can reproduce the PLAID column of
Table 1/2 and the Fig. 1 phase breakdown.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ResidualCodec(NamedTuple):
    """PLAID's b-bit quantile bucket codec for residual values."""

    cutoffs: jax.Array         # (2^b - 1,) bucket boundaries
    bucket_weights: jax.Array  # (2^b,) reconstruction values
    b: int                     # static: bits per dimension


def train_residual_codec(residuals: jax.Array, b: int) -> ResidualCodec:
    """Quantile bucketization over a sample of residual values (all dims pooled,
    as in ColBERTv2)."""
    flat = residuals.reshape(-1)
    nbuckets = 1 << b
    qs = jnp.linspace(0.0, 1.0, nbuckets + 1)[1:-1]
    cutoffs = jnp.quantile(flat, qs)
    mids = jnp.linspace(0.0, 1.0, 2 * nbuckets + 1)[1::2]
    bucket_weights = jnp.quantile(flat, mids)
    return ResidualCodec(cutoffs, bucket_weights, b)


def encode_residual(r: jax.Array, codec: ResidualCodec) -> jax.Array:
    """(..., d) -> (..., d * b / 8) uint8, bit-packed."""
    codes = jnp.searchsorted(codec.cutoffs, r).astype(jnp.uint8)  # (..., d)
    return pack_codes(codes, codec.b)


def decode_residual(packed: jax.Array, codec: ResidualCodec, d: int) -> jax.Array:
    """(..., d*b/8) uint8 -> (..., d) fp32 reconstruction."""
    codes = unpack_codes(packed, codec.b, d)
    return codec.bucket_weights[codes.astype(jnp.int32)]


def pack_codes(codes: jax.Array, b: int) -> jax.Array:
    """Pack b-bit codes (values < 2^b) along the last axis, 8/b per byte."""
    per = 8 // b
    *lead, d = codes.shape
    assert d % per == 0
    grp = codes.reshape(*lead, d // per, per).astype(jnp.uint32)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * b)
    packed = jnp.sum(grp << shifts, axis=-1)  # disjoint bit fields -> sum == OR
    return packed.astype(jnp.uint8)


def unpack_codes(packed: jax.Array, b: int, d: int) -> jax.Array:
    """Inverse of :func:`pack_codes`: (..., d*b/8) uint8 -> (..., d) codes."""
    per = 8 // b
    mask = jnp.uint32((1 << b) - 1)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * b)
    grp = (packed.astype(jnp.uint32)[..., None] >> shifts) & mask
    out = grp.reshape(*packed.shape[:-1], -1)
    return out[..., :d].astype(jnp.uint8)
