"""PLAID baseline engine (Santhanam et al., CIKM 2022) — the system EMVB beats.

Same index, same centroid vocabulary, but:
  * top-nprobe over the FULL centroid score matrix (no threshold pre-filter);
  * candidate filtering = centroid interaction over ALL candidates (no
    bit-vector phase);
  * final scoring DECOMPRESSES the b-bit residual codes into full-precision
    embeddings (centroid + bucket values) before exact MaxSim — the step the
    paper shows costs up to 5x the late interaction itself (Fig. 1).

Implemented with the same fixed-shape discipline so the two engines are
directly comparable in benchmarks (Table 1/2, Fig. 1/4).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import interaction
from .engine import candidate_bitmap, centroid_scores, RetrievalResult
from .index import PackedIndex
from .residual import decode_residual


@dataclasses.dataclass(frozen=True)
class PlaidConfig:
    """Static PLAID retrieval configuration (hashable jit argument)."""

    n_q: int = 32
    nprobe: int = 4
    n_docs: int = 64      # docs decompressed + exactly scored
    k: int = 10


def _retrieve_one(q: jax.Array, index: PackedIndex, token_mask: jax.Array,
                  cfg: PlaidConfig) -> RetrievalResult:
    n_docs_corpus = index.codes.shape[0]
    d = index.centroids.shape[1]

    # ---- phase 1: retrieval (full top-nprobe, the cost EMVB §4.1 attacks) ---
    cs = centroid_scores(q, index.centroids)                    # (n_q, n_c)
    _, probe_ids = jax.lax.top_k(cs, cfg.nprobe)
    bitmap = candidate_bitmap(index.ivf, index.ivf_lens, probe_ids,
                              n_docs_corpus)

    # ---- phase 2: filtering = centroid interaction on ALL candidates -------
    sbar_all = interaction.centroid_interaction(cs.T, index.codes, token_mask)
    sbar_all = jnp.where(bitmap, sbar_all, -jnp.inf)
    _, sel2 = jax.lax.top_k(sbar_all, cfg.n_docs)
    sel2 = sel2.astype(jnp.int32)

    # ---- phase 3: decompression (centroid + b-bit bucket residuals) --------
    codec = index.plaid_codec
    s2_codes = jnp.take(index.codes, sel2, axis=0)              # (nd, cap)
    s2_packed = jnp.take(index.plaid_res, sel2, axis=0)         # (nd, cap, db/8)
    res = decode_residual(s2_packed, codec, d)                  # (nd, cap, d)
    cent = jnp.take(index.centroids,
                    jnp.clip(s2_codes, 0, index.centroids.shape[0] - 1), axis=0)
    emb = cent + res                                            # (nd, cap, d)

    # ---- phase 4: exact late interaction on decompressed vectors -----------
    s2_mask = jnp.take(token_mask, sel2, axis=0)
    scores = interaction.maxsim(q, emb, s2_mask)
    top_scores, top_local = jax.lax.top_k(scores, cfg.k)
    return RetrievalResult(top_scores, jnp.take(sel2, top_local))


@functools.partial(jax.jit, static_argnames=("cfg",))
def retrieve(index: PackedIndex, queries: jax.Array,
             cfg: PlaidConfig) -> RetrievalResult:
    """PLAID retrieval: queries (B, n_q, d) -> top-k (scores, ids)."""
    token_mask = index.token_mask()
    return jax.vmap(lambda q: _retrieve_one(q, index, token_mask, cfg))(queries)


# Phase-split entry points for the Fig. 1 breakdown benchmark. -------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def phase_retrieval(index: PackedIndex, q: jax.Array, cfg: PlaidConfig):
    """PLAID phase 1: full top-nprobe probe -> (cs, candidate bitmap)."""
    cs = centroid_scores(q, index.centroids)
    _, probe_ids = jax.lax.top_k(cs, cfg.nprobe)
    bitmap = candidate_bitmap(index.ivf, index.ivf_lens, probe_ids,
                              index.codes.shape[0])
    return cs, bitmap


@functools.partial(jax.jit, static_argnames=("cfg",))
def phase_filtering(index: PackedIndex, cs: jax.Array, bitmap: jax.Array,
                    cfg: PlaidConfig):
    """PLAID phase 2: centroid interaction over ALL candidates -> top ids."""
    token_mask = index.token_mask()
    sbar = interaction.centroid_interaction(cs.T, index.codes, token_mask)
    sbar = jnp.where(bitmap, sbar, -jnp.inf)
    _, sel1 = jax.lax.top_k(sbar, cfg.n_docs)
    return sel1.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=())
def phase_decompression(index: PackedIndex, sel2: jax.Array):
    """PLAID phase 3: decompress b-bit residuals (centroid + bucket values)
    into full-precision embeddings — the cost EMVB's PQ LUT removes."""
    d = index.centroids.shape[1]
    codec = index.plaid_codec
    s2_codes = jnp.take(index.codes, sel2, axis=0)
    s2_packed = jnp.take(index.plaid_res, sel2, axis=0)
    res = decode_residual(s2_packed, codec, d)
    cent = jnp.take(index.centroids,
                    jnp.clip(s2_codes, 0, index.centroids.shape[0] - 1), axis=0)
    return cent + res


@functools.partial(jax.jit, static_argnames=("k",))
def phase_late_interaction(index: PackedIndex, q: jax.Array, emb: jax.Array,
                           sel2: jax.Array, k: int):
    """PLAID phase 4: exact MaxSim on decompressed vectors -> final top-k."""
    token_mask = index.token_mask()
    s2_mask = jnp.take(token_mask, sel2, axis=0)
    scores = interaction.maxsim(q, emb, s2_mask)
    top_scores, top_local = jax.lax.top_k(scores, k)
    return top_scores, jnp.take(sel2, top_local)
