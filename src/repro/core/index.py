"""Index building for multi-vector retrieval (shared by EMVB and PLAID).

Layout decisions (fixed shapes — TPU first):
  * documents padded to ``cap`` tokens; ``doc_lens`` gives true lengths.
  * ALL integer padding uses the one-past-end sentinel (``n_docs`` for doc ids,
    ``n_c`` for centroid ids) so that scatter ``mode='drop'`` and clipped
    gathers are unambiguous (never Python-style negative wrapping).
  * the inverted file (IVF) is a padded (n_c, list_cap) doc-id table.

The builder runs once per corpus (eager), everything downstream is jit-able.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kmeans import kmeans_spherical, assign
from .pq import PQCodebooks, train_pq, train_opq, encode_pq
from .residual import ResidualCodec, train_residual_codec, encode_residual


@dataclasses.dataclass(frozen=True)
class IndexMeta:
    n_docs: int
    n_centroids: int
    d: int
    cap: int           # padded tokens per doc
    m: int             # PQ subspaces
    nbits: int         # PQ bits per subspace
    plaid_b: int       # PLAID residual bits/dim
    list_cap: int      # padded IVF list length
    # doc-id entries silently truncated from IVF lists that overflowed
    # list_cap (0 when list_cap was auto-sized). Non-zero means phase 1
    # cannot reach the dropped docs through the overflowed centroid — size
    # list_cap up if retrieval quality matters more than IVF memory.
    n_dropped: int = 0


class PackedIndex(NamedTuple):
    centroids: jax.Array      # (n_c, d) fp32, L2-normalized
    codes: jax.Array          # (n_docs, cap) int32, pad = n_c
    doc_lens: jax.Array       # (n_docs,) int32
    res_codes: jax.Array      # (n_docs, cap, m) uint8 — PQ codes (EMVB)
    pq_codebooks: jax.Array   # (m, K, dsub) fp32
    ivf: jax.Array            # (n_c, list_cap) int32, pad = n_docs
    ivf_lens: jax.Array       # (n_c,) int32
    plaid_res: jax.Array      # (n_docs, cap, d*b//8) uint8 — b-bit codes (PLAID)
    plaid_cutoffs: jax.Array
    plaid_weights: jax.Array
    opq_rotation: jax.Array   # (d, d); identity when OPQ disabled

    @property
    def pq(self) -> PQCodebooks:
        return PQCodebooks(self.pq_codebooks)

    @property
    def plaid_codec(self) -> ResidualCodec:
        nb = self.plaid_weights.shape[0]
        return ResidualCodec(self.plaid_cutoffs, self.plaid_weights,
                             int(np.log2(nb)))

    def token_mask(self) -> jax.Array:
        cap = self.codes.shape[1]
        return jnp.arange(cap)[None, :] < self.doc_lens[:, None]


def bytes_per_embedding(meta: IndexMeta, method: str) -> float:
    """Paper Table 1 'Bytes' column: centroid id + residual code bytes.
    Centroid ids are stored at machine widths (1/2/4 bytes) — 2^18 centroids
    take a 4-byte id, matching the paper's 20/36-byte accounting."""
    bits = int(np.ceil(np.log2(meta.n_centroids)))
    cid = 1 if bits <= 8 else 2 if bits <= 16 else 4
    if method == "emvb":
        return cid + meta.m * meta.nbits / 8
    if method == "plaid":
        return cid + meta.d * meta.plaid_b / 8
    raise ValueError(method)


def build_index(key: jax.Array,
                doc_embs: np.ndarray,      # (n_docs, cap, d) fp32, zero-padded
                doc_lens: np.ndarray,      # (n_docs,)
                *,
                n_centroids: int,
                m: int = 16,
                nbits: int = 8,
                plaid_b: int = 2,
                list_cap: Optional[int] = None,
                kmeans_iters: int = 8,
                pq_train_size: int = 65536,
                use_opq: bool = False) -> tuple[PackedIndex, IndexMeta]:
    n_docs, cap, d = doc_embs.shape
    k1, k2, k3 = jax.random.split(key, 3)

    mask = (np.arange(cap)[None, :] < doc_lens[:, None])
    flat = jnp.asarray(doc_embs.reshape(-1, d)[mask.reshape(-1)])
    flat = flat / jnp.maximum(jnp.linalg.norm(flat, axis=-1, keepdims=True), 1e-12)

    # --- centroid vocabulary (spherical k-means on all token embeddings) ----
    centroids, _ = kmeans_spherical(k1, flat, n_centroids, iters=kmeans_iters)

    # --- per-token assignment + residuals ------------------------------------
    normed = np.asarray(doc_embs, dtype=np.float32)
    norms = np.maximum(np.linalg.norm(normed, axis=-1, keepdims=True), 1e-12)
    normed = normed / norms
    flat_all = jnp.asarray(normed.reshape(-1, d))
    codes_flat = np.asarray(assign(flat_all, centroids))            # (n_docs*cap,)
    residual_flat = np.asarray(flat_all) - np.asarray(centroids)[codes_flat]

    codes = codes_flat.reshape(n_docs, cap).astype(np.int32)
    codes[~mask] = n_centroids                                      # sentinel pad

    # --- EMVB: PQ (optionally OPQ) on residuals ------------------------------
    res_sample_idx = np.random.default_rng(0).choice(
        mask.sum(), size=min(pq_train_size, int(mask.sum())), replace=False)
    res_sample = jnp.asarray(residual_flat[mask.reshape(-1)][res_sample_idx])
    if use_opq:
        opq = train_opq(k2, res_sample, m, nbits=nbits)
        rotation, pq_cb = opq.rotation, opq.cb
        residual_rot = jnp.asarray(residual_flat) @ rotation
    else:
        rotation = jnp.eye(d, dtype=jnp.float32)
        pq_cb = train_pq(k2, res_sample, m, nbits=nbits)
        residual_rot = jnp.asarray(residual_flat)
    res_codes = np.asarray(encode_pq(residual_rot, pq_cb))
    res_codes = res_codes.reshape(n_docs, cap, m).astype(np.uint8)

    # --- PLAID baseline: b-bit bucket codec on raw residuals ----------------
    codec = train_residual_codec(res_sample, plaid_b)
    plaid_packed = np.asarray(
        encode_residual(jnp.asarray(residual_flat), codec))
    plaid_packed = plaid_packed.reshape(n_docs, cap, -1)

    # --- inverted file: centroid -> doc ids ----------------------------------
    doc_of_token = np.broadcast_to(np.arange(n_docs)[:, None], (n_docs, cap))[mask]
    pairs = np.stack([codes_flat[mask.reshape(-1)], doc_of_token], axis=1)
    lists: list[np.ndarray] = [np.empty(0, np.int64)] * n_centroids
    order = np.argsort(pairs[:, 0], kind="stable")
    sorted_pairs = pairs[order]
    cids, starts = np.unique(sorted_pairs[:, 0], return_index=True)
    bounds = np.append(starts, len(sorted_pairs))
    max_len = 0
    for i, c in enumerate(cids):
        docs = np.unique(sorted_pairs[bounds[i]:bounds[i + 1], 1])
        lists[int(c)] = docs
        max_len = max(max_len, len(docs))
    if list_cap is None:
        list_cap = max(8, int(max_len))
    ivf = np.full((n_centroids, list_cap), n_docs, dtype=np.int32)  # sentinel
    ivf_lens = np.zeros((n_centroids,), dtype=np.int32)
    n_dropped = 0
    n_overflowed = 0
    for c, docs in enumerate(lists):
        ln = min(len(docs), list_cap)
        if len(docs) > ln:
            n_dropped += len(docs) - ln
            n_overflowed += 1
        ivf[c, :ln] = docs[:ln]
        ivf_lens[c] = ln
    if n_dropped:
        warnings.warn(
            f"build_index: {n_overflowed} IVF list(s) overflowed "
            f"list_cap={list_cap}; {n_dropped} doc-id entries dropped "
            f"(longest list: {max_len}). Dropped docs are unreachable "
            "through the overflowed centroids in phase 1 — raise list_cap "
            "(or leave it None to auto-size) if recall matters.",
            stacklevel=2)

    meta = IndexMeta(n_docs=n_docs, n_centroids=n_centroids, d=d, cap=cap,
                     m=m, nbits=nbits, plaid_b=plaid_b, list_cap=list_cap,
                     n_dropped=n_dropped)
    idx = PackedIndex(
        centroids=centroids,
        codes=jnp.asarray(codes),
        doc_lens=jnp.asarray(doc_lens.astype(np.int32)),
        res_codes=jnp.asarray(res_codes),
        pq_codebooks=pq_cb.codebooks,
        ivf=jnp.asarray(ivf),
        ivf_lens=jnp.asarray(ivf_lens),
        plaid_res=jnp.asarray(plaid_packed),
        plaid_cutoffs=codec.cutoffs,
        plaid_weights=codec.bucket_weights,
        opq_rotation=rotation,
    )
    return idx, meta
