"""Index building for multi-vector retrieval (shared by EMVB and PLAID).

Layout decisions (fixed shapes — TPU first):
  * documents padded to ``cap`` tokens; ``doc_lens`` gives true lengths.
  * ALL integer padding uses the one-past-end sentinel (``n_docs`` for doc ids,
    ``n_c`` for centroid ids) so that scatter ``mode='drop'`` and clipped
    gathers are unambiguous (never Python-style negative wrapping).
  * the inverted file (IVF) is a padded (n_c, list_cap) doc-id table.

The builder runs once per corpus (eager), everything downstream is jit-able.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .bitvector import PredicateSet
from .kmeans import kmeans_spherical, assign
from .pq import PQCodebooks, train_pq, train_opq, encode_pq
from .residual import ResidualCodec, train_residual_codec, encode_residual


@dataclasses.dataclass(frozen=True)
class IndexMeta:
    """Static description of a :class:`PackedIndex` (shapes + build params).

    Hashable and JSON-serializable — ``repro.core.store`` round-trips it
    through the on-disk manifest (docs/INDEX_FORMAT.md). The drift fields
    (``n_grown`` / ``*_quant_mse``) are the incremental-growth telemetry:
    ``add_passages`` quantizes new passages against the FROZEN centroid/PQ
    codebooks, so :attr:`drift` is how callers decide when a re-train is
    warranted.
    """

    n_docs: int
    n_centroids: int
    d: int
    cap: int           # padded tokens per doc
    m: int             # PQ subspaces
    nbits: int         # PQ bits per subspace
    plaid_b: int       # PLAID residual bits/dim
    list_cap: int      # padded IVF list length
    # doc-id entries silently truncated from IVF lists that overflowed
    # list_cap (0 when list_cap was auto-sized). Non-zero means phase 1
    # cannot reach the dropped docs through the overflowed centroid — size
    # list_cap up if retrieval quality matters more than IVF memory.
    n_dropped: int = 0
    # docs appended by store.add_passages / encoded by store.new_generation
    # AFTER the centroid/PQ codebooks were trained (the last n_grown docs).
    n_grown: int = 0
    # mean squared token -> assigned-centroid residual norm over the docs the
    # codebooks were TRAINED on (the quantization error baseline)...
    train_quant_mse: float = 0.0
    # ... and the same statistic over the n_grown appended docs (0.0 until
    # something is grown). Quantized against frozen codebooks, so this only
    # ever degrades as the corpus distribution moves.
    grown_quant_mse: float = 0.0
    # names of the packed per-doc metadata predicates: bit i of
    # PackedIndex.pred_words is pred_names[i] (docs/FILTERING.md). Empty
    # means no predicate plane (pred_words is all-zero). FilterPlans compile
    # against this ordering, so it is part of the index identity.
    pred_names: tuple = ()
    # constant-space document budget (arXiv 2504.01818): when set, every
    # document was pooled down to at most doc_budget vectors by
    # pool_documents BEFORE quantization, and growth paths MUST pool
    # incoming docs the same way. None = today's per-token layout,
    # bit-exactly (pooling code never runs).
    doc_budget: Optional[int] = None
    # real (pre-pooling) token count across the corpus — the denominator of
    # the unpooled counterfactual in store.generation_footprint. 0 on
    # indexes saved before schema v4 (footprints then fall back to the
    # stored token count).
    n_raw_tokens: int = 0

    @property
    def drift(self) -> float:
        """Quantization-drift ratio ``grown_quant_mse / train_quant_mse``.

        1.0 means appended passages quantize as well as the training corpus
        (no drift, or nothing grown yet); sustained values well above 1
        (rule of thumb: > ~1.5) mean the frozen centroids/codebooks no
        longer fit the incoming distribution and a re-train (fresh
        ``build_index`` over the union corpus) is warranted.
        """
        if self.n_grown == 0 or self.train_quant_mse == 0.0:
            return 1.0
        return self.grown_quant_mse / self.train_quant_mse


class PackedIndex(NamedTuple):
    """The complete on-device retrieval index — a flat pytree of arrays.

    Being a NamedTuple of arrays (no Python state), it passes through jit /
    vmap / shard_map unchanged, and ``repro.core.store`` can persist it
    field-by-field. All shapes are fixed; integer padding uses one-past-end
    sentinels (see the module docstring).
    """

    centroids: jax.Array      # (n_c, d) fp32, L2-normalized
    codes: jax.Array          # (n_docs, cap) int32, pad = n_c
    doc_lens: jax.Array       # (n_docs,) int32
    res_codes: jax.Array      # (n_docs, cap, m) uint8 — PQ codes (EMVB)
    pq_codebooks: jax.Array   # (m, K, dsub) fp32
    ivf: jax.Array            # (n_c, list_cap) int32, pad = n_docs
    ivf_lens: jax.Array       # (n_c,) int32
    plaid_res: jax.Array      # (n_docs, cap, d*b//8) uint8 — b-bit codes (PLAID)
    plaid_cutoffs: jax.Array
    plaid_weights: jax.Array
    opq_rotation: jax.Array   # (d, d); identity when OPQ disabled
    pred_words: jax.Array     # (n_docs,) uint32 predicate plane; bit i of
    #                           word d == meta.pred_names[i] holds for doc d
    #                           (all-zero when the index has no predicates)

    @property
    def pq(self) -> PQCodebooks:
        """PQ codebooks wrapped in their NamedTuple view."""
        return PQCodebooks(self.pq_codebooks)

    @property
    def plaid_codec(self) -> ResidualCodec:
        """The PLAID b-bit residual codec reconstructed from its arrays."""
        nb = self.plaid_weights.shape[0]
        return ResidualCodec(self.plaid_cutoffs, self.plaid_weights,
                             int(np.log2(nb)))

    def token_mask(self) -> jax.Array:
        """(n_docs, cap) bool — True for real (non-padding) tokens."""
        cap = self.codes.shape[1]
        return jnp.arange(cap)[None, :] < self.doc_lens[:, None]


def bytes_per_embedding(meta: IndexMeta, method: str) -> float:
    """Paper Table 1 'Bytes' column: centroid id + residual code bytes.
    Centroid ids are stored at machine widths (1/2/4 bytes) — 2^18 centroids
    take a 4-byte id, matching the paper's 20/36-byte accounting."""
    bits = int(np.ceil(np.log2(meta.n_centroids)))
    cid = 1 if bits <= 8 else 2 if bits <= 16 else 4
    if method == "emvb":
        return cid + meta.m * meta.nbits / 8
    if method == "plaid":
        return cid + meta.d * meta.plaid_b / 8
    raise ValueError(method)


def quantize_tokens(centroids: jax.Array, doc_embs: np.ndarray,
                    doc_lens: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Assign every token to its nearest (frozen) centroid — paper §4.1.

    The shared quantization step of ``build_index`` AND the incremental
    growth path (``store.add_passages`` / ``store.new_generation``): both
    MUST encode a given document identically, which is what makes a grown
    monolithic index and a multi-generation timeline score docs bit-for-bit
    the same (tests/test_store.py).

    centroids : (n_c, d) fp32 — the frozen centroid vocabulary
    doc_embs  : (n_docs, cap, d) fp32, zero-padded (rows are re-normalized)
    doc_lens  : (n_docs,) int
    -> (codes (n_docs, cap) int32 with the ``n_c`` pad sentinel,
        residual_flat (n_docs*cap, d) fp32 token - centroid residuals,
        mask (n_docs, cap) bool of real tokens)
    """
    n_docs, cap, d = doc_embs.shape
    n_centroids = centroids.shape[0]
    mask = (np.arange(cap)[None, :] < np.asarray(doc_lens)[:, None])
    normed = np.asarray(doc_embs, dtype=np.float32)
    norms = np.maximum(np.linalg.norm(normed, axis=-1, keepdims=True), 1e-12)
    normed = normed / norms
    flat_all = jnp.asarray(normed.reshape(-1, d))
    codes_flat = np.asarray(assign(flat_all, centroids))            # (n_docs*cap,)
    residual_flat = np.asarray(flat_all) - np.asarray(centroids)[codes_flat]
    codes = codes_flat.reshape(n_docs, cap).astype(np.int32)
    codes[~mask] = n_centroids                                      # sentinel pad
    return codes, residual_flat, mask


def pool_documents(doc_embs: np.ndarray, doc_lens: np.ndarray,
                   budget: int, *, iters: int = 4
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Pool every document down to at most ``budget`` vectors (arXiv
    2504.01818: constant-space multi-vector docs).

    Documents with ``len <= budget`` pass through UNCHANGED (their token
    rows are copied verbatim), which is what makes ``budget >=
    max_doc_len`` pooling bit-exact to the unpooled index. Longer docs are
    clustered with a per-doc deterministic spherical k-means (evenly spaced
    token indices as seeds — no RNG, so build and growth paths encode a
    given document identically), then each cluster is MEAN-POOLED over its
    raw token vectors. Empty clusters (duplicate tokens) are dropped, so a
    pooled length can come out below ``budget``; downstream the pooled
    vectors take the ordinary ``quantize_tokens`` path, which re-normalizes
    rows.

    doc_embs : (n_docs, cap, d) fp32, zero-padded
    doc_lens : (n_docs,) int
    -> (pooled_embs (n_docs, min(cap, budget), d) fp32 zero-padded,
        pooled_lens (n_docs,) int32)
    """
    if budget < 1:
        raise ValueError(f"doc_budget must be >= 1, got {budget}")
    doc_embs = np.asarray(doc_embs, dtype=np.float32)
    doc_lens = np.asarray(doc_lens)
    n_docs, cap, d = doc_embs.shape
    new_cap = min(cap, int(budget))
    out = np.zeros((n_docs, new_cap, d), np.float32)
    out_lens = np.zeros((n_docs,), np.int32)
    for i in range(n_docs):
        ln = int(doc_lens[i])
        toks = doc_embs[i, :ln]
        if ln <= budget:
            out[i, :ln] = toks
            out_lens[i] = ln
            continue
        normed = toks / np.maximum(
            np.linalg.norm(toks, axis=-1, keepdims=True), 1e-12)
        # deterministic seeds: evenly spaced token positions (strictly
        # increasing because ln > budget, so seeds are distinct indices)
        seed_idx = np.round(np.linspace(0, ln - 1, budget)).astype(int)
        cents = normed[seed_idx]
        labels = np.argmax(normed @ cents.T, axis=1)
        for _ in range(iters):
            sums = np.zeros((budget, d), np.float32)
            np.add.at(sums, labels, normed)
            counts = np.bincount(labels, minlength=budget)
            means = sums / np.maximum(counts, 1)[:, None]
            means /= np.maximum(
                np.linalg.norm(means, axis=-1, keepdims=True), 1e-12)
            # empty clusters keep their previous centroid (degenerate docs
            # — e.g. all-identical tokens — simply collapse below)
            cents = np.where((counts > 0)[:, None], means, cents)
            labels = np.argmax(normed @ cents.T, axis=1)
        sums = np.zeros((budget, d), np.float32)
        np.add.at(sums, labels, toks)          # mean over RAW token vectors
        counts = np.bincount(labels, minlength=budget)
        keep = counts > 0
        pooled = sums[keep] / counts[keep][:, None]
        out[i, :pooled.shape[0]] = pooled
        out_lens[i] = pooled.shape[0]
    return out, out_lens


def _build_ivf(codes: np.ndarray, n_centroids: int,
               list_cap: Optional[int], *, origin: str = "build_index"
               ) -> tuple[np.ndarray, np.ndarray, int, int]:
    """Build the padded (n_c, list_cap) inverted file from sentinel-padded
    token codes. Returns (ivf, ivf_lens, list_cap, n_dropped) and warns when
    a fixed list_cap truncates lists (docs become unreachable via that
    centroid in phase 1)."""
    n_docs, cap = codes.shape
    mask = codes < n_centroids
    doc_of_token = np.broadcast_to(
        np.arange(n_docs)[:, None], (n_docs, cap))[mask]
    pairs = np.stack([codes[mask], doc_of_token], axis=1)
    lists: list[np.ndarray] = [np.empty(0, np.int64)] * n_centroids
    order = np.argsort(pairs[:, 0], kind="stable")
    sorted_pairs = pairs[order]
    cids, starts = np.unique(sorted_pairs[:, 0], return_index=True)
    bounds = np.append(starts, len(sorted_pairs))
    max_len = 0
    for i, c in enumerate(cids):
        docs = np.unique(sorted_pairs[bounds[i]:bounds[i + 1], 1])
        lists[int(c)] = docs
        max_len = max(max_len, len(docs))
    if list_cap is None:
        list_cap = max(8, int(max_len))
    ivf = np.full((n_centroids, list_cap), n_docs, dtype=np.int32)  # sentinel
    ivf_lens = np.zeros((n_centroids,), dtype=np.int32)
    n_dropped = 0
    n_overflowed = 0
    for c, docs in enumerate(lists):
        ln = min(len(docs), list_cap)
        if len(docs) > ln:
            n_dropped += len(docs) - ln
            n_overflowed += 1
        ivf[c, :ln] = docs[:ln]
        ivf_lens[c] = ln
    if n_dropped:
        warnings.warn(
            f"{origin}: {n_overflowed} IVF list(s) overflowed "
            f"list_cap={list_cap}; {n_dropped} doc-id entries dropped "
            f"(longest list: {max_len}). Dropped docs are unreachable "
            "through the overflowed centroids in phase 1 — raise list_cap "
            "(or leave it None to auto-size) if recall matters.",
            stacklevel=3)
    return ivf, ivf_lens, list_cap, n_dropped


def build_index(key: jax.Array,
                doc_embs: np.ndarray,      # (n_docs, cap, d) fp32, zero-padded
                doc_lens: np.ndarray,      # (n_docs,)
                *,
                n_centroids: int,
                m: int = 16,
                nbits: int = 8,
                plaid_b: int = 2,
                list_cap: Optional[int] = None,
                kmeans_iters: int = 8,
                pq_train_size: int = 65536,
                use_opq: bool = False,
                predicates=None,
                doc_budget: Optional[int] = None
                ) -> tuple[PackedIndex, IndexMeta]:
    """Build the full EMVB/PLAID index over a padded corpus (eager, once).

    Trains the centroid vocabulary (spherical k-means over all real token
    embeddings, paper §4.1), assigns every token, PQ-encodes the residuals
    (paper §4.4 / C3; OPQ optional), fits the PLAID b-bit baseline codec,
    and builds the padded inverted file phase 1 probes. The returned
    :class:`IndexMeta` records the quantization-error baseline
    (``train_quant_mse``) that ``store.add_passages`` later measures its
    drift statistic against.

    ``predicates`` optionally attaches a metadata predicate plane: a
    :class:`~repro.core.bitvector.PredicateSet` or a ``{name: (n_docs,)
    bool}`` mapping, packed one bit per name into ``pred_words`` and named
    in ``meta.pred_names`` (docs/FILTERING.md).

    ``doc_budget`` turns on the constant-space representation: documents
    are pooled to at most ``doc_budget`` vectors by :func:`pool_documents`
    before any training/quantization, ``cap`` shrinks to ``min(cap,
    doc_budget)``, and the budget is recorded in ``meta.doc_budget`` so the
    growth paths pool identically. ``None`` leaves every byte of the index
    bit-exactly as before.

    -> (PackedIndex, IndexMeta)
    """
    n_raw_tokens = int(np.asarray(doc_lens).sum())
    if doc_budget is not None:
        doc_embs, doc_lens = pool_documents(doc_embs, doc_lens, doc_budget)
    n_docs, cap, d = doc_embs.shape
    k1, k2, k3 = jax.random.split(key, 3)

    if predicates is None:
        pred_names: tuple = ()
        pred_words = np.zeros(n_docs, np.uint32)
    else:
        pset = (predicates if isinstance(predicates, PredicateSet)
                else PredicateSet.pack(predicates))
        if pset.words.shape[0] != n_docs:
            raise ValueError(
                f"predicate plane covers {pset.words.shape[0]} docs but the "
                f"corpus has {n_docs}: predicates must be given for every "
                "doc at build time")
        pred_names = pset.names
        pred_words = np.asarray(pset.words)

    mask = (np.arange(cap)[None, :] < doc_lens[:, None])
    flat = jnp.asarray(doc_embs.reshape(-1, d)[mask.reshape(-1)])
    flat = flat / jnp.maximum(jnp.linalg.norm(flat, axis=-1, keepdims=True), 1e-12)

    # --- centroid vocabulary (spherical k-means on all token embeddings) ----
    centroids, _ = kmeans_spherical(k1, flat, n_centroids, iters=kmeans_iters)

    # --- per-token assignment + residuals ------------------------------------
    codes, residual_flat, mask = quantize_tokens(centroids, doc_embs, doc_lens)

    # --- EMVB: PQ (optionally OPQ) on residuals ------------------------------
    res_sample_idx = np.random.default_rng(0).choice(
        mask.sum(), size=min(pq_train_size, int(mask.sum())), replace=False)
    res_sample = jnp.asarray(residual_flat[mask.reshape(-1)][res_sample_idx])
    if use_opq:
        opq = train_opq(k2, res_sample, m, nbits=nbits)
        rotation, pq_cb = opq.rotation, opq.cb
        residual_rot = jnp.asarray(residual_flat) @ rotation
    else:
        rotation = jnp.eye(d, dtype=jnp.float32)
        pq_cb = train_pq(k2, res_sample, m, nbits=nbits)
        residual_rot = jnp.asarray(residual_flat)
    res_codes = np.asarray(encode_pq(residual_rot, pq_cb))
    res_codes = res_codes.reshape(n_docs, cap, m).astype(np.uint8)

    # --- PLAID baseline: b-bit bucket codec on raw residuals ----------------
    codec = train_residual_codec(res_sample, plaid_b)
    plaid_packed = np.asarray(
        encode_residual(jnp.asarray(residual_flat), codec))
    plaid_packed = plaid_packed.reshape(n_docs, cap, -1)

    # --- inverted file: centroid -> doc ids ----------------------------------
    ivf, ivf_lens, list_cap, n_dropped = _build_ivf(
        codes, n_centroids, list_cap, origin="build_index")

    # quantization-error baseline for store.add_passages' drift statistic
    real_res = residual_flat[mask.reshape(-1)]
    train_quant_mse = float(np.mean(np.sum(real_res * real_res, axis=-1)))

    meta = IndexMeta(n_docs=n_docs, n_centroids=n_centroids, d=d, cap=cap,
                     m=m, nbits=nbits, plaid_b=plaid_b, list_cap=list_cap,
                     n_dropped=n_dropped, train_quant_mse=train_quant_mse,
                     pred_names=pred_names, doc_budget=doc_budget,
                     n_raw_tokens=n_raw_tokens)
    idx = PackedIndex(
        centroids=centroids,
        codes=jnp.asarray(codes),
        doc_lens=jnp.asarray(doc_lens.astype(np.int32)),
        res_codes=jnp.asarray(res_codes),
        pq_codebooks=pq_cb.codebooks,
        ivf=jnp.asarray(ivf),
        ivf_lens=jnp.asarray(ivf_lens),
        plaid_res=jnp.asarray(plaid_packed),
        plaid_cutoffs=codec.cutoffs,
        plaid_weights=codec.bucket_weights,
        opq_rotation=rotation,
        pred_words=jnp.asarray(pred_words),
    )
    return idx, meta
