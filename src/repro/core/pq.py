"""Product Quantization (PQ) for residual compression — paper §4.4 (C3).

The paper replaces PLAID's b-bit residual codec with PQ so that ``q_i . r`` is
computed *without decompression* through a per-query lookup table (LUT).
On TPU the LUT for m=16..32 subspaces × 256 codes × fp32 is 128–256 KB per
query term set — it lives entirely in VMEM inside the fused kernel
(``repro.kernels.pqscore``); the functions here are the reference math and the
index-building path.

Also implements:
  * OPQ (Ge et al., 2013): alternating procrustes rotation — used for the
    out-of-domain setting (paper Table 2, where JMPQ is unavailable).
  * STE ("JMPQ-style") quantization for joint training inside the ColBERT
    encoder trainer (Fang et al., 2022 optimize PQ codes during fine-tuning;
    with the encoder in-framework, a straight-through estimator is the
    JAX-native equivalent).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kmeans import kmeans


class PQCodebooks(NamedTuple):
    """Per-subspace PQ codebooks (paper §4.4): one K-entry table per slice."""

    codebooks: jax.Array  # (m, K, dsub) fp32

    @property
    def m(self) -> int:
        """Number of subspaces."""
        return self.codebooks.shape[0]

    @property
    def ksub(self) -> int:
        """Codewords per subspace (2^nbits)."""
        return self.codebooks.shape[1]

    @property
    def dsub(self) -> int:
        """Dimensions per subspace (d / m)."""
        return self.codebooks.shape[2]


def _split(x: jax.Array, m: int) -> jax.Array:
    """(n, d) -> (m, n, dsub)."""
    n, d = x.shape
    assert d % m == 0, f"d={d} not divisible by m={m}"
    return x.reshape(n, m, d // m).swapaxes(0, 1)


def train_pq(key: jax.Array, x: jax.Array, m: int, *, nbits: int = 8,
             iters: int = 8) -> PQCodebooks:
    """Train per-subspace codebooks on residual vectors x (n, d)."""
    ksub = 1 << nbits
    subs = _split(x, m)  # (m, n, dsub)
    keys = jax.random.split(key, m)

    def _one(args):
        k_i, sub = args
        c, _ = kmeans(k_i, sub, ksub, iters=iters)
        return c

    cbs = jax.lax.map(_one, (keys, subs))  # (m, K, dsub)
    return PQCodebooks(cbs)


@functools.partial(jax.jit, static_argnames=())
def encode_pq(x: jax.Array, cb: PQCodebooks) -> jax.Array:
    """(n, d) -> (n, m) uint8 codes (nearest codeword per subspace)."""
    subs = _split(x, cb.m)  # (m, n, dsub)

    def _one(args):
        sub, c = args
        d2 = jnp.sum(c * c, -1)[None, :] - 2.0 * (sub @ c.T)
        return jnp.argmin(d2, axis=-1).astype(jnp.uint8)

    codes = jax.lax.map(_one, (subs, cb.codebooks))  # (m, n)
    return codes.T


def decode_pq(codes: jax.Array, cb: PQCodebooks) -> jax.Array:
    """(n, m) uint8 -> (n, d) reconstruction."""
    # gather codewords: out[n, s] = codebooks[s, codes[n, s]]
    recon = jnp.take_along_axis(
        cb.codebooks[None],                               # (1, m, K, dsub)
        codes.astype(jnp.int32)[:, :, None, None],        # (n, m, 1, 1)
        axis=2,
    )[:, :, 0, :]                                         # (n, m, dsub)
    return recon.reshape(codes.shape[0], -1)


def build_lut(q: jax.Array, cb: PQCodebooks) -> jax.Array:
    """Inner-product LUT. q (..., d) -> (..., m, K) where
    lut[..., s, c] = q[..., s*dsub:(s+1)*dsub] . codebooks[s, c]."""
    *lead, d = q.shape
    qs = q.reshape(*lead, cb.m, cb.dsub)
    return jnp.einsum("...sd,skd->...sk", qs, cb.codebooks)


def lut_score(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Score tokens against a LUT without decompression.

    lut   (..., m, K)
    codes (n, m) uint8
    ->    (..., n)  : sum_s lut[..., s, codes[n, s]]
    """
    idx = codes.astype(jnp.int32)[..., None]               # (n, m, 1)
    # lut (..., 1, m, K) gathered at idx -> (..., n, m, 1)
    gathered = jnp.take_along_axis(lut[..., None, :, :], idx, axis=-1)
    return gathered[..., 0].sum(axis=-1)


def pq_ste(x: jax.Array, cb: PQCodebooks) -> jax.Array:
    """Straight-through PQ quantization: forward = decode(encode(x)),
    backward = identity. The JMPQ analogue used while fine-tuning the encoder."""
    xq = decode_pq(encode_pq(x, cb), cb)
    return x + jax.lax.stop_gradient(xq - x)


# ---------------------------------------------------------------------------
# OPQ — optimized product quantization (parametric procrustes variant).
# ---------------------------------------------------------------------------

class OPQ(NamedTuple):
    """Optimized PQ: an orthonormal rotation plus the codebooks trained
    on the rotated residuals (Ge et al., 2013)."""

    rotation: jax.Array  # (d, d) orthonormal
    cb: PQCodebooks


def train_opq(key: jax.Array, x: jax.Array, m: int, *, nbits: int = 8,
              kmeans_iters: int = 6, opq_iters: int = 4) -> OPQ:
    """Alternate: PQ-train on rotated data <-> procrustes update of R.

    R step: min_R ||xR - x_hat||_F s.t. R orthonormal  =>  R = U V^T where
    U S V^T = svd(x^T x_hat).
    """
    d = x.shape[1]
    R = jnp.eye(d, dtype=x.dtype)
    cb = None
    for it in range(opq_iters):
        key, sub = jax.random.split(key)
        xr = x @ R
        cb = train_pq(sub, xr, m, nbits=nbits, iters=kmeans_iters)
        xhat = decode_pq(encode_pq(xr, cb), cb)
        u, _, vt = jnp.linalg.svd(x.T @ xhat, full_matrices=False)
        R = u @ vt
    return OPQ(R, cb)


def pq_reconstruction_mse(x: jax.Array, cb: PQCodebooks) -> jax.Array:
    """Mean squared encode->decode reconstruction error of x (n, d)."""
    xhat = decode_pq(encode_pq(x, cb), cb)
    return jnp.mean(jnp.sum((x - xhat) ** 2, axis=-1))
