"""Lloyd's k-means in JAX — used for both the centroid vocabulary (|C| up to 2^18)
and the per-subspace PQ codebooks.

Distance computations are chunked over the data axis with ``lax.map`` so the
(n, k) score matrix never fully materializes; this is the same blocking a TPU
implementation would use to keep the working set in VMEM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _pairwise_sq_dists(x: jax.Array, c: jax.Array) -> jax.Array:
    """(n, d) x (k, d) -> (n, k) squared L2 distances (up to a per-row const)."""
    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2 ; the ||x||^2 term is constant per
    # row and irrelevant for the argmin, so we drop it.
    return jnp.sum(c * c, axis=-1)[None, :] - 2.0 * (x @ c.T)


def assign(x: jax.Array, centroids: jax.Array, *, chunk: int = 16384) -> jax.Array:
    """Nearest-centroid assignment, chunked. Returns int32 (n,)."""
    n = x.shape[0]
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    xb = xp.reshape(-1, chunk, x.shape[1])

    def _one(block):
        return jnp.argmin(_pairwise_sq_dists(block, centroids), axis=-1).astype(jnp.int32)

    out = jax.lax.map(_one, xb).reshape(-1)
    return out[:n]


def _update(x: jax.Array, assignment: jax.Array, k: int, old: jax.Array,
            key: jax.Array) -> jax.Array:
    sums = jax.ops.segment_sum(x, assignment, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones((x.shape[0],), x.dtype), assignment,
                                 num_segments=k)
    new = sums / jnp.maximum(counts, 1.0)[:, None]
    # Empty clusters: re-seed from random data points (keeps k live clusters,
    # matching faiss behaviour closely enough for index building).
    reseed = x[jax.random.randint(key, (k,), 0, x.shape[0])]
    return jnp.where((counts > 0)[:, None], new, reseed)


@functools.partial(jax.jit, static_argnames=("k", "iters", "chunk"))
def kmeans(key: jax.Array, x: jax.Array, k: int, *, iters: int = 8,
           chunk: int = 16384) -> Tuple[jax.Array, jax.Array]:
    """Run Lloyd's algorithm. Returns (centroids (k, d), assignment (n,))."""
    init_key, loop_key = jax.random.split(key)
    perm = jax.random.permutation(init_key, x.shape[0])[:k]
    centroids0 = x[perm]

    def _body(carry, subkey):
        centroids = carry
        a = assign(x, centroids, chunk=chunk)
        centroids = _update(x, a, k, centroids, subkey)
        return centroids, None

    centroids, _ = jax.lax.scan(_body, centroids0,
                                jax.random.split(loop_key, iters))
    return centroids, assign(x, centroids, chunk=chunk)


def kmeans_spherical(key: jax.Array, x: jax.Array, k: int, *, iters: int = 8,
                     chunk: int = 16384) -> Tuple[jax.Array, jax.Array]:
    """Spherical k-means (centroids re-normalized each step) — the variant used
    for the centroid vocabulary, since ColBERT embeddings are L2-normalized and
    scored by dot product."""
    c, a = kmeans(key, x, k, iters=iters, chunk=chunk)
    c = c / jnp.maximum(jnp.linalg.norm(c, axis=-1, keepdims=True), 1e-12)
    return c, assign(x, c, chunk=chunk)
