"""Index lifecycle: persistence, incremental growth, multi-generation serving.

Three concerns, one subsystem (docs/INDEX_FORMAT.md has the on-disk schema):

* **Persistence** — ``save_index`` / ``load_index`` write a
  :class:`~repro.core.index.PackedIndex` + :class:`~repro.core.index.IndexMeta`
  to a versioned directory (``manifest.json`` + ``arrays.npz``). Loading is
  bit-exact: retrieval on a loaded index equals retrieval on the original,
  ids AND score bits (tests/test_store.py).

* **Incremental growth** — ``add_passages`` appends passages to an existing
  index WITHOUT re-running k-means: new tokens are quantized against the
  frozen centroid/PQ/PLAID codebooks (the exact ``quantize_tokens`` path
  ``build_index`` used), IVF lists are extended (list_cap grows instead of
  dropping entries), and the quantization-error drift statistic on
  ``IndexMeta`` tells callers when the frozen codebooks have gone stale.

* **Multi-generation serving** — à la PLAID SHIRTTT (Lawrie et al., 2024):
  an append-only stream is served as a :class:`ShardedTimeline` of immutable
  index generations, each built or grown independently (possibly with
  different ``n_docs``), merged at query time by
  ``repro.core.engine.retrieve_timeline`` (single device) or
  ``repro.launch.serve.make_timeline_retriever`` (shard_map plan per
  generation). Per-generation footprint stays bounded — growth never
  rewrites an old generation.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zipfile
from typing import Iterator

import jax.numpy as jnp
import numpy as np

from .index import IndexMeta, PackedIndex, _build_ivf, quantize_tokens
from .pq import encode_pq
from .residual import encode_residual

# Bump on ANY incompatible change to the manifest or array set; readers
# refuse files from the future. See docs/INDEX_FORMAT.md for the policy.
SCHEMA_VERSION = 1
_FORMAT = "emvb-packed-index"
_TIMELINE_FORMAT = "emvb-sharded-timeline"
_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


# ---------------------------------------------------------------------------
# Persistence — versioned on-disk format
# ---------------------------------------------------------------------------

def save_index(path: str, index: PackedIndex, meta: IndexMeta) -> str:
    """Write an index to ``path`` (a directory; created if missing).

    Layout: ``manifest.json`` (format name, ``schema_version``, the full
    ``IndexMeta``, and a per-array dtype/shape manifest) + ``arrays.npz``
    (every ``PackedIndex`` field, uncompressed, bit-exact). Returns ``path``.
    """
    os.makedirs(path, exist_ok=True)
    arrays = {f: np.asarray(getattr(index, f)) for f in PackedIndex._fields}
    manifest = {
        "format": _FORMAT,
        "schema_version": SCHEMA_VERSION,
        "meta": dataclasses.asdict(meta),
        "arrays": {f: {"dtype": str(a.dtype), "shape": list(a.shape)}
                   for f, a in arrays.items()},
    }
    # The manifest gates validity: retract any existing one BEFORE touching
    # the arrays (covers overwriting a prior save), write the arrays, then
    # publish the new manifest atomically — a crash at any point leaves a
    # directory load_index rejects instead of a torn or stale index.
    mpath = os.path.join(path, _MANIFEST)
    if os.path.exists(mpath):
        os.remove(mpath)
    np.savez(os.path.join(path, _ARRAYS), **arrays)
    tmp = mpath + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, mpath)
    return path


def _fail(path: str, why: str) -> ValueError:
    return ValueError(f"load_index({path!r}): {why}")


def load_index(path: str) -> tuple[PackedIndex, IndexMeta]:
    """Load an index written by :func:`save_index` — the bit-exact inverse.

    Every failure mode raises an actionable ``ValueError``: missing/corrupt
    files, wrong format, a future ``schema_version`` (this build refuses to
    guess at formats from the future), missing or unknown meta fields, and
    any array whose dtype/shape disagrees with the manifest.
    """
    mpath = os.path.join(path, _MANIFEST)
    if not os.path.isfile(mpath):
        raise _fail(path, f"no {_MANIFEST} — not a saved EMVB index "
                          "(or a save was interrupted before the manifest "
                          "was written)")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise _fail(path, f"corrupt {_MANIFEST}: {e}") from e
    if not isinstance(manifest, dict) or manifest.get("format") != _FORMAT:
        raise _fail(path, f"{_MANIFEST} has format="
                          f"{manifest.get('format')!r}, expected {_FORMAT!r}")
    version = manifest.get("schema_version")
    if not isinstance(version, int) or version < 1:
        raise _fail(path, f"bad schema_version={version!r}")
    if version > SCHEMA_VERSION:
        raise _fail(path, f"schema_version={version} is newer than this "
                          f"build understands (<= {SCHEMA_VERSION}); "
                          "upgrade repro to read this index")

    meta_fields = {f.name for f in dataclasses.fields(IndexMeta)}
    meta_dict = manifest.get("meta")
    if not isinstance(meta_dict, dict):
        raise _fail(path, f"{_MANIFEST} is missing the 'meta' table")
    missing = sorted(meta_fields - meta_dict.keys())
    unknown = sorted(meta_dict.keys() - meta_fields)
    if missing:
        raise _fail(path, f"manifest meta is missing field(s) "
                          f"{missing} — corrupt or hand-edited manifest")
    if unknown:
        raise _fail(path, f"manifest meta has unknown field(s) {unknown} at "
                          f"schema_version={version}; new fields require a "
                          "schema version bump (docs/INDEX_FORMAT.md)")
    meta = IndexMeta(**meta_dict)

    decl = manifest.get("arrays")
    if not isinstance(decl, dict) or \
            sorted(decl) != sorted(PackedIndex._fields):
        raise _fail(path, "manifest 'arrays' table does not list exactly the "
                          f"PackedIndex fields {sorted(PackedIndex._fields)}")
    apath = os.path.join(path, _ARRAYS)
    if not os.path.isfile(apath):
        raise _fail(path, f"no {_ARRAYS} next to the manifest")
    try:
        with np.load(apath) as npz:
            arrays = {k: npz[k] for k in npz.files}
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise _fail(path, f"corrupt {_ARRAYS}: {e}") from e

    fields = []
    for f in PackedIndex._fields:
        if f not in arrays:
            raise _fail(path, f"{_ARRAYS} is missing array {f!r} declared "
                              "in the manifest")
        a, want = arrays[f], decl[f]
        if str(a.dtype) != want["dtype"] or list(a.shape) != want["shape"]:
            raise _fail(path, f"array {f!r} is {a.dtype}{list(a.shape)} but "
                              f"the manifest declares {want['dtype']}"
                              f"{want['shape']} — corrupt save")
        fields.append(jnp.asarray(a))
    index = PackedIndex(*fields)

    # light cross-checks: meta and arrays must describe the same index
    n_docs, cap = index.codes.shape
    if (meta.n_docs, meta.cap) != (n_docs, cap) or \
            meta.n_centroids != index.centroids.shape[0]:
        raise _fail(path, f"meta (n_docs={meta.n_docs}, cap={meta.cap}, "
                          f"n_centroids={meta.n_centroids}) disagrees with "
                          f"the arrays (codes {n_docs}x{cap}, centroids "
                          f"{index.centroids.shape[0]}) — corrupt save")
    return index, meta


# ---------------------------------------------------------------------------
# Incremental growth — quantize against frozen codebooks
# ---------------------------------------------------------------------------

def _encode_passages(index: PackedIndex, doc_embs: np.ndarray,
                     doc_lens: np.ndarray):
    """Encode new passages against an index's FROZEN codebooks.

    Runs the exact build-time path — ``quantize_tokens`` + PQ (+ OPQ
    rotation) + PLAID codec — so a passage encodes bit-identically whether
    it entered via ``build_index``-then-``add_passages`` or via
    ``new_generation``. Returns (codes, res_codes, plaid_res,
    residual_sq_sum, n_tokens); the last two feed the drift statistic.
    """
    n_new, cap, d = doc_embs.shape
    codes, residual_flat, mask = quantize_tokens(
        index.centroids, doc_embs, doc_lens)
    rotation = np.asarray(index.opq_rotation)
    if np.array_equal(rotation, np.eye(d, dtype=rotation.dtype)):
        residual_rot = jnp.asarray(residual_flat)   # skip the identity matmul
    else:
        residual_rot = jnp.asarray(residual_flat) @ index.opq_rotation
    m = index.res_codes.shape[-1]
    res_codes = np.asarray(encode_pq(residual_rot, index.pq))
    res_codes = res_codes.reshape(n_new, cap, m).astype(np.uint8)
    plaid_res = np.asarray(
        encode_residual(jnp.asarray(residual_flat), index.plaid_codec))
    plaid_res = plaid_res.reshape(n_new, cap, -1)
    real = residual_flat[mask.reshape(-1)]
    return codes, res_codes, plaid_res, float(np.sum(real * real)), \
        int(mask.sum())


def _check_new_docs(meta: IndexMeta, doc_embs: np.ndarray,
                    doc_lens: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Validate (and coerce) new-passage arrays against the index geometry."""
    doc_embs = np.asarray(doc_embs, dtype=np.float32)
    doc_lens = np.asarray(doc_lens, dtype=np.int32)
    if doc_embs.ndim != 3 or doc_embs.shape[0] != doc_lens.shape[0]:
        raise ValueError(
            f"doc_embs {doc_embs.shape} / doc_lens {doc_lens.shape}: "
            "expected (n_new, cap, d) embeddings with one length per doc")
    if doc_embs.shape[1] != meta.cap or doc_embs.shape[2] != meta.d:
        raise ValueError(
            f"new passages are padded to (cap={doc_embs.shape[1]}, "
            f"d={doc_embs.shape[2]}) but the index was built with "
            f"(cap={meta.cap}, d={meta.d}); re-pad (or truncate) the new "
            "docs to the index geometry first")
    if doc_embs.shape[0] == 0:
        raise ValueError("no passages to add (n_new=0)")
    return doc_embs, doc_lens


def add_passages(index: PackedIndex, meta: IndexMeta, doc_embs: np.ndarray,
                 doc_lens: np.ndarray) -> tuple[PackedIndex, IndexMeta]:
    """Append passages to an existing index without re-running k-means.

    New docs are quantized against the FROZEN centroid and PQ/PLAID
    codebooks (so existing doc ids, codes and scores are untouched), their
    doc ids continue after the current corpus, and the IVF is extended
    in-place semantics-wise: ``list_cap`` grows as needed instead of
    dropping entries (host-side realloc; the old one-past-end sentinels are
    rewritten for the new ``n_docs``).

    Drift accounting: ``meta.n_grown`` counts docs appended since the
    codebooks were trained and ``meta.grown_quant_mse`` tracks their mean
    squared token->centroid residual — compare against
    ``meta.train_quant_mse`` via ``meta.drift`` to decide when a re-train
    (fresh ``build_index`` over the union corpus) is warranted.

    doc_embs : (n_new, cap, d) fp32, zero-padded to the INDEX's cap/d
    doc_lens : (n_new,) int
    -> (PackedIndex, IndexMeta) — a new index/meta pair (inputs unchanged)
    """
    doc_embs, doc_lens = _check_new_docs(meta, doc_embs, doc_lens)
    n_old, n_new = meta.n_docs, doc_embs.shape[0]
    n_total = n_old + n_new
    new_codes, new_res, new_plaid, sq_sum, n_tok = _encode_passages(
        index, doc_embs, doc_lens)

    # --- extend the IVF: new lists first, then merge with the old ones ------
    add_ivf, add_lens, _, _ = _build_ivf(
        new_codes, meta.n_centroids, None, origin="add_passages")
    old_ivf = np.asarray(index.ivf)
    old_lens = np.asarray(index.ivf_lens)
    need = old_lens + add_lens
    list_cap = max(meta.list_cap, int(need.max()))
    ivf = np.full((meta.n_centroids, list_cap), n_total, dtype=np.int32)
    for c in np.nonzero(old_lens)[0]:
        ivf[c, :old_lens[c]] = old_ivf[c, :old_lens[c]]
    for c in np.nonzero(add_lens)[0]:
        ivf[c, old_lens[c]:need[c]] = add_ivf[c, :add_lens[c]] + n_old
    ivf_lens = need.astype(np.int32)

    # --- drift statistic over ALL grown docs (old grown + this batch) -------
    all_lens = np.asarray(index.doc_lens)
    old_grown_tok = int(all_lens[n_old - meta.n_grown:].sum())
    grown_tok = old_grown_tok + n_tok
    grown_mse = (meta.grown_quant_mse * old_grown_tok + sq_sum) / \
        max(grown_tok, 1)

    plaid_res = np.asarray(index.plaid_res)
    if plaid_res.shape[0] == n_old:                 # real PLAID codes
        plaid_res = np.concatenate([plaid_res, new_plaid], axis=0)
    grown = PackedIndex(
        centroids=index.centroids,
        codes=jnp.asarray(np.concatenate(
            [np.asarray(index.codes), new_codes], axis=0)),
        doc_lens=jnp.asarray(np.concatenate([all_lens, doc_lens], axis=0)),
        res_codes=jnp.asarray(np.concatenate(
            [np.asarray(index.res_codes), new_res], axis=0)),
        pq_codebooks=index.pq_codebooks,
        ivf=jnp.asarray(ivf),
        ivf_lens=jnp.asarray(ivf_lens),
        plaid_res=jnp.asarray(plaid_res),
        plaid_cutoffs=index.plaid_cutoffs,
        plaid_weights=index.plaid_weights,
        opq_rotation=index.opq_rotation,
    )
    grown_meta = dataclasses.replace(
        meta, n_docs=n_total, list_cap=list_cap, n_grown=meta.n_grown + n_new,
        grown_quant_mse=float(grown_mse))
    return grown, grown_meta


def new_generation(base: PackedIndex, base_meta: IndexMeta,
                   doc_embs: np.ndarray, doc_lens: np.ndarray
                   ) -> tuple[PackedIndex, IndexMeta]:
    """Build a fresh, self-contained index generation for NEW passages only,
    reusing a base index's frozen centroid/PQ/PLAID codebooks.

    The PLAID-SHIRTTT building block: each arriving corpus slice becomes an
    immutable generation with LOCAL doc ids and its own (auto-sized) IVF,
    sharing the base's codebooks so scores are directly comparable — a
    :class:`ShardedTimeline` of such generations merges per-generation
    top-k by score with no calibration step. Every doc counts as "grown"
    (quantized against foreign codebooks), so the generation's
    ``meta.drift`` measures how far the stream has moved from the base
    training distribution.

    -> (PackedIndex, IndexMeta) for the new generation alone
    """
    doc_embs, doc_lens = _check_new_docs(base_meta, doc_embs, doc_lens)
    n_new = doc_embs.shape[0]
    codes, res_codes, plaid_res, sq_sum, n_tok = _encode_passages(
        base, doc_embs, doc_lens)
    ivf, ivf_lens, list_cap, n_dropped = _build_ivf(
        codes, base_meta.n_centroids, None, origin="new_generation")
    gen = PackedIndex(
        centroids=base.centroids,
        codes=jnp.asarray(codes),
        doc_lens=jnp.asarray(doc_lens),
        res_codes=jnp.asarray(res_codes),
        pq_codebooks=base.pq_codebooks,
        ivf=jnp.asarray(ivf),
        ivf_lens=jnp.asarray(ivf_lens),
        plaid_res=jnp.asarray(plaid_res),
        plaid_cutoffs=base.plaid_cutoffs,
        plaid_weights=base.plaid_weights,
        opq_rotation=base.opq_rotation,
    )
    gen_meta = dataclasses.replace(
        base_meta, n_docs=n_new, list_cap=list_cap, n_dropped=n_dropped,
        n_grown=n_new, grown_quant_mse=sq_sum / max(n_tok, 1))
    return gen, gen_meta


# ---------------------------------------------------------------------------
# Multi-generation timeline (PLAID SHIRTTT)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedTimeline:
    """An ordered sequence of immutable index generations served as one
    corpus (PLAID SHIRTTT's temporal sharding).

    Generation g's local doc ids map to the global id space at offset
    ``offsets[g]`` (generations are concatenated in arrival order, so
    global ids are stable as the timeline grows). Query through
    ``repro.core.engine.retrieve_timeline`` or, sharded,
    ``repro.launch.serve.make_timeline_retriever``.
    """

    generations: tuple[PackedIndex, ...]
    metas: tuple[IndexMeta, ...]

    def __post_init__(self):
        """Validate the generation/meta pairing and codebook compatibility."""
        if len(self.generations) != len(self.metas):
            raise ValueError(
                f"{len(self.generations)} generation(s) but "
                f"{len(self.metas)} meta(s)")
        if not self.generations:
            raise ValueError("a ShardedTimeline needs >= 1 generation")
        d0 = self.metas[0]
        geom = ("n_centroids", "d", "cap", "m", "nbits", "plaid_b")
        for g, m in enumerate(self.metas[1:], start=1):
            mine = tuple(getattr(m, f) for f in geom)
            base = tuple(getattr(d0, f) for f in geom)
            if mine != base:
                raise ValueError(
                    f"generation {g} geometry {dict(zip(geom, mine))} "
                    f"differs from generation 0 {dict(zip(geom, base))}; "
                    "generations must share the frozen codebooks (build "
                    "them with store.new_generation)")
        # geometry can coincide by accident (e.g. two independent
        # build_index runs) — scores are only comparable if the CODEBOOK
        # CONTENTS match, so check the arrays, not just their shapes
        c0 = self.generations[0]
        for g, gen in enumerate(self.generations[1:], start=1):
            if not (np.array_equal(np.asarray(gen.centroids),
                                   np.asarray(c0.centroids)) and
                    np.array_equal(np.asarray(gen.pq_codebooks),
                                   np.asarray(c0.pq_codebooks))):
                raise ValueError(
                    f"generation {g} was quantized against different "
                    "centroid/PQ codebooks than generation 0 — its scores "
                    "are not comparable and a merged top-k would be "
                    "silently wrong. Build generations from one base index "
                    "with store.new_generation (a re-trained codebook "
                    "starts a NEW timeline epoch)")

    @property
    def offsets(self) -> tuple[int, ...]:
        """Global doc-id offset of each generation (cumulative n_docs)."""
        offs, acc = [], 0
        for m in self.metas:
            offs.append(acc)
            acc += m.n_docs
        return tuple(offs)

    @property
    def n_docs(self) -> int:
        """Total docs across all generations."""
        return sum(m.n_docs for m in self.metas)

    def __len__(self) -> int:
        """Number of generations."""
        return len(self.generations)

    def __iter__(self) -> Iterator[tuple[PackedIndex, IndexMeta, int]]:
        """Yield (index, meta, global-id offset) per generation, in order."""
        return iter(zip(self.generations, self.metas, self.offsets))

    def append(self, index: PackedIndex, meta: IndexMeta) -> "ShardedTimeline":
        """A new timeline with ``index`` appended as the latest generation."""
        return ShardedTimeline(self.generations + (index,),
                               self.metas + (meta,))

    @classmethod
    def of(cls, *pairs: tuple[PackedIndex, IndexMeta]) -> "ShardedTimeline":
        """Build a timeline from (index, meta) pairs in arrival order."""
        return cls(tuple(i for i, _ in pairs), tuple(m for _, m in pairs))


def save_timeline(path: str, timeline: ShardedTimeline) -> str:
    """Persist a timeline: one :func:`save_index` directory per generation
    (``gen-0000``, ``gen-0001``, ...) plus a ``timeline.json`` listing them
    in order. Returns ``path``."""
    os.makedirs(path, exist_ok=True)
    names = []
    for g, (index, meta, _) in enumerate(timeline):
        name = f"gen-{g:04d}"
        save_index(os.path.join(path, name), index, meta)
        names.append(name)
    with open(os.path.join(path, "timeline.json"), "w") as f:
        json.dump({"format": _TIMELINE_FORMAT,
                   "schema_version": SCHEMA_VERSION,
                   "generations": names}, f, indent=1)
    return path


def load_timeline(path: str) -> ShardedTimeline:
    """Load a timeline written by :func:`save_timeline` (bit-exact, like
    :func:`load_index`); raises actionable ``ValueError`` on corruption."""
    tpath = os.path.join(path, "timeline.json")
    if not os.path.isfile(tpath):
        raise ValueError(f"load_timeline({path!r}): no timeline.json — not "
                         "a saved timeline")
    try:
        with open(tpath) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ValueError(
            f"load_timeline({path!r}): corrupt timeline.json: {e}") from e
    if manifest.get("format") != _TIMELINE_FORMAT:
        raise ValueError(
            f"load_timeline({path!r}): format={manifest.get('format')!r}, "
            f"expected {_TIMELINE_FORMAT!r}")
    version = manifest.get("schema_version")
    if not isinstance(version, int) or version > SCHEMA_VERSION:
        raise ValueError(
            f"load_timeline({path!r}): schema_version={version!r} is not "
            f"readable by this build (<= {SCHEMA_VERSION})")
    names = manifest.get("generations")
    if not isinstance(names, list) or not names:
        raise ValueError(f"load_timeline({path!r}): empty or missing "
                         "'generations' list")
    pairs = [load_index(os.path.join(path, n)) for n in names]
    return ShardedTimeline.of(*pairs)
