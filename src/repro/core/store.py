"""Index lifecycle: persistence, incremental growth, multi-generation serving.

Three concerns, one subsystem (docs/INDEX_FORMAT.md has the on-disk schema):

* **Persistence** — ``save_index`` / ``load_index`` write a
  :class:`~repro.core.index.PackedIndex` + :class:`~repro.core.index.IndexMeta`
  to a versioned directory (``manifest.json`` + ``arrays.npz``). Loading is
  bit-exact: retrieval on a loaded index equals retrieval on the original,
  ids AND score bits (tests/test_store.py).

* **Incremental growth** — ``add_passages`` appends passages to an existing
  index WITHOUT re-running k-means: new tokens are quantized against the
  frozen centroid/PQ/PLAID codebooks (the exact ``quantize_tokens`` path
  ``build_index`` used), IVF lists are extended (list_cap grows instead of
  dropping entries), and the quantization-error drift statistic on
  ``IndexMeta`` tells callers when the frozen codebooks have gone stale.

* **Multi-generation serving** — à la PLAID SHIRTTT (Lawrie et al., 2024):
  an append-only stream is served as a :class:`ShardedTimeline` of immutable
  index generations, each built or grown independently (possibly with
  different ``n_docs``), merged at query time by
  ``repro.core.engine.retrieve_timeline`` (single device) or
  ``repro.launch.serve.make_timeline_retriever`` (shard_map plan per
  generation). Per-generation footprint stays bounded — growth never
  rewrites an old generation.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
import zipfile
from typing import Iterator

import jax.numpy as jnp
import numpy as np

from .bitvector import MAX_PREDICATES, PredicateSet
from .index import IndexMeta, PackedIndex, _build_ivf, bytes_per_embedding, \
    pool_documents, quantize_tokens
from .pq import encode_pq
from .residual import encode_residual

# Bump on ANY incompatible change to the manifest or array set; readers
# refuse files from the future. See docs/INDEX_FORMAT.md for the policy.
# v2: manifest gains the content ``fingerprint`` (the serving cache's
# generation id); v1 files load fine, they just carry no fingerprint.
# v3: the predicate plane — ``pred_words`` joins the array set and
# ``pred_names`` the meta (docs/FILTERING.md). Additive: v2 files load as
# "no plane" (empty names, all-zero words), and their fingerprints verify
# over the v2 field subset.
# v4: constant-space document budgets — ``doc_budget`` and
# ``n_raw_tokens`` join the meta (no array changes, so v3 fingerprints
# stay full-field). Additive: v3 files load as ``doc_budget=None`` /
# ``n_raw_tokens=0`` (per-token layout, footprints fall back to the
# stored token count).
SCHEMA_VERSION = 4
_FORMAT = "emvb-packed-index"
_TIMELINE_FORMAT = "emvb-sharded-timeline"
_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"

# the array set of schema v2 saves (everything but the predicate plane) —
# what their persisted fingerprints were computed over
_V2_FIELDS = tuple(f for f in PackedIndex._fields if f != "pred_words")


# ---------------------------------------------------------------------------
# Content fingerprints — the serving cache's generation ids
# ---------------------------------------------------------------------------

def index_fingerprint(index: PackedIndex, *, fields=None) -> str:
    """Content fingerprint of an index: sha256 over every array's name,
    dtype, shape and bytes (hex digest).

    Equal fingerprints mean equal array contents, and every retrieval input
    is a ``PackedIndex`` field — so equal fingerprints mean bit-identical
    retrieval. That makes the fingerprint the serving layer's generation id: a per-generation cached result
    keyed by it can never be served against different contents —
    ``add_passages`` necessarily changes ``codes``/``doc_lens`` and with
    them the fingerprint. Persisted in the ``save_index`` manifest and
    verified on load (docs/INDEX_FORMAT.md). Schema v3 folds the predicate
    plane (``pred_words``) into the hash; ``fields`` lets the loader verify
    v2-era saves over the v2 field subset.
    """
    h = hashlib.sha256()
    for f in (PackedIndex._fields if fields is None else fields):
        a = np.ascontiguousarray(np.asarray(getattr(index, f)))
        h.update(f.encode())
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Persistence — versioned on-disk format
# ---------------------------------------------------------------------------

def save_index(path: str, index: PackedIndex, meta: IndexMeta) -> str:
    """Write an index to ``path`` (a directory; created if missing).

    Layout: ``manifest.json`` (format name, ``schema_version``, the content
    ``fingerprint``, the full ``IndexMeta``, and a per-array dtype/shape
    manifest) + ``arrays.npz`` (every ``PackedIndex`` field, uncompressed,
    bit-exact). Returns ``path``.
    """
    os.makedirs(path, exist_ok=True)
    arrays = {f: np.asarray(getattr(index, f)) for f in PackedIndex._fields}
    manifest = {
        "format": _FORMAT,
        "schema_version": SCHEMA_VERSION,
        "fingerprint": index_fingerprint(index),
        "meta": dataclasses.asdict(meta),
        "arrays": {f: {"dtype": str(a.dtype), "shape": list(a.shape)}
                   for f, a in arrays.items()},
    }
    # The manifest gates validity: retract any existing one BEFORE touching
    # the arrays (covers overwriting a prior save), write the arrays, then
    # publish the new manifest atomically — a crash at any point leaves a
    # directory load_index rejects instead of a torn or stale index.
    mpath = os.path.join(path, _MANIFEST)
    if os.path.exists(mpath):
        os.remove(mpath)
    np.savez(os.path.join(path, _ARRAYS), **arrays)
    tmp = mpath + ".tmp"
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1)
    os.replace(tmp, mpath)
    return path


def _fail(path: str, why: str) -> ValueError:
    return ValueError(f"load_index({path!r}): {why}")


def load_index(path: str) -> tuple[PackedIndex, IndexMeta]:
    """Load an index written by :func:`save_index` — the bit-exact inverse.

    Every failure mode raises an actionable ``ValueError``: missing/corrupt
    files, wrong format, a future ``schema_version`` (this build refuses to
    guess at formats from the future), missing or unknown meta fields, any
    array whose dtype/shape disagrees with the manifest, and (schema v2+)
    a manifest ``fingerprint`` that disagrees with the recomputed content
    fingerprint — silently corrupted array BYTES, not just wrong shapes.
    """
    mpath = os.path.join(path, _MANIFEST)
    if not os.path.isfile(mpath):
        raise _fail(path, f"no {_MANIFEST} — not a saved EMVB index "
                          "(or a save was interrupted before the manifest "
                          "was written)")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise _fail(path, f"corrupt {_MANIFEST}: {e}") from e
    if not isinstance(manifest, dict) or manifest.get("format") != _FORMAT:
        raise _fail(path, f"{_MANIFEST} has format="
                          f"{manifest.get('format')!r}, expected {_FORMAT!r}")
    version = manifest.get("schema_version")
    if not isinstance(version, int) or version < 1:
        raise _fail(path, f"bad schema_version={version!r}")
    if version > SCHEMA_VERSION:
        raise _fail(path, f"schema_version={version} is newer than this "
                          f"build understands (<= {SCHEMA_VERSION}); "
                          "upgrade repro to read this index")

    meta_fields = {f.name for f in dataclasses.fields(IndexMeta)}
    meta_dict = manifest.get("meta")
    if not isinstance(meta_dict, dict):
        raise _fail(path, f"{_MANIFEST} is missing the 'meta' table")
    if version < 3:
        # v2 manifests predate the predicate plane: default to "no plane"
        meta_dict.setdefault("pred_names", [])
    if version < 4:
        # v3 manifests predate document budgets: per-token layout
        meta_dict.setdefault("doc_budget", None)
        meta_dict.setdefault("n_raw_tokens", 0)
    missing = sorted(meta_fields - meta_dict.keys())
    unknown = sorted(meta_dict.keys() - meta_fields)
    if missing:
        raise _fail(path, f"manifest meta is missing field(s) "
                          f"{missing} — corrupt or hand-edited manifest")
    if unknown:
        raise _fail(path, f"manifest meta has unknown field(s) {unknown} at "
                          f"schema_version={version}; new fields require a "
                          "schema version bump (docs/INDEX_FORMAT.md)")
    pn = meta_dict["pred_names"]
    if not (isinstance(pn, list) and
            all(isinstance(n, str) for n in pn)):
        raise _fail(path, f"meta pred_names={pn!r} is not a list of "
                          "predicate name strings — corrupt or hand-edited "
                          "manifest")
    if len(pn) > MAX_PREDICATES:
        raise _fail(path, f"meta declares {len(pn)} predicate names > "
                          f"{MAX_PREDICATES} (one bit per name in a uint32 "
                          "word)")
    meta_dict["pred_names"] = tuple(pn)   # JSON round-trips tuples as lists
    db = meta_dict["doc_budget"]
    if db is not None and (isinstance(db, bool) or
                           not isinstance(db, int) or db < 1):
        raise _fail(path, f"meta doc_budget={db!r} is neither null nor a "
                          "positive integer — corrupt or hand-edited "
                          "manifest")
    nrt = meta_dict["n_raw_tokens"]
    if isinstance(nrt, bool) or not isinstance(nrt, int) or nrt < 0:
        raise _fail(path, f"meta n_raw_tokens={nrt!r} is not a "
                          "non-negative integer — corrupt or hand-edited "
                          "manifest")
    meta = IndexMeta(**meta_dict)

    # v2 saves carry no pred_words array; everything else is identical
    want_fields = PackedIndex._fields if version >= 3 else _V2_FIELDS
    decl = manifest.get("arrays")
    if not isinstance(decl, dict) or \
            sorted(decl) != sorted(want_fields):
        raise _fail(path, "manifest 'arrays' table does not list exactly "
                          f"the schema-v{version} array set "
                          f"{sorted(want_fields)}")
    apath = os.path.join(path, _ARRAYS)
    if not os.path.isfile(apath):
        raise _fail(path, f"no {_ARRAYS} next to the manifest")
    try:
        with np.load(apath) as npz:
            arrays = {k: npz[k] for k in npz.files}
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise _fail(path, f"corrupt {_ARRAYS}: {e}") from e

    loaded = {}
    for f in want_fields:
        if f not in arrays:
            raise _fail(path, f"{_ARRAYS} is missing array {f!r} declared "
                              "in the manifest")
        a, want = arrays[f], decl[f]
        if str(a.dtype) != want["dtype"] or list(a.shape) != want["shape"]:
            raise _fail(path, f"array {f!r} is {a.dtype}{list(a.shape)} but "
                              f"the manifest declares {want['dtype']}"
                              f"{want['shape']} — corrupt save")
        loaded[f] = jnp.asarray(a)
    if version < 3:
        # the empty plane: no names, no bits — schema-v3 in-memory shape
        loaded["pred_words"] = jnp.zeros(loaded["codes"].shape[0],
                                         jnp.uint32)
    index = PackedIndex(**loaded)

    # light cross-checks: meta and arrays must describe the same index
    n_docs, cap = index.codes.shape
    if (meta.n_docs, meta.cap) != (n_docs, cap) or \
            meta.n_centroids != index.centroids.shape[0]:
        raise _fail(path, f"meta (n_docs={meta.n_docs}, cap={meta.cap}, "
                          f"n_centroids={meta.n_centroids}) disagrees with "
                          f"the arrays (codes {n_docs}x{cap}, centroids "
                          f"{index.centroids.shape[0]}) — corrupt save")
    if meta.doc_budget is not None and meta.cap > meta.doc_budget:
        raise _fail(path, f"meta declares doc_budget={meta.doc_budget} but "
                          f"cap={meta.cap} exceeds it — a budgeted index "
                          "never stores more than doc_budget vectors per "
                          "doc (corrupt or hand-edited manifest)")
    if meta.n_raw_tokens and \
            meta.n_raw_tokens < int(np.asarray(index.doc_lens).sum()):
        raise _fail(path, f"meta n_raw_tokens={meta.n_raw_tokens} is below "
                          "the stored token count "
                          f"{int(np.asarray(index.doc_lens).sum())} — "
                          "pooling never grows a document (corrupt or "
                          "hand-edited manifest)")
    pw = np.asarray(index.pred_words)
    if pw.shape != (n_docs,):
        raise _fail(path, f"predicate plane pred_words has "
                          f"{list(pw.shape)} word(s) but the index has "
                          f"{n_docs} docs — the plane packs exactly one "
                          "uint32 word per doc (corrupt save)")
    n_names = len(meta.pred_names)
    if n_names < MAX_PREDICATES and pw.size and \
            (int(pw.max()) >> n_names):
        raise _fail(path, f"predicate plane has bits set beyond the "
                          f"{n_names} name(s) in meta.pred_names "
                          f"{meta.pred_names} — the plane and the manifest "
                          "disagree about which predicates exist (corrupt "
                          "or hand-edited save)")

    # content fingerprint (schema v2+): the dtype/shape checks above cannot
    # see flipped BYTES; the fingerprint can. v1 files predate it. v2
    # fingerprints were computed before the predicate plane existed, so
    # they verify over the v2 field subset.
    if version >= 2:
        declared = manifest.get("fingerprint")
        if not isinstance(declared, str):
            raise _fail(path, "manifest has no 'fingerprint' at "
                              f"schema_version={version} (required since "
                              "v2) — corrupt or hand-edited manifest")
        actual = index_fingerprint(
            index, fields=PackedIndex._fields if version >= 3
            else _V2_FIELDS)
        if declared != actual:
            raise _fail(path, f"manifest fingerprint {declared[:12]}… "
                              f"disagrees with the array contents "
                              f"({actual[:12]}…) — the arrays were modified "
                              "after the save, or the save is corrupt")
    return index, meta


# ---------------------------------------------------------------------------
# Incremental growth — quantize against frozen codebooks
# ---------------------------------------------------------------------------

def _encode_passages(index: PackedIndex, doc_embs: np.ndarray,
                     doc_lens: np.ndarray):
    """Encode new passages against an index's FROZEN codebooks.

    Runs the exact build-time path — ``quantize_tokens`` + PQ (+ OPQ
    rotation) + PLAID codec — so a passage encodes bit-identically whether
    it entered via ``build_index``-then-``add_passages`` or via
    ``new_generation``. Returns (codes, res_codes, plaid_res,
    residual_sq_sum, n_tokens); the last two feed the drift statistic.
    """
    n_new, cap, d = doc_embs.shape
    codes, residual_flat, mask = quantize_tokens(
        index.centroids, doc_embs, doc_lens)
    rotation = np.asarray(index.opq_rotation)
    if np.array_equal(rotation, np.eye(d, dtype=rotation.dtype)):
        residual_rot = jnp.asarray(residual_flat)   # skip the identity matmul
    else:
        residual_rot = jnp.asarray(residual_flat) @ index.opq_rotation
    m = index.res_codes.shape[-1]
    res_codes = np.asarray(encode_pq(residual_rot, index.pq))
    res_codes = res_codes.reshape(n_new, cap, m).astype(np.uint8)
    plaid_res = np.asarray(
        encode_residual(jnp.asarray(residual_flat), index.plaid_codec))
    plaid_res = plaid_res.reshape(n_new, cap, -1)
    real = residual_flat[mask.reshape(-1)]
    return codes, res_codes, plaid_res, float(np.sum(real * real)), \
        int(mask.sum())


def _pool_new_docs(meta: IndexMeta, doc_embs: np.ndarray,
                   doc_lens: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray, int]:
    """Apply the index's document budget to incoming RAW passages.

    Growth paths must encode a doc exactly as ``build_index`` would have:
    for a budgeted index (``meta.doc_budget`` set) that means pooling with
    :func:`~repro.core.index.pool_documents` FIRST, then padding the
    pooled arrays out to the index ``cap``. Raw inputs may be padded to
    any cap >= 1 (they are pooled down before the geometry check); an
    unbudgeted index passes everything through untouched. Returns
    ``(doc_embs, doc_lens, n_raw)`` where ``n_raw`` is the pre-pooling
    token count for the footprint counterfactual.
    """
    doc_embs = np.asarray(doc_embs, dtype=np.float32)
    doc_lens = np.asarray(doc_lens, dtype=np.int32)
    n_raw = int(doc_lens.sum()) if doc_lens.ndim == 1 else 0
    if meta.doc_budget is None or doc_embs.ndim != 3:
        return doc_embs, doc_lens, n_raw
    doc_embs, doc_lens = pool_documents(doc_embs, doc_lens,
                                        meta.doc_budget)
    cap = doc_embs.shape[1]
    if cap < meta.cap:                       # pad pooled docs to index cap
        pad = np.zeros((doc_embs.shape[0], meta.cap - cap,
                        doc_embs.shape[2]), np.float32)
        doc_embs = np.concatenate([doc_embs, pad], axis=1)
    elif cap > meta.cap:
        if int(doc_lens.max(initial=0)) > meta.cap:
            raise ValueError(
                f"new passages still hold up to {int(doc_lens.max())} "
                f"vectors after pooling to doc_budget="
                f"{meta.doc_budget}, but the index cap is {meta.cap} — "
                "the base corpus never filled the budget; rebuild with a "
                "larger cap (or a budget <= cap) to grow these docs")
        doc_embs = doc_embs[:, :meta.cap]    # all-zero padding columns
    return doc_embs, doc_lens, n_raw


def _grown_raw_tokens(meta: IndexMeta, n_raw: int) -> int:
    """Growth bookkeeping for ``meta.n_raw_tokens``.

    Indexes that track raw tokens (any v4 build) keep the count exact;
    pre-v4 loads carry 0 and stay at 0 for unbudgeted indexes (footprints
    then fall back to the stored token count, which IS the raw count when
    nothing is pooled).
    """
    if meta.n_raw_tokens == 0 and meta.doc_budget is None:
        return 0
    return meta.n_raw_tokens + n_raw


def _check_new_docs(meta: IndexMeta, doc_embs: np.ndarray,
                    doc_lens: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Validate (and coerce) new-passage arrays against the index geometry."""
    doc_embs = np.asarray(doc_embs, dtype=np.float32)
    doc_lens = np.asarray(doc_lens, dtype=np.int32)
    if doc_embs.ndim != 3 or doc_embs.shape[0] != doc_lens.shape[0]:
        raise ValueError(
            f"doc_embs {doc_embs.shape} / doc_lens {doc_lens.shape}: "
            "expected (n_new, cap, d) embeddings with one length per doc")
    if doc_embs.shape[1] != meta.cap or doc_embs.shape[2] != meta.d:
        raise ValueError(
            f"new passages are padded to (cap={doc_embs.shape[1]}, "
            f"d={doc_embs.shape[2]}) but the index was built with "
            f"(cap={meta.cap}, d={meta.d}); re-pad (or truncate) the new "
            "docs to the index geometry first")
    if doc_embs.shape[0] == 0:
        raise ValueError("no passages to add (n_new=0)")
    return doc_embs, doc_lens


def _pack_new_predicates(meta: IndexMeta, n_new: int, predicates,
                         origin: str) -> np.ndarray:
    """Pack (and validate) the predicate words for newly grown docs.

    The plane layout is fixed at build time: an index WITH pred_names
    requires exactly those predicates for every new doc (bit positions
    follow ``meta.pred_names`` order regardless of mapping order); an index
    WITHOUT a plane rejects predicates outright.
    """
    if not meta.pred_names:
        if predicates is not None:
            raise ValueError(
                f"{origin}: predicates were given but the index has no "
                "predicate plane (meta.pred_names is empty) — build the "
                "base index with build_index(predicates=...) first")
        return np.zeros(n_new, np.uint32)
    if predicates is None:
        raise ValueError(
            f"{origin}: the index has predicate plane {meta.pred_names} "
            "but no predicates were given for the new docs — every doc "
            "must carry every named predicate")
    if isinstance(predicates, PredicateSet):
        pset = predicates
    else:
        if sorted(predicates) != sorted(meta.pred_names):
            raise ValueError(
                f"{origin}: new docs carry predicates "
                f"{tuple(sorted(predicates))} but the index's plane is "
                f"{meta.pred_names} — names must match exactly")
        pset = PredicateSet.pack({n: predicates[n]
                                  for n in meta.pred_names})
    if pset.names != tuple(meta.pred_names):
        raise ValueError(
            f"{origin}: predicate names {pset.names} do not match the "
            f"index's plane {meta.pred_names} (bit positions are fixed at "
            "build time; pack in the index's name order)")
    words = np.asarray(pset.words)
    if words.shape[0] != n_new:
        raise ValueError(
            f"{origin}: predicate plane covers {words.shape[0]} docs but "
            f"{n_new} docs are being added")
    return words


def add_passages(index: PackedIndex, meta: IndexMeta, doc_embs: np.ndarray,
                 doc_lens: np.ndarray,
                 predicates=None) -> tuple[PackedIndex, IndexMeta]:
    """Append passages to an existing index without re-running k-means.

    New docs are quantized against the FROZEN centroid and PQ/PLAID
    codebooks (so existing doc ids, codes and scores are untouched), their
    doc ids continue after the current corpus, and the IVF is extended
    in-place semantics-wise: ``list_cap`` grows as needed instead of
    dropping entries (host-side realloc; the old one-past-end sentinels are
    rewritten for the new ``n_docs``).

    Drift accounting: ``meta.n_grown`` counts docs appended since the
    codebooks were trained and ``meta.grown_quant_mse`` tracks their mean
    squared token->centroid residual — compare against
    ``meta.train_quant_mse`` via ``meta.drift`` to decide when a re-train
    (fresh ``build_index`` over the union corpus) is warranted.

    doc_embs   : (n_new, cap, d) fp32, zero-padded to the INDEX's cap/d —
                 except on a budgeted index (``meta.doc_budget`` set),
                 which accepts RAW docs at any cap and pools them down
                 exactly as ``build_index`` would have
    doc_lens   : (n_new,) int
    predicates : the new docs' predicate values when the index has a plane
                 (a ``{name: (n_new,) bool}`` mapping or PredicateSet over
                 exactly ``meta.pred_names``); must stay ``None`` when it
                 has none
    -> (PackedIndex, IndexMeta) — a new index/meta pair (inputs unchanged)
    """
    doc_embs, doc_lens, n_raw = _pool_new_docs(meta, doc_embs, doc_lens)
    doc_embs, doc_lens = _check_new_docs(meta, doc_embs, doc_lens)
    n_old, n_new = meta.n_docs, doc_embs.shape[0]
    n_total = n_old + n_new
    new_pred = _pack_new_predicates(meta, n_new, predicates, "add_passages")
    new_codes, new_res, new_plaid, sq_sum, n_tok = _encode_passages(
        index, doc_embs, doc_lens)

    # --- extend the IVF: new lists first, then merge with the old ones ------
    add_ivf, add_lens, _, _ = _build_ivf(
        new_codes, meta.n_centroids, None, origin="add_passages")
    old_ivf = np.asarray(index.ivf)
    old_lens = np.asarray(index.ivf_lens)
    need = old_lens + add_lens
    list_cap = max(meta.list_cap, int(need.max()))
    ivf = np.full((meta.n_centroids, list_cap), n_total, dtype=np.int32)
    for c in np.nonzero(old_lens)[0]:
        ivf[c, :old_lens[c]] = old_ivf[c, :old_lens[c]]
    for c in np.nonzero(add_lens)[0]:
        ivf[c, old_lens[c]:need[c]] = add_ivf[c, :add_lens[c]] + n_old
    ivf_lens = need.astype(np.int32)

    # --- drift statistic over ALL grown docs (old grown + this batch) -------
    all_lens = np.asarray(index.doc_lens)
    old_grown_tok = int(all_lens[n_old - meta.n_grown:].sum())
    grown_tok = old_grown_tok + n_tok
    grown_mse = (meta.grown_quant_mse * old_grown_tok + sq_sum) / \
        max(grown_tok, 1)

    plaid_res = np.asarray(index.plaid_res)
    if plaid_res.shape[0] == n_old:                 # real PLAID codes
        plaid_res = np.concatenate([plaid_res, new_plaid], axis=0)
    grown = PackedIndex(
        centroids=index.centroids,
        codes=jnp.asarray(np.concatenate(
            [np.asarray(index.codes), new_codes], axis=0)),
        doc_lens=jnp.asarray(np.concatenate([all_lens, doc_lens], axis=0)),
        res_codes=jnp.asarray(np.concatenate(
            [np.asarray(index.res_codes), new_res], axis=0)),
        pq_codebooks=index.pq_codebooks,
        ivf=jnp.asarray(ivf),
        ivf_lens=jnp.asarray(ivf_lens),
        plaid_res=jnp.asarray(plaid_res),
        plaid_cutoffs=index.plaid_cutoffs,
        plaid_weights=index.plaid_weights,
        opq_rotation=index.opq_rotation,
        pred_words=jnp.asarray(np.concatenate(
            [np.asarray(index.pred_words), new_pred])),
    )
    grown_meta = dataclasses.replace(
        meta, n_docs=n_total, list_cap=list_cap, n_grown=meta.n_grown + n_new,
        grown_quant_mse=float(grown_mse),
        n_raw_tokens=_grown_raw_tokens(meta, n_raw))
    return grown, grown_meta


def new_generation(base: PackedIndex, base_meta: IndexMeta,
                   doc_embs: np.ndarray, doc_lens: np.ndarray,
                   predicates=None) -> tuple[PackedIndex, IndexMeta]:
    """Build a fresh, self-contained index generation for NEW passages only,
    reusing a base index's frozen centroid/PQ/PLAID codebooks.

    The PLAID-SHIRTTT building block: each arriving corpus slice becomes an
    immutable generation with LOCAL doc ids and its own (auto-sized) IVF,
    sharing the base's codebooks so scores are directly comparable — a
    :class:`ShardedTimeline` of such generations merges per-generation
    top-k by score with no calibration step. Every doc counts as "grown"
    (quantized against foreign codebooks), so the generation's
    ``meta.drift`` measures how far the stream has moved from the base
    training distribution.

    ``predicates`` follows the :func:`add_passages` rule: required (over
    exactly ``base_meta.pred_names``) when the base has a plane, forbidden
    when it has none — a timeline serves ONE compiled FilterPlan across all
    its generations, so bit positions must agree everywhere.

    A budgeted base (``base_meta.doc_budget`` set) pools the incoming RAW
    docs (any input cap) before encoding, exactly as ``build_index`` would
    have, and the generation meta carries the budget forward — the whole
    timeline stays constant-space.

    -> (PackedIndex, IndexMeta) for the new generation alone
    """
    doc_embs, doc_lens, n_raw = _pool_new_docs(base_meta, doc_embs,
                                               doc_lens)
    doc_embs, doc_lens = _check_new_docs(base_meta, doc_embs, doc_lens)
    n_new = doc_embs.shape[0]
    pred_words = _pack_new_predicates(base_meta, n_new, predicates,
                                      "new_generation")
    codes, res_codes, plaid_res, sq_sum, n_tok = _encode_passages(
        base, doc_embs, doc_lens)
    ivf, ivf_lens, list_cap, n_dropped = _build_ivf(
        codes, base_meta.n_centroids, None, origin="new_generation")
    gen = PackedIndex(
        centroids=base.centroids,
        codes=jnp.asarray(codes),
        doc_lens=jnp.asarray(doc_lens),
        res_codes=jnp.asarray(res_codes),
        pq_codebooks=base.pq_codebooks,
        ivf=jnp.asarray(ivf),
        ivf_lens=jnp.asarray(ivf_lens),
        plaid_res=jnp.asarray(plaid_res),
        plaid_cutoffs=base.plaid_cutoffs,
        plaid_weights=base.plaid_weights,
        opq_rotation=base.opq_rotation,
        pred_words=jnp.asarray(pred_words),
    )
    gen_meta = dataclasses.replace(
        base_meta, n_docs=n_new, list_cap=list_cap, n_dropped=n_dropped,
        n_grown=n_new, grown_quant_mse=sq_sum / max(n_tok, 1),
        n_raw_tokens=n_raw)
    return gen, gen_meta


# ---------------------------------------------------------------------------
# Multi-generation timeline (PLAID SHIRTTT)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedTimeline:
    """An ordered sequence of immutable index generations served as one
    corpus (PLAID SHIRTTT's temporal sharding).

    Generation g's local doc ids map to the global id space at offset
    ``offsets[g]`` (generations are concatenated in arrival order, so
    global ids are stable as the timeline grows). Query through
    ``repro.core.engine.retrieve_timeline`` or, sharded,
    ``repro.launch.serve.make_timeline_retriever``.
    """

    generations: tuple[PackedIndex, ...]
    metas: tuple[IndexMeta, ...]

    def __post_init__(self):
        """Validate the generation/meta pairing and codebook compatibility."""
        if len(self.generations) != len(self.metas):
            raise ValueError(
                f"{len(self.generations)} generation(s) but "
                f"{len(self.metas)} meta(s)")
        if not self.generations:
            raise ValueError("a ShardedTimeline needs >= 1 generation")
        d0 = self.metas[0]
        geom = ("n_centroids", "d", "cap", "m", "nbits", "plaid_b")
        for g, m in enumerate(self.metas[1:], start=1):
            mine = tuple(getattr(m, f) for f in geom)
            base = tuple(getattr(d0, f) for f in geom)
            if mine != base:
                raise ValueError(
                    f"generation {g} geometry {dict(zip(geom, mine))} "
                    f"differs from generation 0 {dict(zip(geom, base))}; "
                    "generations must share the frozen codebooks (build "
                    "them with store.new_generation)")
            if tuple(m.pred_names) != tuple(d0.pred_names):
                raise ValueError(
                    f"generation {g} has predicate plane {m.pred_names} "
                    f"but generation 0 has {d0.pred_names}; one compiled "
                    "FilterPlan serves a whole timeline, so predicate bit "
                    "positions must agree everywhere (grow generations "
                    "with store.new_generation, passing the same "
                    "predicate names)")
        # geometry can coincide by accident (e.g. two independent
        # build_index runs) — scores are only comparable if the CODEBOOK
        # CONTENTS match, so check the arrays, not just their shapes
        c0 = self.generations[0]
        for g, gen in enumerate(self.generations[1:], start=1):
            if not (np.array_equal(np.asarray(gen.centroids),
                                   np.asarray(c0.centroids)) and
                    np.array_equal(np.asarray(gen.pq_codebooks),
                                   np.asarray(c0.pq_codebooks))):
                raise ValueError(
                    f"generation {g} was quantized against different "
                    "centroid/PQ codebooks than generation 0 — its scores "
                    "are not comparable and a merged top-k would be "
                    "silently wrong. Build generations from one base index "
                    "with store.new_generation (a re-trained codebook "
                    "starts a NEW timeline epoch)")

    @property
    def offsets(self) -> tuple[int, ...]:
        """Global doc-id offset of each generation (cumulative n_docs)."""
        offs, acc = [], 0
        for m in self.metas:
            offs.append(acc)
            acc += m.n_docs
        return tuple(offs)

    @functools.cached_property
    def fingerprints(self) -> tuple[str, ...]:
        """Content fingerprint (:func:`index_fingerprint`) per generation.

        The serving layer's cache keys. Computed once per timeline OBJECT
        (cached_property): the timeline is immutable, so any mutation —
        ``append``, ``with_newest`` — builds a new timeline whose changed
        generation hashes to a new fingerprint, which is exactly the cache
        invalidation rule (stale entries keyed by the old fingerprint are
        simply never hit again).
        """
        return tuple(index_fingerprint(g) for g in self.generations)

    @property
    def n_docs(self) -> int:
        """Total docs across all generations."""
        return sum(m.n_docs for m in self.metas)

    def __len__(self) -> int:
        """Number of generations."""
        return len(self.generations)

    def __iter__(self) -> Iterator[tuple[PackedIndex, IndexMeta, int]]:
        """Yield (index, meta, global-id offset) per generation, in order."""
        return iter(zip(self.generations, self.metas, self.offsets))

    def append(self, index: PackedIndex, meta: IndexMeta) -> "ShardedTimeline":
        """A new timeline with ``index`` appended as the latest generation."""
        return ShardedTimeline(self.generations + (index,),
                               self.metas + (meta,))

    def with_newest(self, index: PackedIndex,
                    meta: IndexMeta) -> "ShardedTimeline":
        """A new timeline with the NEWEST generation replaced by ``index``.

        The ``add_passages``-on-the-open-generation step of a streaming
        deployment: grow ``timeline.generations[-1]`` functionally, then
        swap it in here. Only the last generation may be replaced — older
        ones are immutable by contract (cached results key on their
        fingerprints), and replacing the tail changes no other generation's
        global id offset.
        """
        return ShardedTimeline(self.generations[:-1] + (index,),
                               self.metas[:-1] + (meta,))

    @classmethod
    def of(cls, *pairs: tuple[PackedIndex, IndexMeta]) -> "ShardedTimeline":
        """Build a timeline from (index, meta) pairs in arrival order."""
        return cls(tuple(i for i, _ in pairs), tuple(m for _, m in pairs))


# ---------------------------------------------------------------------------
# Maintenance primitives — generation compaction + codebook epochs
# (policy/orchestration live in repro.serving.maintenance; docs/MAINTENANCE.md)
# ---------------------------------------------------------------------------

def merge_generations(timeline: ShardedTimeline, lo: int,
                      hi: int) -> ShardedTimeline:
    """Compact generations ``[lo, hi)`` of a timeline into ONE generation.

    The offline half of PLAID SHIRTTT's hierarchical merge schedule: many
    small temporal shards re-materialize as one bigger shard, cutting the
    per-query fan-out (fig7: latency grows ~linearly with generation count)
    without touching any doc's quantization. All generations of a timeline
    share the frozen codebooks (``ShardedTimeline.__post_init__`` enforces
    it), so the merge is pure bookkeeping:

    * **arrays** — codes / doc_lens / res_codes / plaid_res concatenate in
      generation order, so every doc keeps its GLOBAL id (offsets of the
      untouched generations before and after the range are unchanged too);
    * **IVF** — per centroid, the per-generation lists concatenate with
      each generation's local doc-id offset added (the candidate bitmap
      unions lists, so within-list order is irrelevant); entries a
      generation's own build dropped stay dropped — the merge never
      resurrects or loses reachability, which is what makes the
      equivalence contract below exact;
    * **meta** — ``n_docs``/``n_dropped`` sum; ``list_cap`` re-sizes to the
      longest merged list; the drift statistic merges token-weighted over
      the grown SUFFIX of the range (``n_grown`` counts "the last n_grown
      docs", so grown docs of a partially-grown generation buried under a
      later generation's docs can no longer be represented and fold into
      the untracked prefix — a conservative under-count, never a wrong
      ratio).

    Contract (tests/test_maintenance.py): under cut-lossless budgets,
    ``retrieve_timeline(merge_generations(tl, lo, hi)) ==
    retrieve_timeline(tl)`` — ids AND score bits, jnp reference and both
    megakernels. Every phase's score is per-document given the shared
    codebooks, and ``lax.top_k`` ties resolve toward the lower global doc
    id on both paths (generations concatenate in id order).

    The merged generation has a NEW content fingerprint (its cached
    partials recompute); generations outside ``[lo, hi)`` keep theirs (their
    cache entries keep serving — the hot-swap warm path).
    """
    n_gens = len(timeline)
    if not (isinstance(lo, int) and isinstance(hi, int)
            and 0 <= lo < hi <= n_gens):
        raise ValueError(
            f"merge_generations range [lo={lo}, hi={hi}) is not a valid "
            f"generation slice of a {n_gens}-generation timeline")
    if hi - lo < 2:
        raise ValueError(
            f"merge_generations range [lo={lo}, hi={hi}) spans a single "
            "generation — nothing to compact")
    gens = timeline.generations[lo:hi]
    metas = timeline.metas[lo:hi]
    budgets = {m.doc_budget for m in metas}
    if len(budgets) > 1:
        raise ValueError(
            f"merge_generations range [lo={lo}, hi={hi}) mixes document "
            f"budgets {sorted(budgets, key=str)} — a merged generation has "
            "ONE doc_budget and pooled/unpooled docs must not be conflated "
            "silently; re-encode one side (store.new_generation against a "
            "common base) before compacting")
    n_total = sum(m.n_docs for m in metas)
    for g, (gen, m) in enumerate(zip(gens, metas), start=lo):
        if np.asarray(gen.plaid_res).shape[0] != m.n_docs:
            raise ValueError(
                f"generation {g} carries placeholder PLAID residuals "
                f"(shape {np.asarray(gen.plaid_res).shape} for "
                f"{m.n_docs} docs) — only full generations can be merged")

    codes = np.concatenate([np.asarray(g.codes) for g in gens], axis=0)
    doc_lens = np.concatenate([np.asarray(g.doc_lens) for g in gens])
    res_codes = np.concatenate([np.asarray(g.res_codes) for g in gens],
                               axis=0)
    plaid_res = np.concatenate([np.asarray(g.plaid_res) for g in gens],
                               axis=0)
    # predicate planes concatenate like every other per-doc array: bit
    # positions are timeline-wide (pred_names equality is enforced by
    # ShardedTimeline), so no per-word fixup is needed — only the doc-id
    # offsets above move, and those are implicit in concatenation order
    pred_words = np.concatenate([np.asarray(g.pred_words) for g in gens])

    # IVF: concatenate per-centroid lists with local doc-id offset fixup
    n_c = metas[0].n_centroids
    lens = np.stack([np.asarray(g.ivf_lens) for g in gens])      # (R, n_c)
    need = lens.sum(axis=0)
    list_cap = max(8, int(need.max()))
    ivf = np.full((n_c, list_cap), n_total, dtype=np.int32)      # sentinel
    cursor = np.zeros(n_c, dtype=np.int64)
    off = 0
    for r, (gen, m) in enumerate(zip(gens, metas)):
        g_ivf = np.asarray(gen.ivf)
        for c in np.nonzero(lens[r])[0]:
            ln = lens[r, c]
            ivf[c, cursor[c]:cursor[c] + ln] = g_ivf[c, :ln] + off
            cursor[c] += ln
        off += m.n_docs

    # drift statistic: token-weighted over the grown suffix of the range
    n_grown, num, tok = 0, 0.0, 0
    tail_open = True
    for gen, m in zip(reversed(gens), reversed(metas)):
        if not tail_open or m.n_grown == 0:
            tail_open = False
            continue
        n_grown += m.n_grown
        lens_g = np.asarray(gen.doc_lens)
        t = int(lens_g[m.n_docs - m.n_grown:].sum())
        num += m.grown_quant_mse * t
        tok += t
        if m.n_grown < m.n_docs:
            tail_open = False

    first = gens[0]
    merged = PackedIndex(
        centroids=first.centroids,
        codes=jnp.asarray(codes),
        doc_lens=jnp.asarray(doc_lens),
        res_codes=jnp.asarray(res_codes),
        pq_codebooks=first.pq_codebooks,
        ivf=jnp.asarray(ivf),
        ivf_lens=jnp.asarray(need.astype(np.int32)),
        plaid_res=jnp.asarray(plaid_res),
        plaid_cutoffs=first.plaid_cutoffs,
        plaid_weights=first.plaid_weights,
        opq_rotation=first.opq_rotation,
        pred_words=jnp.asarray(pred_words),
    )
    # raw-token accounting survives the merge only if every generation
    # tracked it (pre-v4 loads carry 0 — summing those would under-count)
    n_raw = (sum(m.n_raw_tokens for m in metas)
             if all(m.n_raw_tokens for m in metas) else 0)
    merged_meta = dataclasses.replace(
        metas[0], n_docs=n_total, list_cap=list_cap,
        n_dropped=sum(m.n_dropped for m in metas), n_grown=n_grown,
        grown_quant_mse=float(num / tok) if tok else 0.0,
        n_raw_tokens=n_raw)
    return ShardedTimeline(
        timeline.generations[:lo] + (merged,) + timeline.generations[hi:],
        timeline.metas[:lo] + (merged_meta,) + timeline.metas[hi:])


@dataclasses.dataclass(frozen=True)
class EpochedTimeline:
    """An ordered sequence of codebook EPOCHS, each a :class:`ShardedTimeline`.

    ``ShardedTimeline`` refuses generations quantized against different
    codebooks — their scores are not bit-comparable and a merged-by-score
    top-k would be silently wrong. Re-epoching (a fresh ``build_index``
    over a drifted corpus slice — ``repro.serving.maintenance``) therefore
    opens a NEW timeline rather than appending a generation, and this class
    is the container: epoch 0 is the oldest codebook regime, the last epoch
    is the live one (only ITS newest generation is mutable).

    Global doc ids concatenate across epochs (``epoch_offsets``), exactly
    like generations concatenate within one. Retrieval
    (``repro.core.engine.retrieve_timeline``) merges BY SCORE within an
    epoch and BY RANK across epochs
    (``repro.core.engine.merge_partial_topk_by_rank`` — scores from
    different codebooks are not comparable, ranks are; docs/MAINTENANCE.md
    has the semantics).
    """

    epochs: tuple[ShardedTimeline, ...]

    def __post_init__(self):
        """Validate epoch types and the shared query geometry (d, cap)."""
        if not self.epochs:
            raise ValueError("an EpochedTimeline needs >= 1 epoch")
        for e, tl in enumerate(self.epochs):
            if not isinstance(tl, ShardedTimeline):
                raise ValueError(
                    f"epoch {e} is a {type(tl).__name__}, expected a "
                    "ShardedTimeline (wrap single indexes with "
                    "ShardedTimeline.of)")
        m0 = self.epochs[0].metas[0]
        for e, tl in enumerate(self.epochs[1:], start=1):
            m = tl.metas[0]
            if (m.d, m.cap) != (m0.d, m0.cap):
                raise ValueError(
                    f"epoch {e} has (d={m.d}, cap={m.cap}) but epoch 0 has "
                    f"(d={m0.d}, cap={m0.cap}); every epoch serves the same "
                    "queries, so the embedding geometry must match "
                    "(codebooks MAY differ — that is what epochs are for)")

    @classmethod
    def of(cls, timeline) -> "EpochedTimeline":
        """Wrap a plain ``ShardedTimeline`` as one epoch (idempotent on an
        ``EpochedTimeline``)."""
        if isinstance(timeline, cls):
            return timeline
        return cls((timeline,))

    @property
    def epoch_offsets(self) -> tuple[int, ...]:
        """Global doc-id offset of each epoch (cumulative epoch n_docs)."""
        offs, acc = [], 0
        for tl in self.epochs:
            offs.append(acc)
            acc += tl.n_docs
        return tuple(offs)

    @property
    def n_docs(self) -> int:
        """Total docs across all epochs."""
        return sum(tl.n_docs for tl in self.epochs)

    @property
    def n_generations(self) -> int:
        """Total generations across all epochs."""
        return sum(len(tl) for tl in self.epochs)

    def __len__(self) -> int:
        """Number of epochs."""
        return len(self.epochs)

    def __iter__(self) -> Iterator[tuple[ShardedTimeline, int]]:
        """Yield (epoch timeline, global doc-id offset), oldest first."""
        return iter(zip(self.epochs, self.epoch_offsets))

    def with_newest_epoch(self, tl: ShardedTimeline) -> "EpochedTimeline":
        """A new EpochedTimeline with the LIVE (last) epoch replaced —
        the growth/compaction step; older epochs are sealed by contract."""
        return EpochedTimeline(self.epochs[:-1] + (tl,))

    def append_epoch(self, tl: ShardedTimeline) -> "EpochedTimeline":
        """A new EpochedTimeline with ``tl`` opened as the live epoch."""
        return EpochedTimeline(self.epochs + (tl,))


def save_timeline(path: str, timeline: ShardedTimeline) -> str:
    """Persist a timeline: one :func:`save_index` directory per generation
    (``gen-0000``, ``gen-0001``, ...) plus a ``timeline.json`` listing them
    in order with their content fingerprints. Returns ``path``."""
    os.makedirs(path, exist_ok=True)
    names = []
    for g, (index, meta, _) in enumerate(timeline):
        name = f"gen-{g:04d}"
        save_index(os.path.join(path, name), index, meta)
        names.append(name)
    with open(os.path.join(path, "timeline.json"), "w") as f:
        json.dump({"format": _TIMELINE_FORMAT,
                   "schema_version": SCHEMA_VERSION,
                   "generations": names,
                   "fingerprints": list(timeline.fingerprints)}, f, indent=1)
    return path


def load_timeline(path: str) -> ShardedTimeline:
    """Load a timeline written by :func:`save_timeline` (bit-exact, like
    :func:`load_index`); raises actionable ``ValueError`` on corruption."""
    tpath = os.path.join(path, "timeline.json")
    if not os.path.isfile(tpath):
        raise ValueError(f"load_timeline({path!r}): no timeline.json — not "
                         "a saved timeline")
    try:
        with open(tpath) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ValueError(
            f"load_timeline({path!r}): corrupt timeline.json: {e}") from e
    if manifest.get("format") != _TIMELINE_FORMAT:
        raise ValueError(
            f"load_timeline({path!r}): format={manifest.get('format')!r}, "
            f"expected {_TIMELINE_FORMAT!r}")
    version = manifest.get("schema_version")
    if not isinstance(version, int) or version > SCHEMA_VERSION:
        raise ValueError(
            f"load_timeline({path!r}): schema_version={version!r} is not "
            f"readable by this build (<= {SCHEMA_VERSION})")
    names = manifest.get("generations")
    if not isinstance(names, list) or not names:
        raise ValueError(f"load_timeline({path!r}): empty or missing "
                         "'generations' list")
    pairs = [load_index(os.path.join(path, n)) for n in names]
    timeline = ShardedTimeline.of(*pairs)
    _check_timeline_fingerprints(path, version, manifest, names, timeline)
    return timeline


def _check_timeline_fingerprints(path: str, version: int, manifest: dict,
                                 names: list, timeline: ShardedTimeline
                                 ) -> None:
    """Fingerprint round trip (schema v2+): ``load_index`` already verified
    each generation's arrays against ITS manifest; this verifies the loaded
    generations are the ones THIS timeline listed — a swapped or restored-
    from-elsewhere gen-NNNN directory is internally consistent but wrong.

    Reuses each generation's manifest fingerprint (just proven equal to
    its array contents by ``load_index``) instead of re-hashing the
    arrays — string compares, not a second sha256 pass over the timeline.
    The verified values also seed ``timeline.fingerprints``' cache (so
    serving a loaded timeline starts without any hashing at all) — but
    ONLY when every generation manifest is current-schema: pre-v3
    fingerprints hash the v2 field subset, and seeding those would let a
    later ``save_timeline`` persist subset hashes next to fresh full-field
    generation manifests, a guaranteed mismatch on the next load.
    """
    if version < 2:
        return
    declared = manifest.get("fingerprints")
    if not isinstance(declared, list) or len(declared) != len(names):
        raise ValueError(
            f"load_timeline({path!r}): timeline.json needs one fingerprint "
            f"per generation at schema_version={version} "
            f"(got {declared!r} for {len(names)} generation(s))")
    actual, seed_ok = [], True
    for g, name in enumerate(names):
        with open(os.path.join(path, name, _MANIFEST)) as f:
            gman = json.load(f)
        got = gman.get("fingerprint")
        if got is None:     # a v1 generation directory: hash it this once
            got = index_fingerprint(timeline.generations[g])
        elif gman.get("schema_version", 0) < SCHEMA_VERSION:
            seed_ok = False
        actual.append(got)
    for name, want, got in zip(names, declared, actual):
        if want != got:
            raise ValueError(
                f"load_timeline({path!r}): generation {name!r} has "
                f"fingerprint {got[:12]}… but timeline.json declares "
                f"{want[:12]}… — the generation directory was replaced "
                "after the timeline was saved")
    if seed_ok:
        timeline.__dict__["fingerprints"] = tuple(actual)


# ---------------------------------------------------------------------------
# Footprint accounting — bytes_per_embedding extended to the timeline
# (Efficient Constant-Space Multi-Vector Retrieval motivates bounding the
# per-shard budget; a capacity plan for the streaming case needs the
# per-generation footprint plus the manifest overhead, not just the paper's
# per-embedding constant).
# ---------------------------------------------------------------------------

def generation_footprint(index: PackedIndex, meta: IndexMeta) -> dict:
    """Byte footprint of ONE generation, as stored and as served.

    Returns a dict with ``array_bytes`` (per ``PackedIndex`` field),
    ``index_bytes`` (their sum — device footprint and, the arrays being
    saved uncompressed, the ``arrays.npz`` payload), ``manifest_bytes``
    (the serialized ``manifest.json`` overhead, fingerprint included),
    ``total_bytes``, and two per-embedding views: ``bytes_per_embedding``
    (the paper's Table-1 constant, :func:`~repro.core.index
    .bytes_per_embedding`) and ``bytes_per_embedding_actual`` — the doc
    payload (codes + PQ residuals + PLAID residuals) divided by REAL
    tokens, so the gap to the constant is the padding + id-width tax the
    fixed-shape layout pays.

    Constant-space accounting (``meta.doc_budget``): ``bytes_per_doc`` is
    the packed per-doc payload as stored (pooled vectors for a budgeted
    index), ``unpooled_bytes_per_doc`` is the counterfactual — the same
    per-token byte width times ``meta.n_raw_tokens`` pre-pooling tokens —
    and ``pooling_savings`` is the fraction of payload bytes the budget
    saved (0.0 when nothing was pooled). Both per-doc views count packed
    tokens only; the fixed-shape padding tax stays visible in
    ``bytes_per_embedding_actual``.
    """
    arrays = {f: np.asarray(getattr(index, f)) for f in PackedIndex._fields}
    array_bytes = {f: int(a.nbytes) for f, a in arrays.items()}
    index_bytes = sum(array_bytes.values())
    manifest = {
        "format": _FORMAT,
        "schema_version": SCHEMA_VERSION,
        "fingerprint": "0" * 64,    # placeholder: size-accurate, hash-free
        "meta": dataclasses.asdict(meta),
        "arrays": {f: {"dtype": str(a.dtype), "shape": list(a.shape)}
                   for f, a in arrays.items()},
    }
    manifest_bytes = len(json.dumps(manifest, indent=1).encode())
    n_tokens = int(np.asarray(index.doc_lens).sum())
    payload = (array_bytes["codes"] + array_bytes["res_codes"]
               + array_bytes["plaid_res"])
    # per-token byte width of the packed payload (one centroid id + PQ +
    # PLAID residual codes per stored token slot)
    tok_bytes = (arrays["codes"].dtype.itemsize
                 + arrays["res_codes"].shape[-1]
                 * arrays["res_codes"].dtype.itemsize
                 + arrays["plaid_res"].shape[-1]
                 * arrays["plaid_res"].dtype.itemsize)
    n_raw = meta.n_raw_tokens or n_tokens
    n_docs_ = max(meta.n_docs, 1)
    return {
        "n_docs": meta.n_docs,
        "n_tokens": n_tokens,
        "n_raw_tokens": n_raw,
        "doc_budget": meta.doc_budget,
        "bytes_per_doc": tok_bytes * n_tokens / n_docs_,
        "unpooled_bytes_per_doc": tok_bytes * n_raw / n_docs_,
        "pooling_savings": 1.0 - n_tokens / max(n_raw, 1),
        "array_bytes": array_bytes,
        "index_bytes": index_bytes,
        "manifest_bytes": manifest_bytes,
        "total_bytes": index_bytes + manifest_bytes,
        # the predicate plane's share of index_bytes (4 bytes/doc): the
        # filtered-search feature's whole footprint cost, reported
        # separately so capacity plans can see it
        "predicate_bytes": array_bytes["pred_words"],
        "bytes_per_embedding": bytes_per_embedding(meta, "emvb"),
        "bytes_per_embedding_actual": payload / max(n_tokens, 1),
    }


def timeline_footprint(timeline) -> dict:
    """Byte footprint of a whole timeline: per-generation footprints
    (:func:`generation_footprint`) plus the ``timeline.json`` manifest
    overhead, summed — the capacity-planning number for the streaming case
    (ROADMAP), reported per snapshot by ``repro.serving.metrics``.

    Accepts a :class:`ShardedTimeline` or an :class:`EpochedTimeline` (the
    latter sums its epochs and adds ``n_epochs``).
    """
    if isinstance(timeline, EpochedTimeline):
        per = [timeline_footprint(tl) for tl in timeline.epochs]
        n_tokens = sum(p["n_tokens"] for p in per)
        payload = sum(p["bytes_per_embedding_actual"] * p["n_tokens"]
                      for p in per)
        return {
            "n_epochs": len(per),
            "n_generations": sum(p["n_generations"] for p in per),
            "n_docs": timeline.n_docs,
            "n_tokens": n_tokens,
            "generations": [g for p in per for g in p["generations"]],
            "index_bytes": sum(p["index_bytes"] for p in per),
            "manifest_bytes": sum(p["manifest_bytes"] for p in per),
            "total_bytes": sum(p["total_bytes"] for p in per),
            "predicate_bytes": sum(p["predicate_bytes"] for p in per),
            "bytes_per_embedding": per[0]["bytes_per_embedding"],
            "bytes_per_embedding_actual": payload / max(n_tokens, 1),
            **_pooling_rollup(per, timeline.n_docs),
        }
    gens = [generation_footprint(g, m) for g, m, _ in timeline]
    tj = {"format": _TIMELINE_FORMAT, "schema_version": SCHEMA_VERSION,
          "generations": [f"gen-{g:04d}" for g in range(len(timeline))],
          "fingerprints": ["0" * 64] * len(timeline)}
    timeline_manifest_bytes = len(json.dumps(tj, indent=1).encode())
    n_tokens = sum(g["n_tokens"] for g in gens)
    index_bytes = sum(g["index_bytes"] for g in gens)
    manifest_bytes = (sum(g["manifest_bytes"] for g in gens)
                      + timeline_manifest_bytes)
    payload = sum(g["bytes_per_embedding_actual"] * g["n_tokens"]
                  for g in gens)
    return {
        "n_generations": len(timeline),
        "n_docs": timeline.n_docs,
        "n_tokens": n_tokens,
        "generations": gens,
        "index_bytes": index_bytes,
        "manifest_bytes": manifest_bytes,
        "total_bytes": index_bytes + manifest_bytes,
        "predicate_bytes": sum(g["predicate_bytes"] for g in gens),
        "bytes_per_embedding": gens[0]["bytes_per_embedding"],
        "bytes_per_embedding_actual": payload / max(n_tokens, 1),
        **_pooling_rollup(gens, timeline.n_docs),
    }


def _pooling_rollup(parts: list, n_docs: int) -> dict:
    """Aggregate the constant-space keys over per-generation (or per-epoch)
    footprints: doc-weighted payload sums; ``doc_budget`` is the common
    value, or ``"mixed"`` when parts disagree (an epoched timeline mid-
    migration)."""
    pooled = sum(p["bytes_per_doc"] * p["n_docs"] for p in parts)
    raw = sum(p["unpooled_bytes_per_doc"] * p["n_docs"] for p in parts)
    budgets = {p["doc_budget"] for p in parts}
    return {
        "n_raw_tokens": sum(p["n_raw_tokens"] for p in parts),
        "doc_budget": (parts[0]["doc_budget"] if len(budgets) == 1
                       else "mixed"),
        "bytes_per_doc": pooled / max(n_docs, 1),
        "unpooled_bytes_per_doc": raw / max(n_docs, 1),
        "pooling_savings": 1.0 - pooled / max(raw, 1e-9),
    }
