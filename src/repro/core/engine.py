"""EMVB retrieval engine — the paper's full four-phase pipeline, jit-able.

Phases (single query; batched via vmap):
  1. centroid scoring + candidate generation  (CS matmul, masked top-nprobe,
     IVF gather -> candidate bitmap)                              [paper §4.1]
  2. bit-vector pre-filter F(P,q), select top-n_filter docs       [paper §4.2]
  3. centroid interaction S̄ on survivors, select top-n_docs      [paper §4.3]
  4. PQ late interaction w/ dynamic term filter, final top-k      [paper §4.4]

Every phase has fixed shapes. ``EngineConfig`` is hashable and passed as a
static jit argument. The same functions run single-device (benchmarks/tests)
and under shard_map with per-shard local indices (launch/serve.py).

Query-term masking: every entry point takes an optional per-term mask
(``q_masks (B, n_q)`` / ``q_mask (n_q,)`` bool, True = live). Masked
(zero-padded or pruned) terms are excluded end-to-end — no bit in the
Eq. 4 bit vectors, no IVF probes, no row in S̄, no MaxSim term in Eq. 5/6 —
so retrieval of a padded query with its mask is bit-exact to retrieval of
the unpadded prefix (tests/test_query_masking.py), and ``prune_queries``
turns the mask into a latency knob (smaller static n_q).

The public phase-split entry points (``phase1_candidates`` …
``phase4_late_interaction``, plus the fused ``phase12_prefilter`` and
``phase34_late_interaction``) and ``retrieve`` share the SAME internal
``_phaseN`` helpers, so composing the split phases reproduces ``retrieve``
exactly by construction — the invariant tests/test_engine_phases.py asserts.

Kernel dispatch: ``use_kernels`` selects the Pallas kernels over the jnp
reference math; ``fused_prefilter`` additionally replaces the four-launch
phase 1b-2 sequence (bitpack -> bitfilter -> mask -> top_k, with full-corpus
intermediates) by the single ``kernels/prefilter.py`` megakernel;
``fused_late_interaction`` does the same for phases 3-4 (cinter -> top_k ->
gather -> pqscore -> top_k becomes the single ``kernels/pqinter.py``
megakernel); ``kernel_interpret`` picks Pallas interpret mode (CPU) vs
compiled Mosaic (TPU) — it replaces the old mutable ``kernels.ops.INTERPRET``
module global.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import TYPE_CHECKING, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import bitvector, interaction
from .index import PackedIndex
from .pq import build_lut
from repro.obs import trace

if TYPE_CHECKING:  # avoid a runtime engine <-> store import cycle
    from .store import ShardedTimeline


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static retrieval configuration — hashable, passed as a jit-static arg.

    Field groups: the paper's knobs (``th`` for the Eq. 4 bit vectors,
    ``th_r`` for the Eq. 6 term filter, ``nprobe``/``n_filter``/``n_docs``/
    ``k`` for the per-phase selection budgets) and the implementation knobs
    (kernel dispatch, candidate layout, CS precision). ``__post_init__``
    rejects inconsistent combinations with actionable errors.
    """

    n_q: int = 32            # query terms (<= 32: one uint32 bit per term)
    nprobe: int = 4          # centroid lists unioned per query term
    th: float = 0.4          # bit-vector threshold (paper Fig. 2: 0.4)
    th_r: Optional[float] = 0.5   # Eq. 6 term filter; None -> Eq. 5
    n_filter: int = 512      # docs surviving the bit-vector pre-filter
    n_docs: int = 64         # docs entering PQ late interaction
    k: int = 10              # final results
    use_kernels: bool = False  # Pallas kernels vs jnp ref
    # With use_kernels: run phases 1b-2 as the single fused megakernel
    # (kernels/prefilter.py) instead of bitpack -> bitfilter -> mask -> top_k
    # with full-corpus intermediates. False keeps the four separate kernels
    # (the benchmarks time both).
    fused_prefilter: bool = True
    # With use_kernels: run phases 3-4 as the single fused megakernel
    # (kernels/pqinter.py: centroid interaction + phase-3 top-n_docs + PQ
    # late interaction + final top-k in one launch) instead of
    # cinter -> top_k -> gather -> pqscore -> top_k with per-survivor
    # intermediates. False keeps the two separate kernels.
    fused_late_interaction: bool = True
    # Pallas interpret mode (CPU validation) vs compiled Mosaic (TPU).
    kernel_interpret: bool = True
    # With use_kernels + a fused megakernel: run each micro-batch as ONE
    # batch-native kernel launch (kernels/prefilter.py::prefilter_batched,
    # kernels/pqinter.py::pqinter_batched) that loads the index-resident
    # operands into VMEM once and iterates queries in-kernel, instead of
    # ``jax.vmap`` over single-query launches. Bit-exact to the vmap path
    # (ids AND score bits, tie order); B = 1 and non-kernel configs always
    # take the vmap path.
    batched_kernels: bool = True
    # 'score_all' evaluates F on every (local) doc masked by the candidate
    # bitmap (TPU-friendly); 'compact' gathers candidates into a fixed buffer
    # of size cand_cap first (closer to the paper's CPU loop).
    candidate_mode: str = "score_all"
    cand_cap: int = 4096
    # Per-token compaction for phase 4 (DESIGN.md §2 mode (b)): tokens whose
    # centroid is close to NO query term are compacted away before the
    # centroid/LUT gathers, shrinking them cap -> compact_cap. Requires th_r.
    compact_cap: Optional[int] = None
    # Reduced-precision centroid scores (paper §6: "the centroid interaction
    # is carried out with reduced precision"): "bfloat16" halves the CS
    # matrix HBM traffic — the memory bound of the sharded serving plan.
    cs_dtype: str = "float32"
    # Metadata filter: a compiled bitvector.FilterPlan over the index's
    # predicate plane (docs/FILTERING.md), or None for unfiltered. The plan
    # is a static tuple of word-mask clauses, so the kernel signatures stay
    # shape-stable (one jit trace per distinct plan) and it folds into
    # config_fingerprint — filtered and unfiltered cache entries can never
    # collide. Filtered retrieval enforces the filter at EVERY selection:
    # phase 2 ANDs it into the candidate bitmap (in-kernel for the fused
    # score_all megakernel), phases 3-4 mask non-passing survivors' scores
    # to -inf, so the contract `filtered == retrieve-then-post-filter` holds
    # bit-exactly under lossless budgets.
    doc_filter: Optional[bitvector.FilterPlan] = None

    def __post_init__(self):
        """Fail fast with actionable messages on the configs that otherwise
        die deep inside ``top_k``/the bit pack (or worse, run silently
        wrong)."""
        if self.n_q > 32:
            raise ValueError(
                f"n_q={self.n_q} > 32: the stacked bit vector packs one "
                "query term per bit of a uint32 word (paper Fig. 3); split "
                "the query or widen the word type first")
        if self.k > self.n_docs:
            raise ValueError(
                f"k={self.k} > n_docs={self.n_docs}: phase 4 can only rank "
                "the n_docs survivors of phase 3; raise n_docs (paper uses "
                "n_docs >= 4*k) or lower k")
        if self.n_docs > self.n_filter:
            raise ValueError(
                f"n_docs={self.n_docs} > n_filter={self.n_filter}: phase 3 "
                "selects from the n_filter bit-vector survivors; raise "
                "n_filter or lower n_docs")
        if self.candidate_mode not in ("score_all", "compact"):
            raise ValueError(
                f"unknown candidate_mode={self.candidate_mode!r}: expected "
                "'score_all' (mask the whole corpus by the candidate "
                "bitmap) or 'compact' (gather candidates into a cand_cap "
                "buffer)")
        # cand_cap only bounds the compact-mode candidate buffer; score_all
        # configs never touch it, so don't reject them over its default.
        if self.candidate_mode == "compact" and self.cand_cap < self.n_filter:
            raise ValueError(
                f"cand_cap={self.cand_cap} < n_filter={self.n_filter}: in "
                "candidate_mode='compact' the top-n_filter selection runs "
                "over the cand_cap candidate buffer; raise cand_cap to at "
                "least n_filter")
        if self.compact_cap is not None and self.th_r is None:
            raise ValueError(
                f"compact_cap={self.compact_cap} requires th_r: per-token "
                "compaction keeps tokens whose centroid beats the Eq. 6 "
                "threshold — set th_r or drop compact_cap")
        if self.cs_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"unknown cs_dtype={self.cs_dtype!r}: expected 'float32' or "
                "'bfloat16'")
        if self.doc_filter is not None and \
                not isinstance(self.doc_filter, bitvector.FilterPlan):
            raise ValueError(
                f"doc_filter is a {type(self.doc_filter).__name__}: expected "
                "a compiled FilterPlan (or None) — compile your FilterExpr "
                "against the index's predicate names first with "
                "bitvector.compile_filter(expr, meta.pred_names)")


class RetrievalResult(NamedTuple):
    """Top-k retrieval output: scores sorted descending + global doc ids."""

    scores: jax.Array   # (B, k)
    doc_ids: jax.Array  # (B, k) int32


class QueryBatch(NamedTuple):
    """A batch of queries with its optional per-term mask — the one value
    that travels everywhere ``q`` + ``q_mask`` used to travel as parallel
    loose arrays (engine entry points, the serving batcher, the launch/serve
    plan factories).

    ``q`` is (B, n_q, d); ``q_mask`` is (B, n_q) bool (True = live term) or
    None for all-live. A plain array still works wherever a QueryBatch is
    accepted — ``QueryBatch(q)`` and ``q`` are interchangeable inputs.
    """

    q: jax.Array                       # (B, n_q, d)
    q_mask: Optional[jax.Array] = None  # (B, n_q) bool, None = all live


def _as_query_batch(queries, q_masks=None) -> QueryBatch:
    """Normalize ``queries`` (array or QueryBatch) + optional loose
    ``q_masks`` into one QueryBatch; reject conflicting masks."""
    if isinstance(queries, QueryBatch):
        if q_masks is not None and queries.q_mask is not None:
            raise ValueError(
                "got a q_mask both inside the QueryBatch and as a separate "
                "argument — pass exactly one")
        return QueryBatch(queries.q,
                          queries.q_mask if q_masks is None else q_masks)
    return QueryBatch(queries, q_masks)


def _kops(cfg: EngineConfig):
    """The Pallas kernel dispatch module, or None for the jnp reference."""
    if not cfg.use_kernels:
        return None
    from repro.kernels import ops as kops
    return kops


def _with_filter(cfg: EngineConfig, doc_filter) -> EngineConfig:
    """Fold a per-call ``doc_filter`` into the static config (kwarg wins
    over any filter already on ``cfg``); ``EngineConfig.__post_init__``
    rejects uncompiled FilterExprs with the compile hint."""
    if doc_filter is None:
        return cfg
    return dataclasses.replace(cfg, doc_filter=doc_filter)


# ---------------------------------------------------------------------------
# Phase 1 — centroid scores, bitvector, probes, candidate bitmap
# ---------------------------------------------------------------------------

def centroid_scores(q: jax.Array, centroids: jax.Array,
                    dtype: str = "float32") -> jax.Array:
    """q (n_q, d), centroids (n_c, d) -> CS (n_q, n_c)."""
    if dtype == "bfloat16":
        return (q.astype(jnp.bfloat16) @ centroids.T.astype(jnp.bfloat16))
    return q @ centroids.T


def candidate_bitmap(ivf: jax.Array, ivf_lens: jax.Array, probe_ids: jax.Array,
                     n_docs: int) -> jax.Array:
    """Union of the IVF lists of the probed centroids -> (n_docs,) bool.

    Probe ids >= n_c (the one-past-end sentinel ``masked_topk_centroids``
    emits for masked query terms) contribute NOTHING: their list length is
    forced to 0, so a padded/pruned term cannot add candidates."""
    n_c = ivf.shape[0]
    flat = probe_ids.reshape(-1)
    safe = jnp.clip(flat, 0, n_c - 1)
    lists = jnp.take(ivf, safe, axis=0)                          # (P, list_cap)
    lens = jnp.where(flat < n_c, jnp.take(ivf_lens, safe), 0)    # (P,)
    valid = jnp.arange(ivf.shape[1])[None, :] < lens[:, None]
    ids = jnp.where(valid, lists, n_docs)                        # sentinel
    bitmap = jnp.zeros((n_docs,), jnp.bool_)
    return bitmap.at[ids.reshape(-1)].set(True, mode="drop")


def _doc_pass(index: PackedIndex, cfg: EngineConfig) -> Optional[jax.Array]:
    """(n_docs,) bool — docs passing ``cfg.doc_filter`` — or None when
    unfiltered. Evaluated over the index's predicate plane; constant across
    a query batch, so under vmap it lowers to one corpus-wide pass."""
    if cfg.doc_filter is None:
        return None
    return bitvector.apply_filter_plan(cfg.doc_filter, index.pred_words)


# ---------------------------------------------------------------------------
# Internal phase helpers — single source of truth for retrieve() AND the
# public phase-split entry points.
# ---------------------------------------------------------------------------

def _phase1(q: jax.Array, index: PackedIndex, cfg: EngineConfig,
            q_mask: Optional[jax.Array] = None):
    """-> (cs (n_q, n_c), bits (n_c,) u32, bitmap (n_docs,) bool).

    q_mask (n_q,) bool: masked terms pack a 0 bit AND probe no IVF lists."""
    kops = _kops(cfg)
    cs = centroid_scores(q, index.centroids, cfg.cs_dtype)
    if kops is not None:
        bits = kops.bitpack(cs, cfg.th, q_mask, interpret=cfg.kernel_interpret)
    else:
        bits = bitvector.build_bitvectors(cs, cfg.th, q_mask)
    probe_ids = bitvector.masked_topk_centroids(cs, cfg.th, cfg.nprobe,
                                                q_mask)
    bitmap = candidate_bitmap(index.ivf, index.ivf_lens, probe_ids,
                              index.codes.shape[0])
    doc_pass = _doc_pass(index, cfg)
    if doc_pass is not None:
        bitmap = bitmap & doc_pass     # filtered docs are never candidates
    return cs, bits, bitmap


def _compact_candidates(bitmap: jax.Array, cfg: EngineConfig):
    """Fixed-size candidate buffer (ids of bitmap==True, arbitrary order)."""
    _, cand_ids = jax.lax.top_k(bitmap.astype(jnp.int32), cfg.cand_cap)
    cand_ids = cand_ids.astype(jnp.int32)
    cand_valid = jnp.take(bitmap, cand_ids)
    return cand_ids, cand_valid


def _phase2(index: PackedIndex, token_mask: jax.Array, bits: jax.Array,
            bitmap: jax.Array, cfg: EngineConfig) -> jax.Array:
    """Unfused bit-vector pre-filter -> sel1 (n_filter,) int32."""
    kops = _kops(cfg)
    if cfg.candidate_mode == "compact":
        cand_ids, cand_valid = _compact_candidates(bitmap, cfg)
        c_codes = jnp.take(index.codes, cand_ids, axis=0)
        c_mask = jnp.take(token_mask, cand_ids, axis=0) & cand_valid[:, None]
        if kops is not None:
            f = kops.bitfilter(bits, c_codes, c_mask,
                               interpret=cfg.kernel_interpret)
        else:
            f = bitvector.filter_score(bits, c_codes, c_mask)
        f = jnp.where(cand_valid, f, -1)
        _, sel1_local = jax.lax.top_k(f, cfg.n_filter)
        sel1 = jnp.take(cand_ids, sel1_local)
    else:
        if kops is not None:
            f = kops.bitfilter(bits, index.codes, token_mask,
                               interpret=cfg.kernel_interpret)
        else:
            f = bitvector.filter_score(bits, index.codes, token_mask)
        f = jnp.where(bitmap, f, -1)                             # (n_docs,)
        _, sel1 = jax.lax.top_k(f, cfg.n_filter)
    return sel1.astype(jnp.int32)


def _phase12(q: jax.Array, index: PackedIndex, token_mask: jax.Array,
             cfg: EngineConfig, q_mask: Optional[jax.Array] = None):
    """Phases 1-2 -> (cs, sel1). Dispatches to the fused megakernel when
    configured; otherwise composes _phase1 + _phase2."""
    kops = _kops(cfg)
    if kops is None or not cfg.fused_prefilter:
        cs, bits, bitmap = _phase1(q, index, cfg, q_mask)
        return cs, _phase2(index, token_mask, bits, bitmap, cfg)
    # Fused path: the bit table never leaves the kernel; no full-corpus f.
    cs = centroid_scores(q, index.centroids, cfg.cs_dtype)
    probe_ids = bitvector.masked_topk_centroids(cs, cfg.th, cfg.nprobe,
                                                q_mask)
    bitmap = candidate_bitmap(index.ivf, index.ivf_lens, probe_ids,
                              index.codes.shape[0])
    if cfg.candidate_mode == "compact":
        # Filter BEFORE compaction: non-passing docs never enter the
        # fixed-size candidate buffer, matching the unfused path's
        # pre-filtered bitmap bit for bit.
        doc_pass = _doc_pass(index, cfg)
        if doc_pass is not None:
            bitmap = bitmap & doc_pass
        cand_ids, cand_valid = _compact_candidates(bitmap, cfg)
        c_codes = jnp.take(index.codes, cand_ids, axis=0)
        c_mask = jnp.take(token_mask, cand_ids, axis=0)
        _, sel1_local, _ = kops.prefilter(cs, cfg.th, c_codes, c_mask,
                                          cand_valid, cfg.n_filter, q_mask,
                                          interpret=cfg.kernel_interpret)
        sel1 = jnp.take(cand_ids, sel1_local)
    else:
        # score_all: the predicate words ride into the megakernel and the
        # static word-combine plan ANDs them into the candidate bitmap
        # INSIDE the launch — no host-side full-corpus pass mask.
        plan = None if cfg.doc_filter is None else cfg.doc_filter.clauses
        _, sel1, _ = kops.prefilter(cs, cfg.th, index.codes, token_mask,
                                    bitmap, cfg.n_filter, q_mask,
                                    pred_words=index.pred_words, plan=plan,
                                    interpret=cfg.kernel_interpret)
    return cs, sel1.astype(jnp.int32)


def _phase3(index: PackedIndex, token_mask: jax.Array, cs: jax.Array,
            sel1: jax.Array, cfg: EngineConfig,
            q_mask: Optional[jax.Array] = None) -> jax.Array:
    """Centroid interaction on survivors -> sel2 (n_docs,) int32."""
    kops = _kops(cfg)
    cs_t = cs.T                                                  # (n_c, n_q)
    s1_codes = jnp.take(index.codes, sel1, axis=0)               # (nf, cap)
    s1_mask = jnp.take(token_mask, sel1, axis=0)
    if kops is not None:
        sbar = kops.cinter(cs_t, s1_codes, s1_mask, q_mask,
                           interpret=cfg.kernel_interpret)
    else:
        sbar = interaction.centroid_interaction(cs_t, s1_codes, s1_mask,
                                                q_mask)
    doc_pass = _doc_pass(index, cfg)
    if doc_pass is not None:
        # Under tight budgets phase 2's fixed n_filter slots can still admit
        # non-passing fillers; mask their S̄ to -inf so they cannot displace
        # passing docs from the phase-3 cut.
        sbar = jnp.where(jnp.take(doc_pass, sel1), sbar, -jnp.inf)
    _, sel2_local = jax.lax.top_k(sbar, cfg.n_docs)
    return jnp.take(sel1, sel2_local)                            # (nd,)


def _phase4(index: PackedIndex, token_mask: jax.Array, q: jax.Array,
            cs: jax.Array, sel2: jax.Array, cfg: EngineConfig,
            q_mask: Optional[jax.Array] = None):
    """PQ late interaction (+ Eq. 6 term filter) -> (scores, ids), (k,)."""
    kops = _kops(cfg)
    n_c = index.centroids.shape[0]
    cs_t = cs.T
    pq = index.pq
    q_rot = q @ index.opq_rotation
    lut = build_lut(q_rot, pq)                                   # (n_q, m, K)
    s2_codes = jnp.take(index.codes, sel2, axis=0)
    s2_res = jnp.take(index.res_codes, sel2, axis=0)
    s2_mask = jnp.take(token_mask, sel2, axis=0)
    if kops is not None:
        scores = kops.pqscore(cs_t, lut, s2_codes, s2_res, s2_mask, cfg.th_r,
                              q_mask, interpret=cfg.kernel_interpret)
    elif cfg.compact_cap is not None and cfg.th_r is not None:
        scores = interaction.late_interaction_pq_compact(
            cs_t, lut, s2_codes, s2_res, s2_mask, cfg.th_r, cfg.compact_cap,
            q_mask=q_mask)
    else:
        centroid = None
        if cfg.cs_dtype != "float32":
            # exact f32 centroid term for the FINAL scores: gather the few
            # selected docs' centroid vectors (small) instead of trusting
            # the reduced-precision CS used by phases 1-3
            cent_vecs = jnp.take(index.centroids,
                                 jnp.clip(s2_codes, 0, n_c - 1), axis=0)
            centroid = jnp.einsum("ntd,qd->ntq", cent_vecs, q)
        scores = interaction.late_interaction_pq(
            cs_t, lut, s2_codes, s2_res, s2_mask, cfg.th_r, centroid=centroid,
            q_mask=q_mask)
    doc_pass = _doc_pass(index, cfg)
    if doc_pass is not None:
        # Final guard: a non-passing doc that slipped through the fixed
        # phase-2/3 slots must not appear in the top-k.
        scores = jnp.where(jnp.take(doc_pass, sel2), scores, -jnp.inf)
    top_scores, top_local = jax.lax.top_k(scores, cfg.k)
    return top_scores, jnp.take(sel2, top_local)


def _phase34(index: PackedIndex, token_mask: jax.Array, q: jax.Array,
             cs: jax.Array, sel1: jax.Array, cfg: EngineConfig,
             q_mask: Optional[jax.Array] = None):
    """Phases 3-4 -> (scores, ids), both (k,). Dispatches to the fused
    megakernel when configured; otherwise composes _phase3 + _phase4."""
    kops = _kops(cfg)
    if kops is None or not cfg.fused_late_interaction:
        sel2 = _phase3(index, token_mask, cs, sel1, cfg, q_mask)
        return _phase4(index, token_mask, q, cs, sel2, cfg, q_mask)
    # Fused path: S̄, the phase-3 selection, the Eq. 5/6 PQ scores and the
    # final top-k never leave the kernel; codes/residuals are gathered ONCE
    # for the phase-2 survivors instead of once per phase.
    q_rot = q @ index.opq_rotation
    lut = build_lut(q_rot, index.pq)                             # (n_q, m, K)
    s1_codes = jnp.take(index.codes, sel1, axis=0)               # (nf, cap)
    s1_res = jnp.take(index.res_codes, sel1, axis=0)
    s1_mask = jnp.take(token_mask, sel1, axis=0)
    doc_pass = _doc_pass(index, cfg)
    s1_pass = None if doc_pass is None else jnp.take(doc_pass, sel1)
    top_scores, top_pos, _, _ = kops.pqinter(
        cs.T, lut, s1_codes, s1_res, s1_mask, cfg.th_r, cfg.n_docs, cfg.k,
        q_mask, doc_pass=s1_pass, interpret=cfg.kernel_interpret)
    return top_scores, jnp.take(sel1, top_pos)


# ---------------------------------------------------------------------------
# Full pipeline (single query)
# ---------------------------------------------------------------------------

def _retrieve_one(q: jax.Array, index: PackedIndex, token_mask: jax.Array,
                  cfg: EngineConfig,
                  q_mask: Optional[jax.Array] = None) -> RetrievalResult:
    cs, sel1 = _phase12(q, index, token_mask, cfg, q_mask)
    top_scores, top_ids = _phase34(index, token_mask, q, cs, sel1, cfg,
                                   q_mask)
    return RetrievalResult(top_scores, top_ids)


# ---------------------------------------------------------------------------
# Batched phase helpers — ONE launch per micro-batch on the batch-native
# megakernels when ``cfg.batched_kernels`` applies, ``jax.vmap`` over the
# single-query helpers otherwise. The pre-kernel math (centroid scores,
# probes, bitmaps, gathers, LUTs) is vmapped over the SAME single-query
# functions in both branches, so the two paths are bit-identical by
# construction everywhere but the (bit-exact) kernel swap.
# ---------------------------------------------------------------------------

def _vmap1(fn, queries, q_masks):
    """vmap ``fn(q, q_mask)`` over the batch, eliding a ``None`` mask."""
    if q_masks is None:
        return jax.vmap(lambda q: fn(q, None))(queries)
    return jax.vmap(fn)(queries, q_masks)


def _phase12_batch(index: PackedIndex, token_mask: jax.Array,
                   queries: jax.Array, cfg: EngineConfig,
                   q_masks: Optional[jax.Array] = None):
    """Batched phases 1-2 -> (cs (B, n_q, n_c), sel1 (B, n_filter))."""
    kops = _kops(cfg)
    nb = queries.shape[0]
    if (kops is None or not cfg.fused_prefilter or not cfg.batched_kernels
            or nb <= 1):
        return _vmap1(
            lambda q, m: _phase12(q, index, token_mask, cfg, m),
            queries, q_masks)
    cs = jax.vmap(
        lambda q: centroid_scores(q, index.centroids, cfg.cs_dtype))(queries)
    probe_ids = _vmap1(
        lambda c, m: bitvector.masked_topk_centroids(c, cfg.th, cfg.nprobe,
                                                     m), cs, q_masks)
    bitmap = jax.vmap(
        lambda p: candidate_bitmap(index.ivf, index.ivf_lens, p,
                                   index.codes.shape[0]))(probe_ids)
    if cfg.candidate_mode == "compact":
        # Same pre-compaction filter as the single-query fused path, shared
        # across the batch (the pass mask is query-independent).
        doc_pass = _doc_pass(index, cfg)
        if doc_pass is not None:
            bitmap = bitmap & doc_pass[None, :]
        cand_ids, cand_valid = jax.vmap(
            lambda b: _compact_candidates(b, cfg))(bitmap)
        c_codes = jnp.take(index.codes, cand_ids, axis=0)  # (B, cand_cap, cap)
        c_mask = jnp.take(token_mask, cand_ids, axis=0)
        _, sel1_local, _ = kops.prefilter_batched(
            cs, cfg.th, c_codes, c_mask, cand_valid, cfg.n_filter, q_masks,
            interpret=cfg.kernel_interpret)
        sel1 = jnp.take_along_axis(cand_ids, sel1_local, axis=1)
    else:
        plan = None if cfg.doc_filter is None else cfg.doc_filter.clauses
        _, sel1, _ = kops.prefilter_batched(
            cs, cfg.th, index.codes, token_mask, bitmap, cfg.n_filter,
            q_masks, pred_words=index.pred_words, plan=plan,
            interpret=cfg.kernel_interpret)
    return cs, sel1.astype(jnp.int32)


def _phase34_batch(index: PackedIndex, token_mask: jax.Array,
                   queries: jax.Array, cs: jax.Array, sel1: jax.Array,
                   cfg: EngineConfig,
                   q_masks: Optional[jax.Array] = None) -> RetrievalResult:
    """Batched phases 3-4 -> RetrievalResult with (B, k) scores/ids."""
    kops = _kops(cfg)
    nb = queries.shape[0]
    if (kops is None or not cfg.fused_late_interaction
            or not cfg.batched_kernels or nb <= 1):
        if q_masks is None:
            scores, ids = jax.vmap(
                lambda q, c, s: _phase34(index, token_mask, q, c, s, cfg)
            )(queries, cs, sel1)
        else:
            scores, ids = jax.vmap(
                lambda q, c, s, m: _phase34(index, token_mask, q, c, s, cfg,
                                            m))(queries, cs, sel1, q_masks)
        return RetrievalResult(scores, ids)
    q_rot = jax.vmap(lambda q: q @ index.opq_rotation)(queries)
    lut = jax.vmap(lambda qr: build_lut(qr, index.pq))(q_rot)
    s1_codes = jnp.take(index.codes, sel1, axis=0)           # (B, nf, cap)
    s1_res = jnp.take(index.res_codes, sel1, axis=0)
    s1_mask = jnp.take(token_mask, sel1, axis=0)
    doc_pass = _doc_pass(index, cfg)
    s1_pass = None if doc_pass is None else jnp.take(doc_pass, sel1)  # (B,nf)
    top_scores, top_pos, _, _ = kops.pqinter_batched(
        jnp.swapaxes(cs, -1, -2), lut, s1_codes, s1_res, s1_mask, cfg.th_r,
        cfg.n_docs, cfg.k, q_masks, doc_pass=s1_pass,
        interpret=cfg.kernel_interpret)
    return RetrievalResult(top_scores,
                           jnp.take_along_axis(sel1, top_pos, axis=1))


def _retrieve_batch(index: PackedIndex, queries: jax.Array,
                    cfg: EngineConfig,
                    q_masks: Optional[jax.Array] = None) -> RetrievalResult:
    """The full batched pipeline — shared by ``retrieve`` and the shard_map
    plan in launch/serve.py (so sharded serving rides the batched kernels
    too)."""
    token_mask = index.token_mask()
    cs, sel1 = _phase12_batch(index, token_mask, queries, cfg, q_masks)
    return _phase34_batch(index, token_mask, queries, cs, sel1, cfg, q_masks)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _retrieve_jit(index: PackedIndex, queries: jax.Array, cfg: EngineConfig,
                  q_masks: Optional[jax.Array] = None) -> RetrievalResult:
    return _retrieve_batch(index, queries, cfg, q_masks)


def retrieve(index: PackedIndex, queries, cfg: EngineConfig,
             q_masks: Optional[jax.Array] = None, *,
             doc_filter: Optional[bitvector.FilterPlan] = None
             ) -> RetrievalResult:
    """queries (B, n_q, d) or QueryBatch -> RetrievalResult, (B, k) each.

    doc_filter : optional compiled :class:`~repro.core.bitvector.FilterPlan`
    restricting results to documents whose predicate-plane bits satisfy the
    filter (docs/FILTERING.md); equivalent to setting ``cfg.doc_filter``
    (which it overrides for this call). Filtered retrieval equals
    retrieve-then-post-filter bit for bit under lossless budgets, in every
    dispatch mode.

    q_masks : optional (B, n_q) bool — True for live query terms (or carry
    it inside a :class:`QueryBatch`). Masked (zero-padded / pruned) terms
    are excluded from every phase: they pack no bit into the Eq. 4 bit
    vectors, probe no IVF lists, contribute no row to S̄ and no MaxSim term
    to Eq. 5/6. Retrieval of a padded query with its mask is bit-exact to
    retrieval of the unpadded prefix; omitting the mask (or passing
    all-True) reproduces the unmasked pipeline bit for bit.

    With ``cfg.use_kernels`` + fused megakernels + ``cfg.batched_kernels``
    and B > 1, the batch runs as ONE batch-native kernel launch per fused
    phase pair; otherwise each query runs under ``jax.vmap``. The two paths
    are bit-identical — ids AND score bits, including tie order.
    """
    qb = _as_query_batch(queries, q_masks)
    # spans time DISPATCH, not device compute: jax returns futures, so
    # unless the caller blocks inside the span this measures enqueue cost
    with trace.span("engine.retrieve.dispatch", batch=qb.q.shape[0],
                    filtered=(doc_filter or cfg.doc_filter) is not None):
        return _retrieve_jit(index, qb.q, _with_filter(cfg, doc_filter),
                             qb.q_mask)


# ---------------------------------------------------------------------------
# Phase-split entry points (benchmarks: paper Fig. 1-style breakdown).
#
# ONE convention: ``phaseN(index, queries, cfg, *, q_mask=None, ...)`` on
# BATCHED queries ((B, n_q, d) array or QueryBatch), intermediates riding as
# keyword-only arguments with a leading batch axis, results batched. Every
# entry point also takes ``doc_filter=`` (a compiled FilterPlan), folded
# into the static config exactly as ``retrieve`` does. Each is
# a plain-Python normalizer over a jit'd batched internal that composes the
# SAME _phaseN helpers retrieve() uses, so composing the split phases
# reproduces ``retrieve`` exactly by construction.
#
# The pre-PR-7 single-query signatures (mixed index-first/array-first orders,
# loose positional q/q_mask) still work through deprecation shims for one
# release: they warn ``DeprecationWarning``, lift to B=1 and squeeze the
# result. scripts/check_legacy_signatures.py keeps new in-tree callers out.
# ---------------------------------------------------------------------------

def _warn_legacy(name: str, hint: str) -> None:
    warnings.warn(
        f"{name} with the pre-batch single-query signature is deprecated "
        f"and will be removed; call {name}({hint}) on batched queries "
        "(a (B, n_q, d) array or a QueryBatch) instead",
        DeprecationWarning, stacklevel=3)


def _squeeze0(tree):
    return jax.tree_util.tree_map(lambda x: x[0], tree)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _phase1_entry(index, queries, cfg, q_masks=None):
    return _vmap1(lambda q, m: _phase1(q, index, cfg, m), queries, q_masks)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _phase2_entry(index, cfg, bits, bitmap):
    token_mask = index.token_mask()
    return jax.vmap(
        lambda b, bm: _phase2(index, token_mask, b, bm, cfg))(bits, bitmap)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _phase12_entry(index, queries, cfg, q_masks=None):
    return _phase12_batch(index, index.token_mask(), queries, cfg, q_masks)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _phase3_entry(index, cfg, cs, sel1, q_masks=None):
    token_mask = index.token_mask()
    if q_masks is None:
        return jax.vmap(
            lambda c, s: _phase3(index, token_mask, c, s, cfg))(cs, sel1)
    return jax.vmap(
        lambda c, s, m: _phase3(index, token_mask, c, s, cfg, m)
    )(cs, sel1, q_masks)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _phase4_entry(index, queries, cfg, cs, sel2, q_masks=None):
    token_mask = index.token_mask()
    if q_masks is None:
        scores, ids = jax.vmap(
            lambda q, c, s: _phase4(index, token_mask, q, c, s, cfg)
        )(queries, cs, sel2)
    else:
        scores, ids = jax.vmap(
            lambda q, c, s, m: _phase4(index, token_mask, q, c, s, cfg, m)
        )(queries, cs, sel2, q_masks)
    return RetrievalResult(scores, ids)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _phase34_entry(index, queries, cfg, cs, sel1, q_masks=None):
    return _phase34_batch(index, index.token_mask(), queries, cs, sel1, cfg,
                          q_masks)


def _legacy_call(args, kwargs, cfg_pos: int):
    """Detect a legacy positional call: EngineConfig sitting at the OLD
    position (``cfg_pos``) in the post-``index`` positional args."""
    if len(args) > cfg_pos:
        return isinstance(args[cfg_pos], EngineConfig)
    return False


def phase1_candidates(index: PackedIndex, *args, **kwargs):
    """Phase 1 (paper §4.1) — ``(index, queries, cfg, *, q_mask=None)`` ->
    (cs (B, n_q, n_c), bits (B, n_c) u32, bitmap (B, n_docs) bool): centroid
    scores, the stacked Eq. 4 bit vectors, and the IVF candidate bitmap."""
    queries, cfg = args[0], args[1]
    cfg = _with_filter(cfg, kwargs.get("doc_filter"))
    legacy = (not isinstance(queries, QueryBatch)
              and getattr(queries, "ndim", 3) == 2) or len(args) > 2
    if legacy:
        _warn_legacy("phase1_candidates", "index, queries, cfg")
        q_mask = args[2] if len(args) > 2 else kwargs.get("q_mask")
        qm = None if q_mask is None else q_mask[None]
        return _squeeze0(_phase1_entry(index, queries[None], cfg, qm))
    qb = _as_query_batch(queries, kwargs.get("q_mask"))
    return _phase1_entry(index, qb.q, cfg, qb.q_mask)


def phase2_prefilter(index: PackedIndex, *args, **kwargs):
    """Phase 2 (paper §4.2) — ``(index, queries, cfg, *, bits, bitmap)`` ->
    sel1 (B, n_filter) int32: the bit-vector pre-filter — score F(P, q)
    (paper Eq. 4) for every candidate, select the top-n_filter doc ids.

    ``bits``/``bitmap`` are phase 1's batched outputs; omitted, phase 1
    runs internally. Takes no q_mask: masked terms are already 0 bits in
    ``bits``, so Eq. 4's popcount structurally cannot count them (the
    ``queries`` mask only feeds the internal phase-1 run)."""
    if _legacy_call(args, kwargs, 2):
        _warn_legacy("phase2_prefilter",
                     "index, queries, cfg, bits=..., bitmap=...")
        bits, bitmap, cfg = args
        return _squeeze0(
            _phase2_entry(index, cfg, bits[None], bitmap[None]))
    queries, cfg = args[0], args[1]
    cfg = _with_filter(cfg, kwargs.get("doc_filter"))
    bits, bitmap = kwargs.get("bits"), kwargs.get("bitmap")
    if bits is None or bitmap is None:
        qb = _as_query_batch(queries, kwargs.get("q_mask"))
        _, bits, bitmap = _phase1_entry(index, qb.q, cfg, qb.q_mask)
    return _phase2_entry(index, cfg, bits, bitmap)


def phase12_prefilter(index: PackedIndex, *args, **kwargs):
    """Fused phases 1-2 — ``(index, queries, cfg, *, q_mask=None)`` ->
    (cs (B, n_q, n_c), sel1 (B, n_filter)); with a fused-prefilter config
    this is the megakernel launch (ONE batch-native launch when
    ``cfg.batched_kernels`` applies) the breakdown benchmark times against
    the phase1_candidates + phase2_prefilter pair."""
    queries, cfg = args[0], args[1]
    cfg = _with_filter(cfg, kwargs.get("doc_filter"))
    legacy = (not isinstance(queries, QueryBatch)
              and getattr(queries, "ndim", 3) == 2) or len(args) > 2
    if legacy:
        _warn_legacy("phase12_prefilter", "index, queries, cfg")
        q_mask = args[2] if len(args) > 2 else kwargs.get("q_mask")
        qm = None if q_mask is None else q_mask[None]
        return _squeeze0(_phase12_entry(index, queries[None], cfg, qm))
    qb = _as_query_batch(queries, kwargs.get("q_mask"))
    return _phase12_entry(index, qb.q, cfg, qb.q_mask)


def phase3_centroid_interaction(index: PackedIndex, *args, **kwargs):
    """Phase 3 (paper §4.3) — ``(index, queries, cfg, *, q_mask=None, cs,
    sel1)`` -> sel2 (B, n_docs) int32: centroid interaction S̄ (the Eq. 2
    proxy) on the phase-2 survivors; select the top-n_docs for late
    interaction. ``cs``/``sel1`` are phase 1-2's batched outputs; omitted,
    phases 1-2 run internally."""
    if _legacy_call(args, kwargs, 2):
        _warn_legacy("phase3_centroid_interaction",
                     "index, queries, cfg, cs=..., sel1=...")
        cs, sel1 = args[0], args[1]
        cfg = args[2]
        q_mask = args[3] if len(args) > 3 else kwargs.get("q_mask")
        qm = None if q_mask is None else q_mask[None]
        return _phase3_entry(index, cfg, cs[None], sel1[None], qm)[0]
    queries, cfg = args[0], args[1]
    cfg = _with_filter(cfg, kwargs.get("doc_filter"))
    qb = _as_query_batch(queries, kwargs.get("q_mask"))
    cs, sel1 = kwargs.get("cs"), kwargs.get("sel1")
    if cs is None or sel1 is None:
        cs_c, sel1_c = _phase12_entry(index, qb.q, cfg, qb.q_mask)
        cs = cs_c if cs is None else cs
        sel1 = sel1_c if sel1 is None else sel1
    return _phase3_entry(index, cfg, cs, sel1, qb.q_mask)


def phase4_late_interaction(index: PackedIndex, *args, **kwargs):
    """Phase 4 (paper §4.4) — ``(index, queries, cfg, *, q_mask=None, cs,
    sel2)`` -> RetrievalResult ((B, k) scores/ids): PQ late interaction on
    the phase-3 survivors — paper Eq. 5, or Eq. 6 with the dynamic per-term
    filter when ``cfg.th_r`` is set — and the final top-k selection.
    ``cs``/``sel2`` are phase 1-3's batched outputs; omitted, phases 1-3
    run internally."""
    if _legacy_call(args, kwargs, 3):
        _warn_legacy("phase4_late_interaction",
                     "index, queries, cfg, cs=..., sel2=...")
        q, cs, sel2, cfg = args[0], args[1], args[2], args[3]
        q_mask = args[4] if len(args) > 4 else kwargs.get("q_mask")
        qm = None if q_mask is None else q_mask[None]
        return _squeeze0(
            _phase4_entry(index, q[None], cfg, cs[None], sel2[None], qm))
    queries, cfg = args[0], args[1]
    cfg = _with_filter(cfg, kwargs.get("doc_filter"))
    qb = _as_query_batch(queries, kwargs.get("q_mask"))
    cs, sel2 = kwargs.get("cs"), kwargs.get("sel2")
    if cs is None or sel2 is None:
        cs_c, sel1 = _phase12_entry(index, qb.q, cfg, qb.q_mask)
        cs = cs_c if cs is None else cs
        if sel2 is None:
            sel2 = _phase3_entry(index, cfg, cs, sel1, qb.q_mask)
    return _phase4_entry(index, qb.q, cfg, cs, sel2, qb.q_mask)


def phase34_late_interaction(index: PackedIndex, *args, **kwargs):
    """Fused phases 3-4 — ``(index, queries, cfg, *, q_mask=None, cs,
    sel1)`` -> RetrievalResult ((B, k) scores/ids); with a
    fused-late-interaction config this is the megakernel launch (ONE
    batch-native launch when ``cfg.batched_kernels`` applies) the breakdown
    benchmark times against the phase3_centroid_interaction +
    phase4_late_interaction pair (which keep their unfused behavior,
    mirroring how phase1/phase2 relate to phase12_prefilter). ``cs``/
    ``sel1`` are phase 1-2's batched outputs; omitted, phases 1-2 run
    internally."""
    if _legacy_call(args, kwargs, 3):
        _warn_legacy("phase34_late_interaction",
                     "index, queries, cfg, cs=..., sel1=...")
        q, cs, sel1, cfg = args[0], args[1], args[2], args[3]
        q_mask = args[4] if len(args) > 4 else kwargs.get("q_mask")
        qm = None if q_mask is None else q_mask[None]
        return _squeeze0(
            _phase34_entry(index, q[None], cfg, cs[None], sel1[None], qm))
    queries, cfg = args[0], args[1]
    cfg = _with_filter(cfg, kwargs.get("doc_filter"))
    qb = _as_query_batch(queries, kwargs.get("q_mask"))
    cs, sel1 = kwargs.get("cs"), kwargs.get("sel1")
    if cs is None or sel1 is None:
        cs_c, sel1_c = _phase12_entry(index, qb.q, cfg, qb.q_mask)
        cs = cs_c if cs is None else cs
        sel1 = sel1_c if sel1 is None else sel1
    return _phase34_entry(index, qb.q, cfg, cs, sel1, qb.q_mask)


# ---------------------------------------------------------------------------
# Multi-generation serving (PLAID SHIRTTT): run the fused pipeline per
# immutable index generation, merge per-generation top-k by score.
# ---------------------------------------------------------------------------

def adapt_config_to_corpus(cfg: EngineConfig, n_docs: int,
                           cap: Optional[int] = None) -> EngineConfig:
    """Clamp a config's selection budgets to a (small) corpus of ``n_docs``.

    Timeline generations can be smaller than ``n_filter``/``n_docs``/
    ``cand_cap`` (a freshly opened generation might hold a few hundred
    docs); ``lax.top_k`` cannot select more entries than exist, so the
    budgets are clamped to the generation size. Clamping is lossless: a
    top-min(n_filter, n_docs) cut over n_docs docs keeps everything the
    unclamped cut would. ``k`` is NOT clamped — a generation smaller than
    ``k`` cannot fill a top-k and raises an actionable error instead.

    ``cap`` (the index's per-doc token capacity, ``meta.cap``) additionally
    clamps ``compact_cap``: the per-token compaction buffer selects
    ``compact_cap`` tokens per doc out of ``cap``, so a ``compact_cap``
    above ``cap`` dies in ``lax.top_k`` over the token axis. The clamp is
    lossless too — a buffer covering every token reproduces Eq. 6 exactly
    (tests/test_interaction.py) — and preserves the
    ``compact_cap``-requires-``th_r`` invariant (``None`` stays ``None``,
    a clamped value keeps needing the threshold it already had).
    """
    if n_docs < cfg.k:
        raise ValueError(
            f"corpus/generation has {n_docs} docs but cfg.k={cfg.k}: "
            "every generation must hold >= k docs to fill a per-generation "
            "top-k — batch tiny additions with store.add_passages instead "
            "of opening a new generation")
    nf = min(cfg.n_filter, n_docs)
    cc = cfg.compact_cap
    if cc is not None and cap is not None:
        cc = min(cc, cap)
    return dataclasses.replace(
        cfg, n_filter=nf, n_docs=min(cfg.n_docs, nf),
        cand_cap=max(min(cfg.cand_cap, n_docs), nf), compact_cap=cc)


def merge_partial_topk(parts: list[RetrievalResult],
                       k: int) -> RetrievalResult:
    """Merge per-generation partial top-k results (GLOBAL doc ids) into one
    final top-k.

    Concatenates the partials in generation (= global id) order and
    re-selects the top ``k`` by score. The SINGLE definition of the merge,
    shared by ``retrieve_timeline``, the sharded plan in ``launch/serve.py``
    and the serving cache (``repro.serving``) — so the documented tie
    contract (``lax.top_k`` prefers the earlier concatenation position =
    the lower global doc id) cannot diverge between the paths, and a merge
    of CACHED partials is bit-identical to a merge of freshly computed ones.
    """
    scores = jnp.concatenate([r.scores for r in parts], axis=1)   # (B, G*k)
    ids = jnp.concatenate([r.doc_ids for r in parts], axis=1)
    top_scores, pos = jax.lax.top_k(scores, k)
    return RetrievalResult(top_scores,
                           jnp.take_along_axis(ids, pos, axis=1))


def merge_partial_topk_by_rank(parts: list[RetrievalResult],
                               k: int) -> RetrievalResult:
    """Merge per-EPOCH top-k results whose scores are NOT comparable.

    Scores from different codebook epochs live on different quantization
    grids (each epoch's PQ/centroid codebooks define their own error
    profile), so a by-score merge across epochs would silently prefer
    whichever epoch's codebooks happen to inflate scores — ranks are the
    only calibration-free common currency. The merge interleaves by
    per-epoch rank, NEWEST epoch first at every rank (its codebooks were
    trained on the freshest slice of the distribution, so its rank-r doc is
    the best-informed rank-r claim), and truncates to ``k``:

        rank 0 of epoch E-1, rank 0 of epoch E-2, ..., rank 1 of E-1, ...

    Doc-id sets are disjoint across epochs (each owns a global id range),
    so no dedup is needed. The returned ``scores`` are each doc's OWN-epoch
    score — diagnostic only: they are not sorted and not mutually
    comparable; consumers must rank by position. A single part passes
    through unchanged (the common non-re-epoched case stays bit-exact).
    docs/MAINTENANCE.md discusses the semantics.
    """
    if len(parts) == 1:
        return parts[0]
    ids = jnp.stack([p.doc_ids for p in reversed(parts)], axis=1)  # (B, E, k)
    sc = jnp.stack([p.scores for p in reversed(parts)], axis=1)
    b = ids.shape[0]
    return RetrievalResult(
        jnp.swapaxes(sc, 1, 2).reshape(b, -1)[:, :k],
        jnp.swapaxes(ids, 1, 2).reshape(b, -1)[:, :k])


def merge_generation_topk(parts: list[RetrievalResult], offsets,
                          k: int) -> RetrievalResult:
    """Merge per-generation top-k results carrying LOCAL doc ids.

    Applies each generation's global doc-id ``offset`` then defers to
    :func:`merge_partial_topk` (the single merge definition).
    """
    return merge_partial_topk(
        [RetrievalResult(r.scores, r.doc_ids + off)
         for r, off in zip(parts, offsets)], k)


def retrieve_generation_topk(index: PackedIndex, meta, offset: int,
                             queries: jax.Array, cfg: EngineConfig,
                             q_masks: Optional[jax.Array] = None, *,
                             doc_filter: Optional[bitvector.FilterPlan] = None
                             ) -> RetrievalResult:
    """One generation's partial top-k, doc ids mapped into the GLOBAL space.

    The reusable intermediate of the timeline merge path: runs the full
    four-phase pipeline (``retrieve``, budgets clamped to the generation via
    :func:`adapt_config_to_corpus`) over ONE immutable generation and
    offsets its local doc ids by the generation's position in the timeline.
    ``retrieve_timeline`` is ``merge_partial_topk`` over these partials —
    and because a generation is immutable, a partial depends only on
    (query bytes, generation contents, config), which is exactly what makes
    it cacheable (``repro.serving.cache``): a cached partial merges
    bit-identically with freshly computed ones.

    ``doc_filter`` (or ``cfg.doc_filter``) must be compiled against THIS
    timeline's predicate names — checked against ``meta.pred_names`` here,
    where the generation's meta is in hand.
    """
    cfg = _with_filter(cfg, doc_filter)
    if cfg.doc_filter is not None and \
            tuple(cfg.doc_filter.names) != tuple(meta.pred_names):
        raise ValueError(
            f"doc_filter was compiled against predicate names "
            f"{tuple(cfg.doc_filter.names)} but this generation declares "
            f"{tuple(meta.pred_names)}: bit positions would disagree — "
            "recompile the FilterExpr with compile_filter(expr, "
            "meta.pred_names) for this timeline")
    part = retrieve(index, queries,
                    adapt_config_to_corpus(cfg, meta.n_docs, meta.cap),
                    q_masks)
    return RetrievalResult(part.scores, part.doc_ids + jnp.int32(offset))


def retrieve_timeline(timeline: "ShardedTimeline", queries: jax.Array,
                      cfg: EngineConfig,
                      q_masks: Optional[jax.Array] = None, *,
                      doc_filter=None) -> RetrievalResult:
    """Retrieve over a :class:`~repro.core.store.ShardedTimeline` — the
    PLAID-SHIRTTT merge path.

    Runs the existing fused four-phase pipeline (``retrieve``, so every
    kernel/config choice applies unchanged) once per immutable generation,
    offsets each generation's local doc ids into the global id space, and
    merges the per-generation top-k by score into one final top-k.

    Equivalence contract (tests/test_store.py): all generations share the
    frozen centroid/PQ codebooks, and every phase's SCORE (Eq. 4 filter,
    Eq. 2 proxy, Eq. 5/6 late interaction) is per-document given those
    codebooks — so a document scores bit-identically in a timeline
    generation and in one monolithic index grown over the union corpus.
    With cut-lossless budgets (``n_filter``/``n_docs`` at least the
    candidate count, e.g. the corpus size — clamped per generation
    automatically) the merged top-k therefore equals the monolithic top-k
    exactly, ids AND score bits. Under tight budgets the two legitimately
    diverge in the timeline's FAVOR: phase 2/3 keep the top-n of the
    *visible pool*, and a per-generation pool has fewer competitors — the
    same relative-selection caveat the shard_map plan documents. Score
    ties: ``lax.top_k`` breaks ties toward the lower index at every cut
    and generations are concatenated in id order, so both paths resolve
    ties toward the lower GLOBAL doc id.

    Budgets are clamped per generation via :func:`adapt_config_to_corpus`;
    generations of equal shape share one jit cache entry. The per-generation
    partials are exposed as :func:`retrieve_generation_topk` so the serving
    layer (``repro.serving``) can cache them per immutable generation and
    merge cached + fresh partials through the same
    :func:`merge_partial_topk`.

    Also accepts an :class:`~repro.core.store.EpochedTimeline` (codebook
    epochs opened by drift-triggered re-epoching —
    ``repro.serving.maintenance``): each epoch retrieves as above, its
    local doc ids shift by the epoch's global offset, and the per-epoch
    top-k merge BY RANK through :func:`merge_partial_topk_by_rank` —
    scores from different codebooks are not bit-comparable, ranks are.
    A single-epoch EpochedTimeline is bit-exact to its plain timeline.

    ``doc_filter`` accepts a compiled :class:`FilterPlan` (must match the
    timeline's predicate names) or a raw
    :class:`~repro.core.bitvector.FilterExpr`, which is compiled here
    against each (epoch's) timeline's own predicate names — the one entry
    point where per-epoch name sets can legitimately differ.
    """
    epochs = getattr(timeline, "epochs", None)
    if epochs is not None:
        parts = [
            RetrievalResult(r.scores, r.doc_ids + jnp.int32(eoff))
            for tl, eoff in timeline
            for r in (retrieve_timeline(tl, queries, cfg, q_masks,
                                        doc_filter=doc_filter),)]
        return merge_partial_topk_by_rank(parts, cfg.k)
    if isinstance(doc_filter, bitvector.FilterExpr):
        doc_filter = bitvector.compile_filter(doc_filter,
                                              timeline.metas[0].pred_names)
    cfg = _with_filter(cfg, doc_filter)
    # dispatch-only span (see retrieve): per-generation launches + merge
    # enqueue here; device compute overlaps with whatever the caller does
    # next until it blocks on the result
    with trace.span("engine.retrieve_timeline.dispatch",
                    generations=len(timeline.generations)):
        parts = [retrieve_generation_topk(gen, meta, off, queries, cfg,
                                          q_masks)
                 for gen, meta, off in timeline]
        return merge_partial_topk(parts, cfg.k)


# ---------------------------------------------------------------------------
# Query-embedding pruning (Tonellotto & Macdonald, 2021) — the speed knob
# query masking unlocks on top of EMVB's pipeline.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("keep",))
def prune_queries(q: jax.Array, keep: int,
                  importance: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Keep the ``keep`` most important terms of each query.

    q          : (..., n_q, d) query term embeddings
    keep       : static number of terms to retain (keep <= n_q)
    importance : optional (..., n_q) per-term importance. Defaults to the
                 term's L2 norm — zero-padded terms rank last, so pruning
                 doubles as pad-stripping; callers with model-derived
                 importance (e.g. encoder attention mass) pass it here.
    -> (q_pruned (..., keep, d), q_mask (..., keep) bool)

    The selected terms keep their original relative order (so a keep == n_q
    prune is the identity), and ``q_mask`` is False exactly where the kept
    slot holds a zero EMBEDDING (padding) — detected from the term's norm,
    never from the sign of the caller's importance, so zero/negative
    importance scores (attention logits, IDF deltas) on real terms cannot
    silently mask them. Feed both to ``retrieve``: the smaller static n_q
    shrinks every per-term tensor in all four phases — CS rows, bit-vector
    bits, S̄ rows, LUT rows — which is where the latency saving comes from
    (masking alone keeps shapes fixed).
    """
    n_q = q.shape[-2]
    assert keep <= n_q, f"keep={keep} exceeds n_q={n_q}"
    if importance is None:
        importance = jnp.linalg.norm(q, axis=-1)
    _, sel = jax.lax.top_k(importance, keep)
    sel = jnp.sort(sel, axis=-1)                       # original term order
    q_pruned = jnp.take_along_axis(q, sel[..., None], axis=-2)
    q_mask = jnp.linalg.norm(q_pruned, axis=-1) > 0
    return q_pruned, q_mask
