"""EMVB retrieval engine — the paper's full four-phase pipeline, jit-able.

Phases (single query; batched via vmap):
  1. centroid scoring + candidate generation  (CS matmul, masked top-nprobe,
     IVF gather -> candidate bitmap)                              [paper §4.1]
  2. bit-vector pre-filter F(P,q), select top-n_filter docs       [paper §4.2]
  3. centroid interaction S̄ on survivors, select top-n_docs      [paper §4.3]
  4. PQ late interaction w/ dynamic term filter, final top-k      [paper §4.4]

Every phase has fixed shapes. ``EngineConfig`` is hashable and passed as a
static jit argument. The same functions run single-device (benchmarks/tests)
and under shard_map with per-shard local indices (launch/serve.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import bitvector, interaction
from .index import PackedIndex
from .pq import PQCodebooks, build_lut


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_q: int = 32            # query terms (<= 32: one uint32 bit per term)
    nprobe: int = 4          # centroid lists unioned per query term
    th: float = 0.4          # bit-vector threshold (paper Fig. 2: 0.4)
    th_r: Optional[float] = 0.5   # Eq. 6 term filter; None -> Eq. 5
    n_filter: int = 512      # docs surviving the bit-vector pre-filter
    n_docs: int = 64         # docs entering PQ late interaction
    k: int = 10              # final results
    use_kernels: bool = False  # Pallas kernels (interpret on CPU) vs jnp ref
    # 'score_all' evaluates F on every (local) doc masked by the candidate
    # bitmap (TPU-friendly); 'compact' gathers candidates into a fixed buffer
    # of size cand_cap first (closer to the paper's CPU loop).
    candidate_mode: str = "score_all"
    cand_cap: int = 4096
    # Per-token compaction for phase 4 (DESIGN.md §2 mode (b)): tokens whose
    # centroid is close to NO query term are compacted away before the
    # centroid/LUT gathers, shrinking them cap -> compact_cap. Requires th_r.
    compact_cap: Optional[int] = None
    # Reduced-precision centroid scores (paper §6: "the centroid interaction
    # is carried out with reduced precision"): "bfloat16" halves the CS
    # matrix HBM traffic — the memory bound of the sharded serving plan.
    cs_dtype: str = "float32"


class RetrievalResult(NamedTuple):
    scores: jax.Array   # (B, k)
    doc_ids: jax.Array  # (B, k) int32


# ---------------------------------------------------------------------------
# Phase 1 — centroid scores, bitvector, probes, candidate bitmap
# ---------------------------------------------------------------------------

def centroid_scores(q: jax.Array, centroids: jax.Array,
                    dtype: str = "float32") -> jax.Array:
    """q (n_q, d), centroids (n_c, d) -> CS (n_q, n_c)."""
    if dtype == "bfloat16":
        return (q.astype(jnp.bfloat16) @ centroids.T.astype(jnp.bfloat16))
    return q @ centroids.T


def candidate_bitmap(ivf: jax.Array, ivf_lens: jax.Array, probe_ids: jax.Array,
                     n_docs: int) -> jax.Array:
    """Union of the IVF lists of the probed centroids -> (n_docs,) bool."""
    lists = jnp.take(ivf, probe_ids.reshape(-1), axis=0)        # (P, list_cap)
    lens = jnp.take(ivf_lens, probe_ids.reshape(-1), axis=0)    # (P,)
    valid = jnp.arange(ivf.shape[1])[None, :] < lens[:, None]
    ids = jnp.where(valid, lists, n_docs)                        # sentinel
    bitmap = jnp.zeros((n_docs,), jnp.bool_)
    return bitmap.at[ids.reshape(-1)].set(True, mode="drop")


# ---------------------------------------------------------------------------
# Full pipeline (single query)
# ---------------------------------------------------------------------------

def _retrieve_one(q: jax.Array, index: PackedIndex, token_mask: jax.Array,
                  cfg: EngineConfig) -> RetrievalResult:
    n_docs_corpus = index.codes.shape[0]
    n_c = index.centroids.shape[0]

    if cfg.use_kernels:
        from repro.kernels import ops as kops
    else:
        kops = None

    # ---- phase 1 ----
    cs = centroid_scores(q, index.centroids, cfg.cs_dtype)       # (n_q, n_c)
    if kops is not None:
        bits = kops.bitpack(cs, cfg.th)
    else:
        bits = bitvector.build_bitvectors(cs, cfg.th)            # (n_c,) u32
    probe_ids = bitvector.masked_topk_centroids(cs, cfg.th, cfg.nprobe)
    bitmap = candidate_bitmap(index.ivf, index.ivf_lens, probe_ids,
                              n_docs_corpus)

    # ---- phase 2: bit-vector pre-filter ----
    if cfg.candidate_mode == "compact":
        # Fixed-size candidate buffer (ids of bitmap==True, arbitrary order).
        _, cand_ids = jax.lax.top_k(bitmap.astype(jnp.int32), cfg.cand_cap)
        cand_ids = cand_ids.astype(jnp.int32)
        cand_valid = jnp.take(bitmap, cand_ids)
        c_codes = jnp.take(index.codes, cand_ids, axis=0)
        c_mask = jnp.take(token_mask, cand_ids, axis=0) & cand_valid[:, None]
        if kops is not None:
            f = kops.bitfilter(bits, c_codes, c_mask)
        else:
            f = bitvector.filter_score(bits, c_codes, c_mask)
        f = jnp.where(cand_valid, f, -1)
        _, sel1_local = jax.lax.top_k(f, cfg.n_filter)
        sel1 = jnp.take(cand_ids, sel1_local)
    else:
        if kops is not None:
            f = kops.bitfilter(bits, index.codes, token_mask)
        else:
            f = bitvector.filter_score(bits, index.codes, token_mask)
        f = jnp.where(bitmap, f, -1)                             # (n_docs,)
        _, sel1 = jax.lax.top_k(f, cfg.n_filter)
    sel1 = sel1.astype(jnp.int32)

    # ---- phase 3: centroid interaction on survivors ----
    cs_t = cs.T                                                  # (n_c, n_q)
    s1_codes = jnp.take(index.codes, sel1, axis=0)               # (nf, cap)
    s1_mask = jnp.take(token_mask, sel1, axis=0)
    if kops is not None:
        sbar = kops.cinter(cs_t, s1_codes, s1_mask)
    else:
        sbar = interaction.centroid_interaction(cs_t, s1_codes, s1_mask)
    _, sel2_local = jax.lax.top_k(sbar, cfg.n_docs)
    sel2 = jnp.take(sel1, sel2_local)                            # (nd,)

    # ---- phase 4: PQ late interaction (+ Eq. 6 term filter) ----
    pq = index.pq
    q_rot = q @ index.opq_rotation
    lut = build_lut(q_rot, pq)                                   # (n_q, m, K)
    s2_codes = jnp.take(index.codes, sel2, axis=0)
    s2_res = jnp.take(index.res_codes, sel2, axis=0)
    s2_mask = jnp.take(token_mask, sel2, axis=0)
    if kops is not None:
        scores = kops.pqscore(cs_t, lut, s2_codes, s2_res, s2_mask, cfg.th_r)
    elif cfg.compact_cap is not None and cfg.th_r is not None:
        scores = interaction.late_interaction_pq_compact(
            cs_t, lut, s2_codes, s2_res, s2_mask, cfg.th_r, cfg.compact_cap)
    else:
        centroid = None
        if cfg.cs_dtype != "float32":
            # exact f32 centroid term for the FINAL scores: gather the few
            # selected docs' centroid vectors (small) instead of trusting
            # the reduced-precision CS used by phases 1-3
            cent_vecs = jnp.take(index.centroids,
                                 jnp.clip(s2_codes, 0, n_c - 1), axis=0)
            centroid = jnp.einsum("ntd,qd->ntq", cent_vecs, q)
        scores = interaction.late_interaction_pq(
            cs_t, lut, s2_codes, s2_res, s2_mask, cfg.th_r, centroid=centroid)
    top_scores, top_local = jax.lax.top_k(scores, cfg.k)
    return RetrievalResult(top_scores, jnp.take(sel2, top_local))


@functools.partial(jax.jit, static_argnames=("cfg",))
def retrieve(index: PackedIndex, queries: jax.Array,
             cfg: EngineConfig) -> RetrievalResult:
    """queries (B, n_q, d) -> top-k (scores, ids) per query."""
    token_mask = index.token_mask()
    return jax.vmap(lambda q: _retrieve_one(q, index, token_mask, cfg))(queries)


# ---------------------------------------------------------------------------
# Phase-split entry points (benchmarks: paper Fig. 1-style breakdown)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("cfg",))
def phase1_candidates(index: PackedIndex, q: jax.Array, cfg: EngineConfig):
    cs = centroid_scores(q, index.centroids)
    bits = bitvector.build_bitvectors(cs, cfg.th)
    probe_ids = bitvector.masked_topk_centroids(cs, cfg.th, cfg.nprobe)
    bitmap = candidate_bitmap(index.ivf, index.ivf_lens, probe_ids,
                              index.codes.shape[0])
    return cs, bits, bitmap


@functools.partial(jax.jit, static_argnames=("cfg",))
def phase2_prefilter(index: PackedIndex, bits: jax.Array, bitmap: jax.Array,
                     cfg: EngineConfig):
    token_mask = index.token_mask()
    f = bitvector.filter_score(bits, index.codes, token_mask)
    f = jnp.where(bitmap, f, -1)
    _, sel1 = jax.lax.top_k(f, cfg.n_filter)
    return sel1.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("cfg",))
def phase3_centroid_interaction(index: PackedIndex, cs: jax.Array,
                                sel1: jax.Array, cfg: EngineConfig):
    token_mask = index.token_mask()
    sbar = interaction.centroid_interaction(
        cs.T, jnp.take(index.codes, sel1, axis=0),
        jnp.take(token_mask, sel1, axis=0))
    _, sel2_local = jax.lax.top_k(sbar, cfg.n_docs)
    return jnp.take(sel1, sel2_local)


@functools.partial(jax.jit, static_argnames=("cfg",))
def phase4_late_interaction(index: PackedIndex, q: jax.Array, cs: jax.Array,
                            sel2: jax.Array, cfg: EngineConfig):
    token_mask = index.token_mask()
    lut = build_lut(q @ index.opq_rotation, index.pq)
    scores = interaction.late_interaction_pq(
        cs.T, lut,
        jnp.take(index.codes, sel2, axis=0),
        jnp.take(index.res_codes, sel2, axis=0),
        jnp.take(token_mask, sel2, axis=0), cfg.th_r)
    top_scores, top_local = jax.lax.top_k(scores, cfg.k)
    return top_scores, jnp.take(sel2, top_local)
