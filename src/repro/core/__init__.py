"""EMVB core — the paper's contribution as composable JAX modules."""
from . import bitvector, engine, index, interaction, kmeans, plaid, pq, residual, store  # noqa: F401
from .engine import (EngineConfig, QueryBatch, RetrievalResult,  # noqa: F401
                     prune_queries, retrieve, retrieve_timeline)
from .index import (PackedIndex, IndexMeta, build_index,  # noqa: F401
                    bytes_per_embedding, pool_documents)
from .plaid import PlaidConfig  # noqa: F401
from .store import (EpochedTimeline, ShardedTimeline, add_passages,  # noqa: F401
                    generation_footprint, index_fingerprint, load_index,
                    load_timeline, merge_generations, new_generation,
                    save_index, save_timeline, timeline_footprint)
