"""EMVB core — the paper's contribution as composable JAX modules."""
from . import bitvector, engine, index, interaction, kmeans, plaid, pq, residual  # noqa: F401
from .engine import EngineConfig, prune_queries, retrieve  # noqa: F401
from .index import PackedIndex, IndexMeta, build_index, bytes_per_embedding  # noqa: F401
from .plaid import PlaidConfig  # noqa: F401
