"""EMVB contribution C1 — stacked bit-vector pre-filter (paper §4.2).

The paper stores, for each query term i, the set ``close_i^th`` of centroids
whose score exceeds ``th``, as *vertically stacked* bit vectors: one 32-bit
word per centroid whose bit i says "centroid is close to query term i"
(paper Fig. 3). A passage's filter score is then

    F(P, q) = popcount( OR_{j in P} word[code_j] )            (paper Eq. 4)

i.e. how many query terms have at least one close passage token.

TPU adaptation (see DESIGN.md §2): instead of compressstore'd index lists we
build the packed words directly as a dense (n_c,) uint32 tensor — a pure
VPU threshold+shift+or, branchless by construction. Membership testing is a
uint32 gather + OR-reduction + ``lax.population_count``. These functions are
the jnp reference; ``repro.kernels.bitpack`` / ``repro.kernels.bitfilter``
are the Pallas versions.

The same word layout generalizes beyond query-term membership: a
:class:`PredicateSet` packs up to 32 NAMED per-document boolean predicates
(language, tenant, date bucket, ...) into one uint32 word per document, and a
:class:`FilterExpr` (AND/OR/NOT over predicate names) compiles through
:func:`compile_filter` into a :class:`FilterPlan` — a static tuple of
``(required_mask, forbidden_mask)`` clause pairs that every dispatch path
(jnp reference, unfused kernels, both megakernels) evaluates with the same
two bitwise ops per clause. See docs/FILTERING.md.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np


def build_bitvectors(cs: jax.Array, th: float,
                     q_mask: Optional[jax.Array] = None) -> jax.Array:
    """Pack per-term threshold masks into stacked bit vectors.

    cs     : (..., n_q, n_c) centroid score matrix (n_q <= 32)
    q_mask : optional (..., n_q) bool — True for live query terms. Masked
             (padded / pruned) terms pack a 0 bit for EVERY centroid, so
             Eq. 4's popcount can never count them.
    -> (..., n_c) uint32 ; bit i of word c == (cs[..., i, c] > th)
    """
    n_q = cs.shape[-2]
    assert n_q <= 32, "stacked bitvector packs one query term per bit of uint32"
    mask = (cs > th)
    if q_mask is not None:
        mask = mask & q_mask[..., :, None]
    mask = mask.astype(jnp.uint32)
    shifts = jnp.arange(n_q, dtype=jnp.uint32)
    # Disjoint bit fields: sum == bitwise OR.
    return jnp.sum(mask << shifts[..., :, None], axis=-2).astype(jnp.uint32)


def or_reduce(words: jax.Array, axis: int) -> jax.Array:
    """Bitwise-OR reduction along ``axis``."""
    return jax.lax.reduce(words, jnp.uint32(0), jax.lax.bitwise_or,
                          (axis % words.ndim,))


def filter_score(bits: jax.Array, codes: jax.Array,
                 token_mask: jax.Array) -> jax.Array:
    """Evaluate Eq. 4 for a batch of passages.

    bits       : (n_c,) uint32 stacked bit vectors for ONE query
    codes      : (n_docs, cap) int32 centroid id per token (padded)
    token_mask : (n_docs, cap) bool — True for real tokens
    -> (n_docs,) int32 = F(P, q)
    """
    words = jnp.take(bits, jnp.clip(codes, 0, bits.shape[0] - 1), axis=0)
    words = jnp.where(token_mask, words, jnp.uint32(0))
    ored = or_reduce(words, axis=-1)              # (n_docs,)
    return jax.lax.population_count(ored).astype(jnp.int32)


def filter_score_batch(bits: jax.Array, codes: jax.Array,
                       token_mask: jax.Array) -> jax.Array:
    """Batched over queries: bits (B, n_c) -> (B, n_docs)."""
    return jax.vmap(filter_score, in_axes=(0, None, None))(bits, codes, token_mask)


def masked_topk_centroids(cs: jax.Array, th: float, nprobe: int,
                          q_mask: Optional[jax.Array] = None) -> jax.Array:
    """Top-nprobe centroid ids per query term, restricted to the survivors of
    the threshold (paper §4.1: the pre-filter 'tears down' the number of
    evaluated elements; the TPU-native equivalent masks non-survivors to -inf
    so top_k never ranks them above any survivor).

    The ranking runs in f32 regardless of the CS dtype: the old code
    computed ``cs - 1e6`` in the CS dtype, and under reduced-precision CS
    (bf16 ulp at 1e6 is 2048) that offset collapsed all non-survivor scores
    onto a handful of values, so the bf16 probe selection silently diverged
    from the f32 one. Casting to f32 first is the dtype-safe fix that
    PRESERVES the fallback ordering: if a term has fewer than nprobe
    survivors the remaining slots still fall back to the best-scoring
    non-survivors (harmless: their inverted lists are unioned with
    higher-scoring ones). For f32 CS this is bit-identical to the old
    behavior.

    q_mask : optional (..., n_q) bool — masked terms probe NOTHING: their
             rows are returned as the one-past-end sentinel ``n_c``, which
             ``candidate_bitmap`` treats as an empty list.
    cs -> (..., n_q, nprobe) int32.
    """
    cs32 = cs.astype(jnp.float32)
    masked = jnp.where(cs > th, cs32, cs32 - 1e6)
    _, idx = jax.lax.top_k(masked, nprobe)
    idx = idx.astype(jnp.int32)
    if q_mask is not None:
        idx = jnp.where(q_mask[..., :, None], idx, jnp.int32(cs.shape[-1]))
    return idx


# ---------------------------------------------------------------------------
# Predicate planes: the SAME u32 word layout, repurposed for named per-doc
# metadata predicates. Bit i of pred_words[d] == "predicate names[i] holds
# for document d". Built once at index/growth time, persisted per generation
# (store schema v3), and ANDed into the candidate bitmap at query time.
# ---------------------------------------------------------------------------

MAX_PREDICATES = 32  # one uint32 word per document


@dataclasses.dataclass(frozen=True)
class PredicateSet:
    """Named boolean per-document predicates packed one-bit-per-name.

    ``words[d]`` holds bit ``i`` set iff predicate ``names[i]`` is true for
    document ``d`` — the exact layout :func:`build_bitvectors` uses for
    query terms, so the kernels' gather/AND machinery applies unchanged.
    Build one with :meth:`pack`; pass it (or the raw dict) to
    ``build_index(predicates=...)``.
    """

    names: tuple[str, ...]
    words: jax.Array  # (n_docs,) uint32

    @classmethod
    def pack(cls, predicates: Mapping[str, np.ndarray]) -> "PredicateSet":
        """Pack ``{name: (n_docs,) bool array}`` into one word per doc.

        Insertion order of the mapping fixes the bit positions (and thereby
        the on-disk ``pred_names`` order every FilterPlan compiles against).
        """
        names = tuple(predicates)
        if not names:
            raise ValueError(
                "PredicateSet.pack got an empty mapping: pass at least one "
                "named predicate, or use predicates=None for no plane")
        if len(names) > MAX_PREDICATES:
            raise ValueError(
                f"{len(names)} predicates > {MAX_PREDICATES}: the plane "
                "packs one bit per predicate into a uint32 word")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate predicate names in {names}")
        words = None
        for i, name in enumerate(names):
            col = np.asarray(predicates[name])
            if col.ndim != 1:
                raise ValueError(
                    f"predicate {name!r} has shape {col.shape}: expected a "
                    "1-D (n_docs,) boolean array")
            if words is None:
                words = np.zeros(col.shape[0], np.uint32)
            elif col.shape[0] != words.shape[0]:
                raise ValueError(
                    f"predicate {name!r} has {col.shape[0]} docs but "
                    f"{names[0]!r} has {words.shape[0]}: all predicates "
                    "must cover the same corpus")
            words |= col.astype(bool).astype(np.uint32) << np.uint32(i)
        return cls(names, jnp.asarray(words))

    def mask(self, name: str) -> jax.Array:
        """Unpack one named predicate back to a (n_docs,) bool array."""
        try:
            i = self.names.index(name)
        except ValueError:
            raise ValueError(
                f"unknown predicate {name!r}: this set has {self.names}"
            ) from None
        return (self.words >> jnp.uint32(i)) & jnp.uint32(1) != 0


class FilterExpr:
    """Base of the tiny AND/OR/NOT expression tree over predicate names.

    Compose with operators — ``Pred("en") & ~Pred("draft") | Pred("fr")`` —
    then compile against an index's ``meta.pred_names`` via
    :func:`compile_filter`. Instances are frozen and hashable, so they can
    key caches (the serving layer memoizes compiled plans by expression).
    """

    def __and__(self, other: "FilterExpr") -> "And":
        return And(self, other)

    def __or__(self, other: "FilterExpr") -> "Or":
        return Or(self, other)

    def __invert__(self) -> "Not":
        return Not(self)


@dataclasses.dataclass(frozen=True)
class Pred(FilterExpr):
    """Leaf: the named predicate must hold."""

    name: str


@dataclasses.dataclass(frozen=True)
class And(FilterExpr):
    """Both sub-expressions must hold."""

    lhs: FilterExpr
    rhs: FilterExpr


@dataclasses.dataclass(frozen=True)
class Or(FilterExpr):
    """At least one sub-expression must hold."""

    lhs: FilterExpr
    rhs: FilterExpr


@dataclasses.dataclass(frozen=True)
class Not(FilterExpr):
    """The sub-expression must NOT hold."""

    operand: FilterExpr


@dataclasses.dataclass(frozen=True)
class FilterPlan:
    """A compiled filter: static DNF clauses over one predicate word.

    ``clauses`` is a tuple of ``(required, forbidden)`` uint32 mask pairs; a
    document with word ``w`` passes iff ANY clause has
    ``(w & required) == required and (w & forbidden) == 0``. An empty tuple
    matches nothing; the ``(0, 0)`` clause matches everything. Being a flat
    tuple of Python ints, a plan is hashable — it rides on ``EngineConfig``
    as a static jit argument (one trace per distinct plan, shape-stable
    kernel signatures) and folds into ``config_fingerprint`` so filtered and
    unfiltered cache entries can never collide.

    ``names`` records the pred_names ordering the plan was compiled against;
    layers that hold an :class:`~repro.core.index.IndexMeta` use it to
    reject plans compiled for a different plane layout.
    """

    names: tuple[str, ...]
    clauses: tuple[tuple[int, int], ...]


def _dnf(expr: FilterExpr, bit_of: dict, negate: bool
         ) -> list[tuple[int, int]]:
    """Push negations to the leaves and expand to (required, forbidden)
    clause pairs; contradictory clauses (a bit both required and forbidden)
    are dropped as statically-false."""
    if isinstance(expr, Pred):
        if expr.name not in bit_of:
            raise ValueError(
                f"filter references unknown predicate {expr.name!r}: this "
                f"index has {tuple(bit_of) or '(no predicate plane)'}")
        bit = 1 << bit_of[expr.name]
        return [(0, bit)] if negate else [(bit, 0)]
    if isinstance(expr, Not):
        return _dnf(expr.operand, bit_of, not negate)
    if not isinstance(expr, (And, Or)):
        raise TypeError(
            f"expected a FilterExpr (Pred/And/Or/Not), got "
            f"{type(expr).__name__}")
    lhs = _dnf(expr.lhs, bit_of, negate)
    rhs = _dnf(expr.rhs, bit_of, negate)
    conjunction = isinstance(expr, And) != negate  # De Morgan under negate
    if not conjunction:
        return lhs + rhs
    out = []
    for p1, n1 in lhs:
        for p2, n2 in rhs:
            pos, neg = p1 | p2, n1 | n2
            if pos & neg:
                continue
            out.append((pos, neg))
    return out


def compile_filter(expr: FilterExpr,
                   names: tuple[str, ...]) -> FilterPlan:
    """Compile a :class:`FilterExpr` into a :class:`FilterPlan`.

    ``names`` is the index's predicate ordering (``meta.pred_names``) — bit
    ``i`` of every plane word is ``names[i]``, so a plan is only valid for
    indexes built with the same names in the same order.
    """
    names = tuple(names)
    if len(names) > MAX_PREDICATES:
        raise ValueError(f"{len(names)} predicate names > {MAX_PREDICATES}")
    bit_of = {n: i for i, n in enumerate(names)}
    if len(bit_of) != len(names):
        raise ValueError(f"duplicate predicate names in {names}")
    raw = _dnf(expr, bit_of, False)
    clauses, seen = [], set()
    for c in raw:
        if c not in seen:
            seen.add(c)
            clauses.append(c)
    return FilterPlan(names=names, clauses=tuple(clauses))


def apply_filter_plan(plan: Union[FilterPlan, tuple], words: jax.Array
                      ) -> jax.Array:
    """Evaluate a compiled plan against predicate words.

    ``plan`` : a :class:`FilterPlan` or its raw ``clauses`` tuple (the form
    the kernels receive as a static argument).
    ``words`` : (...,) uint32 predicate plane words.
    -> (...,) bool — True where the document passes the filter. Two bitwise
    ops per clause; every dispatch path shares this exact evaluation, which
    is what makes in-kernel filtering bit-exact against the jnp reference.
    """
    clauses = plan.clauses if isinstance(plan, FilterPlan) else tuple(plan)
    ok = jnp.zeros(words.shape, jnp.bool_)
    for pos, neg in clauses:
        c = jnp.ones(words.shape, jnp.bool_)
        if pos:
            c = c & ((words & jnp.uint32(pos)) == jnp.uint32(pos))
        if neg:
            c = c & ((words & jnp.uint32(neg)) == jnp.uint32(0))
        ok = ok | c
    return ok
