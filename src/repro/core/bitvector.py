"""EMVB contribution C1 — stacked bit-vector pre-filter (paper §4.2).

The paper stores, for each query term i, the set ``close_i^th`` of centroids
whose score exceeds ``th``, as *vertically stacked* bit vectors: one 32-bit
word per centroid whose bit i says "centroid is close to query term i"
(paper Fig. 3). A passage's filter score is then

    F(P, q) = popcount( OR_{j in P} word[code_j] )            (paper Eq. 4)

i.e. how many query terms have at least one close passage token.

TPU adaptation (see DESIGN.md §2): instead of compressstore'd index lists we
build the packed words directly as a dense (n_c,) uint32 tensor — a pure
VPU threshold+shift+or, branchless by construction. Membership testing is a
uint32 gather + OR-reduction + ``lax.population_count``. These functions are
the jnp reference; ``repro.kernels.bitpack`` / ``repro.kernels.bitfilter``
are the Pallas versions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def build_bitvectors(cs: jax.Array, th: float,
                     q_mask: Optional[jax.Array] = None) -> jax.Array:
    """Pack per-term threshold masks into stacked bit vectors.

    cs     : (..., n_q, n_c) centroid score matrix (n_q <= 32)
    q_mask : optional (..., n_q) bool — True for live query terms. Masked
             (padded / pruned) terms pack a 0 bit for EVERY centroid, so
             Eq. 4's popcount can never count them.
    -> (..., n_c) uint32 ; bit i of word c == (cs[..., i, c] > th)
    """
    n_q = cs.shape[-2]
    assert n_q <= 32, "stacked bitvector packs one query term per bit of uint32"
    mask = (cs > th)
    if q_mask is not None:
        mask = mask & q_mask[..., :, None]
    mask = mask.astype(jnp.uint32)
    shifts = jnp.arange(n_q, dtype=jnp.uint32)
    # Disjoint bit fields: sum == bitwise OR.
    return jnp.sum(mask << shifts[..., :, None], axis=-2).astype(jnp.uint32)


def or_reduce(words: jax.Array, axis: int) -> jax.Array:
    """Bitwise-OR reduction along ``axis``."""
    return jax.lax.reduce(words, jnp.uint32(0), jax.lax.bitwise_or,
                          (axis % words.ndim,))


def filter_score(bits: jax.Array, codes: jax.Array,
                 token_mask: jax.Array) -> jax.Array:
    """Evaluate Eq. 4 for a batch of passages.

    bits       : (n_c,) uint32 stacked bit vectors for ONE query
    codes      : (n_docs, cap) int32 centroid id per token (padded)
    token_mask : (n_docs, cap) bool — True for real tokens
    -> (n_docs,) int32 = F(P, q)
    """
    words = jnp.take(bits, jnp.clip(codes, 0, bits.shape[0] - 1), axis=0)
    words = jnp.where(token_mask, words, jnp.uint32(0))
    ored = or_reduce(words, axis=-1)              # (n_docs,)
    return jax.lax.population_count(ored).astype(jnp.int32)


def filter_score_batch(bits: jax.Array, codes: jax.Array,
                       token_mask: jax.Array) -> jax.Array:
    """Batched over queries: bits (B, n_c) -> (B, n_docs)."""
    return jax.vmap(filter_score, in_axes=(0, None, None))(bits, codes, token_mask)


def masked_topk_centroids(cs: jax.Array, th: float, nprobe: int,
                          q_mask: Optional[jax.Array] = None) -> jax.Array:
    """Top-nprobe centroid ids per query term, restricted to the survivors of
    the threshold (paper §4.1: the pre-filter 'tears down' the number of
    evaluated elements; the TPU-native equivalent masks non-survivors to -inf
    so top_k never ranks them above any survivor).

    The ranking runs in f32 regardless of the CS dtype: the old code
    computed ``cs - 1e6`` in the CS dtype, and under reduced-precision CS
    (bf16 ulp at 1e6 is 2048) that offset collapsed all non-survivor scores
    onto a handful of values, so the bf16 probe selection silently diverged
    from the f32 one. Casting to f32 first is the dtype-safe fix that
    PRESERVES the fallback ordering: if a term has fewer than nprobe
    survivors the remaining slots still fall back to the best-scoring
    non-survivors (harmless: their inverted lists are unioned with
    higher-scoring ones). For f32 CS this is bit-identical to the old
    behavior.

    q_mask : optional (..., n_q) bool — masked terms probe NOTHING: their
             rows are returned as the one-past-end sentinel ``n_c``, which
             ``candidate_bitmap`` treats as an empty list.
    cs -> (..., n_q, nprobe) int32.
    """
    cs32 = cs.astype(jnp.float32)
    masked = jnp.where(cs > th, cs32, cs32 - 1e6)
    _, idx = jax.lax.top_k(masked, nprobe)
    idx = idx.astype(jnp.int32)
    if q_mask is not None:
        idx = jnp.where(q_mask[..., :, None], idx, jnp.int32(cs.shape[-1]))
    return idx
