"""Synthetic data generators with *planted relevance* so retrieval quality
(MRR@k, Recall@k, Success@k) is measurable without external datasets.

Corpus model (MS MARCO-like, scaled): topic vectors on the unit sphere; each
document draws a topic, its token embeddings are topic + per-token jitter,
L2-normalized. A query samples a target document, takes ``n_q`` of its tokens
and perturbs them — so the target document is the ground-truth best answer
under exact MaxSim with overwhelming probability.

An out-of-domain variant (LoTTE-like) shifts the topic distribution and
lengthens documents (the paper notes LoTTE's longer docs are why EMVB's
pre-filter pays off even more there).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np


class Corpus(NamedTuple):
    doc_embs: np.ndarray   # (n_docs, cap, d) fp32, zero-padded, L2-normed rows
    doc_lens: np.ndarray   # (n_docs,) int32
    queries: np.ndarray    # (n_queries, n_q, d) fp32, L2-normed
    gt_doc: np.ndarray     # (n_queries,) int32 planted ground-truth doc


def make_corpus(seed: int, *, n_docs: int = 2000, cap: int = 48,
                min_len: int = 16, d: int = 128, n_topics: int = 64,
                n_queries: int = 64, n_q: int = 32,
                token_noise: float = 0.35, query_noise: float = 0.12,
                topic_shift: float = 0.0) -> Corpus:
    rng = np.random.default_rng(seed)
    topics = rng.normal(size=(n_topics, d)).astype(np.float32)
    if topic_shift:
        topics += topic_shift * rng.normal(size=(1, d)).astype(np.float32)
    topics /= np.linalg.norm(topics, axis=-1, keepdims=True)

    doc_lens = rng.integers(min_len, cap + 1, size=n_docs).astype(np.int32)
    doc_topic = rng.integers(0, n_topics, size=n_docs)
    noise = rng.normal(size=(n_docs, cap, d)).astype(np.float32) * token_noise
    doc_embs = topics[doc_topic][:, None, :] + noise
    doc_embs /= np.maximum(
        np.linalg.norm(doc_embs, axis=-1, keepdims=True), 1e-12)
    pad_mask = np.arange(cap)[None, :] >= doc_lens[:, None]
    doc_embs[pad_mask] = 0.0

    gt = rng.integers(0, n_docs, size=n_queries).astype(np.int32)
    queries = np.empty((n_queries, n_q, d), np.float32)
    for qi, docid in enumerate(gt):
        take = rng.integers(0, doc_lens[docid], size=n_q)
        qtok = doc_embs[docid, take] + \
            rng.normal(size=(n_q, d)).astype(np.float32) * query_noise
        queries[qi] = qtok / np.maximum(
            np.linalg.norm(qtok, axis=-1, keepdims=True), 1e-12)
    return Corpus(doc_embs, doc_lens, queries, gt)


def make_ood_corpus(seed: int, **kw) -> Corpus:
    """LoTTE-like: distribution-shifted topics, longer documents."""
    kw.setdefault("cap", 96)
    kw.setdefault("min_len", 48)
    kw.setdefault("topic_shift", 0.8)
    return make_corpus(seed, **kw)


# --- retrieval quality metrics ------------------------------------------------

def mrr_at_k(ranked_ids: np.ndarray, gt: np.ndarray, k: int = 10) -> float:
    """ranked_ids (B, >=k) -> mean reciprocal rank@k of the planted doc."""
    rr = 0.0
    for ids, g in zip(ranked_ids[:, :k], gt):
        hits = np.nonzero(ids == g)[0]
        if hits.size:
            rr += 1.0 / (hits[0] + 1)
    return rr / len(gt)


def recall_at_k(ranked_ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    return float(np.mean([
        g in ids[:k] for ids, g in zip(ranked_ids, gt)]))


def success_at_k(ranked_ids: np.ndarray, gt: np.ndarray, k: int) -> float:
    return recall_at_k(ranked_ids, gt, k)
