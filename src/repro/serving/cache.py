"""Per-generation result cache — the serving layer's memory.

EMVB's candidate-generation phases dominate latency (PLAID, Santhanam et
al., 2022), and on a ``ShardedTimeline`` every generation except the newest
is immutable — so a generation's partial top-k for a given query is a pure
function of three fingerprints and can be cached forever:

    key = (query_fingerprint, generation_fingerprint, config_fingerprint)

* ``query_fingerprint`` hashes the quantized query bytes (the f32 array the
  engine actually consumes) AND the per-term ``q_mask`` — a padded query
  and its unpadded prefix hash differently even though they retrieve
  identically (PR 3's contract); collapsing them would be a second
  equivalence the cache does not need to assume.
* the generation fingerprint is ``repro.core.store.index_fingerprint`` —
  content-addressed, persisted in the store manifest, bumped by ANY
  mutation (``add_passages`` on the open generation changes ``codes`` and
  with it the fingerprint), so stale entries are unreachable by
  construction rather than by eviction discipline.
* ``config_fingerprint`` hashes every ``EngineConfig`` field: the same
  query over the same generation under a different ``k``/``th``/kernel
  choice is a different result.

Entries are the per-query, per-generation partial ``(scores (k,), global
doc ids (k,))`` pairs that :func:`repro.core.engine.merge_partial_topk`
merges — stored as numpy, so a hit costs no device transfer bookkeeping
and a warm merge is bit-identical to a cold one. Eviction is LRU under a
byte budget (``max_bytes``); hit/miss/eviction counters feed
``repro.serving.metrics``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.core.engine import EngineConfig

CacheKey = tuple[str, str, str]


def query_fingerprint(query: np.ndarray,
                      q_mask: Optional[np.ndarray] = None) -> str:
    """Fingerprint one query: sha1 over the quantized query bytes + mask.

    ``query`` is the (n_q, d) f32 array the engine consumes (already
    padded/quantized by the batcher); ``q_mask`` is the (n_q,) bool term
    mask, ``None`` meaning all-True (the two hash identically, since they
    retrieve identically bit for bit — PR 3). Shape and dtype are hashed
    too, so a (16, d) prefix and its (32, d) zero-padded form stay distinct
    keys (they hit different jit programs even though scores agree).
    """
    q = np.ascontiguousarray(np.asarray(query, dtype=np.float32))
    m = (np.ones(q.shape[0], dtype=bool) if q_mask is None
         else np.ascontiguousarray(np.asarray(q_mask, dtype=bool)))
    h = hashlib.sha1()
    h.update(repr(q.shape).encode())
    h.update(q.tobytes())
    h.update(m.tobytes())
    return h.hexdigest()


def config_fingerprint(cfg: EngineConfig, doc_budget=None) -> str:
    """Fingerprint an ``EngineConfig``: sha1 over every field, sorted.

    Python's ``hash()`` is salted per process, so the dataclass hash cannot
    key anything that outlives a process; the field dump can. Every field
    participates — kernel dispatch flags included, since the bit-exactness
    contract is per config, not just per budget.

    ``doc_budget`` folds the served timeline's document budget (or a tuple
    of per-epoch budgets) into the key: a pooled and an unpooled index over
    the same corpus can coincidentally share a generation fingerprint when
    every doc fits the budget, so the representation regime must be keyed
    explicitly — pooled and unpooled partials never collide. ``None`` (the
    per-token layout) leaves the fingerprint bit-identical to pre-budget
    builds, so existing cache keys survive the upgrade.
    """
    fields = sorted(dataclasses.asdict(cfg).items())
    if doc_budget is not None:
        fields.append(("doc_budget", doc_budget))
    return hashlib.sha1(repr(fields).encode()).hexdigest()


@dataclasses.dataclass
class _Entry:
    """One cached partial: scores + GLOBAL doc ids for a single query over
    a single immutable generation."""

    scores: np.ndarray    # (k,) — dtype as the engine produced it
    doc_ids: np.ndarray   # (k,) int32, global id space
    nbytes: int


class ResultCache:
    """LRU result cache under a byte budget.

    Maps :data:`CacheKey` -> per-query partial top-k. ``get`` refreshes
    recency; ``put`` evicts least-recently-used entries until the budget
    holds (an entry larger than the whole budget is simply not cached).
    Counters (``hits``/``misses``/``evictions``/``bytes``) are cumulative;
    ``repro.serving.metrics`` snapshots them. Not thread-safe — the service
    loop is cooperative single-thread (docs/SERVING.md).
    """

    def __init__(self, max_bytes: int = 64 << 20):
        """``max_bytes``: LRU byte budget over entry payloads (default
        64 MiB — at k=10 a partial is ~80 payload bytes, so the default
        holds hundreds of thousands of (query, generation) partials)."""
        self.max_bytes = int(max_bytes)
        self._entries: OrderedDict[CacheKey, _Entry] = OrderedDict()
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        """Number of cached (query, generation, config) partials."""
        return len(self._entries)

    def get(self, key: CacheKey
            ) -> Optional[tuple[np.ndarray, np.ndarray]]:
        """-> (scores, doc_ids) and refresh recency, or None on miss."""
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return e.scores, e.doc_ids

    def put(self, key: CacheKey, scores: np.ndarray,
            doc_ids: np.ndarray) -> None:
        """Insert one partial (copied to owned host arrays); LRU-evict to
        budget.

        The copy is load-bearing, not defensive: callers pass row VIEWS
        into a whole batch's device-result buffer, and caching the view
        would pin the full (B, k) buffer alive while accounting only the
        row — the byte budget would hold on paper while resident memory
        exceeded it by up to the batch size.
        """
        scores = np.array(scores, copy=True)
        doc_ids = np.array(doc_ids, copy=True)
        nbytes = scores.nbytes + doc_ids.nbytes
        if nbytes > self.max_bytes:
            return
        old = self._entries.pop(key, None)
        if old is not None:
            self.bytes -= old.nbytes
        self._entries[key] = _Entry(scores, doc_ids, nbytes)
        self.bytes += nbytes
        while self.bytes > self.max_bytes:
            _, evicted = self._entries.popitem(last=False)
            self.bytes -= evicted.nbytes
            self.evictions += 1

    def clear(self) -> None:
        """Drop every entry (counters keep their cumulative totals)."""
        self._entries.clear()
        self.bytes = 0

    def stats(self) -> dict:
        """Cumulative counters + current occupancy, one flat dict."""
        lookups = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "bytes": self.bytes,
            "max_bytes": self.max_bytes,
            "hits": self.hits,
            "misses": self.misses,
            "lookups": lookups,
            "evictions": self.evictions,
            "hit_rate": self.hits / lookups if lookups else 0.0,
        }
