"""Size/deadline micro-batching for the retrieval service.

Kernel launches amortize across concurrent users: a batch of B queries
costs far less than B single-query calls (the engine vmaps one program
over the batch). But the engine's shapes are static — every query must
arrive as (n_q, d) — while real queries have heterogeneous term counts.
The batcher bridges the two with PR 3's mask machinery: each submitted
query is zero-padded to the static ``n_q`` with a per-term mask, which the
engine honors bit-exactly (a padded query with its mask retrieves
identically to the unpadded prefix), so heterogeneous queries batch
without changing any result.

Batching policy (cooperative, no background thread — docs/SERVING.md):

* **size** — a batch closes as soon as ``max_batch`` queries are pending
  (the service flushes it immediately);
* **deadline** — otherwise it closes ``max_delay_s`` after its OLDEST
  PENDING query was submitted: ``due()`` turns True and the next
  ``poll()``/``flush()`` drains it. The anchor is per query, not per
  batch: a query left behind when a full ``max_batch`` drains keeps its
  original submit time, so EVERY query — lone, batched, or overflowed —
  waits at most ``max_delay_s`` for company. The clock is injectable for
  deterministic tests.

Predicate filters (docs/FILTERING.md) batch by HOMOGENEITY: the engine's
filter plan is static per launch, so one drained batch must share one
filter. ``drain`` therefore pops the longest FRONT RUN of pending queries
whose filter equals the oldest entry's — FIFO order is preserved (no
reordering, so the per-query deadline promise still holds; a query never
waits behind a younger one), and a filter change simply closes the batch
early. Alternating filters degrade to batch-of-one, which is correct,
just unamortized.

The cache-hit/cache-miss lane split happens per generation downstream
(``RetrievalService._execute``): the batcher's job ends at a dense
:class:`~repro.core.engine.QueryBatch` — (B, n_q, d) queries + (B, n_q)
mask — plus the batch's shared filter and the tickets to fill.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import numpy as np

from repro.core.engine import QueryBatch
from repro.obs import trace


def pad_query(query: np.ndarray, n_q: int,
              q_mask: Optional[np.ndarray] = None
              ) -> tuple[np.ndarray, np.ndarray]:
    """Zero-pad one (t, d) query to the static (n_q, d) + its (n_q,) mask.

    ``q_mask`` (optional, (t,) bool) masks terms of the UNPADDED query —
    e.g. the mask ``prune_queries`` returned; padding slots are always
    masked False on top of it. A query already at ``n_q`` terms passes
    through unchanged (its mask defaulting to all-True). Rejects t > n_q
    with an actionable error — the engine's bit-vector word is 32 bits
    wide, splitting longer queries is the caller's call, not a silent
    truncation.
    """
    q = np.asarray(query, dtype=np.float32)
    if q.ndim != 2:
        raise ValueError(f"query has shape {q.shape}: expected (terms, d)")
    t = q.shape[0]
    if t > n_q:
        raise ValueError(
            f"query has {t} terms but the service is configured for "
            f"n_q={n_q}; prune it first (repro.core.engine.prune_queries) "
            "or raise cfg.n_q")
    mask = np.ones(t, dtype=bool) if q_mask is None \
        else np.asarray(q_mask, dtype=bool)
    if mask.shape != (t,):
        raise ValueError(f"q_mask has shape {mask.shape}: expected ({t},) "
                         "— one bool per (unpadded) query term")
    if t == n_q:
        return q, mask
    out = np.zeros((n_q, q.shape[1]), dtype=np.float32)
    out[:t] = q
    full = np.zeros(n_q, dtype=bool)
    full[:t] = mask
    return out, full


class Ticket:
    """A submitted query's handle: filled by the flush that computes it."""

    __slots__ = ("scores", "doc_ids", "_done")

    def __init__(self):
        """A fresh, unfilled ticket."""
        self.scores: Optional[np.ndarray] = None
        self.doc_ids: Optional[np.ndarray] = None
        self._done = False

    @property
    def done(self) -> bool:
        """True once a flush has filled this ticket."""
        return self._done

    def _fill(self, scores: np.ndarray, doc_ids: np.ndarray) -> None:
        self.scores = scores
        self.doc_ids = doc_ids
        self._done = True

    def result(self) -> tuple[np.ndarray, np.ndarray]:
        """-> (scores (k,), global doc ids (k,)); raises if still pending
        (drive the service: ``flush()`` now or ``poll()`` past the
        deadline)."""
        if not self._done:
            raise RuntimeError(
                "ticket is still pending — the batch has not been flushed; "
                "call service.flush() (or poll() once the deadline passes)")
        return self.scores, self.doc_ids


class MicroBatcher:
    """Accumulates padded queries until size or deadline closes the batch.

    The service owns the flush loop; the batcher only answers "is a batch
    due?" and hands over dense arrays. Not thread-safe (docs/SERVING.md).
    """

    def __init__(self, n_q: int, max_batch: int = 16,
                 max_delay_s: float = 0.002,
                 clock: Callable[[], float] = time.monotonic):
        """``n_q``: static term count queries are padded to. ``max_batch``:
        size trigger. ``max_delay_s``: deadline trigger, measured from the
        oldest pending submit. ``clock``: injectable monotonic clock."""
        if max_batch < 1:
            raise ValueError(f"max_batch={max_batch} < 1")
        self.n_q = n_q
        self.max_batch = max_batch
        self.max_delay_s = max_delay_s
        self.clock = clock
        self._queries: list[np.ndarray] = []
        self._masks: list[np.ndarray] = []
        self._tickets: list[Ticket] = []
        self._submits: list[float] = []     # submit time per pending query
        self._filters: list = []            # compiled FilterPlan (or None)
        # cumulative count of queries drained LATER than max_delay_s after
        # their submit — i.e. the cooperative poll loop broke the per-query
        # deadline promise. A size-triggered drain or an exactly-on-time
        # poll never counts (the comparison is strict); a slow poll cadence
        # shows up here before it shows up in p99.
        self.deadline_misses = 0

    def __len__(self) -> int:
        """Number of pending (not yet drained) queries."""
        return len(self._queries)

    def submit(self, query: np.ndarray,
               q_mask: Optional[np.ndarray] = None,
               doc_filter=None) -> Ticket:
        """Enqueue one (t, d) query (padded to n_q) -> its :class:`Ticket`.

        ``doc_filter`` (optional compiled ``bitvector.FilterPlan``) rides
        with the query; ``drain`` groups consecutive same-filter queries
        into one batch."""
        q, m = pad_query(query, self.n_q, q_mask)
        self._queries.append(q)
        self._masks.append(m)
        self._submits.append(self.clock())
        self._filters.append(doc_filter)
        ticket = Ticket()
        self._tickets.append(ticket)
        return ticket

    def due(self) -> bool:
        """True when the pending batch should flush: full, or the OLDEST
        pending query is older than ``max_delay_s``."""
        if not self._queries:
            return False
        if len(self._queries) >= self.max_batch:
            return True
        return self.clock() - self._submits[0] >= self.max_delay_s

    def drain(self) -> Optional[tuple[QueryBatch, list[Ticket], object]]:
        """Pop up to ``max_batch`` pending queries as one dense batch.

        -> (QueryBatch with (B, n_q, d) f32 ``q`` and (B, n_q) bool
        ``q_mask``, the B tickets to fill, the batch's shared
        ``doc_filter``), or ``None`` when nothing is pending. The batch is
        the longest front run sharing the OLDEST entry's filter — filters
        never mix within a batch (the engine's filter plan is static per
        launch) and queries are never reordered (a later same-filter query
        does NOT jump a differing one; the deadline promise is FIFO).
        Queries left behind — by ``max_batch`` or by a filter change —
        stay queued with their ORIGINAL submit times: the deadline is a
        per-query latency promise ("a lone query waits at most
        ``max_delay_s``"), so a query left behind keeps aging —
        re-anchoring its deadline to the drain would let it wait up to
        twice the promise.

        Telemetry per drain: the drained queries' queue wait (oldest
        entry's, the batch's worst case) is recorded as a
        ``batcher.queue_wait`` span on the current tracer, and every
        drained query that waited STRICTLY longer than ``max_delay_s``
        bumps ``deadline_misses``.
        """
        if not self._queries:
            return None
        doc_filter = self._filters[0]
        n = 1
        while (n < min(len(self._queries), self.max_batch)
               and self._filters[n] == doc_filter):
            n += 1
        now = self.clock()
        self.deadline_misses += sum(
            1 for t in self._submits[:n] if now - t > self.max_delay_s)
        trace.record("batcher.queue_wait", now - self._submits[0],
                     batch=n, pending=len(self._queries) - n)
        qb = QueryBatch(np.stack(self._queries[:n]),
                        np.stack(self._masks[:n]))
        tickets = self._tickets[:n]
        del self._queries[:n], self._masks[:n], self._tickets[:n], \
            self._submits[:n], self._filters[:n]
        return qb, tickets, doc_filter
