"""Online index maintenance: compaction, drift-triggered re-epoching, and
the policy that decides between them.

A long-running service accumulates generations (``new_generation`` per
arrival batch) and drift (``IndexMeta.drift`` grows as appended passages
quantize worse against the frozen codebooks). Left alone, both degrade the
serving path: many small generations mean many per-generation kernel
launches and cache entries per query, and drifted quantization means Eq. 5
scores that no longer rank faithfully. This module closes the loop with
three pieces, mirroring the PLAID SHIRTTT shard-management playbook
(PAPERS.md) on top of PR 4's temporal sharding:

* :func:`repro.core.store.merge_generations` (re-exported here) — the
  mechanism for **compaction**: generations share frozen codebooks, so a
  contiguous range concatenates into one generation losslessly (same ids,
  same score bits).
* :func:`reepoch_tail` — the mechanism for **re-training**: rebuild the
  drifted suffix of the timeline with ``build_index`` (fresh codebooks =
  a new epoch, ``store.EpochedTimeline``), preserving every surviving
  doc's GLOBAL id so caches and downstream references stay valid.
* :class:`MaintenancePolicy` + :class:`MaintenanceRunner` — the decision
  loop: inspect the timeline's shape and drift telemetry, pick merge vs
  retrain, apply it OFF the serving path, and hand the result to
  ``RetrievalService.update_timeline`` (the double-buffered hot swap).

Merge vs retrain in one line: **merge when the codebooks still fit**
(drift under threshold — compaction is free of quality risk because it is
bit-exact) **and retrain when they don't** (drift over threshold — no
amount of merging fixes quantization error; docs/MAINTENANCE.md has the
full decision table).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Union

import jax
import numpy as np

from repro.core.index import build_index, pool_documents
from repro.core.store import (EpochedTimeline, ShardedTimeline,
                              merge_generations)
from repro.obs import trace

Timeline = Union[ShardedTimeline, EpochedTimeline]

# fetch_embeddings(start, stop) -> ((stop-start, cap, d) fp32 zero-padded
# embeddings, (stop-start,) int lengths) for GLOBAL doc ids [start, stop).
# Re-epoching re-quantizes raw embeddings, which the index does not store —
# the corpus owner (whoever called add_passages) must supply them.
EmbeddingFetcher = Callable[[int, int], tuple[np.ndarray, np.ndarray]]


class MaintenanceAction(NamedTuple):
    """One decided maintenance step over the NEWEST epoch's generations.

    ``kind`` is ``"merge"`` (compact generations ``[lo, hi)`` into one,
    bit-exact) or ``"reepoch"`` (rebuild generations ``[lo, hi)`` — always
    a suffix, ``hi == len(epoch)`` — with fresh codebooks). ``reason`` is a
    human-readable sentence for logs/metrics.
    """

    kind: str
    lo: int
    hi: int
    reason: str


@dataclass(frozen=True)
class MaintenancePolicy:
    """When to compact and when to retrain (docs/MAINTENANCE.md).

    merge_factor           : generations per hierarchical merge — frozen
                             generations sit in size tiers
                             (``tier = floor(log_merge_factor(n_docs))``,
                             the LSM/PLAID-SHIRTTT schedule) and
                             ``merge_factor`` adjacent same-tier ones
                             compact into one of the next tier. Total
                             merge work stays O(n log n) docs.
    max_frozen_generations : hard bound on frozen generations regardless
                             of tiers — each frozen generation costs a
                             kernel launch and a cache lookup per query,
                             so the serving path wants few of them. "Age"
                             is measured in generation ARRIVALS (metas
                             carry no wall-clock timestamps; a generation
                             with many newer siblings is old).
    drift_threshold        : ``IndexMeta.drift`` above this marks a
                             generation's quantization stale and triggers
                             re-epoching of the tail from the first such
                             generation (the ~1.5 rule of thumb from
                             ``IndexMeta.drift``).
    """

    merge_factor: int = 4
    max_frozen_generations: int = 8
    drift_threshold: float = 1.5

    def __post_init__(self):
        if self.merge_factor < 2:
            raise ValueError(
                f"merge_factor={self.merge_factor} < 2: a merge must "
                "combine at least two generations")
        if self.max_frozen_generations < 1:
            raise ValueError(
                f"max_frozen_generations={self.max_frozen_generations} "
                "< 1: the timeline always has at least the open "
                "generation")
        if self.drift_threshold <= 1.0:
            raise ValueError(
                f"drift_threshold={self.drift_threshold} <= 1.0: drift "
                "is a ratio with baseline 1.0 (no drift); a threshold "
                "at or below it would retrain forever")

    def tier(self, n_docs: int) -> int:
        """Size tier of a generation: ``floor(log_merge_factor(n_docs))``."""
        return int(math.floor(
            math.log(max(n_docs, 1)) / math.log(self.merge_factor)))

    def decide(self, timeline: Timeline) -> Optional[MaintenanceAction]:
        """Inspect a timeline and return the next action, or ``None`` when
        it is in shape.

        Checks in priority order over the NEWEST epoch (older epochs are
        already compacted, retrained artifacts):

        1. **drift** — any generation over ``drift_threshold`` means the
           epoch's codebooks no longer fit the data arriving since; the
           tail from the FIRST such generation (including the open one —
           its docs were quantized by the same stale codebooks) is
           re-epoched. Retrain outranks merge: compacting drifted
           generations would only bake the bad quantization into a bigger
           artifact.
        2. **hierarchical merge** — the earliest run of ``merge_factor``
           adjacent same-tier FROZEN generations compacts into one.
        3. **size bound** — more than ``max_frozen_generations`` frozen
           generations (tiers notwithstanding) compacts the oldest
           ``merge_factor`` (at least two).

        One action per call: apply it, then call ``decide`` again — merges
        cascade naturally (a merged generation may complete a run in the
        next tier up).
        """
        tl = EpochedTimeline.of(timeline).epochs[-1]
        n = len(tl)

        for lo, meta in enumerate(tl.metas):
            if meta.drift > self.drift_threshold:
                return MaintenanceAction(
                    "reepoch", lo, n,
                    f"generation {lo} drift {meta.drift:.2f} > "
                    f"{self.drift_threshold:g}: frozen codebooks no "
                    "longer fit, rebuilding tail with fresh ones")

        frozen = tl.metas[:-1]
        tiers = [self.tier(m.n_docs) for m in frozen]
        for i in range(len(frozen) - self.merge_factor + 1):
            run = tiers[i:i + self.merge_factor]
            if all(t == run[0] for t in run):
                return MaintenanceAction(
                    "merge", i, i + self.merge_factor,
                    f"{self.merge_factor} adjacent tier-{run[0]} frozen "
                    f"generations at [{i}, {i + self.merge_factor}): "
                    "hierarchical compaction")

        if len(frozen) > self.max_frozen_generations:
            hi = max(2, min(self.merge_factor, len(frozen)))
            return MaintenanceAction(
                "merge", 0, hi,
                f"{len(frozen)} frozen generations > bound "
                f"{self.max_frozen_generations}: compacting the oldest "
                f"{hi}")

        return None


def reepoch_tail(timeline: Timeline, lo: int, doc_embs: np.ndarray,
                 doc_lens: np.ndarray, *, key: jax.Array,
                 **build_kwargs) -> EpochedTimeline:
    """Rebuild the newest epoch's generations ``[lo:]`` with FRESH codebooks,
    opening a new epoch.

    The drifted tail's raw embeddings (``doc_embs`` (n, cap, d) zero-padded,
    ``doc_lens`` (n,) — the docs of generations ``[lo:]`` in timeline
    order) go through a full :func:`~repro.core.index.build_index`:
    re-trained centroids and PQ codebooks quantize them losslessly-fresh
    (drift resets to 1.0). Geometry (``n_centroids``/``m``/``nbits``/
    ``plaid_b``) AND the document budget (``doc_budget``) default to the
    old epoch's and are overridable through ``build_kwargs``. A budgeted
    epoch takes RAW embeddings at any cap (the fetcher never sees pooled
    vectors — the index doesn't store raw ones either way): they are
    pooled deterministically, validated against the recorded pooled
    lengths, and re-encoded under the fresh codebooks.

    **Global ids are preserved by construction**: only a SUFFIX is ever
    rebuilt, in corpus order, so doc ``i`` of the old timeline is doc ``i``
    of the new one — which is exactly what keeps result-cache entries
    (storing global ids) and downstream references valid across the swap.
    The truncated old epoch keeps its generations' fingerprints, so their
    cache entries stay warm too.

    -> the new :class:`EpochedTimeline`: old epochs unchanged, newest epoch
    truncated to ``[:lo]`` (dropped entirely when ``lo == 0``), plus a new
    single-generation epoch holding the rebuilt tail. Scores from the new
    epoch are not bit-comparable to the old ones — ``retrieve_timeline``
    merges across epochs by rank (``merge_partial_topk_by_rank``).
    """
    et = EpochedTimeline.of(timeline)
    tl = et.epochs[-1]
    if not isinstance(lo, int) or isinstance(lo, bool):
        raise TypeError(f"lo must be an int, got {type(lo).__name__}")
    if not 0 <= lo < len(tl):
        raise ValueError(
            f"lo={lo} out of range for a {len(tl)}-generation epoch: "
            "the rebuilt tail [lo:] must be non-empty")

    tail_docs = sum(m.n_docs for m in tl.metas[lo:])
    embs = np.asarray(doc_embs, dtype=np.float32)
    lens = np.asarray(doc_lens)
    meta0 = tl.metas[0]
    # the document budget is part of the epoch's representation contract
    # and carries into the rebuilt epoch unless explicitly overridden
    kwargs = dict(n_centroids=meta0.n_centroids, m=meta0.m,
                  nbits=meta0.nbits, plaid_b=meta0.plaid_b,
                  doc_budget=meta0.doc_budget)
    kwargs.update(build_kwargs)
    budgeted = meta0.doc_budget is not None or \
        kwargs["doc_budget"] is not None
    if embs.ndim != 3 or embs.shape[2] != meta0.d or \
            (not budgeted and embs.shape[1] != meta0.cap):
        raise ValueError(
            f"doc_embs has shape {embs.shape}: expected "
            f"(n, cap={meta0.cap}, d={meta0.d}) matching the epoch"
            + (" (a budgeted epoch accepts RAW docs at any cap; they are "
               "pooled down)" if budgeted else ""))
    if embs.shape[0] != tail_docs:
        raise ValueError(
            f"doc_embs has {embs.shape[0]} docs but generations "
            f"[{lo}:{len(tl)}) hold {tail_docs}: re-epoching must rebuild "
            "EXACTLY the tail slice (global ids depend on it)")
    want_lens = np.concatenate(
        [np.asarray(g.doc_lens) for g in tl.generations[lo:]])
    if meta0.doc_budget is None:
        check_lens = lens
    elif kwargs["doc_budget"] == meta0.doc_budget:
        # recorded lengths are POOLED lengths: pool the supplied raw docs
        # the same deterministic way and compare those
        check_lens = pool_documents(embs, lens, meta0.doc_budget)[1]
    else:
        check_lens = None   # budget override re-pools; lengths can't match
    if check_lens is not None and not np.array_equal(check_lens, want_lens):
        raise ValueError(
            "doc_lens do not match the tail generations' recorded "
            "lengths: the supplied embeddings are not the same docs "
            "(global-id stability would silently break)")
    index, meta = build_index(key, embs, lens, **kwargs)
    fresh = ShardedTimeline((index,), (meta,))

    if lo == 0:
        return et.with_newest_epoch(fresh)
    truncated = ShardedTimeline(tl.generations[:lo], tl.metas[:lo])
    return EpochedTimeline(et.epochs[:-1] + (truncated,)).append_epoch(fresh)


class MaintenanceRunner:
    """Drives the policy against a live :class:`~repro.serving.service
    .RetrievalService` — the glue between deciding and serving.

    ``run_once()`` is cooperative like everything else in the serving loop:
    call it between flushes (e.g. alongside ``poll()``). Each applied
    action builds the new timeline OFF the serving path and installs it via
    ``service.update_timeline`` — the double-buffered swap — so queries
    keep being answered throughout; actions compose on
    ``service.latest_timeline`` (the staged snapshot when one is waiting),
    never on a stale view.
    """

    def __init__(self, service, policy: Optional[MaintenancePolicy] = None,
                 *, fetch_embeddings: Optional[EmbeddingFetcher] = None,
                 build_key: Optional[jax.Array] = None,
                 build_kwargs: Optional[dict] = None, max_actions: int = 4):
        """``service``: the RetrievalService to maintain. ``policy``:
        decision thresholds (defaults). ``fetch_embeddings``: raw-embedding
        source for re-epoching, ``(global_start, global_stop) -> (embs,
        lens)`` — required before any reepoch action can apply.
        ``build_key``: PRNG key for re-epoch ``build_index`` calls (split
        per action). ``build_kwargs``: geometry overrides forwarded to
        :func:`reepoch_tail`. ``max_actions``: cap per ``run_once`` (merges
        cascade; this bounds one call's work)."""
        self.service = service
        self.policy = policy if policy is not None else MaintenancePolicy()
        self.fetch_embeddings = fetch_embeddings
        self._key = build_key if build_key is not None \
            else jax.random.PRNGKey(0)
        self.build_kwargs = dict(build_kwargs) if build_kwargs else {}
        self.max_actions = int(max_actions)

    def run_once(self) -> list[MaintenanceAction]:
        """Decide-and-apply until the policy is satisfied (or
        ``max_actions`` hit); -> the actions applied, oldest first."""
        applied: list[MaintenanceAction] = []
        while len(applied) < self.max_actions:
            et = EpochedTimeline.of(self.service.latest_timeline)
            with trace.span("maintenance.decide") as dsp:
                action = self.policy.decide(et)
                dsp.set(kind=action.kind if action else None)
            if action is None:
                break
            if action.kind == "merge":
                with trace.span("maintenance.merge", lo=action.lo,
                                hi=action.hi):
                    new_tl = merge_generations(et.epochs[-1], action.lo,
                                               action.hi)
                    self.service.update_timeline(
                        et.with_newest_epoch(new_tl))
            else:
                if self.fetch_embeddings is None:
                    raise RuntimeError(
                        f"maintenance wants to re-epoch ({action.reason}) "
                        "but no fetch_embeddings was configured: re-"
                        "training needs the raw embeddings, which the "
                        "index does not store — construct the "
                        "MaintenanceRunner with fetch_embeddings=")
                tl = et.epochs[-1]
                start = et.epoch_offsets[-1] + tl.offsets[action.lo]
                stop = start + sum(m.n_docs for m in tl.metas[action.lo:])
                with trace.span("maintenance.reepoch", lo=action.lo,
                                docs=stop - start):
                    embs, lens = self.fetch_embeddings(start, stop)
                    self._key, sub = jax.random.split(self._key)
                    self.service.update_timeline(
                        reepoch_tail(et, action.lo, embs, lens, key=sub,
                                     **self.build_kwargs))
            self.service.metrics.record_maintenance(action.kind)
            applied.append(action)
        return applied
