"""EMVB serving subsystem: per-generation result caching + micro-batching.

The service loop over a ``repro.core.store.ShardedTimeline``:
:class:`RetrievalService` (the façade), :class:`ResultCache` (per-
immutable-generation partial top-k, LRU under a byte budget),
:class:`MicroBatcher` (size/deadline batching with PR 3's pad+mask
machinery) and :class:`ServiceMetrics` (hit rate, warm/cold split,
p50/p99 latency, byte accounting). See docs/SERVING.md.
"""
from .batcher import MicroBatcher, Ticket, pad_query  # noqa: F401
from .cache import ResultCache, config_fingerprint, query_fingerprint  # noqa: F401
from .metrics import LatencyStats, ServiceMetrics  # noqa: F401
from .service import RetrievalService  # noqa: F401
