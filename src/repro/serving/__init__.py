"""EMVB serving subsystem: caching, micro-batching, online maintenance.

The service loop over a ``repro.core.store.ShardedTimeline`` (or, once
re-epoching opens codebook epochs, an ``EpochedTimeline``):
:class:`RetrievalService` (the façade, double-buffered timeline hot
swap), :class:`ResultCache` (per-immutable-generation partial top-k, LRU
under a byte budget), :class:`MicroBatcher` (size/deadline batching with
PR 3's pad+mask machinery), :class:`ServiceMetrics` (hit rate, warm/cold
split, p50/p99 latency, maintenance counters, byte accounting) and the
maintenance loop (:class:`MaintenancePolicy` deciding generation
compaction vs drift-triggered re-epoching, :class:`MaintenanceRunner`
applying it off the serving path). See docs/SERVING.md and
docs/MAINTENANCE.md.
"""
from .batcher import MicroBatcher, Ticket, pad_query  # noqa: F401
from .cache import ResultCache, config_fingerprint, query_fingerprint  # noqa: F401
from .maintenance import (MaintenanceAction, MaintenancePolicy,  # noqa: F401
                          MaintenanceRunner, reepoch_tail)
from .metrics import LatencyStats, ServiceMetrics  # noqa: F401
from .service import RetrievalService  # noqa: F401
