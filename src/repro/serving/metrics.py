"""Serving telemetry: warm/cold traffic split, latency percentiles, and the
byte accounting (cache occupancy + timeline footprint) in one snapshot.

A query is **warm** when every cacheable (immutable) generation's partial
was a cache hit — only the newest, still-mutable generation was computed —
and **cold** otherwise. The split is the cache's effectiveness measured in
requests rather than lookups: a Zipf-repeated stream should go warm almost
immediately (benchmarks/fig8_serving.py tracks exactly that), while a
stream of distinct queries stays cold no matter how large the cache.

Latency is recorded per flushed batch into bounded reservoirs
(:class:`LatencyStats`), reported as p50/p95/p99/max — the numbers a
capacity plan actually budgets against, not means. The snapshot also folds
in ``repro.core.store.timeline_footprint`` (per-generation bytes +
manifest overhead; ROADMAP's `bytes_per_embedding`-for-the-timeline item)
next to the cache's byte occupancy, so one dict answers "what does this
service cost in memory and what latency does it buy".

Since the observability PR, :class:`ServiceMetrics` is built on the
instrument registry (:class:`repro.obs.registry.MetricsRegistry`): every
counter is a registered ``Counter``, the reservoirs export as
``Summary`` quantiles, and subsystems ADD instruments by registering them
instead of editing ``snapshot()``. Two renderings of the same registry:
``snapshot()`` keeps the historical JSON dict shape (tests pin it), and
``exposition()`` renders the Prometheus text format that
``scripts/check_metrics_exposition.py`` lints in CI. The historical
attribute reads (``metrics.warm_queries`` etc.) survive as read-only
properties over the registered counters.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.obs.registry import MetricsRegistry

# timeline-footprint keys every producer must supply (the core byte
# accounting repro.core.store.timeline_footprint has emitted since PR 4) …
REQUIRED_FOOTPRINT_KEYS = (
    "n_generations", "n_docs", "n_tokens", "index_bytes", "manifest_bytes",
    "total_bytes", "predicate_bytes", "bytes_per_embedding",
    "bytes_per_embedding_actual")
# … and the genuinely optional ones, passed through when present:
# pooling accounting exists only for producers aware of document budgets
# (PR 9), n_epochs only for epoched timelines (PR 6).
OPTIONAL_FOOTPRINT_KEYS = (
    "n_raw_tokens", "doc_budget", "bytes_per_doc", "unpooled_bytes_per_doc",
    "pooling_savings", "n_epochs")


class LatencyStats:
    """Bounded-reservoir latency recorder with percentile readout.

    Keeps the most recent ``window`` samples (a ring buffer): long-running
    services would otherwise grow an unbounded sample list, and recent
    samples are the ones a serving dashboard wants anyway. ``count`` and
    ``total_s`` stay cumulative over ALL samples.

    **Ring-wrap semantics** (tests/test_serving.py pins them): the write
    cursor wraps at ``window``, overwriting oldest-first, so once
    ``count > window`` the buffer holds exactly the most recent ``window``
    samples — in scrambled storage order, which percentiles and max are
    insensitive to. ``percentile``/``max`` therefore read
    ``samples[:min(count, window)]``: the filled prefix before the first
    wrap, the entire ring after it. Quantiles computed this way are over a
    sliding window, not all history — by design (``mean_ms`` is the one
    all-history statistic, from the cumulative ``total_s``).
    """

    def __init__(self, window: int = 4096):
        """``window``: number of most-recent samples percentiles see."""
        self._window = int(window)
        self._samples = np.zeros(self._window, dtype=np.float64)
        self._next = 0
        self.count = 0
        self.total_s = 0.0

    def record(self, seconds: float) -> None:
        """Record one latency sample (seconds)."""
        self._samples[self._next] = seconds
        self._next = (self._next + 1) % self._window
        self.count += 1
        self.total_s += seconds

    def percentile(self, pct: float) -> float:
        """The ``pct``-th percentile (seconds) over the most recent
        ``min(count, window)`` samples; 0.0 before the first sample."""
        n = min(self.count, self._window)
        if n == 0:
            return 0.0
        return float(np.percentile(self._samples[:n], pct))

    def max(self) -> float:
        """The maximum (seconds) over the same window ``percentile``
        sees; 0.0 before the first sample."""
        n = min(self.count, self._window)
        if n == 0:
            return 0.0
        return float(np.max(self._samples[:n]))

    def snapshot(self) -> dict:
        """count / mean / p50 / p95 / p99 / max, milliseconds for the
        readable fields (mean over ALL samples, quantiles+max over the
        window)."""
        return {
            "count": self.count,
            "mean_ms": (self.total_s / self.count * 1e3) if self.count
            else 0.0,
            "p50_ms": self.percentile(50) * 1e3,
            "p95_ms": self.percentile(95) * 1e3,
            "p99_ms": self.percentile(99) * 1e3,
            "max_ms": self.max() * 1e3,
        }


class ServiceMetrics:
    """Registry-backed counters + latency reservoirs for one
    :class:`~repro.serving.service.RetrievalService`.

    ``record_batch`` is the single ingestion point: the service calls it
    once per executed batch with the warm/cold split it just observed.
    ``snapshot`` folds in the cache's counters and the timeline's footprint
    so callers get the whole picture from one dict; ``exposition`` renders
    the same registry as Prometheus text. Historical counter attributes
    (``batches``, ``warm_queries``, ``swaps``, …) are read-only properties
    over the registered instruments — mutate through the ``record_*``
    verbs, never by assignment.
    """

    def __init__(self, window: int = 4096,
                 registry: Optional[MetricsRegistry] = None):
        """``window`` sizes every latency reservoir (see LatencyStats);
        ``registry`` lets services share one exposition endpoint
        (instruments are get-or-create, so two ServiceMetrics sharing a
        registry also share counters — usually you want one each)."""
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        r = self.registry
        self._c_batches = r.counter(
            "emvb_batches_total", "Micro-batches executed")
        self._c_queries = r.counter(
            "emvb_queries_total", "Queries served")
        self._c_warm = r.counter(
            "emvb_warm_queries_total",
            "Queries whose cacheable partials all cache-hit")
        self._c_cold = r.counter(
            "emvb_cold_queries_total",
            "Queries that computed at least one cacheable partial")
        # predicate-filtered vs unfiltered traffic (docs/FILTERING.md):
        # filtered queries hit a different cache-key space (the filter
        # fingerprint joins the config fingerprint), so their warm share
        # ramps independently — the split makes that visible
        self._c_filtered = r.counter(
            "emvb_filtered_queries_total",
            "Queries served under a predicate filter")
        self._c_unfiltered = r.counter(
            "emvb_unfiltered_queries_total",
            "Queries served without a predicate filter")
        # maintenance counters (docs/MAINTENANCE.md): timeline snapshot
        # swaps (and how many had to wait for a flush boundary), plus the
        # actions the maintenance loop applied
        self._c_swaps = r.counter(
            "emvb_timeline_swaps_total", "Timeline snapshot swaps installed")
        self._c_deferred = r.counter(
            "emvb_deferred_swaps_total",
            "Swaps staged behind pending queries, installed at a flush "
            "boundary")
        self._c_merges = r.counter(
            "emvb_maintenance_merges_total",
            "Generation compactions applied")
        self._c_reepochs = r.counter(
            "emvb_maintenance_reepochs_total",
            "Drift-triggered codebook rebuilds applied")
        # serving-lane instruments the hand-rolled version never had:
        # the batcher's live queue depth and cumulative deadline misses
        # (bound to the live batcher by RetrievalService via bind_batcher),
        # the per-generation cache hit ratio, and the batch-size histogram
        self._g_queue_depth = r.gauge(
            "emvb_batcher_queue_depth",
            "Queries pending in the micro-batcher")
        self._c_deadline = r.counter(
            "emvb_deadline_misses_total",
            "Queries drained LATER than max_delay_s after submit (the "
            "cooperative poll loop ran behind the deadline promise)")
        self._g_gen_hit_ratio = r.gauge(
            "emvb_generation_cache_hit_ratio",
            "Per-generation result-cache hit ratio (label: generation "
            "content fingerprint, truncated)",
            label_names=("generation",))
        self._h_batch_size = r.histogram(
            "emvb_batch_size", "Executed micro-batch sizes (queries)",
            buckets=(1, 2, 4, 8, 16, 32, 64))
        self.batch_latency = LatencyStats(window)
        self.warm_latency = LatencyStats(window)
        self.cold_latency = LatencyStats(window)
        r.summary("emvb_batch_latency_seconds",
                  "Per-batch wall latency (all batches)",
                  stats=self.batch_latency)
        r.summary("emvb_warm_batch_latency_seconds",
                  "Per-batch wall latency, fully-warm batches",
                  stats=self.warm_latency)
        r.summary("emvb_cold_batch_latency_seconds",
                  "Per-batch wall latency, batches with >= 1 miss",
                  stats=self.cold_latency)
        # per-generation lookup tallies behind the labeled hit-ratio gauge
        self._gen_lookups: dict[str, list] = {}

    # -- historical attribute reads (properties over the registry) ----------

    @property
    def batches(self) -> int:
        """Micro-batches executed."""
        return int(self._c_batches.value())

    @property
    def queries(self) -> int:
        """Queries served."""
        return int(self._c_queries.value())

    @property
    def warm_queries(self) -> int:
        """Queries whose cacheable partials all hit."""
        return int(self._c_warm.value())

    @property
    def cold_queries(self) -> int:
        """Queries that computed at least one cacheable partial."""
        return int(self._c_cold.value())

    @property
    def filtered_queries(self) -> int:
        """Queries served under a predicate filter."""
        return int(self._c_filtered.value())

    @property
    def unfiltered_queries(self) -> int:
        """Queries served without a predicate filter."""
        return int(self._c_unfiltered.value())

    @property
    def swaps(self) -> int:
        """Timeline snapshot swaps installed."""
        return int(self._c_swaps.value())

    @property
    def deferred_swaps(self) -> int:
        """Swaps that waited for a flush boundary."""
        return int(self._c_deferred.value())

    @property
    def merges(self) -> int:
        """Generation compactions applied."""
        return int(self._c_merges.value())

    @property
    def reepochs(self) -> int:
        """Codebook rebuilds applied."""
        return int(self._c_reepochs.value())

    @property
    def deadline_misses(self) -> int:
        """Queries drained later than the deadline promise."""
        return int(self._c_deadline.value())

    # -- ingestion verbs -----------------------------------------------------

    def record_batch(self, n_queries: int, n_warm: int,
                     seconds: float, n_filtered: int = 0) -> None:
        """Record one executed batch: size, how many of its queries were
        warm (all immutable-generation partials cache-hit), wall seconds,
        and how many ran under a predicate filter (a micro-batch is
        homogeneous — all-filtered or all-unfiltered — so ``n_filtered``
        is 0 or ``n_queries`` from the service, but mixed counts are
        accepted for direct callers).

        The batch latency lands in the warm reservoir only when the WHOLE
        batch was warm (mixed batches pay the miss lane's compute, which is
        cold-path latency by any honest accounting).
        """
        self._c_batches.inc()
        self._c_queries.inc(n_queries)
        self._c_warm.inc(n_warm)
        self._c_cold.inc(n_queries - n_warm)
        self._c_filtered.inc(n_filtered)
        self._c_unfiltered.inc(n_queries - n_filtered)
        self._h_batch_size.observe(n_queries)
        self.batch_latency.record(seconds)
        if n_warm == n_queries:
            self.warm_latency.record(seconds)
        else:
            self.cold_latency.record(seconds)

    def record_swap(self, deferred: bool = False) -> None:
        """Record one installed timeline snapshot swap; ``deferred=True``
        when the swap was staged behind pending queries and applied at the
        next flush boundary (the double-buffered hot-swap path)."""
        self._c_swaps.inc()
        if deferred:
            self._c_deferred.inc()

    def record_maintenance(self, kind: str) -> None:
        """Record one applied maintenance action: ``"merge"`` (generation
        compaction) or ``"reepoch"`` (drift-triggered codebook rebuild)."""
        if kind == "merge":
            self._c_merges.inc()
        elif kind == "reepoch":
            self._c_reepochs.inc()
        else:
            raise ValueError(
                f"unknown maintenance action kind {kind!r}: expected "
                "'merge' or 'reepoch'")

    def record_deadline_misses(self, n: int) -> None:
        """Add ``n`` deadline misses (standalone use; a service binds the
        batcher's own cumulative counter instead — ``bind_batcher``)."""
        self._c_deadline.inc(n)

    def set_queue_depth(self, n: int) -> None:
        """Set the batcher queue-depth gauge (standalone use; a service
        binds the live batcher instead — ``bind_batcher``)."""
        self._g_queue_depth.set(n)

    def bind_batcher(self, batcher) -> None:
        """Bind the queue-depth gauge and deadline-miss counter to a live
        :class:`~repro.serving.batcher.MicroBatcher` — values are read
        from the batcher at snapshot/exposition time instead of being
        mirrored on the hot path. Called by ``RetrievalService.__init__``
        (latest binding wins; metrics are per-service by contract)."""
        self._g_queue_depth.bind(lambda: len(batcher))
        self._c_deadline.bind(lambda: batcher.deadline_misses)

    def record_generation_lookups(self, generation_fp: str, hits: int,
                                  misses: int) -> None:
        """Accumulate one batch's cache lookups for one immutable
        generation (keyed by content fingerprint, truncated to 12 hex
        chars for label cardinality) and refresh its hit-ratio gauge."""
        key = generation_fp[:12]
        tally = self._gen_lookups.setdefault(key, [0, 0])
        tally[0] += hits
        tally[1] += misses
        total = tally[0] + tally[1]
        self._g_gen_hit_ratio.set(
            tally[0] / total if total else 0.0, generation=key)

    # -- renderings ----------------------------------------------------------

    def _timeline_section(self, timeline_footprint: dict) -> dict:
        """Validate and trim a footprint dict for the snapshot: the
        required byte-accounting keys must ALL be present (a partial dict
        means the producer is not ``repro.core.store.timeline_footprint``
        and the capacity numbers would silently lie); optional keys pass
        through when present."""
        missing = [k for k in REQUIRED_FOOTPRINT_KEYS
                   if k not in timeline_footprint]
        if missing:
            raise KeyError(
                f"timeline_footprint is missing required keys {missing}: "
                "pass the dict produced by repro.core.store."
                "timeline_footprint(timeline) (generation-level or "
                "hand-built dicts lack the timeline rollup; optional "
                f"keys are {list(OPTIONAL_FOOTPRINT_KEYS)})")
        out = {k: timeline_footprint[k] for k in REQUIRED_FOOTPRINT_KEYS}
        out.update({k: timeline_footprint[k] for k in OPTIONAL_FOOTPRINT_KEYS
                    if k in timeline_footprint})
        return out

    def snapshot(self, cache=None,
                 timeline_footprint: Optional[dict] = None) -> dict:
        """One flat-ish dict: traffic counters, warm share, latency
        percentiles, batcher depth/deadline misses, per-generation cache
        hit ratios, plus ``cache`` stats (a ``ResultCache``) and the
        ``timeline`` footprint when provided (all
        :data:`REQUIRED_FOOTPRINT_KEYS` must be present — missing ones
        raise ``KeyError`` rather than silently dropping byte
        accounting)."""
        queries = self.queries
        out = {
            "batches": self.batches,
            "queries": queries,
            "warm_queries": self.warm_queries,
            "cold_queries": self.cold_queries,
            "warm_fraction": (self.warm_queries / queries
                              if queries else 0.0),
            "filtered_queries": self.filtered_queries,
            "unfiltered_queries": self.unfiltered_queries,
            "latency": self.batch_latency.snapshot(),
            "warm_latency": self.warm_latency.snapshot(),
            "cold_latency": self.cold_latency.snapshot(),
            "maintenance": {
                "swaps": self.swaps,
                "deferred_swaps": self.deferred_swaps,
                "merges": self.merges,
                "reepochs": self.reepochs,
            },
            "batcher": {
                "queue_depth": int(self._g_queue_depth.value()),
                "deadline_misses": self.deadline_misses,
            },
            "generations": {
                fp: {"hits": h, "misses": m,
                     "hit_ratio": h / (h + m) if h + m else 0.0}
                for fp, (h, m) in self._gen_lookups.items()
            },
        }
        if cache is not None:
            out["cache"] = cache.stats()
        if timeline_footprint is not None:
            out["timeline"] = self._timeline_section(timeline_footprint)
        return out

    def exposition(self, cache=None,
                   timeline_footprint: Optional[dict] = None) -> str:
        """The registry rendered as Prometheus text exposition
        (``scripts/check_metrics_exposition.py`` lints the format).

        ``cache`` (a ``ResultCache``) binds its cumulative counters and
        occupancy as callback-backed instruments; ``timeline_footprint``
        (validated like ``snapshot``) sets the timeline byte gauges. Both
        register on first use, so a bare ServiceMetrics exposes only its
        own instruments.
        """
        r = self.registry
        if cache is not None:
            r.counter("emvb_cache_hits_total",
                      "Result-cache hits").bind(lambda: cache.hits)
            r.counter("emvb_cache_misses_total",
                      "Result-cache misses").bind(lambda: cache.misses)
            r.counter("emvb_cache_evictions_total",
                      "Result-cache LRU evictions").bind(
                          lambda: cache.evictions)
            r.gauge("emvb_cache_bytes",
                    "Result-cache occupancy (payload bytes)").bind(
                        lambda: cache.bytes)
            r.gauge("emvb_cache_entries",
                    "Result-cache entries").bind(lambda: len(cache))
        if timeline_footprint is not None:
            fp = self._timeline_section(timeline_footprint)
            r.gauge("emvb_timeline_generations",
                    "Generations in the served timeline").set(
                        fp["n_generations"])
            r.gauge("emvb_timeline_docs",
                    "Documents in the served timeline").set(fp["n_docs"])
            r.gauge("emvb_timeline_total_bytes",
                    "Timeline footprint incl. manifests (bytes)").set(
                        fp["total_bytes"])
            r.gauge("emvb_timeline_bytes_per_embedding",
                    "Nominal bytes per embedding (paper Table 1 "
                    "accounting)").set(fp["bytes_per_embedding"])
        return r.exposition()
