"""Serving telemetry: warm/cold traffic split, latency percentiles, and the
byte accounting (cache occupancy + timeline footprint) in one snapshot.

A query is **warm** when every cacheable (immutable) generation's partial
was a cache hit — only the newest, still-mutable generation was computed —
and **cold** otherwise. The split is the cache's effectiveness measured in
requests rather than lookups: a Zipf-repeated stream should go warm almost
immediately (benchmarks/fig8_serving.py tracks exactly that), while a
stream of distinct queries stays cold no matter how large the cache.

Latency is recorded per flushed batch into bounded reservoirs
(:class:`LatencyStats`), reported as p50/p99 — the numbers a capacity plan
actually budgets against, not means. The snapshot also folds in
``repro.core.store.timeline_footprint`` (per-generation bytes + manifest
overhead; ROADMAP's `bytes_per_embedding`-for-the-timeline item) next to
the cache's byte occupancy, so one dict answers "what does this service
cost in memory and what latency does it buy".
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class LatencyStats:
    """Bounded-reservoir latency recorder with percentile readout.

    Keeps the most recent ``window`` samples (a ring buffer): long-running
    services would otherwise grow an unbounded sample list, and recent
    samples are the ones a serving dashboard wants anyway. ``count`` and
    ``total_s`` stay cumulative over ALL samples.
    """

    def __init__(self, window: int = 4096):
        """``window``: number of most-recent samples percentiles see."""
        self._window = int(window)
        self._samples = np.zeros(self._window, dtype=np.float64)
        self._next = 0
        self.count = 0
        self.total_s = 0.0

    def record(self, seconds: float) -> None:
        """Record one latency sample (seconds)."""
        self._samples[self._next] = seconds
        self._next = (self._next + 1) % self._window
        self.count += 1
        self.total_s += seconds

    def percentile(self, pct: float) -> float:
        """The ``pct``-th percentile (seconds) over the sample window; 0.0
        before the first sample."""
        n = min(self.count, self._window)
        if n == 0:
            return 0.0
        return float(np.percentile(self._samples[:n], pct))

    def snapshot(self) -> dict:
        """count / mean / p50 / p99, milliseconds for the readable fields."""
        return {
            "count": self.count,
            "mean_ms": (self.total_s / self.count * 1e3) if self.count
            else 0.0,
            "p50_ms": self.percentile(50) * 1e3,
            "p99_ms": self.percentile(99) * 1e3,
        }


class ServiceMetrics:
    """Counters + latency reservoirs for one :class:`~repro.serving.service
    .RetrievalService`.

    ``record_batch`` is the single ingestion point: the service calls it
    once per executed batch with the warm/cold split it just observed.
    ``snapshot`` folds in the cache's counters and the timeline's footprint
    so callers get the whole picture from one dict.
    """

    def __init__(self, window: int = 4096):
        """``window`` sizes every latency reservoir (see LatencyStats)."""
        self.batches = 0
        self.queries = 0
        self.warm_queries = 0
        self.cold_queries = 0
        # predicate-filtered vs unfiltered traffic (docs/FILTERING.md):
        # filtered queries hit a different cache-key space (the filter
        # fingerprint joins the config fingerprint), so their warm share
        # ramps independently — the split makes that visible
        self.filtered_queries = 0
        self.unfiltered_queries = 0
        self.batch_latency = LatencyStats(window)
        self.warm_latency = LatencyStats(window)
        self.cold_latency = LatencyStats(window)
        # maintenance counters (docs/MAINTENANCE.md): timeline snapshot
        # swaps (and how many had to wait for a flush boundary), plus the
        # actions the maintenance loop applied
        self.swaps = 0
        self.deferred_swaps = 0
        self.merges = 0
        self.reepochs = 0

    def record_batch(self, n_queries: int, n_warm: int,
                     seconds: float, n_filtered: int = 0) -> None:
        """Record one executed batch: size, how many of its queries were
        warm (all immutable-generation partials cache-hit), wall seconds,
        and how many ran under a predicate filter (a micro-batch is
        homogeneous — all-filtered or all-unfiltered — so ``n_filtered``
        is 0 or ``n_queries`` from the service, but mixed counts are
        accepted for direct callers).

        The batch latency lands in the warm reservoir only when the WHOLE
        batch was warm (mixed batches pay the miss lane's compute, which is
        cold-path latency by any honest accounting).
        """
        self.batches += 1
        self.queries += n_queries
        self.warm_queries += n_warm
        self.cold_queries += n_queries - n_warm
        self.filtered_queries += n_filtered
        self.unfiltered_queries += n_queries - n_filtered
        self.batch_latency.record(seconds)
        if n_warm == n_queries:
            self.warm_latency.record(seconds)
        else:
            self.cold_latency.record(seconds)

    def record_swap(self, deferred: bool = False) -> None:
        """Record one installed timeline snapshot swap; ``deferred=True``
        when the swap was staged behind pending queries and applied at the
        next flush boundary (the double-buffered hot-swap path)."""
        self.swaps += 1
        if deferred:
            self.deferred_swaps += 1

    def record_maintenance(self, kind: str) -> None:
        """Record one applied maintenance action: ``"merge"`` (generation
        compaction) or ``"reepoch"`` (drift-triggered codebook rebuild)."""
        if kind == "merge":
            self.merges += 1
        elif kind == "reepoch":
            self.reepochs += 1
        else:
            raise ValueError(
                f"unknown maintenance action kind {kind!r}: expected "
                "'merge' or 'reepoch'")

    def snapshot(self, cache=None,
                 timeline_footprint: Optional[dict] = None) -> dict:
        """One flat-ish dict: traffic counters, warm share, latency
        percentiles, plus ``cache`` stats (a ``ResultCache``) and the
        ``timeline`` footprint when provided."""
        out = {
            "batches": self.batches,
            "queries": self.queries,
            "warm_queries": self.warm_queries,
            "cold_queries": self.cold_queries,
            "warm_fraction": (self.warm_queries / self.queries
                              if self.queries else 0.0),
            "filtered_queries": self.filtered_queries,
            "unfiltered_queries": self.unfiltered_queries,
            "latency": self.batch_latency.snapshot(),
            "warm_latency": self.warm_latency.snapshot(),
            "cold_latency": self.cold_latency.snapshot(),
            "maintenance": {
                "swaps": self.swaps,
                "deferred_swaps": self.deferred_swaps,
                "merges": self.merges,
                "reepochs": self.reepochs,
            },
        }
        if cache is not None:
            out["cache"] = cache.stats()
        if timeline_footprint is not None:
            out["timeline"] = {
                k: timeline_footprint[k]
                for k in ("n_generations", "n_docs", "n_tokens",
                          "index_bytes", "manifest_bytes", "total_bytes",
                          "predicate_bytes", "bytes_per_embedding",
                          "bytes_per_embedding_actual",
                          # constant-space accounting (docs/ARCHITECTURE.md
                          # pooling stage): what the doc_budget saves vs
                          # the per-token counterfactual
                          "n_raw_tokens", "doc_budget", "bytes_per_doc",
                          "unpooled_bytes_per_doc", "pooling_savings")
                if k in timeline_footprint
            }
        return out
