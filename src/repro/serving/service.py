"""`RetrievalService` — the serving façade over a timeline of generations.

Turns the one-shot :func:`repro.core.engine.retrieve_timeline` into a
service loop:

* queries arrive one at a time (``submit``/``flush``/``poll``, micro-
  batched by ``repro.serving.batcher``) or as ready-made batches
  (``query``);
* per generation, the batch splits into a **cache-hit lane** (partials
  served from ``repro.serving.cache``, host memory, no compute) and a
  **cache-miss lane** (partials computed by the generation's execution
  plan — the single-device engine by default, or a shard_map plan from
  ``repro.launch.serve.make_service``), so the expensive candidate-
  generation phases run for misses only;
* the per-generation partials merge through the same
  :func:`repro.core.engine.merge_partial_topk` the uncached path uses —
  and, when drift-triggered re-epoching has opened codebook epochs
  (``repro.serving.maintenance``), per-epoch results merge by RANK
  through :func:`repro.core.engine.merge_partial_topk_by_rank`, exactly
  as ``retrieve_timeline`` does.

The contract (tests/test_serving.py): ``RetrievalService(timeline,
cfg).query(q) == retrieve_timeline(timeline, q, cfg)`` — ids AND score
bits — cold and warm, across both candidate modes, both megakernels,
masked/pruned queries, and across ``add_passages``/``new_generation``
mutations. It holds because (a) an immutable generation's partial is a
pure function of (query bytes, generation fingerprint, config), (b) the
engine is bit-invariant to batch composition (a miss-lane sub-batch
scores a query exactly as the full batch does), and (c) cached and fresh
partials merge through one shared merge definition.

Mutations are functional, like the store they wrap: ``add_passages`` grows
the NEWEST generation (new fingerprint -> its never-cached partials are
recomputed; older generations keep their cache entries), and
``new_generation`` freezes the current newest — whose partials become
cacheable from the next query on — and opens a fresh one.

**Hot swap (double-buffered).** ``update_timeline`` builds the new
snapshot's per-generation plans FIRST, while the current snapshot keeps
serving, then swaps one reference atomically. If queries are pending in
the micro-batcher the swap is STAGED and applied when the batcher drains
(end of the next ``flush``): a submitted query is always answered against
the snapshot it was accepted under. Maintenance (compaction /
re-epoching, ``repro.serving.maintenance``) rides this path: merged or
re-epoched generations carry new content fingerprints and recompute,
untouched generations keep their fingerprints AND their warm cache
entries across the swap — invalidation by construction, no flush.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitvector, store
from repro.core.engine import (EngineConfig, QueryBatch, RetrievalResult,
                               merge_partial_topk, merge_partial_topk_by_rank,
                               retrieve_generation_topk)
from repro.core.store import EpochedTimeline, ShardedTimeline
from repro.obs import trace

from .batcher import MicroBatcher, Ticket, pad_query
from .cache import ResultCache, config_fingerprint, query_fingerprint
from .metrics import ServiceMetrics

# A generation's execution plan: (queries (B, n_q, d), q_masks (B, n_q)) ->
# partial top-k with doc ids GLOBAL within its epoch. A PlanFactory builds
# one per generation for a given (one-epoch) timeline; the service invokes
# it once per epoch, so factories written for plain timelines keep working.
# Filtered queries call the plan with a THIRD positional argument (the
# compiled FilterPlan); plans that predate filtering keep working for
# unfiltered traffic (the service only passes the third argument when a
# filter is set — a 2-arg plan receiving a filtered query fails with a
# plain TypeError, the honest signal that the plan can't filter).
Plan = Callable[[jax.Array, jax.Array], RetrievalResult]
PlanFactory = Callable[[ShardedTimeline], "list[Plan]"]

Timeline = Union[ShardedTimeline, EpochedTimeline]


class RetrievalService:
    """Cached, micro-batched retrieval over an immutable-generation timeline.

    One instance owns a timeline snapshot, a result cache, a micro-batcher
    and its metrics. Single-threaded by design: deadlines are enforced
    cooperatively through ``poll()`` (docs/SERVING.md discusses why that is
    the right shape for a jit-dispatch loop), and the staged timeline swap
    relies on the same discipline — "atomically between flushes" means no
    batch is ever computed against a half-installed snapshot.
    """

    def __init__(self, timeline: Timeline,
                 cfg: Optional[EngineConfig] = None, *,
                 cache: Optional[ResultCache] = None,
                 metrics: Optional[ServiceMetrics] = None,
                 max_batch: int = 16, max_delay_s: float = 0.002,
                 plan_factory: Optional[PlanFactory] = None,
                 pad_miss_lane: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        """Build a service over ``timeline`` (a ``ShardedTimeline`` or an
        ``EpochedTimeline``).

        cfg           : retrieval configuration (default ``EngineConfig()``);
                        hashed into every cache key.
        cache         : injectable :class:`ResultCache` (fresh 64 MiB LRU by
                        default). Share one across services ONLY if they use
                        the same cfg AND execution plan.
        metrics       : injectable :class:`ServiceMetrics`.
        max_batch     : micro-batch size trigger.
        max_delay_s   : micro-batch deadline trigger (from the oldest
                        pending submit).
        plan_factory  : one-epoch timeline -> per-generation execution
                        plans; defaults to the single-device engine
                        (:func:`~repro.core.engine.retrieve_generation_topk`
                        per generation). ``repro.launch.serve.make_service``
                        injects shard_map plans here. Invoked once per
                        epoch on every swap.
        pad_miss_lane : pad the miss lane to the full batch size (repeating
                        its first row) so every flush reuses ONE compiled
                        shape per generation config instead of recompiling
                        per miss count. Compute cost is the cold path's
                        either way; padding only trades FLOPs for compiles.
        clock         : injectable monotonic clock (deadlines + latency).
        """
        self.cfg = cfg if cfg is not None else EngineConfig()
        self.cache = cache if cache is not None else ResultCache()
        self.metrics = metrics if metrics is not None else ServiceMetrics()
        self.pad_miss_lane = pad_miss_lane
        self.clock = clock
        # overwritten at every install with the snapshot's document budget
        # folded in (see _install); pre-set so a failed first _prepare
        # leaves a coherent object
        self._doc_budget = None
        self._cfg_fp = config_fingerprint(self.cfg)
        # per-filter config fingerprints, memoized by compiled plan: the
        # filter is config as far as the result cache is concerned, so a
        # filtered partial NEVER collides with an unfiltered one (or with a
        # different filter's) for the same (query, generation) pair
        self._filter_cfg_fps: dict = {}
        self._batcher = MicroBatcher(self.cfg.n_q, max_batch, max_delay_s,
                                     clock=clock)
        # queue depth + deadline misses render from the live batcher at
        # snapshot/exposition time (no hot-path mirroring)
        self.metrics.bind_batcher(self._batcher)
        self._plan_factory = plan_factory
        self._staged: Optional[tuple] = None
        self._staged_at: Optional[float] = None   # for the deferred-wait span
        self.update_timeline(timeline)

    # -- timeline lifecycle -------------------------------------------------

    @property
    def timeline(self) -> Timeline:
        """The snapshot currently being served: the plain
        ``ShardedTimeline`` while the service has a single codebook epoch
        (the common case), the full ``EpochedTimeline`` once re-epoching
        has opened more."""
        if len(self._epoched) == 1:
            return self._epoched.epochs[0]
        return self._epoched

    @property
    def epoched(self) -> EpochedTimeline:
        """The snapshot currently being served, always epoch-shaped."""
        return self._epoched

    @property
    def latest_timeline(self) -> EpochedTimeline:
        """The newest accepted snapshot: the STAGED one when a swap is
        waiting for pending queries to drain, else the serving snapshot.
        Mutations (and the maintenance loop) must compose on this — basing
        a new snapshot on the serving one while another is staged would
        silently drop the staged changes."""
        return self._staged[0] if self._staged is not None else self._epoched

    def update_timeline(self, timeline: Timeline) -> None:
        """Swap in a new timeline snapshot — double-buffered.

        The expensive half (per-generation plan builds, fingerprints) runs
        first, against the NEW snapshot, while the current one keeps
        serving; the swap itself is an atomic reference switch. With
        queries pending in the micro-batcher the prepared snapshot is
        STAGED instead and installed when the batcher drains (end of the
        next ``flush``/``poll``/``query``), so a submitted query is always
        answered against the snapshot it was accepted under. Staging twice
        before a flush keeps the LATEST snapshot only.

        No cache flush, ever: entries key on generation CONTENT
        fingerprints, so unchanged generations keep serving from cache and
        changed ones (grown / merged / re-epoched -> new fingerprint)
        recompute — invalidation by construction.
        """
        with trace.span("service.swap.prepare"):
            staged = self._prepare(timeline)
        if len(self._batcher) == 0:
            self._install(staged)
        else:
            self._staged = staged
            self._staged_at = self.clock()

    def _prepare(self, timeline: Timeline) -> tuple:
        """Build everything a swap needs, off the serving path."""
        epoched = EpochedTimeline.of(timeline)
        plans, fps = [], []
        for tl, _ in epoched:
            if self._plan_factory is not None:
                eplans = list(self._plan_factory(tl))
            else:
                eplans = [
                    lambda q, m, f=None, _g=gen, _m=meta, _o=off:
                        retrieve_generation_topk(_g, _m, _o, q, self.cfg, m,
                                                 doc_filter=f)
                    for gen, meta, off in tl]
            if len(eplans) != len(tl):
                raise ValueError(
                    f"plan_factory built {len(eplans)} plan(s) for a "
                    f"{len(tl)}-generation epoch")
            plans.append(eplans)
            fps.append(tl.fingerprints)
        # the snapshot's document-budget signature: None for an all-
        # per-token timeline (config fingerprints stay pre-budget-exact),
        # the budget for one epoch, per-epoch budgets once re-epoching
        # has mixed regimes
        budgets = tuple(tl.metas[0].doc_budget for tl, _ in epoched)
        if all(b is None for b in budgets):
            budget_sig = None
        else:
            budget_sig = budgets[0] if len(budgets) == 1 else budgets
        return (epoched, plans, fps, list(epoched.epoch_offsets),
                budget_sig)

    def _install(self, staged: tuple) -> None:
        """Atomically switch the serving snapshot to a prepared one."""
        swap = hasattr(self, "_epoched")        # constructor install is free
        deferred = self._staged is not None
        if deferred and self._staged_at is not None:
            # how long the prepared snapshot sat behind pending queries
            trace.record("service.swap.deferred_wait",
                         self.clock() - self._staged_at)
        self._staged = None
        self._staged_at = None
        with trace.span("service.swap.install", deferred=deferred):
            (self._epoched, self._plans, self._gen_fps, self._epoch_offsets,
             budget_sig) = staged
            if budget_sig != self._doc_budget or not swap:
                # the budget joins every cache key: pooled and unpooled
                # partials must never collide even when their generation
                # fingerprints coincide (all docs under budget)
                self._doc_budget = budget_sig
                self._cfg_fp = config_fingerprint(self.cfg,
                                                  doc_budget=budget_sig)
                self._filter_cfg_fps = {}
            # only the open generation (last of the live epoch) is mutable
            self._n_cacheable = sum(len(p) for p in self._plans) - 1
        if swap:
            self.metrics.record_swap(deferred=deferred)

    def _maybe_install(self) -> None:
        """Install a staged snapshot once no query is pending against the
        old one — the flush-boundary half of the double buffer."""
        if self._staged is not None and len(self._batcher) == 0:
            self._install(self._staged)

    def add_passages(self, doc_embs: np.ndarray,
                     doc_lens: np.ndarray) -> None:
        """Grow the NEWEST (still-mutable) generation with new passages.

        The grown generation's content fingerprint changes, so its (never
        cached) partials are recomputed with the new docs visible on the
        very next query; older generations' cache entries stay live.
        """
        et = self.latest_timeline
        tl = et.epochs[-1]
        grown, gmeta = store.add_passages(
            tl.generations[-1], tl.metas[-1], doc_embs, doc_lens)
        self.update_timeline(
            et.with_newest_epoch(tl.with_newest(grown, gmeta)))

    def new_generation(self, doc_embs: np.ndarray,
                       doc_lens: np.ndarray) -> None:
        """Freeze the current newest generation and open a fresh one
        (quantized against the LIVE epoch's codebooks).

        From the next query on, the previously-newest generation is
        immutable and therefore CACHEABLE: its partials start populating
        the cache (first lookup per query misses, later ones hit).
        """
        et = self.latest_timeline
        tl = et.epochs[-1]
        gen, meta = store.new_generation(
            tl.generations[0], tl.metas[0], doc_embs, doc_lens)
        self.update_timeline(et.with_newest_epoch(tl.append(gen, meta)))

    # -- query paths --------------------------------------------------------

    def _resolve_filter(self, doc_filter):
        """Normalize a per-query filter to a compiled ``FilterPlan``.

        Accepts ``None`` (unfiltered), an already-compiled ``FilterPlan``
        (validated downstream against each generation's predicate names),
        or a ``FilterExpr`` — compiled here against the SERVING snapshot's
        predicate vocabulary (every generation in a timeline shares one;
        ``ShardedTimeline`` enforces it), so callers can hand the service
        expressions without knowing bit positions."""
        if doc_filter is None or isinstance(doc_filter, bitvector.FilterPlan):
            return doc_filter
        names = self._epoched.epochs[0].metas[0].pred_names
        return bitvector.compile_filter(doc_filter, names)

    def _cfg_fp_for(self, doc_filter) -> str:
        """The config fingerprint for cache keys: the base config's when
        unfiltered, a per-filter one (memoized) when filtered."""
        if doc_filter is None:
            return self._cfg_fp
        fp = self._filter_cfg_fps.get(doc_filter)
        if fp is None:
            fp = config_fingerprint(
                dataclasses.replace(self.cfg, doc_filter=doc_filter),
                doc_budget=self._doc_budget)
            self._filter_cfg_fps[doc_filter] = fp
        return fp

    def query(self, queries, q_masks=None, *,
              doc_filter=None) -> RetrievalResult:
        """Retrieve a ready-made batch, bypassing the micro-batcher.

        queries : (B, t, d) with t <= cfg.n_q (zero-padded up to n_q here),
                  or a :class:`~repro.core.engine.QueryBatch` carrying the
                  mask itself
        q_masks : optional (B, t) bool per-term masks (True = live)
        doc_filter : optional predicate filter applied to the whole batch —
                  a ``bitvector.FilterExpr`` (compiled here against the
                  timeline's predicate names) or a pre-compiled
                  ``FilterPlan``
        -> RetrievalResult (scores (B, k), global doc ids (B, k)) — bit-
        exact to ``retrieve_timeline(timeline, queries, cfg, q_masks,
        doc_filter=doc_filter)``.
        """
        self._maybe_install()
        if isinstance(queries, QueryBatch):
            if q_masks is not None and queries.q_mask is not None:
                raise ValueError(
                    "got a q_mask both inside the QueryBatch and as a "
                    "separate argument — pass exactly one")
            queries, q_masks = queries.q, \
                queries.q_mask if q_masks is None else q_masks
        q = np.asarray(queries, dtype=np.float32)
        if q.ndim != 3:
            raise ValueError(f"queries have shape {q.shape}: expected "
                             "(batch, terms, d)")
        if q.shape[0] == 0:
            raise ValueError(
                "empty query batch (B=0): query() needs at least one "
                "query — guard the caller, or use submit()/flush() for "
                "streams that may be idle")
        padded, masks = [], []
        for i in range(q.shape[0]):
            pq, pm = pad_query(q[i], self.cfg.n_q,
                               None if q_masks is None
                               else np.asarray(q_masks)[i])
            padded.append(pq)
            masks.append(pm)
        return self._execute(np.stack(padded), np.stack(masks),
                             doc_filter=self._resolve_filter(doc_filter))

    def submit(self, query: np.ndarray,
               q_mask: Optional[np.ndarray] = None, *,
               doc_filter=None) -> Ticket:
        """Enqueue one (t, d) query; flushes immediately when the batch
        fills to ``max_batch``. -> a :class:`Ticket` (``result()`` after
        the flush that computes it). ``doc_filter`` (FilterExpr or compiled
        FilterPlan) is resolved NOW — compile errors surface at submit, not
        at flush — and batches only with same-filter neighbors (see
        ``MicroBatcher.drain``)."""
        ticket = self._batcher.submit(query, q_mask,
                                      self._resolve_filter(doc_filter))
        if len(self._batcher) >= self._batcher.max_batch:
            self.flush()
        return ticket

    def flush(self) -> None:
        """Execute ALL pending micro-batches now, filling their tickets;
        then install any staged timeline swap (the batcher is empty — the
        double buffer's safe point)."""
        while True:
            drained = self._batcher.drain()
            if drained is None:
                self._maybe_install()
                return
            qb, tickets, doc_filter = drained
            with trace.span("service.flush", batch=len(tickets)):
                res = self._execute(qb.q, qb.q_mask, doc_filter=doc_filter)
                scores = np.asarray(res.scores)
                ids = np.asarray(res.doc_ids)
                for j, t in enumerate(tickets):
                    t._fill(scores[j], ids[j])

    def poll(self) -> None:
        """Flush iff a pending batch is due (full or past its deadline) —
        the cooperative deadline hook; call it from the serving loop."""
        if self._batcher.due():
            self.flush()
        else:
            self._maybe_install()

    def stats(self) -> dict:
        """Metrics snapshot: traffic + latency + maintenance counters +
        cache bytes + timeline footprint (one dict; see
        ``repro.serving.metrics``)."""
        return self.metrics.snapshot(
            cache=self.cache,
            timeline_footprint=store.timeline_footprint(self.timeline))

    def exposition(self) -> str:
        """The same telemetry as ``stats()`` rendered as Prometheus text
        exposition (cache counters and timeline byte gauges folded in;
        docs/OBSERVABILITY.md documents the metric names,
        scripts/check_metrics_exposition.py lints the format)."""
        return self.metrics.exposition(
            cache=self.cache,
            timeline_footprint=store.timeline_footprint(self.timeline))

    # -- the hit/miss lane split --------------------------------------------

    def _execute(self, q: np.ndarray, masks: np.ndarray, *,
                 doc_filter=None) -> RetrievalResult:
        """Run one dense batch through the per-generation lanes, merge by
        score within each epoch and by rank across epochs. ``doc_filter``
        (a compiled FilterPlan, already resolved) applies to the whole
        batch: it joins the cache key through the config fingerprint and
        rides to every miss-lane plan as the third positional argument."""
        t0 = self.clock()
        n = q.shape[0]
        if n == 0:
            raise ValueError(
                "empty query batch (B=0): nothing to retrieve (the "
                "micro-batcher never drains an empty batch; direct "
                "callers must pass >= 1 query)")
        cfg_fp = self._cfg_fp_for(doc_filter)
        qfps = [query_fingerprint(q[i], masks[i]) for i in range(n)]
        warm = np.full(n, self._n_cacheable > 0)
        n_epochs = len(self._plans)
        epoch_parts = []
        with trace.span("service.execute", batch=n, epochs=n_epochs,
                        filtered=doc_filter is not None):
            for e, (plans, fps, eoff) in enumerate(
                    zip(self._plans, self._gen_fps, self._epoch_offsets)):
                parts = []
                for g, plan in enumerate(plans):
                    # only the live epoch's newest gen is still mutable
                    cacheable = e < n_epochs - 1 or g < len(plans) - 1
                    gen_fp = fps[g]
                    with trace.span("service.generation", epoch=e,
                                    generation=g) as gsp:
                        rows: list = [None] * n
                        miss = []
                        with trace.span("service.cache_lookup",
                                        cacheable=cacheable):
                            for i in range(n):
                                hit = self.cache.get(
                                    (qfps[i], gen_fp, cfg_fp)) \
                                    if cacheable else None
                                if hit is None:
                                    miss.append(i)
                                else:
                                    rows[i] = hit
                        gsp.set(hits=n - len(miss), misses=len(miss))
                        if cacheable:
                            self.metrics.record_generation_lookups(
                                gen_fp, n - len(miss), len(miss))
                        if miss:
                            if cacheable:
                                warm[miss] = False
                            mq, mm = q[miss], masks[miss]
                            padded = self.pad_miss_lane and len(miss) < n
                            if padded:
                                # repeat row 0: 1 compiled shape per cfg
                                pad = n - len(miss)
                                mq = np.concatenate(
                                    [mq, np.repeat(mq[:1], pad, axis=0)])
                                mm = np.concatenate(
                                    [mm, np.repeat(mm[:1], pad, axis=0)])
                            with trace.span("service.miss_execute",
                                            misses=len(miss),
                                            padded=padded):
                                if doc_filter is None:
                                    res = plan(jnp.asarray(mq),
                                               jnp.asarray(mm))
                                else:
                                    res = plan(jnp.asarray(mq),
                                               jnp.asarray(mm), doc_filter)
                                ms = np.asarray(res.scores)[:len(miss)]
                                # epoch-local -> global ids BEFORE caching,
                                # so cached and fresh partials merge
                                # identically (epoch offsets are stable:
                                # compaction and re-epoching both preserve
                                # every surviving doc's global id)
                                mi = np.asarray(res.doc_ids)[:len(miss)] \
                                    + np.int32(eoff)
                            for j, i in enumerate(miss):
                                rows[i] = (ms[j], mi[j])
                                if cacheable:
                                    self.cache.put(
                                        (qfps[i], gen_fp, cfg_fp),
                                        ms[j], mi[j])
                    parts.append(RetrievalResult(
                        jnp.asarray(np.stack([r[0] for r in rows])),
                        jnp.asarray(np.stack([r[1] for r in rows]))))
                with trace.span("service.merge", epoch=e,
                                generations=len(parts)):
                    epoch_parts.append(merge_partial_topk(parts, self.cfg.k))
            with trace.span("service.merge", epochs=n_epochs, final=True):
                merged = epoch_parts[0] if n_epochs == 1 else \
                    merge_partial_topk_by_rank(epoch_parts, self.cfg.k)
                jax.block_until_ready(merged)
        self.metrics.record_batch(n, int(warm.sum()), self.clock() - t0,
                                  n_filtered=0 if doc_filter is None else n)
        return merged
