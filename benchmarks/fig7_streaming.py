"""Streaming-corpus growth (PLAID SHIRTTT-style temporal sharding): latency
and MRR@10 as the corpus grows from 1 to N index generations.

The corpus arrives in equal slices. Generation 0 is a fresh ``build_index``
over the first slice; every later slice becomes an immutable generation via
``store.new_generation`` (quantized against generation 0's FROZEN
centroid/PQ codebooks — no k-means re-run), served as a ``ShardedTimeline``
through ``engine.retrieve_timeline``. Queries plant ground truth across the
WHOLE corpus, so MRR@10 climbs as generations come online while per-query
latency tracks the cost of the per-generation fan-out + merge:

    fig7,streaming,gens=<g>,docs=<n>,retrieve,<us_per_query>,mrr=<m>,drift=x<r>

``drift`` is the newest generation's ``IndexMeta.drift`` (quantization error
vs the gen-0 training baseline — the re-train signal). After the growth
loop, the fully-grown timeline is compacted to ONE generation with
``store.merge_generations`` (the maintenance loop's offline half) and timed
again — the row quantifies how much of the fan-out cost compaction claws
back. (Compaction is bit-exact under cut-lossless budgets; under this
benchmark's TIGHT budgets the merged index selects from one shared pool
where the sharded timeline gave each generation its own — the documented
relative-selection caveat — so the compacted MRR tracks the
monolithic-selection regime, not the gens=N row.)

    fig7,streaming,compacted,docs=<n>,retrieve,<us_per_query>,mrr=<m>

The final row times one monolithic index built over the union corpus at
the same budgets, so the artifact tracks the price of temporal sharding vs
a full re-index — compacted-vs-monolithic isolates what frozen-codebook
quantization costs once the fan-out is gone:

    fig7,streaming,monolithic,docs=<n>,retrieve,<us_per_query>,mrr=<m>
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (EngineConfig, ShardedTimeline, build_index,
                        merge_generations, new_generation, retrieve_timeline)
from repro.core import engine as emvb
from repro.data.synthetic import mrr_at_k

from .common import TH, TH_R, bench_corpus, bench_index, row, time_fn

N_GENS = 4


def run() -> list[str]:
    corpus = bench_corpus("msmarco")
    queries = jnp.asarray(corpus.queries)
    b = queries.shape[0]
    n_docs = corpus.doc_embs.shape[0]
    per = n_docs // N_GENS
    cfg = EngineConfig(k=10, n_filter=512, n_docs=64, th=TH, th_r=TH_R)

    gen0, meta0 = build_index(
        jax.random.PRNGKey(1), corpus.doc_embs[:per], corpus.doc_lens[:per],
        n_centroids=512, m=16, nbits=8, plaid_b=2, kmeans_iters=4)
    timeline = ShardedTimeline.of((gen0, meta0))

    rows = []
    for g in range(1, N_GENS + 1):
        if g > 1:
            lo = (g - 1) * per
            timeline = timeline.append(*new_generation(
                gen0, meta0, corpus.doc_embs[lo:lo + per],
                corpus.doc_lens[lo:lo + per]))
        t = time_fn(lambda tl=timeline: retrieve_timeline(tl, queries, cfg))
        ids = np.asarray(retrieve_timeline(timeline, queries, cfg).doc_ids)
        mrr = mrr_at_k(ids, corpus.gt_doc)
        rows.append(row(
            f"fig7,streaming,gens={g},docs={timeline.n_docs},retrieve",
            t / b * 1e6,
            f"mrr={mrr:.3f},drift=x{timeline.metas[-1].drift:.2f}"))

    # online compaction: merge the N generations back into one (bit-exact,
    # no re-quantization) and measure the reclaimed fan-out latency
    compacted = merge_generations(timeline, 0, len(timeline))
    t = time_fn(lambda: retrieve_timeline(compacted, queries, cfg))
    ids = np.asarray(retrieve_timeline(compacted, queries, cfg).doc_ids)
    rows.append(row(
        f"fig7,streaming,compacted,docs={compacted.n_docs},retrieve",
        t / b * 1e6, f"mrr={mrr_at_k(ids, corpus.gt_doc):.3f}"))

    # the full re-index alternative: one monolithic build over the union
    mono, _ = bench_index("msmarco", m=16)
    t = time_fn(lambda: emvb.retrieve(mono, queries, cfg))
    ids = np.asarray(emvb.retrieve(mono, queries, cfg).doc_ids)
    rows.append(row(f"fig7,streaming,monolithic,docs={n_docs},retrieve",
                    t / b * 1e6,
                    f"mrr={mrr_at_k(ids, corpus.gt_doc):.3f}"))
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
