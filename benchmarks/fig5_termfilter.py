"""Paper Fig. 5 — the Eq. 6 dynamic per-term filter: percentage of original
effectiveness (MRR@10 ratio Eq6/Eq5) and percentage of scored terms, as a
function of th_r.

The scored-term fraction is measured on the documents that actually reach
the late-interaction phase (the engine's phase-3 selection), matching the
paper's setting — on non-candidate documents the fraction is trivially ~0.

Also times the phase-3/4 tail per th_r (p34_* rows): the filter changes how
much PQ work Eq. 6 keeps, so its latency effect shows up here — fused
``kernels/pqinter.py`` megakernel vs the unfused cinter+pqscore kernel pair
vs the XLA-compiled jnp reference.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig
from repro.core import engine as emvb
from repro.core.interaction import scored_term_fraction
from repro.data.synthetic import mrr_at_k

from .common import TH, bench_corpus, bench_index, row, time_fn


def run() -> list[str]:
    corpus = bench_corpus("msmarco")
    queries = np.asarray(corpus.queries)
    idx, _ = bench_index("msmarco", m=16)
    rows = []

    base_cfg = EngineConfig(k=10, th=TH, th_r=None)       # Eq. 5: all terms
    ids = np.asarray(emvb.retrieve(idx, queries, base_cfg).doc_ids)
    base_mrr = mrr_at_k(ids, corpus.gt_doc, 10)
    rows.append(row("fig5,eq5_baseline", 0.0, f"mrr10={base_mrr:.4f},"
                    "terms=100%"))

    # phase-1..3 selection (the docs whose terms phase 4 scores) — one
    # batched pass through the unified entry points
    token_mask = idx.token_mask()
    qb = jnp.asarray(queries[:min(8, len(queries))])
    cs_per_q, bits_b, bmap_b = emvb.phase1_candidates(idx, qb, base_cfg)
    sel1_b = emvb.phase2_prefilter(idx, qb, base_cfg, bits=bits_b,
                                   bitmap=bmap_b)
    sel2_per_q = emvb.phase3_centroid_interaction(idx, qb, base_cfg,
                                                  cs=cs_per_q, sel1=sel1_b)

    # p34 tail latency in the two filter modes (Eq. 5 all-terms vs Eq. 6 at
    # the operating point), one representative query each — every th_r value
    # would recompile the whole phase-3/4 stack per config for no extra
    # signal (the filter mode, not the threshold value, changes the math)
    qb0 = qb[:1]
    cs0, sel1_0 = cs_per_q[:1], sel1_b[:1]

    def p34_rows(th_r):
        rcfg = dataclasses.replace(base_cfg, th_r=th_r)
        fcfg = dataclasses.replace(rcfg, use_kernels=True,
                                   fused_late_interaction=True)
        ucfg = dataclasses.replace(fcfg, fused_late_interaction=False)
        tag = "eq5" if th_r is None else f"eq6,th_r={th_r}"
        for name, cfg in (("unfused_ref", rcfg), ("unfused_kernels", ucfg),
                          ("fused", fcfg)):
            t = time_fn(lambda: emvb.phase34_late_interaction(
                idx, qb0, cfg, cs=cs0, sel1=sel1_0))
            rows.append(row(f"fig5,p34_{name},{tag}", t * 1e6))

    p34_rows(None)
    p34_rows(0.3)
    for th_r in (0.1, 0.2, 0.3, 0.4, 0.5):
        cfg = EngineConfig(k=10, th=TH, th_r=th_r)
        ids = np.asarray(emvb.retrieve(idx, queries, cfg).doc_ids)
        mrr = mrr_at_k(ids, corpus.gt_doc, 10)
        fracs = [float(scored_term_fraction(
            cs.T, jnp.take(idx.codes, sel2, axis=0),
            jnp.take(token_mask, sel2, axis=0), th_r))
            for cs, sel2 in zip(cs_per_q, sel2_per_q)]
        rows.append(row(f"fig5,eq6,th_r={th_r}", 0.0,
                        f"mrr10={mrr:.4f},eff={mrr / base_mrr * 100:.1f}%,"
                        f"terms={np.mean(fracs) * 100:.1f}%"))
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
