"""Constant-space document budgets: quality / latency / bytes-per-doc vs m.

Sweeps the ``doc_budget`` knob (PR 9 tentpole; Constant-Space Multi-Vector
Retrieval) over the scaled MS MARCO-like corpus: each budget point builds
an index whose documents are pooled down to at most ``m`` vectors
(``pool_documents``: deterministic per-doc spherical k-means), then times
retrieval and scores MRR@10 against the planted ground truth. ``m=None``
is the per-token baseline at the SAME build settings, so the sweep isolates
exactly what the budget buys (bytes/doc, latency via the smaller cap) and
what it costs (MRR as pooling gets lossy):

    fig10,budget,m=<m>,docs=<n>,retrieve,<us_per_query>,\
mrr=<q>,bytes_per_doc=<b>,savings=x<s>

``bytes_per_doc`` and ``savings`` come from ``store.generation_footprint``
(the pooled payload vs the per-token counterfactual over
``meta.n_raw_tokens``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, build_index
from repro.core import engine as emvb
from repro.core.store import generation_footprint
from repro.data.synthetic import mrr_at_k

from .common import TH, TH_R, bench_corpus, row, time_fn

BUDGETS = (4, 8, 16, 32, None)


def run() -> list[str]:
    corpus = bench_corpus("msmarco")
    queries = jnp.asarray(corpus.queries)
    b = queries.shape[0]
    n_docs = corpus.doc_embs.shape[0]
    cfg = EngineConfig(k=10, n_filter=512, n_docs=64, th=TH, th_r=TH_R)

    rows = []
    for budget in BUDGETS:
        # same key / geometry at every point: the ONLY variable is m
        idx, meta = build_index(
            jax.random.PRNGKey(0), corpus.doc_embs, corpus.doc_lens,
            n_centroids=512, m=16, nbits=8, plaid_b=2, kmeans_iters=2,
            doc_budget=budget)
        t = time_fn(lambda i=idx: emvb.retrieve(i, queries, cfg))
        ids = np.asarray(emvb.retrieve(idx, queries, cfg).doc_ids)
        fp = generation_footprint(idx, meta)
        rows.append(row(
            f"fig10,budget,m={budget},docs={n_docs},retrieve",
            t / b * 1e6,
            f"mrr={mrr_at_k(ids, corpus.gt_doc):.3f},"
            f"bytes_per_doc={fp['bytes_per_doc']:.1f},"
            f"savings=x{fp['pooling_savings']:.2f}"))
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
