"""Benchmark driver — one module per paper table/figure, plus the roofline
report. ``PYTHONPATH=src python -m benchmarks.run [name ...]``.

Emits ``name,us_per_call,derived`` CSV rows (absolute times are single-core
CPU; the EMVB/PLAID *ratios* are the reproduction target).

``--smoke`` runs the fast default subset (fig1: the phase breakdown, the
fused-vs-unfused megakernel rows and the batched-vs-vmap batch sweep; fig2:
the bit-vector threshold sweep locating the no-recall-loss operating point;
fig4: vectorized-vs-naive set membership and bitfilter-vs-centroid
-interaction; fig6: the query-pruning latency/MRR sweep; fig7: latency +
MRR@10 as the corpus grows 1 -> N streaming generations; fig8:
serving-cache throughput/hit-rate, cold vs warm vs uncached; fig9: the
predicate-filter selectivity sweep, in-kernel vs post-filter; fig10:
the constant-space document-budget sweep, MRR/latency/bytes-per-doc at
m in {4, 8, 16, 32, None}; roofline:
per-megakernel batched-vs-vmap wall time + analytic arithmetic intensity at
B in {1,4,16,64}) and writes the rows to ``BENCH_smoke.json`` — with the
roofline and fig9 suites split out to their own ``BENCH_roofline.json`` /
``BENCH_fig9.json`` so those trajectories are separate CI artifacts —
``--json PATH`` does the same for any suite selection.
BENCH_*.json is gitignored by design — machine-dependent numbers belong in
artifacts, not history.
"""

import argparse
import json
import platform
import sys
import time

from . import (fig1_breakdown, fig2_threshold, fig4_membership,
               fig5_termfilter, fig6_pruning, fig7_streaming, fig8_serving,
               fig9_selectivity, fig10_budget, roofline, table1_msmarco,
               table2_ood)

SUITES = {
    "table1": table1_msmarco,
    "table2": table2_ood,
    "fig1": fig1_breakdown,
    "fig2": fig2_threshold,
    "fig4": fig4_membership,
    "fig5": fig5_termfilter,
    "fig6": fig6_pruning,
    "fig7": fig7_streaming,
    "fig8": fig8_serving,
    "fig9": fig9_selectivity,
    "fig10": fig10_budget,
    "roofline": roofline,
}
SMOKE_SUITES = ["fig1", "fig2", "fig4", "fig6", "fig7", "fig8", "fig9",
                "fig10", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.run", description=__doc__)
    # nargs="*" + choices rejects the empty default in this argparse
    # version, so membership is checked by hand below
    ap.add_argument("names", nargs="*", metavar="name",
                    help=f"suites to run: {', '.join(SUITES)} "
                         "(default: all, or the smoke subset)")
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset + write BENCH_smoke.json (CI artifact)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the rows to this JSON file")
    args = ap.parse_args()
    unknown = [n for n in args.names if n not in SUITES]
    if unknown:
        ap.error(f"unknown suite(s): {', '.join(unknown)}")
    names = args.names or (SMOKE_SUITES if args.smoke else list(SUITES))

    results, timings = {}, {}
    for name in names:
        mod = SUITES[name]
        t0 = time.time()
        print(f"# === {name} ({mod.__name__}) ===", flush=True)
        rows = mod.run()
        for line in rows:
            print(line, flush=True)
        timings[name] = time.time() - t0
        results[name] = rows
        print(f"# {name} done in {timings[name]:.0f}s", flush=True)

    json_path = args.json or ("BENCH_smoke.json" if args.smoke else None)
    if json_path:
        import jax

        meta = {
            "unix_time": int(time.time()),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "python": platform.python_version(),
            "machine": platform.machine(),
            "argv": sys.argv[1:],
        }
        # the roofline and fig9 suites ship as their own artifacts (the
        # kernel-lane and filter-lane perf trajectories) next to the figure
        # smoke rows — the CI upload glob (BENCH_*.json) covers all three
        if args.smoke:
            for split, path in (("roofline", "BENCH_roofline.json"),
                                ("fig9", "BENCH_fig9.json")):
                if split not in results:
                    continue
                payload = {"suites": {split: results.pop(split)},
                           "suite_seconds":
                               {split: round(timings.pop(split), 1)},
                           "meta": meta}
                with open(path, "w") as f:
                    json.dump(payload, f, indent=1)
                print(f"# wrote {path}", flush=True)
        payload = {
            "suites": results,
            "suite_seconds": {k: round(v, 1) for k, v in timings.items()},
            "meta": meta,
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {json_path}", flush=True)


if __name__ == "__main__":
    main()
