"""Benchmark driver — one module per paper table/figure, plus the roofline
report. ``PYTHONPATH=src python -m benchmarks.run [name ...]``.

Emits ``name,us_per_call,derived`` CSV rows (absolute times are single-core
CPU; the EMVB/PLAID *ratios* are the reproduction target).
"""
from __future__ import annotations

import sys
import time

from . import (fig1_breakdown, fig2_threshold, fig4_membership,
               fig5_termfilter, roofline, table1_msmarco, table2_ood)

SUITES = {
    "table1": table1_msmarco,
    "table2": table2_ood,
    "fig1": fig1_breakdown,
    "fig2": fig2_threshold,
    "fig4": fig4_membership,
    "fig5": fig5_termfilter,
    "roofline": roofline,
}


def main() -> None:
    names = sys.argv[1:] or list(SUITES)
    for name in names:
        mod = SUITES[name]
        t0 = time.time()
        print(f"# === {name} ({mod.__name__}) ===", flush=True)
        for line in mod.run():
            print(line, flush=True)
        print(f"# {name} done in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
