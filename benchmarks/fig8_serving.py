"""Serving-layer benchmark: throughput + cache hit-rate on a Zipf-repeated
query stream, cold vs warm, against the uncached ``retrieve_timeline``
baseline.

Real query traffic is heavily repeated (head queries dominate — modeled
here as Zipf(s=1.1) draws from the query pool), and on a ``ShardedTimeline``
every generation but the newest is immutable — so the serving cache
(``repro.serving``) should converge to serving G-1 of G generations from
host memory and computing only the newest. Rows:

    fig8,serving,uncached,docs=<n>,gens=<G>,<us_per_query>
    fig8,serving,cold,<us_per_query>,hit_rate=<r>
    fig8,serving,warm,<us_per_query>,hit_rate=<r>,speedup=x<s>,p50_ms=...
    fig8,serving,traced,<us_per_query>,overhead=x<o>,spans=<n>
    fig8,serving,footprint,0.0,cache_kb=<c>,timeline_mb=<t>,bpe=<b>

``speedup`` is uncached/warm per-query time on the SAME stream — the
acceptance signal (>1x: the cache pays for itself on repeated traffic).
``traced`` reruns the warm stream under a live span tracer
(docs/OBSERVABILITY.md); ``overhead`` = traced/warm per-query time, the
acceptance number for "tracing enabled stays cheap", and the captured
spans + summary land in ``BENCH_trace.json`` (CI artifact, same upload
glob as the other BENCH files). The footprint row carries the byte
accounting (cache occupancy + timeline footprint incl. manifest overhead)
that capacity planning needs.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import (EngineConfig, ShardedTimeline, build_index,
                        new_generation, retrieve_timeline, timeline_footprint)
from repro.serving import RetrievalService

from .common import TH, TH_R, bench_corpus, row

TRACE_PATH = "BENCH_trace.json"

N_GENS = 4
PER_GEN = 512
BATCH = 8
N_BATCHES = 12
ZIPF_S = 1.1


def _zipf_stream(n_queries: int, seed: int = 0) -> np.ndarray:
    """(N_BATCHES, BATCH) query indices, Zipf-weighted over the pool."""
    ranks = np.arange(1, n_queries + 1, dtype=np.float64)
    p = ranks ** -ZIPF_S
    p /= p.sum()
    rng = np.random.default_rng(seed)
    return rng.choice(n_queries, size=(N_BATCHES, BATCH), p=p)


def _time_stream(fn, batches) -> float:
    """Seconds per query for fn(batch) over the whole stream (min of 3)."""
    totals = []
    for _ in range(3):
        t0 = time.perf_counter()
        for b in batches:
            jax.block_until_ready(fn(b))
        totals.append(time.perf_counter() - t0)
    return min(totals) / (len(batches) * batches[0].shape[0])


def run() -> list[str]:
    corpus = bench_corpus("msmarco")
    queries = np.asarray(corpus.queries)
    cfg = EngineConfig(k=10, n_filter=256, n_docs=64, th=TH, th_r=TH_R)

    gen0, meta0 = build_index(
        jax.random.PRNGKey(1), corpus.doc_embs[:PER_GEN],
        corpus.doc_lens[:PER_GEN], n_centroids=512, m=16, nbits=8,
        plaid_b=2, kmeans_iters=4)
    timeline = ShardedTimeline.of((gen0, meta0))
    for g in range(1, N_GENS):
        lo = g * PER_GEN
        timeline = timeline.append(*new_generation(
            gen0, meta0, corpus.doc_embs[lo:lo + PER_GEN],
            corpus.doc_lens[lo:lo + PER_GEN]))

    stream = _zipf_stream(queries.shape[0])
    batches = [queries[idx] for idx in stream]

    # uncached baseline: the one-shot merge path on every batch
    t_base = _time_stream(
        lambda b: retrieve_timeline(timeline, jnp.asarray(b), cfg), batches)
    rows = [row(f"fig8,serving,uncached,docs={timeline.n_docs},"
                f"gens={len(timeline)}", t_base * 1e6)]

    # cold pass: empty cache fills as the stream arrives (single pass — a
    # cold cache is a one-time event, min-of-3 would measure a warm one)
    svc = RetrievalService(timeline, cfg)
    t0 = time.perf_counter()
    for b in batches:
        jax.block_until_ready(svc.query(b))
    t_cold = (time.perf_counter() - t0) / (len(batches) * BATCH)
    cold_hit = svc.cache.stats()["hit_rate"]
    rows.append(row("fig8,serving,cold", t_cold * 1e6,
                    f"hit_rate={cold_hit:.2f}"))

    # warm pass: same stream again — immutable generations now cached
    t_warm = _time_stream(lambda b: svc.query(b), batches)
    stats = svc.stats()
    rows.append(row(
        "fig8,serving,warm", t_warm * 1e6,
        f"hit_rate={stats['cache']['hit_rate']:.2f},"
        f"speedup=x{t_base / t_warm:.2f},"
        f"p50_ms={stats['warm_latency']['p50_ms']:.2f},"
        f"p99_ms={stats['warm_latency']['p99_ms']:.2f}"))

    # traced pass: the SAME warm stream under a live tracer — results are
    # bit-exact with tracing on (spans never touch values), so the only
    # signal is the time delta
    with obs.tracing(capacity=32768) as tracer:
        t_traced = _time_stream(lambda b: svc.query(b), batches)
    spans = tracer.finished()
    overhead = t_traced / t_warm
    rows.append(row("fig8,serving,traced", t_traced * 1e6,
                    f"overhead=x{overhead:.2f},spans={len(spans)}"))

    by_name: dict = {}
    for sp in spans:
        agg = by_name.setdefault(sp["name"], {"count": 0, "total_s": 0.0})
        agg["count"] += 1
        agg["total_s"] += sp["duration_s"]
    with open(TRACE_PATH, "w") as f:
        json.dump({
            "summary": {
                "warm_us_per_query": t_warm * 1e6,
                "traced_us_per_query": t_traced * 1e6,
                "overhead": overhead,
                "spans": len(spans),
                "dropped": tracer.dropped,
                "by_name": {k: {"count": v["count"],
                                "total_ms": v["total_s"] * 1e3}
                            for k, v in sorted(by_name.items())},
            },
            "spans": spans,
        }, f, indent=1, default=str)

    fp = timeline_footprint(timeline)
    rows.append(row(
        "fig8,serving,footprint", 0.0,
        f"cache_kb={stats['cache']['bytes'] / 1024:.1f},"
        f"timeline_mb={fp['total_bytes'] / 2**20:.1f},"
        f"bpe={fp['bytes_per_embedding']:.1f},"
        f"bpe_actual={fp['bytes_per_embedding_actual']:.1f}"))
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
