"""Query-embedding pruning sweep (Tonellotto & Macdonald, 2021) — the speed
knob end-to-end query-term masking unlocks on top of EMVB's pipeline (PLAID
has no analogue).

``prune_queries(q, keep)`` drops the least-important query terms and returns
the physically smaller (B, keep, d) query plus its term mask; every per-term
tensor in all four phases shrinks with it (CS rows, stacked bit-vector bits,
S̄ rows, LUT rows). Rows report batch retrieval latency AND MRR@10 per
``keep`` level, so the CI artifact tracks the latency/quality trade-off:

    fig6,prune,keep=<K>,retrieve,<us_per_query>,mrr=<m>,speedup=x<s>

keep = n_q (32) is the unpruned baseline the speedups are measured against.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig, prune_queries
from repro.core import engine as emvb
from repro.data.synthetic import mrr_at_k

from .common import TH, TH_R, bench_corpus, bench_index, row, time_fn

KEEP_LEVELS = (32, 24, 16, 8)


def run() -> list[str]:
    corpus = bench_corpus("msmarco")
    queries = jnp.asarray(corpus.queries)                # (B, 32, d)
    idx, _ = bench_index("msmarco", m=16)
    cfg = EngineConfig(k=10, n_filter=512, n_docs=64, th=TH, th_r=TH_R)
    b = queries.shape[0]

    rows = []
    base_t = None
    for keep in KEEP_LEVELS:
        qp, qm = prune_queries(queries, keep)
        t = time_fn(lambda qp=qp, qm=qm: emvb.retrieve(idx, qp, cfg, qm))
        ids = np.asarray(emvb.retrieve(idx, qp, cfg, qm).doc_ids)
        mrr = mrr_at_k(ids, corpus.gt_doc)
        if base_t is None:
            base_t = t                                   # keep == n_q
        rows.append(row(f"fig6,prune,keep={keep},retrieve", t / b * 1e6,
                        f"mrr={mrr:.3f},speedup=x{base_t / t:.2f}"))
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
