"""Paper Table 2 — out-of-domain (LoTTE-like) evaluation with OPQ.

JMPQ needs training queries, so (as in the paper) the OOD index uses OPQ and
only m=32. Metrics: Success@5 / Success@100; latency ratios vs PLAID. The
OOD corpus has longer documents — the regime where the paper reports the
pre-filter pays off the most (2.9x).
"""
from __future__ import annotations

import numpy as np

from repro.core import EngineConfig, PlaidConfig
from repro.core import engine as emvb_engine
from repro.core import plaid as plaid_engine
from repro.data.synthetic import success_at_k

from .common import TH, TH_R, bench_corpus, bench_index, row, time_fn


def run() -> list[str]:
    corpus = bench_corpus("ood")
    queries = np.asarray(corpus.queries)
    idx, _ = bench_index("ood", m=32, use_opq=True)
    rows = []
    for k in (10, 100, 1000):
        pcfg = PlaidConfig(k=k, n_docs=max(64, k), nprobe=4)
        ecfg = EngineConfig(k=k, n_filter=max(512, 2 * k),
                            n_docs=max(64, k), nprobe=4, th=TH, th_r=TH_R)
        t_p = time_fn(lambda: plaid_engine.retrieve(idx, queries, pcfg))
        ids_p = np.asarray(plaid_engine.retrieve(idx, queries, pcfg).doc_ids)
        t_e = time_fn(lambda: emvb_engine.retrieve(idx, queries, ecfg))
        ids_e = np.asarray(emvb_engine.retrieve(idx, queries, ecfg).doc_ids)
        nq = len(corpus.gt_doc)
        for name, t, ids, extra in (
                ("plaid", t_p, ids_p, "baseline"),
                ("emvb_m32_opq", t_e, ids_e, f"x{t_p / t_e:.2f}")):
            s5 = success_at_k(ids, corpus.gt_doc, 5)
            s100 = success_at_k(ids, corpus.gt_doc, 100) if k >= 100 \
                else float("nan")
            rows.append(row(f"table2,k={k},{name}", t / nq * 1e6,
                            f"s5={s5:.3f},s100={s100:.3f},{extra}"))
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
