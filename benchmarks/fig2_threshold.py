"""Paper Fig. 2 — (left) R@100 of the bit-vector pre-filter vs threshold th
for several pre-filter sizes, against the no-prefilter centroid-interaction
baseline; (right) time to build close_i^th with the different algorithms.

The paper's four builders are AVX512 variants (Naive IF / Vectorized IF /
Branchless / VecBranchless). The TPU-native analogues compared here:
  numpy_if       — python/numpy row scan with an if (the naive baseline)
  numpy_where    — vectorized masked extraction (the "vectorized IF")
  jnp_branchless — dense threshold+shift+OR bitpack (branchless by
                   construction; our production path, core/bitvector.py)
  pallas_bitpack — the Pallas kernel (interpret mode on CPU)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EngineConfig
from repro.core import engine as emvb
from repro.core.bitvector import build_bitvectors
from repro.data.synthetic import recall_at_k
from repro.kernels import ops

from .common import bench_corpus, bench_index, row, time_fn


def _left(rows: list[str]) -> None:
    corpus = bench_corpus("msmarco")
    queries = np.asarray(corpus.queries)
    idx, _ = bench_index("msmarco", m=16)
    # no-prefilter baseline: n_filter = whole corpus (centroid interaction on
    # every candidate, PLAID-style reference line in the figure)
    base_cfg = EngineConfig(k=100, n_filter=idx.codes.shape[0], n_docs=128,
                            th=-1.0, th_r=None)
    ids = np.asarray(emvb.retrieve(idx, queries, base_cfg).doc_ids)
    base = recall_at_k(ids, corpus.gt_doc, 100)
    rows.append(row("fig2l,baseline_full,th=-1", 0.0, f"r100={base:.3f}"))
    for n_filter in (256, 512, 1024):
        for th in (0.0, 0.2, 0.3, 0.4, 0.5, 0.6):
            cfg = EngineConfig(k=100, n_filter=n_filter, n_docs=128, th=th,
                               th_r=None)
            ids = np.asarray(emvb.retrieve(idx, queries, cfg).doc_ids)
            r = recall_at_k(ids, corpus.gt_doc, 100)
            rows.append(row(f"fig2l,nf={n_filter},th={th}", 0.0,
                            f"r100={r:.3f},delta={r - base:+.3f}"))


def _right(rows: list[str]) -> None:
    rng = np.random.default_rng(0)
    n_q, n_c = 32, 4096
    cs_np = rng.normal(size=(n_q, n_c)).astype(np.float32) * 0.4
    cs = jnp.asarray(cs_np)

    def numpy_if(th):
        out = []
        for i in range(n_q):
            sel = []
            for j in range(n_c):            # the paper's "Naive IF"
                if cs_np[i, j] > th:
                    sel.append(j)
            out.append(sel)
        return out

    def numpy_where(th):
        return [np.nonzero(cs_np[i] > th)[0] for i in range(n_q)]

    jnp_pack = jax.jit(build_bitvectors, static_argnums=1)

    for th in (0.0, 0.3, 0.5):
        t0 = time.perf_counter(); numpy_if(th)
        t_if = time.perf_counter() - t0
        t0 = time.perf_counter(); numpy_where(th)
        t_where = time.perf_counter() - t0
        t_jnp = time_fn(lambda: jnp_pack(cs, th))
        t_pl = time_fn(lambda: ops.bitpack(cs, th))
        rows.append(row(f"fig2r,numpy_if,th={th}", t_if * 1e6))
        rows.append(row(f"fig2r,numpy_where,th={th}", t_where * 1e6,
                        f"x{t_if / t_where:.1f}_vs_if"))
        rows.append(row(f"fig2r,jnp_branchless,th={th}", t_jnp * 1e6,
                        f"x{t_if / t_jnp:.1f}_vs_if"))
        rows.append(row(f"fig2r,pallas_bitpack,th={th}", t_pl * 1e6,
                        "interpret-mode"))


def run() -> list[str]:
    rows: list[str] = []
    _left(rows)
    _right(rows)
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
