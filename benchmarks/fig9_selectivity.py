"""Fig. 9 (ours) — predicate-filter selectivity sweep: in-kernel filtering
vs retrieve-then-post-filter, as the filter keeps fewer documents.

Faceted retrieval has two honest implementations. **In-kernel** ANDs the
predicate plane into the candidate bitmap inside phase 2 and masks
non-passing survivors to -inf in phases 3-4 (docs/FILTERING.md) — budgets
stay at their unfiltered operating point because every selection slot is
spent on passing docs. **Post-filter** retrieves unfiltered and drops
non-passing results on the host — to still deliver k passing docs at
selectivity s it must inflate the retrieval depth to ~k/s (and the
phase-3/4 budgets with it), so its cost grows as 1/s while the in-kernel
lane's stays flat. The sweep measures exactly that crossover; the derived
column reports how many of the k slots each lane actually filled with
passing docs (post-filtering an undersized depth silently starves).

Both lanes run the jnp reference engine AND the fused megakernel path
(interpret mode on this container — ratios, not absolute times, carry).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import EngineConfig
from repro.core import engine as emvb
from repro.core.bitvector import Pred, PredicateSet, compile_filter

from .common import TH, TH_R, bench_corpus, bench_index, row, time_fn

SELECTIVITIES = (0.9, 0.5, 0.1, 0.02)
N_QUERIES = 4      # timed batch; the sweep's signal is per-selectivity cost
SAFETY = 2         # post-filter depth head-room over the expected k/s


def _pred_index():
    """The bench index with one synthetic predicate per swept selectivity
    (bit i of the plane = "doc passes the selectivity-i filter")."""
    idx, meta = bench_index("msmarco", m=16)
    n_docs = int(idx.codes.shape[0])
    rng = np.random.default_rng(9)
    preds = {f"sel{int(s * 100):02d}": rng.random(n_docs) < s
             for s in SELECTIVITIES}
    ps = PredicateSet.pack(preds)
    return (idx._replace(pred_words=ps.words),
            dataclasses.replace(meta, pred_names=ps.names), ps)


def run() -> list[str]:
    corpus = bench_corpus("msmarco")
    idx, meta, ps = _pred_index()
    n_docs = int(idx.codes.shape[0])
    queries = np.asarray(corpus.queries[:N_QUERIES])
    rows: list[str] = []

    base = EngineConfig(k=10, th=TH, th_r=TH_R)
    kernel = dict(use_kernels=True, fused_prefilter=True,
                  fused_late_interaction=True, batched_kernels=True)

    for s in SELECTIVITIES:
        name = f"sel{int(s * 100):02d}"
        plan = compile_filter(Pred(name), meta.pred_names)
        pass_np = np.asarray(ps.mask(name))

        def filled(ids):
            """Mean fraction of the k result slots holding passing docs."""
            keep = pass_np[np.asarray(ids)]
            return float(keep.mean())

        # post-filter depth: expected k/s passing docs per k_post retrieved,
        # with head-room; budgets inflate with it (that inflation IS the cost)
        k_post = min(n_docs, SAFETY * math.ceil(base.k / s))
        post = dataclasses.replace(
            base, k=k_post, n_docs=max(base.n_docs, k_post),
            n_filter=min(n_docs, max(base.n_filter, 2 * k_post)))

        for lane, kw in (("ref", {}), ("fused", kernel)):
            fcfg = dataclasses.replace(base, doc_filter=plan, **kw)
            pcfg = dataclasses.replace(post, **kw)
            t_in = time_fn(lambda: emvb.retrieve(idx, queries, fcfg))
            ids_in = np.asarray(emvb.retrieve(idx, queries, fcfg).doc_ids)

            def post_filter():
                res = emvb.retrieve(idx, queries, pcfg)
                ids = np.asarray(res.doc_ids)
                out = np.zeros((ids.shape[0], base.k), np.int32)
                for b in range(ids.shape[0]):
                    keep = ids[b][pass_np[ids[b]]]
                    out[b, :len(keep[:base.k])] = keep[:base.k]
                return out
            t_post = time_fn(post_filter)
            ids_post = post_filter()

            rows.append(row(f"fig9,inkernel_{lane},s={s}", t_in * 1e6,
                            f"filled={filled(ids_in) * 100:.0f}%"))
            rows.append(row(f"fig9,postfilter_{lane},s={s},k_post={k_post}",
                            t_post * 1e6,
                            f"x{t_post / t_in:.2f},"
                            f"filled={filled(ids_post) * 100:.0f}%"))
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
