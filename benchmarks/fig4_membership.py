"""Paper Fig. 4 — (up) vectorized vs naive fast-set-membership; (down) our
bit-vector pre-filter vs PLAID's centroid interaction, for growing candidate
set sizes.

"Naive" set membership probes each token's centroid id against n_q separate
boolean sets (one per query term, numpy loop). "Vectorized" is the stacked
uint32 bitvector: one gather + OR-reduce + popcount for all 32 terms at once
(core/bitvector.py), the TPU analogue of the paper's single-word trick.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitvector import build_bitvectors, filter_score
from repro.core.interaction import centroid_interaction

from .common import TH, bench_corpus, bench_index, row, time_fn


def run() -> list[str]:
    rows: list[str] = []
    corpus = bench_corpus("msmarco")
    idx, _ = bench_index("msmarco", m=16)
    q = jnp.asarray(corpus.queries[0])
    cs = q @ idx.centroids.T
    bits = build_bitvectors(cs, TH)
    mask_np = np.asarray(idx.token_mask())
    codes_np = np.asarray(idx.codes)
    close_np = np.asarray(cs) > TH                        # (n_q, n_c) bool

    jit_filter = jax.jit(filter_score)
    jit_cinter = jax.jit(centroid_interaction)

    for n_docs in (256, 1024, 4096):
        codes = idx.codes[:n_docs]
        mask = idx.token_mask()[:n_docs]

        # -- up: naive (per-term set probes, numpy) vs vectorized bitvector --
        def naive():
            f = np.zeros(n_docs, np.int32)
            for p in range(n_docs):
                valid = codes_np[p][mask_np[p]]
                for i in range(close_np.shape[0]):
                    if close_np[i][valid].any():
                        f[p] += 1
            return f
        t0 = time.perf_counter(); f_naive = naive()
        t_naive = time.perf_counter() - t0
        t_vec = time_fn(lambda: jit_filter(bits, codes, mask))
        f_vec = np.asarray(jit_filter(bits, codes, mask))
        assert (f_naive == f_vec).all(), "naive and vectorized disagree"
        rows.append(row(f"fig4up,naive,nd={n_docs}", t_naive * 1e6))
        rows.append(row(f"fig4up,vectorized,nd={n_docs}", t_vec * 1e6,
                        f"x{t_naive / t_vec:.1f}"))

        # -- down: our pre-filter vs PLAID centroid interaction --------------
        t_plaid = time_fn(lambda: jit_cinter(cs.T, codes, mask))
        rows.append(row(f"fig4dn,plaid_cinter,nd={n_docs}", t_plaid * 1e6))
        rows.append(row(f"fig4dn,emvb_bitfilter,nd={n_docs}", t_vec * 1e6,
                        f"x{t_plaid / t_vec:.1f}"))
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
