"""Paper Table 1 — EMVB vs PLAID on the (scaled) MS MARCO-like corpus.

Columns: k, method, latency (us/query), bytes/embedding (scaled index +
paper-constant formula), MRR@10, R@100, R@1000. Latencies are single-core CPU
wall times of the jit'd engines — the *ratios* EMVB/PLAID reproduce the
paper's comparison; absolute numbers are not paper numbers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import EngineConfig, PlaidConfig, bytes_per_embedding
from repro.core import engine as emvb_engine
from repro.core import plaid as plaid_engine
from repro.core.index import IndexMeta
from repro.data.synthetic import mrr_at_k, recall_at_k

from .common import TH, TH_R, bench_corpus, bench_index, row, time_fn

# paper-constant bytes/embedding (|C|=2^18 -> 4-byte centroid id, d=128)
_PAPER_BYTES = {("emvb", 16): 20, ("emvb", 32): 36, ("plaid", 2): 36}


def _engine_cfg(k: int) -> EngineConfig:
    return EngineConfig(k=k, n_filter=max(512, 2 * k), n_docs=max(64, k),
                        nprobe=4, th=TH, th_r=TH_R)


def _plaid_cfg(k: int) -> PlaidConfig:
    return PlaidConfig(k=k, n_docs=max(64, k), nprobe=4)


def run() -> list[str]:
    corpus = bench_corpus("msmarco")
    queries = np.asarray(corpus.queries)
    rows = []
    for k in (10, 100, 1000):
        ecfg, pcfg = _engine_cfg(k), _plaid_cfg(k)

        # --- PLAID baseline ---------------------------------------------
        idx16, meta = bench_index("msmarco", m=16)
        t_p = time_fn(lambda: plaid_engine.retrieve(idx16, queries, pcfg))
        res_p = plaid_engine.retrieve(idx16, queries, pcfg)
        ids_p = np.asarray(res_p.doc_ids)
        rows.append(_row(k, "plaid", t_p, meta, "plaid", 2, ids_p, corpus))

        # --- EMVB m = 16 / 32 --------------------------------------------
        for m in (16, 32):
            idx, meta = bench_index("msmarco", m=m)
            t_e = time_fn(lambda: emvb_engine.retrieve(idx, queries, ecfg))
            res_e = emvb_engine.retrieve(idx, queries, ecfg)
            ids_e = np.asarray(res_e.doc_ids)
            rows.append(_row(k, f"emvb_m{m}", t_e, meta, "emvb", m, ids_e,
                             corpus, speedup=t_p / t_e))

        # --- beyond-paper: per-token compaction (TPU-adapted C4) ----------
        ccfg = dataclasses.replace(ecfg, compact_cap=16)
        idx, meta = bench_index("msmarco", m=16)
        t_c = time_fn(lambda: emvb_engine.retrieve(idx, queries, ccfg))
        ids_c = np.asarray(emvb_engine.retrieve(idx, queries, ccfg).doc_ids)
        rows.append(_row(k, "emvb_m16_compact", t_c, meta, "emvb", 16, ids_c,
                         corpus, speedup=t_p / t_c))
    return rows


def _row(k: int, name: str, t: float, meta: IndexMeta, method: str, m: int,
         ids: np.ndarray, corpus, speedup: float | None = None) -> str:
    nq = len(corpus.gt_doc)
    mrr = mrr_at_k(ids, corpus.gt_doc, 10)
    r100 = recall_at_k(ids, corpus.gt_doc, 100) if k >= 100 else float("nan")
    r1000 = recall_at_k(ids, corpus.gt_doc, 1000) if k >= 1000 else float("nan")
    scaled_bytes = bytes_per_embedding(meta, method)
    paper_bytes = _PAPER_BYTES[(method, m)] if method == "emvb" \
        else _PAPER_BYTES[("plaid", 2)]
    per_q_us = t / nq * 1e6
    extra = f"x{speedup:.2f}" if speedup else "baseline"
    return row(f"table1,k={k},{name}", per_q_us,
               f"bytes={scaled_bytes:.0f}(paper:{paper_bytes}),"
               f"mrr10={mrr:.3f},r100={r100:.3f},r1000={r1000:.3f},{extra}")


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
