"""Per-kernel roofline for the two megakernels — the compiled-Mosaic lane.

For each megakernel (``kernels/prefilter.py`` phases 1-2, ``kernels/
pqinter.py`` phases 3-4) at B in {1, 4, 16, 64}:

  * **measured** wall time of the batch-native launch
    (``cfg.batched_kernels``, ONE launch for the whole batch) vs the
    per-query vmap path — bit-exact by the engine contract, so the speedup
    column isolates launch + operand-reload amortization;
  * **analytic** bytes moved and FLOPs from the index/config shapes (the
    op-count model is documented inline), hence arithmetic intensity
    AI = FLOPs/byte against the TPU v5e ridge
    (197 TF/s bf16 / 819 GB/s HBM -> ~240 FLOP/byte), the bound side, and
    ``t_v5e_us`` — the roofline-limited wall time a compiled Mosaic launch
    cannot beat. Interpret-mode (CPU) measured times are NOT comparable to
    ``t_v5e_us``; the analytic columns are the TPU expectation, the
    measured ratio is the portable signal.

Why the two kernels amortize differently: the prefilter's big operands
(packed codes + token mask) are index-resident and shared by every query —
batching divides their traffic by B (``ai`` rises with B, ``ai_vmap`` is
flat). pqinter's operands (per-query LUT, per-query phase-2 gathers) all
carry the batch dimension, so its bytes are the same either way and the
batched win is purely fewer grid launches (the interpret-mode per-step
overhead CPU numbers overweight, and Mosaic launch overhead on TPU).

A second section renders results/dryrun.json (written by
``repro.launch.dryrun``) as the per-(arch x shape x mesh) three-term table
used in EXPERIMENTS.md §Roofline, when that file exists.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os

import numpy as np

from repro.core import EngineConfig
from repro.core import engine as emvb
from repro.launch.analysis import HBM_BW, PEAK_FLOPS

from .common import TH, TH_R, bench_corpus, bench_index, row, time_fn

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results",
                      "dryrun.json")
BATCH_SIZES = (1, 4, 16, 64)
RIDGE = PEAK_FLOPS / HBM_BW          # FLOP/byte where v5e turns compute-bound


# ---------------------------------------------------------------------------
# Analytic traffic/op model. One "FLOP" = one compare/shift/max/add lane op;
# top-k merges are charged log2(list length) ops per scored element.
# ---------------------------------------------------------------------------

def _prefilter_model(idx, cfg: EngineConfig, b: int, n_q: int):
    """-> (flops, bytes_batched, bytes_vmap) for the phase-1/2 megakernel
    (score_all mode: packed codes + token mask + bitmap stream per block)."""
    n_c = idx.centroids.shape[0]
    n_docs, cap = idx.codes.shape
    shared = idx.codes.nbytes + idx.token_mask().nbytes   # index-resident
    per_q = (n_q * n_c * 4            # CS, VMEM-resident for the launch
             + n_docs * 1             # candidate bitmap (bool)
             + n_c * 4                # packed Eq. 4 bit words (out)
             + 2 * cfg.n_filter * 4)  # top-n_filter scores + ids (out)
    flops = b * (3 * n_q * n_c                       # bit-pack: cmp,shl,add
                 + n_docs * (cap + 5)                # gather+OR+popcount+key
                 + n_docs * math.ceil(math.log2(max(cfg.n_filter, 2))))
    return flops, shared + b * per_q, b * (shared + per_q)


def _pqinter_model(idx, cfg: EngineConfig, b: int, n_q: int):
    """-> (flops, bytes_batched, bytes_vmap) for the phase-3/4 megakernel.
    Every operand is per-query (LUT, phase-2 gathers), so bytes_batched ==
    bytes_vmap — batching buys launch amortization, not traffic."""
    n_c = idx.centroids.shape[0]
    cap = idx.codes.shape[1]
    m, ksub, _ = idx.pq_codebooks.shape
    nf, nd, k = cfg.n_filter, cfg.n_docs, cfg.k
    per_q = (n_c * n_q * 4            # CS^T, VMEM-resident for the launch
             + n_q * m * ksub * 4     # per-query PQ look-up table
             + nf * cap * (4 + m + 1)  # sel1 codes (i32) + res (u8) + mask
             + 2 * k * 4)             # final top-k scores + ids (out)
    flops = b * (nf * (2 * cap * n_q + n_q)          # pass 1: S-bar (Eq. 2)
                 + nf * math.ceil(math.log2(max(nd, 2)))   # phase-3 top-k
                 + nd * cap * (m + 2 * n_q)          # pass 2: Eq. 5/6
                 + nd * math.ceil(math.log2(max(k, 2))))
    return flops, b * per_q, b * per_q


def _roofline_row(tag: str, t_b: float, t_v: float, flops: float,
                  by_b: float, by_v: float) -> str:
    ai, ai_v = flops / by_b, flops / by_v
    t_v5e = max(flops / PEAK_FLOPS, by_b / HBM_BW)
    return row(tag, t_b * 1e6,
               f"vmap_us={t_v * 1e6:.1f},speedup=x{t_v / t_b:.2f},"
               f"mflops={flops / 1e6:.1f},mb={by_b / 1e6:.2f},"
               f"mb_vmap={by_v / 1e6:.2f},ai={ai:.1f},ai_vmap={ai_v:.1f},"
               f"bound={'compute' if ai > RIDGE else 'memory'},"
               f"t_v5e_us={t_v5e * 1e6:.1f}")


def kernel_rooflines(batch_sizes=BATCH_SIZES) -> list[str]:
    corpus = bench_corpus("msmarco")
    idx, _ = bench_index("msmarco", m=16)
    queries = np.asarray(corpus.queries)
    n_q = queries.shape[1]
    bcfg = EngineConfig(k=10, n_filter=512, n_docs=64, th=TH, th_r=TH_R,
                        use_kernels=True, fused_prefilter=True,
                        fused_late_interaction=True)
    vcfg = dataclasses.replace(bcfg, batched_kernels=False)
    rows = [f"# ridge={RIDGE:.0f} FLOP/byte (v5e {PEAK_FLOPS / 1e12:.0f}"
            f" TF/s bf16, {HBM_BW / 1e9:.0f} GB/s HBM); measured times are"
            " this machine's kernel mode, t_v5e_us is the compiled bound"]
    for b in batch_sizes:
        reps = -(-b // len(queries))         # tile 32 queries up to B=64
        qb = np.tile(queries, (reps, 1, 1))[:b] if reps > 1 else queries[:b]
        t12b = time_fn(lambda: emvb.phase12_prefilter(idx, qb, bcfg))
        t12v = time_fn(lambda: emvb.phase12_prefilter(idx, qb, vcfg))
        cs, sel1 = emvb.phase12_prefilter(idx, qb, bcfg)
        t34b = time_fn(lambda: emvb.phase34_late_interaction(
            idx, qb, bcfg, cs=cs, sel1=sel1))
        t34v = time_fn(lambda: emvb.phase34_late_interaction(
            idx, qb, vcfg, cs=cs, sel1=sel1))
        rows.append(_roofline_row(
            f"roofline,prefilter,B={b}", t12b, t12v,
            *_prefilter_model(idx, bcfg, b, n_q)))
        rows.append(_roofline_row(
            f"roofline,pqinter,B={b}", t34b, t34v,
            *_pqinter_model(idx, bcfg, b, n_q)))
    return rows


# ---------------------------------------------------------------------------
# Secondary section: the launch-plan roofline table over results/dryrun.json
# ---------------------------------------------------------------------------

def load(path: str = DRYRUN) -> list[dict]:
    with open(path) as f:
        recs = [r for r in json.load(f) if "error" not in r]
    _refresh_model_flops(recs)
    return recs


def _refresh_model_flops(recs: list[dict]) -> None:
    """Recompute the MODEL_FLOPS-derived fields from the current formulas
    (repro.launch.modelflops) — the raw compiled terms in dryrun.json never
    go stale, but the useful-flops accounting has been refined since some
    cells were recorded."""
    from repro.configs import registry
    from repro.launch.modelflops import model_flops
    for r in recs:
        try:
            mf = model_flops(registry.get(r["arch"]), r["shape"])
        except KeyError:
            continue
        if mf is None or not r.get("flops_per_chip"):
            continue
        r["model_flops_total"] = mf
        r["useful_flops_ratio"] = mf / (r["flops_per_chip"] * r["chips"])
        r["roofline_fraction"] = (mf / r["chips"] / PEAK_FLOPS) / \
            max(r["bound_s"], 1e-30)


def table(records: list[dict]) -> list[str]:
    hdr = ("cell", "mesh", "t_comp_ms", "t_mem_ms", "t_coll_ms", "dominant",
           "useful_flops", "roofline_frac")
    rows = [",".join(hdr)]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rows.append(",".join([
            f"{r['arch']}/{r['shape']}", r["mesh"],
            f"{r['t_compute_s'] * 1e3:.1f}", f"{r['t_memory_s'] * 1e3:.1f}",
            f"{r['t_collective_s'] * 1e3:.1f}", r["dominant"],
            f"{(r.get('useful_flops_ratio') or 0) * 100:.0f}%",
            f"{(r.get('roofline_fraction') or 0) * 100:.1f}%",
        ]))
    return rows


def dryrun_rows() -> list[str]:
    recs = load()
    out = table(recs)
    n_dom = {"compute": 0, "memory": 0, "collective": 0}
    for r in recs:
        n_dom[r["dominant"]] += 1
    out.append(f"summary,cells={len(recs)},compute-bound={n_dom['compute']},"
               f"memory-bound={n_dom['memory']},"
               f"collective-bound={n_dom['collective']}")
    return out


def run() -> list[str]:
    rows = kernel_rooflines()
    if os.path.exists(DRYRUN):
        rows += dryrun_rows()
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
