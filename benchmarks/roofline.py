"""Roofline report — renders results/dryrun.json (written by
``repro.launch.dryrun``) as the per-(arch x shape x mesh) three-term table
used in EXPERIMENTS.md §Roofline.

  compute    = HLO_FLOPs/chip / 197 TF/s      (TPU v5e bf16)
  memory     = HLO_bytes/chip / 819 GB/s
  collective = link_bytes/chip / 50 GB/s
"""
from __future__ import annotations

import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "results",
                      "dryrun.json")


def load(path: str = DRYRUN) -> list[dict]:
    with open(path) as f:
        recs = [r for r in json.load(f) if "error" not in r]
    _refresh_model_flops(recs)
    return recs


def _refresh_model_flops(recs: list[dict]) -> None:
    """Recompute the MODEL_FLOPS-derived fields from the current formulas
    (repro.launch.modelflops) — the raw compiled terms in dryrun.json never
    go stale, but the useful-flops accounting has been refined since some
    cells were recorded."""
    from repro.configs import registry
    from repro.launch.analysis import PEAK_FLOPS
    from repro.launch.modelflops import model_flops
    for r in recs:
        try:
            mf = model_flops(registry.get(r["arch"]), r["shape"])
        except KeyError:
            continue
        if mf is None or not r.get("flops_per_chip"):
            continue
        r["model_flops_total"] = mf
        r["useful_flops_ratio"] = mf / (r["flops_per_chip"] * r["chips"])
        r["roofline_fraction"] = (mf / r["chips"] / PEAK_FLOPS) / \
            max(r["bound_s"], 1e-30)


def table(records: list[dict]) -> list[str]:
    hdr = ("cell", "mesh", "t_comp_ms", "t_mem_ms", "t_coll_ms", "dominant",
           "useful_flops", "roofline_frac")
    rows = [",".join(hdr)]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rows.append(",".join([
            f"{r['arch']}/{r['shape']}", r["mesh"],
            f"{r['t_compute_s'] * 1e3:.1f}", f"{r['t_memory_s'] * 1e3:.1f}",
            f"{r['t_collective_s'] * 1e3:.1f}", r["dominant"],
            f"{(r.get('useful_flops_ratio') or 0) * 100:.0f}%",
            f"{(r.get('roofline_fraction') or 0) * 100:.1f}%",
        ]))
    return rows


def run() -> list[str]:
    recs = load()
    out = table(recs)
    n_dom = {"compute": 0, "memory": 0, "collective": 0}
    for r in recs:
        n_dom[r["dominant"]] += 1
    out.append(f"summary,cells={len(recs)},compute-bound={n_dom['compute']},"
               f"memory-bound={n_dom['memory']},"
               f"collective-bound={n_dom['collective']}")
    return out


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
