"""Paper Fig. 1 — breakdown of PLAID query latency across its four phases
(retrieval, filtering, decompression, late interaction), for k = 10/100/1000,
plus the same breakdown for EMVB's four phases for contrast, plus the
fused-vs-unfused megakernel comparisons at both ends of the pipeline:

  * phases 1-2: the ``kernels/prefilter.py`` megakernel (one launch, no
    full-corpus intermediates) against the separate phase1_candidates +
    phase2_prefilter launches it replaces (p12_* rows);
  * phases 3-4: the ``kernels/pqinter.py`` megakernel (one launch: centroid
    interaction + phase-3 selection + Eq. 5/6 PQ scoring + final top-k)
    against the cinter -> top_k -> gather -> pqscore -> top_k composition it
    replaces (p34_* rows). ``p34_unfused_ref`` is the interpret-free
    XLA-compiled jnp path; the ``*_kernels``/``*_fused`` rows run the Pallas
    kernels in the session's kernel mode (interpret on CPU, Mosaic on TPU —
    only the TPU numbers are launch-overhead-faithful).

Plus the batch sweep (batch_sweep rows): full fused ``retrieve`` on the
batch-native megakernels (ONE launch per phase pair for the whole batch,
``cfg.batched_kernels``) against the per-query vmap path at B in {1, 4, 16}
— the batched-vs-vmap speedup is the perf signal that replaces
interpret-mode fused-vs-unfused guesses (the two paths are bit-exact, so
the ratio is pure launch/operand-reload amortization). Per-kernel rooflines
for the same sweep live in ``benchmarks/roofline.py``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import EngineConfig, PlaidConfig
from repro.core import engine as emvb
from repro.core import plaid

from .common import TH, TH_R, bench_corpus, bench_index, row, time_fn


def run() -> list[str]:
    corpus = bench_corpus("msmarco")
    q = np.asarray(corpus.queries[0])            # single query (paper: per-q)
    idx, _ = bench_index("msmarco", m=16)
    rows = []
    for k in (10, 100, 1000):
        pcfg = PlaidConfig(k=k, n_docs=max(64, k))
        cs, bitmap = plaid.phase_retrieval(idx, q, pcfg)
        sel2 = plaid.phase_filtering(idx, cs, bitmap, pcfg)
        emb = plaid.phase_decompression(idx, sel2)
        t1 = time_fn(lambda: plaid.phase_retrieval(idx, q, pcfg))
        t2 = time_fn(lambda: plaid.phase_filtering(idx, cs, bitmap, pcfg))
        t3 = time_fn(lambda: plaid.phase_decompression(idx, sel2))
        t4 = time_fn(lambda: plaid.phase_late_interaction(idx, q, emb, sel2, k))
        for name, t in (("retrieval", t1), ("filtering", t2),
                        ("decompression", t3), ("late_interaction", t4)):
            rows.append(row(f"fig1,plaid,k={k},{name}", t * 1e6))

        ecfg = EngineConfig(k=k, n_filter=max(512, 2 * k), n_docs=max(64, k),
                            th=TH, th_r=TH_R)
        qb = q[None]                         # the unified convention batches
        cs, bits, bmap = emvb.phase1_candidates(idx, qb, ecfg)
        sel1 = emvb.phase2_prefilter(idx, qb, ecfg, bits=bits, bitmap=bmap)
        sel2e = emvb.phase3_centroid_interaction(idx, qb, ecfg, cs=cs,
                                                 sel1=sel1)
        e1 = time_fn(lambda: emvb.phase1_candidates(idx, qb, ecfg))
        e2 = time_fn(lambda: emvb.phase2_prefilter(idx, qb, ecfg, bits=bits,
                                                   bitmap=bmap))
        e3 = time_fn(lambda: emvb.phase3_centroid_interaction(
            idx, qb, ecfg, cs=cs, sel1=sel1))
        e4 = time_fn(lambda: emvb.phase4_late_interaction(
            idx, qb, ecfg, cs=cs, sel2=sel2e))
        for name, t in (("candidates", e1), ("bitvector_prefilter", e2),
                        ("centroid_interaction", e3), ("pq_maxsim", e4)):
            rows.append(row(f"fig1,emvb,k={k},{name}", t * 1e6))

        # fused-vs-unfused phases 1-2: the prefilter megakernel in one
        # launch vs the two separate phase entry points above
        fcfg = dataclasses.replace(ecfg, use_kernels=True,
                                   fused_prefilter=True)
        ucfg = dataclasses.replace(fcfg, fused_prefilter=False)
        ef = time_fn(lambda: emvb.phase12_prefilter(idx, qb, fcfg))
        eu = time_fn(lambda: emvb.phase12_prefilter(idx, qb, ucfg))
        rows.append(row(f"fig1,emvb,k={k},p12_unfused_ref", (e1 + e2) * 1e6))
        rows.append(row(f"fig1,emvb,k={k},p12_unfused_kernels", eu * 1e6))
        rows.append(row(f"fig1,emvb,k={k},p12_fused", ef * 1e6))

        # fused-vs-unfused phases 3-4: the pqinter megakernel in one launch
        # vs the cinter + top_k + gather + pqscore + top_k composition
        f34 = dataclasses.replace(ecfg, use_kernels=True,
                                  fused_late_interaction=True)
        u34 = dataclasses.replace(f34, fused_late_interaction=False)
        ef34 = time_fn(lambda: emvb.phase34_late_interaction(
            idx, qb, f34, cs=cs, sel1=sel1))
        eu34 = time_fn(lambda: emvb.phase34_late_interaction(
            idx, qb, u34, cs=cs, sel1=sel1))
        rows.append(row(f"fig1,emvb,k={k},p34_unfused_ref", (e3 + e4) * 1e6))
        rows.append(row(f"fig1,emvb,k={k},p34_unfused_kernels", eu34 * 1e6))
        rows.append(row(f"fig1,emvb,k={k},p34_fused", ef34 * 1e6))
        rows.append(row(f"fig1,emvb,k={k},p34_fused_speedup_vs_kernels", 0.0,
                        f"x{eu34 / ef34:.2f}"))
    rows += batch_sweep(idx, np.asarray(corpus.queries))
    return rows


def batch_sweep(idx, queries: np.ndarray,
                batch_sizes: tuple[int, ...] = (1, 4, 16)) -> list[str]:
    """Fused retrieve, batch-native megakernels vs per-query vmap, per B.

    Bit-exact by the engine contract, so the ratio isolates what batching
    buys: ONE kernel launch per phase pair with the index-resident operands
    loaded once, vs B launches each re-reading them. B=1 rides the vmap
    path by design (the dispatch falls back), so its speedup is ~x1.
    """
    bcfg = EngineConfig(k=10, n_filter=512, n_docs=64, th=TH, th_r=TH_R,
                        use_kernels=True, fused_prefilter=True,
                        fused_late_interaction=True)
    vcfg = dataclasses.replace(bcfg, batched_kernels=False)
    rows = []
    for b in batch_sizes:
        qb = np.asarray(queries[:b])
        tb = time_fn(lambda: emvb.retrieve(idx, qb, bcfg), iters=3)
        tv = time_fn(lambda: emvb.retrieve(idx, qb, vcfg), iters=3)
        rows.append(row(f"fig1,batch_sweep,B={b},retrieve_batched", tb * 1e6,
                        f"per_q_us={tb / b * 1e6:.1f}"))
        rows.append(row(f"fig1,batch_sweep,B={b},retrieve_vmap", tv * 1e6,
                        f"per_q_us={tv / b * 1e6:.1f}"))
        rows.append(row(f"fig1,batch_sweep,B={b},batched_speedup", 0.0,
                        f"x{tv / tb:.2f}"))
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
