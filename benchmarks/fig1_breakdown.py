"""Paper Fig. 1 — breakdown of PLAID query latency across its four phases
(retrieval, filtering, decompression, late interaction), for k = 10/100/1000,
plus the same breakdown for EMVB's four phases for contrast, plus the
fused-vs-unfused megakernel comparisons at both ends of the pipeline:

  * phases 1-2: the ``kernels/prefilter.py`` megakernel (one launch, no
    full-corpus intermediates) against the separate phase1_candidates +
    phase2_prefilter launches it replaces (p12_* rows);
  * phases 3-4: the ``kernels/pqinter.py`` megakernel (one launch: centroid
    interaction + phase-3 selection + Eq. 5/6 PQ scoring + final top-k)
    against the cinter -> top_k -> gather -> pqscore -> top_k composition it
    replaces (p34_* rows). ``p34_unfused_ref`` is the interpret-free
    XLA-compiled jnp path; the ``*_kernels``/``*_fused`` rows run the Pallas
    kernels in the session's kernel mode (interpret on CPU, Mosaic on TPU —
    only the TPU numbers are launch-overhead-faithful).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import EngineConfig, PlaidConfig
from repro.core import engine as emvb
from repro.core import plaid

from .common import TH, TH_R, bench_corpus, bench_index, row, time_fn


def run() -> list[str]:
    corpus = bench_corpus("msmarco")
    q = np.asarray(corpus.queries[0])            # single query (paper: per-q)
    idx, _ = bench_index("msmarco", m=16)
    rows = []
    for k in (10, 100, 1000):
        pcfg = PlaidConfig(k=k, n_docs=max(64, k))
        cs, bitmap = plaid.phase_retrieval(idx, q, pcfg)
        sel2 = plaid.phase_filtering(idx, cs, bitmap, pcfg)
        emb = plaid.phase_decompression(idx, sel2)
        t1 = time_fn(lambda: plaid.phase_retrieval(idx, q, pcfg))
        t2 = time_fn(lambda: plaid.phase_filtering(idx, cs, bitmap, pcfg))
        t3 = time_fn(lambda: plaid.phase_decompression(idx, sel2))
        t4 = time_fn(lambda: plaid.phase_late_interaction(idx, q, emb, sel2, k))
        for name, t in (("retrieval", t1), ("filtering", t2),
                        ("decompression", t3), ("late_interaction", t4)):
            rows.append(row(f"fig1,plaid,k={k},{name}", t * 1e6))

        ecfg = EngineConfig(k=k, n_filter=max(512, 2 * k), n_docs=max(64, k),
                            th=TH, th_r=TH_R)
        cs, bits, bmap = emvb.phase1_candidates(idx, q, ecfg)
        sel1 = emvb.phase2_prefilter(idx, bits, bmap, ecfg)
        sel2e = emvb.phase3_centroid_interaction(idx, cs, sel1, ecfg)
        e1 = time_fn(lambda: emvb.phase1_candidates(idx, q, ecfg))
        e2 = time_fn(lambda: emvb.phase2_prefilter(idx, bits, bmap, ecfg))
        e3 = time_fn(lambda: emvb.phase3_centroid_interaction(
            idx, cs, sel1, ecfg))
        e4 = time_fn(lambda: emvb.phase4_late_interaction(
            idx, q, cs, sel2e, ecfg))
        for name, t in (("candidates", e1), ("bitvector_prefilter", e2),
                        ("centroid_interaction", e3), ("pq_maxsim", e4)):
            rows.append(row(f"fig1,emvb,k={k},{name}", t * 1e6))

        # fused-vs-unfused phases 1-2: the prefilter megakernel in one
        # launch vs the two separate phase entry points above
        fcfg = dataclasses.replace(ecfg, use_kernels=True,
                                   fused_prefilter=True)
        ucfg = dataclasses.replace(fcfg, fused_prefilter=False)
        ef = time_fn(lambda: emvb.phase12_prefilter(idx, q, fcfg))
        eu = time_fn(lambda: emvb.phase12_prefilter(idx, q, ucfg))
        rows.append(row(f"fig1,emvb,k={k},p12_unfused_ref", (e1 + e2) * 1e6))
        rows.append(row(f"fig1,emvb,k={k},p12_unfused_kernels", eu * 1e6))
        rows.append(row(f"fig1,emvb,k={k},p12_fused", ef * 1e6))

        # fused-vs-unfused phases 3-4: the pqinter megakernel in one launch
        # vs the cinter + top_k + gather + pqscore + top_k composition
        f34 = dataclasses.replace(ecfg, use_kernels=True,
                                  fused_late_interaction=True)
        u34 = dataclasses.replace(f34, fused_late_interaction=False)
        ef34 = time_fn(lambda: emvb.phase34_late_interaction(
            idx, q, cs, sel1, f34))
        eu34 = time_fn(lambda: emvb.phase34_late_interaction(
            idx, q, cs, sel1, u34))
        rows.append(row(f"fig1,emvb,k={k},p34_unfused_ref", (e3 + e4) * 1e6))
        rows.append(row(f"fig1,emvb,k={k},p34_unfused_kernels", eu34 * 1e6))
        rows.append(row(f"fig1,emvb,k={k},p34_fused", ef34 * 1e6))
        rows.append(row(f"fig1,emvb,k={k},p34_fused_speedup_vs_kernels", 0.0,
                        f"x{eu34 / ef34:.2f}"))
    return rows


def main() -> None:
    print("\n".join(run()))


if __name__ == "__main__":
    main()
