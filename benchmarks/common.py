"""Shared benchmark utilities: a scaled MS MARCO-like corpus + timing.

This container is 1 CPU core — absolute times are NOT paper times; the
benchmarks reproduce the paper's *structure* (same tables, same columns, same
ratios under comparison) at a scaled corpus, plus derived columns where the
paper's constants apply (bytes/embedding uses the exact formula).
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

from repro.core import build_index
from repro.data.synthetic import make_corpus, make_ood_corpus

_CACHE = {}

# Threshold calibration: the paper's th=0.4 / th_r=0.5 are tuned to the
# ColBERTv2-on-MS-MARCO centroid-score distribution (2^18 centroids). The
# synthetic corpus at 1024 centroids has a colder score distribution; our own
# Fig.-2-left sweep (fig2_threshold.py) locates its no-recall-loss point at
# th=0.2 — the same operating point the paper picks on its curve.
TH, TH_R = 0.2, 0.3


def bench_corpus(kind: str = "msmarco"):
    """Scaled corpora: 4k docs, 48-token cap (in-domain) / longer docs (OOD,
    the paper's LoTTE observation)."""
    if kind in _CACHE:
        return _CACHE[kind]
    if kind == "msmarco":
        c = make_corpus(7, n_docs=4096, cap=48, min_len=16, n_queries=32,
                        n_topics=128)
    else:
        c = make_ood_corpus(8, n_docs=2048, n_queries=32, n_topics=128)
    _CACHE[kind] = c
    return c


def bench_index(kind: str = "msmarco", m: int = 16, use_opq: bool = False):
    key = (kind, m, use_opq)
    if key in _CACHE:
        return _CACHE[key]
    c = bench_corpus(kind)
    idx, meta = build_index(
        jax.random.PRNGKey(0), c.doc_embs, c.doc_lens, n_centroids=1024,
        m=m, nbits=8, plaid_b=2, kmeans_iters=4, use_opq=use_opq)
    _CACHE[key] = (idx, meta)
    return idx, meta


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time (seconds) of a jit'd callable; blocks on results."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"
